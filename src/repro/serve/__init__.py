"""Serving substrate: prefill / decode steps and the batched engine."""
from repro.serve.steps import (
    decode_serve_step,
    make_serve_cache,
    prefill_serve_step,
    cache_shardings,
)

__all__ = [
    "make_serve_cache",
    "prefill_serve_step",
    "decode_serve_step",
    "cache_shardings",
]
