"""Prefill / decode steps for the inference shapes.

``decode_32k`` and ``long_500k`` lower :func:`decode_serve_step` — ONE new
token against a cache of ``seq_len`` — while ``prefill_32k`` lowers the
batched :func:`prefill_serve_step`.

KV-cache sharding: the cache dominates decode memory (e.g.
llama-3.2-vision-90b at decode_32k holds ~1.7 TB of global KV), so full-
attention caches shard their *sequence* dimension over the 'model' axis
in addition to batch over DP — decode attention is a cache-bandwidth
problem and sequence sharding parallelizes exactly the cache reads (XLA
inserts the cross-shard softmax reductions).  Ring-buffer (sliding-
window) caches and recurrent states are O(window)/O(1) and stay
batch-sharded only.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.model import decode_step, init_cache, prefill
from repro.sharding import logical_rules, rules_pjit


def make_serve_cache(
    cfg: ArchConfig,
    batch: int,
    max_len: int,
    *,
    dtype=jnp.bfloat16,
    prefill_chunk: int = 1,
):
    return init_cache(cfg, batch, max_len, dtype=dtype, prefill_chunk=prefill_chunk)


def prefill_serve_step(
    params,
    tokens: jax.Array,
    cache,
    *,
    cfg: ArchConfig,
    memory: Optional[jax.Array] = None,
    multi_pod: bool = False,
    unroll: bool = False,
) -> Tuple[jax.Array, Dict]:
    """Batched prompt ingestion; returns (last-position logits, cache)."""
    with logical_rules(rules_pjit(multi_pod, fsdp=False)):
        return prefill(params, cfg, tokens, cache, memory=memory, unroll=unroll)


def decode_serve_step(
    params,
    token: jax.Array,          # [B] int32
    cache,
    pos,                       # scalar int32 — absolute position
    *,
    cfg: ArchConfig,
    kv_length: Optional[jax.Array] = None,
    multi_pod: bool = False,
    unroll: bool = False,
) -> Tuple[jax.Array, Dict]:
    """One decode step: [B] token ids in, [B, V] logits + new cache out."""
    with logical_rules(rules_pjit(multi_pod, fsdp=False)):
        return decode_step(params, cfg, token, cache, pos, kv_length=kv_length,
                           unroll=unroll)


# ---------------------------------------------------------------------------
# Cache shardings (for jit in_shardings / dry-run specs)
# ---------------------------------------------------------------------------
def _cache_leaf_spec(path_keys, shape, dp, model_axis: str, mesh) -> P:
    """Batch over DP; full-attention cache *sequence* over 'model' when it
    tiles (decode is cache-bandwidth-bound; sequence sharding parallelizes
    the cache reads); everything else replicated.

    Scan-stacked cache leaves (under the 'stack' subtree) carry a leading
    period dim, shifting batch to dim 1 and sequence to dim 2.
    """
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh_shape.get(a, 1)
    specs = [None] * len(shape)
    bdim = 1 if "stack" in path_keys else 0
    if (
        len(shape) > bdim
        and shape[bdim] % max(dp_size, 1) == 0
        and shape[bdim] >= dp_size
    ):
        specs[bdim] = dp
    name = path_keys[-1] if path_keys else ""
    seq_sharded_names = ("k", "v", "ckv", "krope")
    sdim = bdim + 1
    msize = mesh_shape.get(model_axis, 1)
    if (
        name in seq_sharded_names
        and "cross" not in name
        and len(shape) > sdim
        and shape[sdim] % msize == 0
        and shape[sdim] >= msize
    ):
        specs[sdim] = model_axis
    return P(*specs)


def _path_keys(path):
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        else:
            keys.append(str(p))
    return tuple(keys)


def cache_specs(cache, mesh, multi_pod: bool = False):
    dp = ("pod", "data") if multi_pod else "data"
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_spec(
            _path_keys(path), tuple(leaf.shape), dp, "model", mesh
        ),
        cache,
    )


def cache_shardings(cache, mesh, multi_pod: bool = False):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), cache_specs(cache, mesh, multi_pod)
    )
