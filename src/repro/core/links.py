"""Per-link communication cost models (heterogeneous-links lever).

DeFT's third lever prices a secondary (slow) link with one scalar
``mu`` — a pure inverse-bandwidth ratio.  Real multi-NIC links differ in
*both* startup latency and bandwidth (MG-WFBP's ``alpha + beta * n``
merge model), and a chain-routed ring schedule adds per-hop permutation
rounds that behave like latency, not like bandwidth.  :class:`LinkModel`
carries both terms; everything downstream (simulator FIFO links,
scheduler knapsack pricing, planner candidate scoring, calibration,
attribution) prices link ``l`` through ``LinkModel.time``.

Durations are *nominal primary-link seconds* — the bucket cost model
(``HardwareModel.allreduce_time``) already converts bytes to seconds at
primary-link speed, so ``inv_bw`` is a ratio relative to that link and
the legacy scalar model is exactly ``LinkModel(0.0, mu)``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Transfer cost ``latency + duration * inv_bw`` on one link.

    ``inv_bw``   — inverse-bandwidth factor relative to the primary link
                   (>1 = slower; the legacy ``mu``).
    ``latency``  — fixed per-transfer startup cost in seconds; on a
                   chain-routed link it absorbs the ring schedule's
                   per-hop permutation rounds.

    Zero or negative durations cost nothing (no transfer issued).
    """

    latency: float = 0.0
    inv_bw: float = 1.0

    def time(self, duration: float) -> float:
        if duration <= 0.0:
            return 0.0
        return self.latency + duration * self.inv_bw

    @staticmethod
    def pair_from_mu(mu: float) -> Dict[int, "LinkModel"]:
        """The legacy two-link model: unit primary, ``mu``-scaled
        secondary, no latency term."""
        return {0: LinkModel(0.0, 1.0), 1: LinkModel(0.0, mu)}


def effective_mu(models: Dict[int, LinkModel]) -> float:
    """Scalar ``mu`` equivalent of a two-link model (secondary inverse
    bandwidth over primary's) — the backward-compatible summary consumed
    by code that still thinks in ratios."""
    p = models.get(0, LinkModel())
    s = models.get(1, LinkModel())
    return s.inv_bw / max(p.inv_bw, 1e-12)
