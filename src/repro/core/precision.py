"""Per-bucket wire precision as a scheduling lever (DESIGN.md §13).

The DeFT knapsack prices communication items in seconds derived from
bytes; historically every layer of this repo assumed 4 bytes/element on
the wire (``Bucket.bytes_fp32``).  :class:`PrecisionPolicy` makes the
byte width a first-class, per-bucket decision the planner can trade
against capacity exactly like k-seq and partition changes:

* ``wire[b]`` names the dtype bucket ``b``'s gradients (and, on the
  decoupled sharded engine, its parameter all-gather) travel in — one
  of ``f32`` (4 B), ``bf16`` (2 B), ``int8`` (1 B, blockwise-scaled).
* ``master`` names the resident dtype of the flat parameter/moment
  buffers — ``f32`` (exact) or ``bf16sr`` (stochastic-rounded bf16
  master, halving resident state for the 236B/400B memory envelope).

Pricing rule: a collective's latency term is size-independent, so only
the bandwidth term scales::

    t(policy) = latency + (t_f32 - latency) * wire_bytes / 4

Preserver gate: quantization adds zero-mean noise to each applied
update.  We fold it into the Gaussian-walk check by inflating the walk's
``sigma`` with the byte-weighted mean relative quantization error
(:func:`precision_walk`); a policy is adoptable only when
``check_schedule`` still passes under the inflated noise — the same
accept band that gates k-seq and partition changes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from repro.core.bucket import BucketTimes
from repro.core.preserver import (
    PreserverVerdict,
    WalkParams,
    check_schedule,
    rollout,
    verdict_ok,
)

# Wire dtypes, cheapest-first for the planner's downgrade ladder.
WIRE_DTYPES: Tuple[str, ...] = ("f32", "bf16", "int8")
WIRE_BYTES: Dict[str, int] = {"f32": 4, "bf16": 2, "int8": 1}
MASTER_DTYPES: Tuple[str, ...] = ("f32", "bf16sr")

# Conservative per-element RELATIVE quantization noise (std / magnitude)
# used only for the Preserver's sigma inflation — not an accuracy claim.
# bf16 keeps 8 mantissa bits -> rounding step 2^-8 of the value, uniform
# rounding noise std = step/sqrt(12); int8 blockwise (scale = amax/127)
# rounds in steps of amax/127, and amax/|x| is bounded by the block's
# dynamic range — 1/127/sqrt(12) per unit amax is the honest per-element
# bound we inflate with (elements far below amax see relatively more).
WIRE_REL_NOISE: Dict[str, float] = {
    "f32": 0.0,
    "bf16": (2.0 ** -8) / (12.0 ** 0.5),
    "int8": (1.0 / 127.0) / (12.0 ** 0.5),
}

# How strongly relative quantization noise couples into the walk's sigma.
# The walk's sigma is per-example step noise; gradient quantization noise
# is proportional to the step itself, so the coupling is multiplicative
# on sigma with a safety gain (calibrated coarse: int8 everywhere at the
# default eps=0.01 band must NOT pass for an aggressive k-sequence).
PRECISION_SIGMA_GAIN: float = 40.0

# The size-independent latency floor of one collective (matches the
# +20us term in HardwareModel.allreduce_time).
COLLECTIVE_LATENCY_S: float = 20e-6


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Per-bucket wire dtypes + resident master dtype.

    ``wire`` is indexed by bucket position (0-based, matching
    ``BucketTimes``/``BucketLayout`` order).  Hashable and frozen so it
    can ride on :class:`~repro.train.bucketing.BucketLayout` and key the
    runtime's phase cache.
    """

    wire: Tuple[str, ...]
    master: str = "f32"

    def __post_init__(self):
        object.__setattr__(self, "wire", tuple(self.wire))
        self.validate()

    @staticmethod
    def uniform(n_buckets: int, wire: str = "f32",
                master: str = "f32") -> "PrecisionPolicy":
        return PrecisionPolicy(wire=(wire,) * n_buckets, master=master)

    def validate(self, n_buckets: Optional[int] = None) -> None:
        for w in self.wire:
            if w not in WIRE_BYTES:
                raise ValueError(
                    f"unknown wire dtype {w!r}; choose from {WIRE_DTYPES}"
                )
        if self.master not in MASTER_DTYPES:
            raise ValueError(
                f"unknown master dtype {self.master!r}; "
                f"choose from {MASTER_DTYPES}"
            )
        if n_buckets is not None and len(self.wire) != n_buckets:
            raise ValueError(
                f"policy covers {len(self.wire)} buckets, layout has "
                f"{n_buckets}"
            )

    # ---- queries --------------------------------------------------------
    def wire_bytes_per_elem(self, b: int) -> int:
        return WIRE_BYTES[self.wire[b]]

    @property
    def n(self) -> int:
        return len(self.wire)

    @property
    def mixed(self) -> bool:
        return len(set(self.wire)) > 1

    @property
    def all_f32(self) -> bool:
        return all(w == "f32" for w in self.wire) and self.master == "f32"

    def describe(self) -> str:
        """Compact human tag, e.g. ``bf16x3+int8x2/f32`` or ``f32``."""
        counts: Dict[str, int] = {}
        for w in self.wire:
            counts[w] = counts.get(w, 0) + 1
        wires = "+".join(
            f"{w}x{counts[w]}" if counts[w] > 1 else w
            for w in WIRE_DTYPES if w in counts
        )
        return wires if self.master == "f32" else f"{wires}/{self.master}"

    def with_wire(self, b: int, wire: str) -> "PrecisionPolicy":
        new = list(self.wire)
        new[b] = wire
        return dataclasses.replace(self, wire=tuple(new))


def scale_comm_time(t_f32: float, bytes_per_elem: int,
                    latency_s: float = COLLECTIVE_LATENCY_S) -> float:
    """Re-price one collective's f32 duration at a narrower wire width.

    Only the bandwidth term shrinks; the latency floor is fixed.  A
    duration already at/below the floor (tiny bucket) is returned as-is.
    """
    bw_term = t_f32 - latency_s
    if bw_term <= 0.0:
        return t_f32
    return latency_s + bw_term * (bytes_per_elem / 4.0)


def apply_wire_precision(
    times: BucketTimes,
    policy: PrecisionPolicy,
    latency_s: float = COLLECTIVE_LATENCY_S,
) -> BucketTimes:
    """Price a profiled :class:`BucketTimes` at the policy's wire widths.

    Everything downstream (knapsack capacities, ``rs_times``/``ag_times``
    split, the timeline simulator) consumes seconds, so this is the ONE
    place precision enters the planning pipeline.
    """
    policy.validate(times.n)
    comm = tuple(
        scale_comm_time(times.comm[b], policy.wire_bytes_per_elem(b),
                        latency_s)
        for b in range(times.n)
    )
    return dataclasses.replace(times, comm=comm)


def wire_bytes_total(
    elems: Sequence[int], policy: Optional[PrecisionPolicy]
) -> int:
    """Total wire bytes for per-bucket element counts under a policy
    (f32 when ``policy`` is None) — the obs layer's planned-bytes side."""
    if policy is None:
        return 4 * sum(elems)
    policy.validate(len(tuple(elems)))
    return sum(n * policy.wire_bytes_per_elem(b)
               for b, n in enumerate(elems))


def quantization_noise_factor(
    policy: PrecisionPolicy,
    weights: Optional[Sequence[float]] = None,
    gain: float = PRECISION_SIGMA_GAIN,
) -> float:
    """Multiplicative sigma-inflation for the Preserver walk.

    ``weights`` are per-bucket contribution weights (typically the f32
    comm-time fractions, a bytes proxy); default uniform.  Returns
    ``1 + gain * sum_b w_b * rel_noise(wire[b])`` — exactly 1.0 for an
    all-f32 wire, so the gate is a no-op there.
    """
    n = policy.n
    if weights is None:
        w = [1.0 / max(n, 1)] * n
    else:
        tot = sum(weights)
        w = [x / tot for x in weights] if tot > 0 else [0.0] * n
    noise = sum(w[b] * WIRE_REL_NOISE[policy.wire[b]] for b in range(n))
    if policy.master == "bf16sr":
        # the stochastic-rounded master adds one more rounding per write
        noise += WIRE_REL_NOISE["bf16"]
    return 1.0 + gain * noise


def precision_walk(
    walk: WalkParams,
    policy: PrecisionPolicy,
    times: Optional[BucketTimes] = None,
    gain: float = PRECISION_SIGMA_GAIN,
) -> WalkParams:
    """Inflate a walk's sigma with the policy's quantization noise.

    With ``times`` the per-bucket weights are the f32 comm-time
    fractions (bigger buckets carry more quantized mass); without, the
    weighting is uniform.  The Preserver then gates the (schedule,
    policy) pair jointly: ``check_schedule(ks, period,
    precision_walk(walk, policy, times), eps)``.
    """
    weights = times.comm if times is not None else None
    factor = quantization_noise_factor(policy, weights, gain)
    if factor == 1.0:
        return walk
    return dataclasses.replace(walk, sigma=walk.sigma * factor)


def check_precision_schedule(
    batch_size_sequence: Sequence[int],
    period: int,
    walk: WalkParams,
    policy: PrecisionPolicy,
    times: Optional[BucketTimes] = None,
    eps: float = 0.01,
    gain: float = PRECISION_SIGMA_GAIN,
) -> PreserverVerdict:
    """Preserver gate for a (k-sequence, precision policy) pair.

    The fixed-B reference ``O_B`` trains unquantized, so it rolls the
    CLEAN walk; DeFT's variable sequence ``O_D`` carries the policy's
    quantization noise (inflated sigma).  This makes the gate strictly
    one-sided in precision: narrowing the wire can only push the ratio
    down, never rescue a failing k-sequence.  An all-f32 policy reduces
    exactly to :func:`~repro.core.preserver.check_schedule`.
    """
    inflated = precision_walk(walk, policy, times, gain)
    if inflated is walk:
        return check_schedule(batch_size_sequence, period, walk, eps)
    ks = [float(k) for k in batch_size_sequence]
    if not ks:
        return PreserverVerdict(
            ratio=float("inf"), e_baseline=0.0, e_deft=float("inf"),
            ok=False, eps=eps,
        )
    # no all-ones shortcut here: even the identity k-sequence differs
    # from the reference once its updates are quantized
    e_b = rollout([1.0] * period, walk)
    e_d = rollout(ks, inflated)
    denom = e_d - walk.s_star
    numer = e_b - walk.s_star
    ratio = numer / denom if abs(denom) > 1e-30 else float("inf")
    return PreserverVerdict(
        ratio=ratio, e_baseline=e_b, e_deft=e_d,
        ok=verdict_ok(ratio, eps), eps=eps,
    )
