"""DeFT top level: Profiler -> Solver -> Preserver feedback loop (Fig. 7).

:class:`Planner` is the single planning surface: every consumer (train
driver, adaptive controller, elastic controller, benchmarks) builds a
:class:`PlanRequest` and receives a :class:`PlanResult`.  The request
carries the input source (profiled ``times``, a candidate-partition
grid, or an architecture + hardware model to profile analytically), the
Preserver policy, the solver knobs, and — for the decoupled-collective
item model (DESIGN.md §12) — the all-gather streaming knobs.

Decoupled item model
--------------------
With ``PlanRequest.decoupled`` the fused per-bucket sync is split into
two independently schedulable knapsack items the way DeAR decouples
all-reduce: a *reduce-scatter* item (``(1 - ag_fraction)`` of the wire
time) placed against backward capacity by the existing two-stage Solver,
and an *all-gather* item streamed against the forward pass.  AG items
carry a **deadline** — the forward-prefix time at which the first block
consuming the bucket starts (buckets are in model order, so bucket ``b``
must land before forward block ``b``) — and are placed by the
deadline-constrained knapsack; a late AG stalls the consuming forward
block instead of adding a bubble.

The legacy functions (``solve_schedule`` / ``feedback_solve`` /
``feedback_solve_candidates`` / ``plan_deft``) remain as thin deprecated
shims over the Planner; new call sites must use the facade
(``scripts/check_no_legacy_planner.py`` enforces this in CI).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.configs.base import ArchConfig
from repro.core.bucket import BucketTimes
from repro.core.knapsack import deadline_knapsack
from repro.core.links import LinkModel
from repro.core.precision import (
    PRECISION_SIGMA_GAIN,
    PrecisionPolicy,
    apply_wire_precision,
    check_precision_schedule,
)
from repro.core.preserver import PreserverVerdict, WalkParams, check_schedule
from repro.core.profiler import HardwareModel, Profile, profile_arch
from repro.core.scheduler import (
    DeftSchedule,
    DeftScheduler,
    SchedulerConfig,
    extract_schedule,
)


@dataclasses.dataclass(frozen=True)
class DeftPlan:
    """Everything downstream consumers need (legacy ``plan_deft`` shape)."""

    profile: Profile
    schedule: DeftSchedule
    verdict: PreserverVerdict
    capacity_factor: float       # final (post-feedback) knapsack scale
    retries: int
    scheduler_cfg: SchedulerConfig

    @property
    def coverage_rate(self) -> float:
        return self.profile.coverage_rate


# ---------------------------------------------------------------------------
# Decoupled-collective item model (DESIGN.md §12)
# ---------------------------------------------------------------------------
def ag_times(times: BucketTimes, ag_fraction: float = 0.5) -> Tuple[float, ...]:
    """Per-bucket all-gather seconds under the decoupled item model.

    A ring all-reduce is a reduce-scatter plus an all-gather moving the
    same bytes each, so the default split prices the AG half at half the
    profiled fused wire time; ``ag_fraction`` is the tunable split for
    asymmetric implementations."""
    if not 0.0 <= ag_fraction <= 1.0:
        raise ValueError(f"ag_fraction must be in [0, 1], got {ag_fraction}")
    return tuple(ag_fraction * c for c in times.comm)


def rs_times(times: BucketTimes, ag_fraction: float = 0.5) -> BucketTimes:
    """The reduce-scatter remainder of ``times`` once the AG half is
    split off: identical compute, comm scaled to ``1 - ag_fraction``."""
    if not 0.0 <= ag_fraction <= 1.0:
        raise ValueError(f"ag_fraction must be in [0, 1], got {ag_fraction}")
    return BucketTimes(
        fwd=times.fwd,
        bwd=times.bwd,
        comm=tuple((1.0 - ag_fraction) * c for c in times.comm),
    )


def ag_deadlines(times: BucketTimes) -> Tuple[float, ...]:
    """Deadline of bucket ``b``'s AG item: the forward-prefix time at
    which block ``b`` (the first consumer, model order) starts."""
    acc, out = 0.0, []
    for f in times.fwd:
        out.append(acc)
        acc += f
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class AgItem:
    """One all-gather knapsack item: bucket ``bucket`` streamed during
    the forward of cycle position ``phase``."""

    bucket: int
    phase: int
    duration: float              # seconds on the primary link
    deadline: float              # forward-prefix start of the consumer
    link: int                    # 0 = primary, 1 = secondary (plan-level)
    covered: bool                # meets its deadline in the placement


@dataclasses.dataclass(frozen=True)
class AgStreamPlan:
    """Deadline-knapsack placement of the AG items over one cycle."""

    items: Tuple[AgItem, ...]
    period: int
    ag_fraction: float
    capacity: float              # forward window per phase (seconds)

    def items_for_phase(self, t: int) -> Tuple[AgItem, ...]:
        return tuple(i for i in self.items if i.phase == t)

    @property
    def total_s(self) -> float:
        return sum(i.duration for i in self.items)

    @property
    def covered_s(self) -> float:
        return sum(i.duration for i in self.items if i.covered)

    @property
    def coverage(self) -> float:
        """Fraction of AG wire time hidden behind forward compute
        (1.0 when there are no AG items at all)."""
        total = self.total_s
        return 1.0 if total <= 0.0 else self.covered_s / total


def ag_sim_kwargs(ag_plan: Optional[AgStreamPlan]):
    """Per-bucket ``(durations, links)`` of the first gathering phase —
    the shape ``simulate_deft(ag_times=..., ag_links=...)`` consumes.
    Every gathering phase places the same full bucket set, so the first
    one is representative; returns ``(None, None)`` when the plan has no
    items (pure-stale cycle or no plan at all)."""
    if ag_plan is None or not ag_plan.items:
        return None, None
    t0 = ag_plan.items[0].phase
    nb = max(i.bucket for i in ag_plan.items) + 1
    durs = [0.0] * nb
    links = [0] * nb
    for item in ag_plan.items_for_phase(t0):
        durs[item.bucket] = item.duration
        links[item.bucket] = item.link
    return tuple(durs), tuple(links)


def plan_ag_stream(
    schedule: DeftSchedule,
    times: BucketTimes,
    scfg: Optional[SchedulerConfig] = None,
    *,
    ag_fraction: float = 0.5,
    gather_skip: bool = True,
) -> AgStreamPlan:
    """Place the per-cycle all-gather items against forward capacity.

    A cycle position gathers iff its params are *fresh* — position 0, or
    the previous phase applied an update — matching the runtime's
    gather-reuse masks exactly; with ``gather_skip`` the stale positions
    emit **no AG items** (the runtime serves them from the replicated
    cache).  Fresh positions gather every bucket; each position's items
    go through the deadline-constrained knapsack on the primary link,
    then (heterogeneous setups) the leftovers are re-offered to the
    secondary link at ``mu``-scaled durations.  Items covered by neither
    stall their consuming forward block (the simulator prices the
    stall)."""
    scfg = scfg or SchedulerConfig()
    durs = ag_times(times, ag_fraction)
    deadlines = ag_deadlines(times)
    nb = times.n
    cap = times.fwd_total * scfg.capacity_factor
    items = []
    for t in range(schedule.period):
        fresh = t == 0 or schedule.phases[t - 1].do_update
        if gather_skip and not fresh:
            continue
        sel = set(deadline_knapsack(durs, deadlines, cap))
        rest = [b for b in range(nb) if b not in sel]
        sel2 = set()
        if scfg.heterogeneous and rest:
            if scfg.link_models is None:
                sec_durs = [durs[b] * scfg.mu for b in rest]
            else:
                lm1 = scfg.models().get(1, LinkModel(0.0, scfg.mu))
                sec_durs = [lm1.time(durs[b]) for b in rest]
            picked = deadline_knapsack(
                sec_durs,
                [deadlines[b] for b in rest],
                cap,
            )
            sel2 = {rest[j] for j in picked}
        for b in range(nb):
            items.append(AgItem(
                bucket=b,
                phase=t,
                duration=durs[b],
                deadline=deadlines[b],
                link=1 if b in sel2 else 0,
                covered=b in sel or b in sel2,
            ))
    return AgStreamPlan(
        items=tuple(items),
        period=schedule.period,
        ag_fraction=ag_fraction,
        capacity=cap,
    )


# ---------------------------------------------------------------------------
# Planner facade
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PlanRequest:
    """One planning request; exactly one input source must be set:

    * ``times``      — profiled/calibrated bucket times (train driver,
                       adaptive controller);
    * ``candidates`` — ``(tag, BucketTimes)`` partition grid scored by
                       simulated iteration time (repartitioner, elastic);
    * ``arch``       — architecture profiled analytically against ``hw``
                       (the ``plan_deft`` path).
    """

    times: Optional[BucketTimes] = None
    candidates: Tuple[Tuple[str, BucketTimes], ...] = ()
    arch: Optional[ArchConfig] = None

    # analytic-profile knobs (arch path)
    hw: Optional[HardwareModel] = None
    seq_len: int = 4096
    per_device_batch: int = 1
    partition_elems: int = 6_500_000
    rebase_total_flops: Optional[float] = None

    # Preserver policy
    walk: Optional[WalkParams] = None
    preserve: bool = True        # False: single solve, no Preserver gate
    eps: float = 0.01
    max_retries: int = 10
    capacity_growth: float = 1.2
    initial_factor: float = 1.0

    # solver knobs
    heterogeneous: bool = True
    mu: float = 1.65
    warmup: int = 16
    # per-link latency + inverse-bandwidth models (heterogeneous-link
    # pricing); None = legacy scalar ``mu``
    link_models: Optional[Dict[int, LinkModel]] = None

    # candidate scoring (candidates path)
    baseline_tag: Optional[str] = None
    min_gain: float = 0.0
    sim_iterations: int = 48

    # decoupled-collective item model (§12)
    decoupled: bool = False
    ag_fraction: float = 0.5
    gather_skip: bool = True

    # wire precision (§13): "f32" (off), a forced uniform dtype
    # ("bf16"/"int8"), or "auto" — enumerate per-bucket policies along a
    # largest-comm-first downgrade ladder, each scored by simulated
    # iteration time and gated by the precision-aware Preserver check.
    # An explicit ``precision`` policy overrides the enumeration.
    wire_precision: str = "f32"
    master_dtype: str = "f32"
    precision: Optional[PrecisionPolicy] = None
    precision_min_gain: float = 0.0
    precision_sigma_gain: float = PRECISION_SIGMA_GAIN

    def __post_init__(self):
        sources = (
            (self.times is not None)
            + bool(self.candidates)
            + (self.arch is not None)
        )
        if sources != 1:
            raise ValueError(
                "PlanRequest needs exactly one of times / candidates / "
                f"arch, got {sources}"
            )
        if self.wire_precision not in ("auto", "f32", "bf16", "int8"):
            raise ValueError(
                f"wire_precision must be auto/f32/bf16/int8, got "
                f"{self.wire_precision!r}"
            )
        if self.master_dtype not in ("f32", "bf16sr"):
            raise ValueError(
                f"master_dtype must be f32/bf16sr, got {self.master_dtype!r}"
            )
        if self.precision is not None and self.wire_precision != "f32":
            raise ValueError(
                "pass an explicit precision policy OR wire_precision, "
                "not both"
            )


@dataclasses.dataclass(frozen=True)
class PlanResult:
    """What the Planner returns, superset of every legacy surface."""

    schedule: DeftSchedule
    verdict: Optional[PreserverVerdict]
    scheduler_cfg: SchedulerConfig
    retries: int
    times: BucketTimes                     # profiled (f32-priced) times
    profile: Optional[Profile] = None      # arch path only
    candidates: Tuple[CandidateSolve, ...] = ()
    winner_tag: Optional[str] = None       # candidates path only
    ag_plan: Optional[AgStreamPlan] = None  # decoupled requests only
    # §13: adopted wire-precision policy + the times re-priced under it
    # (the times the schedule actually solved on); None when the request
    # did not engage precision planning
    precision: Optional[PrecisionPolicy] = None
    priced_times: Optional[BucketTimes] = None
    precision_candidates: Tuple["PrecisionSolve", ...] = ()

    @property
    def capacity_factor(self) -> float:
        return self.scheduler_cfg.capacity_factor

    @property
    def ok(self) -> bool:
        return self.verdict is None or self.verdict.ok

    @property
    def wire_times(self) -> BucketTimes:
        """The precision-priced times every downstream consumer (AG
        streaming, simulator, runtime) should execute against."""
        return self.priced_times if self.priced_times is not None else self.times


@dataclasses.dataclass(frozen=True)
class PrecisionSolve:
    """One precision policy's pass through the feedback loop (§13)."""

    policy: PrecisionPolicy
    schedule: DeftSchedule
    verdict: Optional[PreserverVerdict]
    scheduler_cfg: SchedulerConfig
    retries: int
    iteration_time: float        # simulated steady-state seconds/iteration
    coverage: float              # simulated 1 - bubble_fraction
    wire_bytes_scale: float      # policy wire bytes / all-f32 wire bytes


class Planner:
    """The unified planning facade (solve + Preserver feedback +
    candidate scoring + decoupled AG streaming + wire-precision
    enumeration) behind one ``plan(PlanRequest) -> PlanResult`` call.

    Stateless apart from an optional default Gaussian-walk model applied
    when a request does not carry its own."""

    _DEFAULT_WALK = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0,
                               batch=256)

    def __init__(self, walk: Optional[WalkParams] = None):
        self.default_walk = walk

    # -- internals ----------------------------------------------------------
    def _walk(self, req: PlanRequest) -> WalkParams:
        return req.walk or self.default_walk or self._DEFAULT_WALK

    def _solve_times(
        self,
        times: BucketTimes,
        req: PlanRequest,
        policy: Optional[PrecisionPolicy] = None,
        weight_times: Optional[BucketTimes] = None,
    ):
        """Fig. 7 feedback loop over one set of bucket times.

        With ``policy`` the Preserver check is the precision-aware one
        (§13): the fixed-B reference rolls the clean walk while DeFT's
        sequence carries the policy's quantization noise.
        ``weight_times`` supplies the f32 comm weights for the sigma
        inflation (``times`` may already be precision-priced)."""
        walk = self._walk(req)
        factor = req.initial_factor
        schedule, verdict, scfg, retry = None, None, None, 0
        retries = 0 if not req.preserve else req.max_retries
        for retry in range(retries + 1):
            scfg = SchedulerConfig(
                heterogeneous=req.heterogeneous, mu=req.mu,
                capacity_factor=factor,
                link_models=req.link_models,
            )
            schedule = self._solve(times, scfg, warmup=req.warmup)
            if not req.preserve:
                verdict = None
                break
            if policy is None:
                verdict = check_schedule(
                    schedule.batch_size_sequence, schedule.period, walk,
                    eps=req.eps,
                )
            else:
                verdict = check_precision_schedule(
                    schedule.batch_size_sequence, schedule.period, walk,
                    policy, weight_times or times, eps=req.eps,
                    gain=req.precision_sigma_gain,
                )
            if verdict.ok:
                break
            factor *= req.capacity_growth
        return schedule, verdict, scfg, retry

    @staticmethod
    def _solve(
        times: BucketTimes,
        scfg: SchedulerConfig,
        n_buckets: Optional[int] = None,
        warmup: int = 16,
    ) -> DeftSchedule:
        """Solver: Algorithm 2 over the horizon, then cycle extraction."""
        sched = DeftScheduler(times, scfg)
        plans = sched.run()
        return extract_schedule(plans, n_buckets or times.n, warmup=warmup)

    @staticmethod
    def _ag_sim_kwargs(schedule, times: BucketTimes,
                       scfg: SchedulerConfig, req: PlanRequest) -> dict:
        """Streamed-AG kwargs for candidate scoring.

        A decoupled request must be priced with its AG items on their
        *planned links* — without this every gather simulates on the
        primary link, mispricing exactly the candidates whose plan
        off-loaded gathers to the secondary link (the ranking can flip).
        ``times`` are the full (unsplit) bucket times the AG items derive
        from."""
        if not req.decoupled:
            return {}
        agp = plan_ag_stream(
            schedule, times, scfg,
            ag_fraction=req.ag_fraction,
            gather_skip=req.gather_skip,
        )
        durs, links = ag_sim_kwargs(agp)
        if durs is None:
            return {}
        return {"ag_times": durs, "ag_links": links,
                "ag_skip": req.gather_skip}

    def _plan_candidates(self, req: PlanRequest):
        """Candidate-partition path: run the feedback loop over SEVERAL
        bucket partitions of the same model, score each by simulated
        steady-state iteration time, and pick the winner.

        The Preserver gates partition changes exactly like k-sequence
        changes: a candidate whose schedule still fails after the
        capacity feedback retries is disqualified (unless it IS the
        baseline — best-effort semantics).  ``min_gain`` adds switch
        hysteresis so a near-tie never pays a state re-pack."""
        from repro.core.simulator import simulate_deft

        solves = []
        for tag, times in req.candidates:
            solve_on = rs_times(times, req.ag_fraction) if req.decoupled \
                else times
            schedule, verdict, scfg, retries = self._solve_times(solve_on, req)
            sim = simulate_deft(
                solve_on,
                DeftScheduler(solve_on, scfg).run(req.sim_iterations),
                mu=scfg.mu,
                heterogeneous=scfg.heterogeneous,
                link_models=scfg.link_models,
                **self._ag_sim_kwargs(schedule, times, scfg, req),
            )
            solves.append(CandidateSolve(
                tag=tag,
                times=times,
                schedule=schedule,
                verdict=verdict,
                scheduler_cfg=scfg,
                retries=retries,
                iteration_time=sim.iteration_time,
            ))
        if not solves:
            raise ValueError("candidate path needs >= 1 candidate")
        base = next(
            (s for s in solves if s.tag == req.baseline_tag), solves[0]
        )
        best = base
        for s in solves:
            if s is base or not s.verdict.ok:
                continue
            bar = best.iteration_time
            if best is base:
                bar = base.iteration_time * (1.0 - req.min_gain)
            if s.iteration_time < bar:
                best = s
        return best, tuple(solves)

    # -- precision enumeration (§13) ----------------------------------------
    @staticmethod
    def _precision_requested(req: PlanRequest) -> bool:
        return (
            req.precision is not None
            or req.wire_precision != "f32"
            or req.master_dtype != "f32"
        )

    @staticmethod
    def _precision_ladder(times: BucketTimes, req: PlanRequest):
        """Candidate policies, all-f32 baseline first.

        ``auto`` walks a largest-comm-first downgrade ladder: buckets
        flip f32 -> bf16 one at a time in descending f32 comm order,
        then bf16 -> int8 in the same order — ``2n + 1`` monotone
        candidates whose quantization noise only grows, so the first
        gate failure ends the scan (the ladder prefix property makes
        mixed assignments first-class: the winner is whatever prefix
        simulates fastest, not an all-or-nothing dtype flip)."""
        n = times.n
        base = PrecisionPolicy.uniform(n, "f32", req.master_dtype)
        if req.precision is not None:
            return [base, req.precision]
        if req.wire_precision != "auto":
            forced = PrecisionPolicy.uniform(
                n, req.wire_precision, req.master_dtype
            )
            return [base] if forced == base else [base, forced]
        order = sorted(range(n), key=lambda b: -times.comm[b])
        ladder = [base]
        cur = base
        for target in ("bf16", "int8"):
            for b in order:
                cur = cur.with_wire(b, target)
                ladder.append(cur)
        return ladder

    def _solve_precision(
        self, times: BucketTimes, req: PlanRequest,
        policy: PrecisionPolicy,
    ) -> PrecisionSolve:
        from repro.core.simulator import simulate_deft

        priced = apply_wire_precision(times, policy)
        solve_on = rs_times(priced, req.ag_fraction) if req.decoupled \
            else priced
        schedule, verdict, scfg, retries = self._solve_times(
            solve_on, req, policy=policy, weight_times=times,
        )
        sim = simulate_deft(
            solve_on,
            DeftScheduler(solve_on, scfg).run(req.sim_iterations),
            mu=scfg.mu,
            heterogeneous=scfg.heterogeneous,
            link_models=scfg.link_models,
            **self._ag_sim_kwargs(schedule, priced, scfg, req),
        )
        # wire-volume scale vs all-f32, weighted by each bucket's f32
        # comm time (proportional to its bytes — BucketTimes carries no
        # element counts)
        tot = max(times.comm_total, 1e-30)
        scale = sum(
            times.comm[b] * policy.wire_bytes_per_elem(b) / 4.0
            for b in range(times.n)
        ) / tot
        return PrecisionSolve(
            policy=policy,
            schedule=schedule,
            verdict=verdict,
            scheduler_cfg=scfg,
            retries=retries,
            iteration_time=sim.iteration_time,
            coverage=max(0.0, 1.0 - sim.bubble_fraction),
            wire_bytes_scale=scale,
        )

    def _plan_precision(self, times: BucketTimes, req: PlanRequest):
        """Score the precision ladder; adopt the fastest gate-passing
        policy.  All-f32 is the best-effort baseline (kept even when its
        own verdict fails, mirroring the candidate-partition path);
        ``precision_min_gain`` adds switch hysteresis.  An EXPLICIT
        policy (``req.precision`` or a forced uniform wire) is adopted
        whenever the gate allows it — the caller asked for those bytes,
        so a time tie (e.g. every rung latency-floored on a tiny
        profile) must not silently fall back to f32."""
        ladder = self._precision_ladder(times, req)
        solves = [self._solve_precision(times, req, ladder[0])]
        for policy in ladder[1:]:
            s = self._solve_precision(times, req, policy)
            solves.append(s)
            if req.preserve and not s.verdict.ok and \
                    req.wire_precision == "auto":
                break   # noise grows monotonically along the ladder
        base = solves[0]
        explicit = req.precision is not None or \
            req.wire_precision not in ("auto", "f32")
        if explicit and len(solves) > 1:
            forced = solves[-1]
            if not req.preserve or forced.verdict.ok:
                return forced, tuple(solves)
            return base, tuple(solves)
        best = base
        for s in solves[1:]:
            if req.preserve and not s.verdict.ok:
                continue
            bar = best.iteration_time
            if best is base:
                bar = base.iteration_time * (1.0 - req.precision_min_gain)
            if s.iteration_time < bar:
                best = s
        return best, tuple(solves)

    # -- the facade ---------------------------------------------------------
    def plan(self, req: PlanRequest) -> PlanResult:
        profile = None
        candidates: Tuple[CandidateSolve, ...] = ()
        winner_tag = None

        if req.candidates:
            best, candidates = self._plan_candidates(req)
            times = best.times
            schedule, verdict = best.schedule, best.verdict
            scfg, retries = best.scheduler_cfg, best.retries
            winner_tag = best.tag
        else:
            if req.arch is not None:
                profile = profile_arch(
                    req.arch,
                    hw=req.hw or HardwareModel(),
                    seq_len=req.seq_len,
                    per_device_batch=req.per_device_batch,
                    partition_strategy="deft",
                    partition_elems=req.partition_elems,
                    rebase_total_flops=req.rebase_total_flops,
                )
                times = profile.times
            else:
                times = req.times
            solve_on = rs_times(times, req.ag_fraction) if req.decoupled \
                else times
            schedule, verdict, scfg, retries = self._solve_times(solve_on, req)

        precision = None
        priced_times = None
        precision_candidates: Tuple[PrecisionSolve, ...] = ()
        if self._precision_requested(req):
            # precision rides on top of whichever times won above (the
            # candidate path re-prices the winning partition); the
            # winning policy's solve replaces the f32 one
            best_p, precision_candidates = self._plan_precision(times, req)
            precision = best_p.policy
            priced_times = apply_wire_precision(times, precision)
            schedule, verdict = best_p.schedule, best_p.verdict
            scfg, retries = best_p.scheduler_cfg, best_p.retries

        ag_plan = None
        if req.decoupled:
            ag_plan = plan_ag_stream(
                schedule, priced_times if priced_times is not None else times,
                scfg,
                ag_fraction=req.ag_fraction,
                gather_skip=req.gather_skip,
            )
        return PlanResult(
            schedule=schedule,
            verdict=verdict,
            scheduler_cfg=scfg,
            retries=retries,
            times=times,
            profile=profile,
            candidates=candidates,
            winner_tag=winner_tag,
            ag_plan=ag_plan,
            precision=precision,
            priced_times=priced_times,
            precision_candidates=precision_candidates,
        )


# ---------------------------------------------------------------------------
# Legacy shims (deprecated: new call sites must go through Planner —
# scripts/check_no_legacy_planner.py enforces this for src/repro)
# ---------------------------------------------------------------------------
def solve_schedule(
    times: BucketTimes,
    scfg: SchedulerConfig,
    n_buckets: Optional[int] = None,
    warmup: int = 16,
) -> DeftSchedule:
    """Deprecated shim: raw Solver pass.  Use ``Planner.plan`` with
    ``preserve=False`` (or keep the SchedulerConfig knobs on the
    request) instead."""
    return Planner._solve(times, scfg, n_buckets=n_buckets, warmup=warmup)


def feedback_solve(
    times: BucketTimes,
    walk: WalkParams,
    *,
    heterogeneous: bool = True,
    mu: float = 1.65,
    eps: float = 0.01,
    max_retries: int = 10,
    capacity_growth: float = 1.2,
    initial_factor: float = 1.0,
) -> Tuple[DeftSchedule, PreserverVerdict, SchedulerConfig, int]:
    """Deprecated shim: the Fig. 7 feedback loop over profiled bucket
    times.  Use ``Planner.plan(PlanRequest(times=...))``."""
    res = Planner().plan(PlanRequest(
        times=times,
        walk=walk,
        heterogeneous=heterogeneous,
        mu=mu,
        eps=eps,
        max_retries=max_retries,
        capacity_growth=capacity_growth,
        initial_factor=initial_factor,
    ))
    return res.schedule, res.verdict, res.scheduler_cfg, res.retries


@dataclasses.dataclass(frozen=True)
class CandidateSolve:
    """One partition candidate's pass through the feedback loop."""

    tag: str
    times: BucketTimes
    schedule: DeftSchedule
    verdict: PreserverVerdict
    scheduler_cfg: SchedulerConfig
    retries: int
    iteration_time: float        # simulated steady-state seconds/iteration


def feedback_solve_candidates(
    candidates,
    walk: WalkParams,
    *,
    baseline_tag: Optional[str] = None,
    min_gain: float = 0.0,
    sim_iterations: int = 48,
    heterogeneous: bool = True,
    mu: float = 1.65,
    eps: float = 0.01,
    max_retries: int = 10,
    capacity_growth: float = 1.2,
) -> Tuple[CandidateSolve, Tuple[CandidateSolve, ...]]:
    """Deprecated shim: candidate-partition scoring.  Use
    ``Planner.plan(PlanRequest(candidates=...))``."""
    res = Planner().plan(PlanRequest(
        candidates=tuple(candidates),
        walk=walk,
        baseline_tag=baseline_tag,
        min_gain=min_gain,
        sim_iterations=sim_iterations,
        heterogeneous=heterogeneous,
        mu=mu,
        eps=eps,
        max_retries=max_retries,
        capacity_growth=capacity_growth,
    ))
    best = next(s for s in res.candidates if s.tag == res.winner_tag)
    return best, res.candidates


def plan_deft(
    cfg: ArchConfig,
    hw: HardwareModel = HardwareModel(),
    seq_len: int = 4096,
    per_device_batch: int = 1,
    heterogeneous: bool = True,
    mu: float = 1.65,
    walk: Optional[WalkParams] = None,
    eps: float = 0.01,
    max_retries: int = 10,
    capacity_growth: float = 1.2,
    partition_elems: int = 6_500_000,
    rebase_total_flops: Optional[float] = None,
) -> DeftPlan:
    """Deprecated shim: profile -> solve -> preserve.  Use
    ``Planner.plan(PlanRequest(arch=...))``."""
    res = Planner(walk=walk).plan(PlanRequest(
        arch=cfg,
        hw=hw,
        seq_len=seq_len,
        per_device_batch=per_device_batch,
        heterogeneous=heterogeneous,
        mu=mu,
        eps=eps,
        max_retries=max_retries,
        capacity_growth=capacity_growth,
        partition_elems=partition_elems,
        rebase_total_flops=rebase_total_flops,
    ))
    return DeftPlan(
        profile=res.profile,
        schedule=res.schedule,
        verdict=res.verdict,
        capacity_factor=res.capacity_factor,
        retries=res.retries,
        scheduler_cfg=res.scheduler_cfg,
    )
