"""DeFT top level: Profiler -> Solver -> Preserver feedback loop (Fig. 7).

``plan_deft`` is the single entry point used by the train loop, the
benchmarks and the examples: given an architecture + hardware model +
input shape, it profiles bucket times analytically, runs the two-stage
knapsack Solver, checks the resulting variable-batch-size sequence with
the Preserver, and — on failure — enlarges the knapsack capacity (paper:
"allowing more communications in each iteration, which avoids excessive
decrease in parameter update frequency") and re-solves, up to
``max_retries`` (paper: 10).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.configs.base import ArchConfig
from repro.core.bucket import BucketTimes
from repro.core.preserver import PreserverVerdict, WalkParams, check_schedule
from repro.core.profiler import HardwareModel, Profile, profile_arch
from repro.core.scheduler import (
    DeftSchedule,
    DeftScheduler,
    SchedulerConfig,
    extract_schedule,
)


@dataclasses.dataclass(frozen=True)
class DeftPlan:
    """Everything downstream consumers need."""

    profile: Profile
    schedule: DeftSchedule
    verdict: PreserverVerdict
    capacity_factor: float       # final (post-feedback) knapsack scale
    retries: int
    scheduler_cfg: SchedulerConfig

    @property
    def coverage_rate(self) -> float:
        return self.profile.coverage_rate


def solve_schedule(
    times: BucketTimes,
    scfg: SchedulerConfig,
    n_buckets: Optional[int] = None,
    warmup: int = 16,
) -> DeftSchedule:
    """Solver: Algorithm 2 over the horizon, then cycle extraction."""
    sched = DeftScheduler(times, scfg)
    plans = sched.run()
    return extract_schedule(plans, n_buckets or times.n, warmup=warmup)


def feedback_solve(
    times: BucketTimes,
    walk: WalkParams,
    *,
    heterogeneous: bool = True,
    mu: float = 1.65,
    eps: float = 0.01,
    max_retries: int = 10,
    capacity_growth: float = 1.2,
    initial_factor: float = 1.0,
) -> Tuple[DeftSchedule, PreserverVerdict, SchedulerConfig, int]:
    """The Fig. 7 feedback loop over profiled bucket times: solve, check
    with the Preserver, and grow the knapsack capacity on rejection (up to
    ``max_retries``).  Shared by :func:`plan_deft` (analytic profiles),
    the train driver (leaf-bucket profiles) and the online adaptive
    controller (measurement-calibrated profiles)."""
    factor = initial_factor
    schedule, verdict, scfg, retry = None, None, None, 0
    for retry in range(max_retries + 1):
        scfg = SchedulerConfig(
            heterogeneous=heterogeneous, mu=mu, capacity_factor=factor
        )
        schedule = solve_schedule(times, scfg, n_buckets=times.n)
        verdict = check_schedule(
            schedule.batch_size_sequence, schedule.period, walk, eps=eps
        )
        if verdict.ok:
            break
        factor *= capacity_growth
    return schedule, verdict, scfg, retry


@dataclasses.dataclass(frozen=True)
class CandidateSolve:
    """One partition candidate's pass through the feedback loop."""

    tag: str
    times: BucketTimes
    schedule: DeftSchedule
    verdict: PreserverVerdict
    scheduler_cfg: SchedulerConfig
    retries: int
    iteration_time: float        # simulated steady-state seconds/iteration


def feedback_solve_candidates(
    candidates,
    walk: WalkParams,
    *,
    baseline_tag: Optional[str] = None,
    min_gain: float = 0.0,
    sim_iterations: int = 48,
    heterogeneous: bool = True,
    mu: float = 1.65,
    eps: float = 0.01,
    max_retries: int = 10,
    capacity_growth: float = 1.2,
) -> Tuple[CandidateSolve, Tuple[CandidateSolve, ...]]:
    """The candidate-partition path of the Fig. 7 loop: run
    :func:`feedback_solve` over SEVERAL bucket partitions of the same
    model (each a ``(tag, BucketTimes)`` pair), score every candidate by
    its simulated steady-state iteration time, and pick the winner.

    The Preserver gates partition changes exactly like k-sequence
    changes: a candidate whose schedule still fails the Preserver after
    the capacity feedback retries is disqualified (unless it IS the
    baseline — best-effort semantics match :func:`feedback_solve`).
    ``min_gain`` adds switch hysteresis: a non-baseline candidate must
    beat the baseline's iteration time by that relative margin, so a
    near-tie never pays a state re-pack.

    Returns (winner, all candidate solves in input order).
    """
    from repro.core.scheduler import DeftScheduler
    from repro.core.simulator import simulate_deft

    solves = []
    for tag, times in candidates:
        schedule, verdict, scfg, retries = feedback_solve(
            times,
            walk,
            heterogeneous=heterogeneous,
            mu=mu,
            eps=eps,
            max_retries=max_retries,
            capacity_growth=capacity_growth,
        )
        sim = simulate_deft(
            times,
            DeftScheduler(times, scfg).run(sim_iterations),
            mu=scfg.mu,
            heterogeneous=scfg.heterogeneous,
        )
        solves.append(CandidateSolve(
            tag=tag,
            times=times,
            schedule=schedule,
            verdict=verdict,
            scheduler_cfg=scfg,
            retries=retries,
            iteration_time=sim.iteration_time,
        ))
    if not solves:
        raise ValueError("feedback_solve_candidates needs >= 1 candidate")
    base = next(
        (s for s in solves if s.tag == baseline_tag),
        solves[0],
    )
    best = base
    for s in solves:
        if s is base or not s.verdict.ok:
            continue
        bar = best.iteration_time
        if best is base:
            bar = base.iteration_time * (1.0 - min_gain)
        if s.iteration_time < bar:
            best = s
    return best, tuple(solves)


def plan_deft(
    cfg: ArchConfig,
    hw: HardwareModel = HardwareModel(),
    seq_len: int = 4096,
    per_device_batch: int = 1,
    heterogeneous: bool = True,
    mu: float = 1.65,
    walk: Optional[WalkParams] = None,
    eps: float = 0.01,
    max_retries: int = 10,
    capacity_growth: float = 1.2,
    partition_elems: int = 6_500_000,
    rebase_total_flops: Optional[float] = None,
) -> DeftPlan:
    """Profile -> solve -> preserve, with the capacity feedback loop."""
    profile = profile_arch(
        cfg,
        hw=hw,
        seq_len=seq_len,
        per_device_batch=per_device_batch,
        partition_strategy="deft",
        partition_elems=partition_elems,
        rebase_total_flops=rebase_total_flops,
    )
    walk = walk or WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)

    schedule, verdict, scfg, retries = feedback_solve(
        profile.times,
        walk,
        heterogeneous=heterogeneous,
        mu=mu,
        eps=eps,
        max_retries=max_retries,
        capacity_growth=capacity_growth,
    )
    # best effort after max retries (paper caps at 10)
    return DeftPlan(
        profile=profile,
        schedule=schedule,
        verdict=verdict,
        capacity_factor=scfg.capacity_factor,
        retries=retries,
        scheduler_cfg=scfg,
    )
