"""DeFT core: the paper's contribution.

Profiler (analytical bucket-time reconstruction) -> Solver (two-stage 0/1
multi-knapsack scheduling, Algorithms 1+2) -> Preserver (Gaussian-walk
convergence check + capacity feedback).  ``plan_deft`` ties them together.
"""
from repro.core.bucket import Bucket, BucketTimes, build_buckets
from repro.core.deft import (
    AgItem,
    AgStreamPlan,
    CandidateSolve,
    DeftPlan,
    Planner,
    PlanRequest,
    PlanResult,
    ag_deadlines,
    ag_times,
    plan_ag_stream,
    plan_deft,
    rs_times,
    solve_schedule,
)
from repro.core.knapsack import (
    deadline_knapsack,
    greedy_multi_knapsack,
    knapsack_two_link,
    naive_knapsack,
    recursive_knapsack,
)
from repro.core.deft import PrecisionSolve
from repro.core.policies import ALL_BASELINES, BaselinePolicy
from repro.core.precision import (
    WIRE_BYTES,
    WIRE_DTYPES,
    PrecisionPolicy,
    apply_wire_precision,
    check_precision_schedule,
    precision_walk,
    wire_bytes_total,
)
from repro.core.preserver import (
    PreserverVerdict,
    WalkParams,
    check_schedule,
    expected_next_state,
    rollout,
)
from repro.core.profiler import HardwareModel, Profile, profile_arch
from repro.core.scheduler import (
    DeftSchedule,
    DeftScheduler,
    IterationPlan,
    PhaseSpec,
    SchedulerConfig,
    Task,
    extract_schedule,
)
from repro.core.simulator import SimResult, simulate_baseline, simulate_deft

__all__ = [
    "Bucket", "BucketTimes", "build_buckets",
    "DeftPlan", "plan_deft", "solve_schedule",
    "Planner", "PlanRequest", "PlanResult", "CandidateSolve",
    "AgItem", "AgStreamPlan", "plan_ag_stream",
    "rs_times", "ag_times", "ag_deadlines",
    "deadline_knapsack",
    "greedy_multi_knapsack", "knapsack_two_link", "naive_knapsack", "recursive_knapsack",
    "ALL_BASELINES", "BaselinePolicy",
    "PrecisionPolicy", "PrecisionSolve", "WIRE_BYTES", "WIRE_DTYPES",
    "apply_wire_precision", "check_precision_schedule", "precision_walk",
    "wire_bytes_total",
    "PreserverVerdict", "WalkParams", "check_schedule", "expected_next_state", "rollout",
    "HardwareModel", "Profile", "profile_arch",
    "DeftSchedule", "DeftScheduler", "IterationPlan", "PhaseSpec",
    "SchedulerConfig", "Task", "extract_schedule",
    "SimResult", "simulate_baseline", "simulate_deft",
]
