"""Gradient buckets and partition strategies.

Terminology follows the paper: buckets are numbered ``1..N`` from the
*input* layer to the *output* layer.  Backward propagation therefore
produces gradients in the order ``N, N-1, ..., 1``; bucket #1 is the one
whose communication carries the hard dependency (it finishes last in
backward and is needed first by the next iteration's forward).

Three partition strategies are provided, mirroring Table III:

* ``uniform``      — PyTorch-DDP style: greedy fill to a fixed bucket size.
* ``usbyte``       — US-Byte style unequal-sized re-partition that grows
                     bucket sizes geometrically from the output end so early
                     (output-side) communications are small and start early.
* ``deft``         — US-Byte partition + the paper §III.D constraint: the
                     largest bucket's communication time must stay below the
                     smallest knapsack capacity (forward time / mu);
                     over-sized buckets are split.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One gradient bucket.

    index:      1-based, 1 = input-most (paper numbering).
    n_elements: parameter count.
    layer_ids:  decoder-layer indices covered (input->output order);
                (-1,) marks the embedding bucket, (-2,) the head/final-norm.
    split:      (k, of) when the bucket is the k-th split of a partitioned
                layer group (tensor partition), else None.
    """

    index: int
    n_elements: int
    layer_ids: Tuple[int, ...]
    split: Optional[Tuple[int, int]] = None

    def wire_bytes(self, policy=None) -> int:
        """Bytes this bucket's gradient occupies on the wire under a
        :class:`~repro.core.precision.PrecisionPolicy` (f32 when None).

        The policy is indexed by bucket position (``index`` is 1-based,
        matching paper numbering) — the ONE place wire bytes are derived
        from an element count; everything else prices through here or
        :func:`~repro.core.precision.apply_wire_precision`."""
        if policy is None:
            return 4 * self.n_elements
        return policy.wire_bytes_per_elem(self.index - 1) * self.n_elements

    @property
    def bytes_fp32(self) -> int:
        """Deprecated shim — use :meth:`wire_bytes`.  Kept for
        out-of-tree callers; linted against in-tree by
        ``scripts/check_no_legacy_planner.py``."""
        return self.wire_bytes()


@dataclasses.dataclass(frozen=True)
class BucketTimes:
    """Profiled per-bucket times, seconds. Forward/backward are the compute
    times of the layers the bucket covers; comm is the all-reduce time of
    the bucket's gradient on the *primary* link."""

    fwd: Tuple[float, ...]
    bwd: Tuple[float, ...]
    comm: Tuple[float, ...]

    @property
    def n(self) -> int:
        return len(self.fwd)

    @property
    def fwd_total(self) -> float:
        return sum(self.fwd)

    @property
    def bwd_total(self) -> float:
        return sum(self.bwd)

    @property
    def comm_total(self) -> float:
        return sum(self.comm)

    @property
    def coverage_rate(self) -> float:
        """CR = T_comm / (T_fwd + T_bwd) — Table I."""
        return self.comm_total / max(self.fwd_total + self.bwd_total, 1e-12)


def _greedy_fill(
    layer_elems: Sequence[int], target: int
) -> List[List[int]]:
    """Group consecutive layer indices (input->output) so each group reaches
    ``target`` elements (except possibly the last)."""
    groups: List[List[int]] = []
    cur: List[int] = []
    acc = 0
    for i, n in enumerate(layer_elems):
        cur.append(i)
        acc += n
        if acc >= target:
            groups.append(cur)
            cur, acc = [], 0
    if cur:
        groups.append(cur)
    return groups


def partition_uniform(
    layer_elems: Sequence[int], bucket_elems: int
) -> List[Bucket]:
    """PyTorch-DDP-style fixed-size bucketing (default 25 MB = 6,553,600
    fp32 elements). Grouping runs input->output over layer ids; DDP actually
    fills buckets in reverse-registration (output-first) order — the bucket
    *contents* are the same consecutive layer ranges, and we keep paper
    numbering (1 = input-most)."""
    groups = _greedy_fill(layer_elems, bucket_elems)
    return [
        Bucket(index=i + 1, n_elements=sum(layer_elems[j] for j in g), layer_ids=tuple(g))
        for i, g in enumerate(groups)
    ]


def partition_usbyte(
    layer_elems: Sequence[int], base_elems: int, growth: float = 1.6
) -> List[Bucket]:
    """US-Byte-style unequal-sized partition [arXiv US-Byte, TPDS'23]:
    output-side buckets are kept small (their communications launch first
    in backward and must not delay later overlap), growing geometrically
    toward the input side.  We implement it as greedy fill with a target
    that *decays* from input to output."""
    n_layers = len(layer_elems)
    total = sum(layer_elems)
    groups: List[List[int]] = []
    cur: List[int] = []
    acc = 0
    remaining = total
    target = base_elems * growth ** 2
    for i in range(n_layers):
        cur.append(i)
        acc += layer_elems[i]
        # decay target toward the output end
        frac_done = (total - remaining) / max(total, 1)
        target_i = max(base_elems / growth, target * (1 - frac_done))
        remaining -= layer_elems[i]
        if acc >= target_i:
            groups.append(cur)
            cur, acc = [], 0
    if cur:
        groups.append(cur)
    return [
        Bucket(index=i + 1, n_elements=sum(layer_elems[j] for j in g), layer_ids=tuple(g))
        for i, g in enumerate(groups)
    ]


def partition_bytescheduler(
    layer_elems: Sequence[int], partition_elems: int
) -> List[Bucket]:
    """Bytescheduler-style tensor partition: greedy-fill groups, then SLICE
    any bucket larger than the partition size into near-equal blocks (the
    paper's 'tensor partition' — credit-sized blocks, default 6.5M)."""
    grouped = partition_uniform(layer_elems, partition_elems)
    out: List[Bucket] = []
    for b in grouped:
        if b.n_elements <= partition_elems:
            out.append(b)
            continue
        k = -(-b.n_elements // partition_elems)   # ceil
        out.extend(split_bucket(b, k, start_index=0))
    return [dataclasses.replace(b, index=i + 1) for i, b in enumerate(out)]


def split_bucket(b: Bucket, k: int, start_index: int) -> List[Bucket]:
    """Tensor-partition a bucket into k near-equal splits (paper §III.D)."""
    per = b.n_elements // k
    out = []
    for j in range(k):
        n = per if j < k - 1 else b.n_elements - per * (k - 1)
        out.append(
            Bucket(
                index=start_index + j,
                n_elements=n,
                layer_ids=b.layer_ids,
                split=(j, k),
            )
        )
    return out


def apply_deft_constraint(
    buckets: Sequence[Bucket],
    comm_time_of,           # Callable[[int elements], float]
    max_comm_time: float,
) -> List[Bucket]:
    """§III.D: ensure every bucket's comm time < the smallest knapsack
    capacity; re-partition any violator."""
    out: List[Bucket] = []
    for b in buckets:
        t = comm_time_of(b.n_elements)
        if t <= max_comm_time or b.n_elements <= 1:
            out.append(b)
            continue
        k = int(t / max_comm_time) + 1
        out.extend(split_bucket(b, k, start_index=0))
    # renumber 1..N preserving order
    return [dataclasses.replace(b, index=i + 1) for i, b in enumerate(out)]


def model_layer_elems(cfg) -> List[int]:
    """Per-'layer' parameter counts in input->output order, including the
    embedding (first) and the head/final norm (last) as their own entries.
    Encoder (enc-dec archs) parameters are appended to the embedding entry:
    their gradients become ready early in backward, like input-side layers."""
    elems = [cfg.embed_params() + cfg.encoder_param_count()]
    elems.extend(cfg.layer_param_counts())
    head = cfg.d_model
    if not cfg.tie_embeddings:
        head += 0  # untied head already counted in embed_params
    elems.append(head)
    return elems


def build_buckets(
    cfg,
    strategy: str = "deft",
    partition_elems: int = 6_500_000,
    comm_time_of=None,
    max_comm_time: float = float("inf"),
) -> List[Bucket]:
    layer_elems = model_layer_elems(cfg)
    if strategy == "uniform":
        return partition_uniform(layer_elems, partition_elems)
    if strategy == "bytescheduler":
        return partition_bytescheduler(layer_elems, partition_elems)
    if strategy == "usbyte":
        return partition_usbyte(layer_elems, partition_elems)
    if strategy == "deft":
        base = partition_usbyte(layer_elems, partition_elems)
        if comm_time_of is None:
            return base
        return apply_deft_constraint(base, comm_time_of, max_comm_time)
    raise ValueError(f"unknown partition strategy {strategy!r}")
