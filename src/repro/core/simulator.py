"""Two-stream discrete-event timeline simulator.

The paper evaluates wall-clock throughput on a real 16-GPU cluster.  This
container has no cluster, so the *timeline* consequences of each scheduling
scheme (iteration time, bubbles, speedups — Figs. 10-16) are reproduced
with an event-driven model faithful to WFBP semantics:

* one serial **compute stream** (backward ``n-1..0`` then next iteration's
  forward ``0..n-1``),
* one or two FIFO **communication links** (primary; optional secondary at
  ``1/mu`` speed),
* dependency edges: a fresh bucket's comm starts only after its backward;
  a baseline's next-iteration forward of bucket ``b`` waits for bucket
  ``b``'s sync (the hard dependency DeFT removes); DeFT's forward-stage
  comms are WaitAll'ed at forward end (Algorithm 2 line 12).

The simulator runs either a :class:`BaselinePolicy` or a DeFT plan list and
reports steady-state iteration time + bubble fraction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.bucket import BucketTimes
from repro.core.links import LinkModel
from repro.core.policies import BaselinePolicy
from repro.core.scheduler import IterationPlan, Task


@dataclasses.dataclass
class SimResult:
    name: str
    iteration_time: float          # steady-state seconds/iteration
    compute_time: float            # pure compute per iteration
    bubble_fraction: float         # (iter - compute) / iter
    updates_per_iteration: float   # 1.0 for baselines; <=1 for DeFT
    timeline: Optional[List[Tuple[str, float, float, str]]] = None
    # timeline entries: (stream, start, end, label)
    # per-iteration wall durations (incl. warmup iterations) — the adapt
    # control plane consumes these as synthetic per-phase telemetry
    iteration_durations: Tuple[float, ...] = ()
    # decoupled AG streaming (DESIGN.md §12): steady-state seconds per
    # iteration the forward stalled waiting for a late all-gather
    ag_stall_s: float = 0.0

    @property
    def throughput_speedup_vs(self):
        return lambda other: other.iteration_time / self.iteration_time


class _Link:
    def __init__(self, model: LinkModel = LinkModel()):
        self.free_at = 0.0
        self.model = model

    def transmit(self, ready: float, duration: float) -> Tuple[float, float]:
        start = max(self.free_at, ready)
        end = start + self.model.time(duration)
        self.free_at = end
        return start, end


def simulate_baseline(
    times: BucketTimes,
    policy: BaselinePolicy,
    n_iterations: int = 12,
    keep_timeline: bool = False,
) -> SimResult:
    n = times.n
    link = _Link()
    t = 0.0
    timeline: List[Tuple[str, float, float, str]] = []
    comm_done: Dict[int, float] = {}   # bucket -> completion of last sync
    iter_starts: List[float] = []

    for it in range(n_iterations):
        iter_starts.append(t)
        # ---- forward (of this iteration; consumes last iteration's syncs)
        for b in range(n):
            if it > 0:
                if policy.overlap_forward:
                    t = max(t, comm_done.get(b, 0.0))
                # non-overlapping DDP handled after backward below
            s = t
            t += times.fwd[b]
            if keep_timeline:
                timeline.append(("compute", s, t, f"F{b}@{it}"))
        # ---- backward: produce gradients n-1..0
        ready: Dict[int, float] = {}
        for b in range(n - 1, -1, -1):
            s = t
            t += times.bwd[b]
            ready[b] = t
            if keep_timeline:
                timeline.append(("compute", s, t, f"B{b}@{it}"))
        # ---- event-driven link: at each free moment serve the highest-
        # priority READY bucket (a priority queue never idles the link
        # while lower-priority gradients are waiting)
        prio = {b: i for i, b in enumerate(policy.launch_order)}
        pending = set(range(n))
        t_link = link.free_at
        while pending:
            avail = [b for b in pending if ready[b] <= t_link]
            if not avail:
                t_link = min(ready[b] for b in pending)
                continue
            b = min(avail, key=lambda x: prio[x])
            s, e = link.transmit(max(t_link, ready[b]), times.comm[b])
            t_link = e
            comm_done[b] = e
            pending.remove(b)
            if keep_timeline:
                timeline.append(("link0", s, e, f"C{b}@{it}"))
        if not policy.overlap_forward:
            # PyTorch DDP: optimizer step waits for every all-reduce
            t = max(t, max(comm_done.values()))

    compute = times.fwd_total + times.bwd_total
    span = (t - iter_starts[2]) / (n_iterations - 2)  # skip warmup iters
    return SimResult(
        name=policy.name,
        iteration_time=span,
        compute_time=compute,
        bubble_fraction=max(0.0, 1.0 - compute / span),
        updates_per_iteration=1.0,
        timeline=timeline if keep_timeline else None,
        iteration_durations=_durations(iter_starts, t),
    )


def _durations(iter_starts: List[float], t_end: float) -> Tuple[float, ...]:
    bounds = iter_starts + [t_end]
    return tuple(bounds[i + 1] - bounds[i] for i in range(len(iter_starts)))


def simulate_deft(
    times: BucketTimes,
    plans: Sequence[IterationPlan],
    mu: float = 1.65,
    heterogeneous: bool = True,
    keep_timeline: bool = False,
    name: str = "deft",
    ag_times: Optional[Sequence[float]] = None,
    ag_mode: str = "streamed",
    ag_links: Optional[Sequence[int]] = None,
    ag_skip: bool = True,
    link_models: Optional[Dict[int, LinkModel]] = None,
) -> SimResult:
    """Run the DeFT plan list through the timeline model.

    Semantics per Algorithm 2: forward-stage comms launch at forward begin
    and are WaitAll'ed at forward end; backward-stage comms of *old* tasks
    launch at backward begin, fresh tasks at their gradient-ready time;
    parameter updates happen at iteration end and wait for every synced
    task of the completed generation (stale-parameter forward means no
    other dependency exists).

    Decoupled AG extension (DESIGN.md §12): with ``ag_times`` set, an
    iteration whose params are fresh (iteration 0, or the previous plan
    updated; every iteration when ``ag_skip`` is off) transmits one
    all-gather per bucket from forward start in deadline (= model) order,
    on ``ag_links[b]`` (default: all primary).  ``ag_mode="streamed"``
    stalls forward block ``b`` until its own AG lands — late AGs cost a
    *stall*, not a WaitAll bubble; ``ag_mode="burst"`` makes the first
    block wait for every AG (the fused engine's up-front ZeRO gather
    burst, kept as the comparison baseline).

    Heterogeneous-link pricing: ``link_models`` maps link id to a
    :class:`LinkModel` (latency + inverse-bandwidth); when omitted the
    legacy scalar model applies (unit primary, ``mu``-scaled secondary,
    no latency)."""
    n = times.n
    models = dict(link_models) if link_models else LinkModel.pair_from_mu(mu)
    links = {lid: _Link(m) for lid, m in models.items()}
    links.setdefault(0, _Link(LinkModel(0.0, 1.0)))
    links.setdefault(1, _Link(LinkModel(0.0, mu)))
    t = 0.0
    timeline: List[Tuple[str, float, float, str]] = []
    iter_starts: List[float] = []
    stalls: List[float] = []
    pending_done: Dict[Tuple[int, Tuple[int, ...]], float] = {}
    n_updates = 0
    if ag_times is not None and ag_mode not in ("streamed", "burst"):
        raise ValueError(f"unknown ag_mode {ag_mode!r}")

    for idx, plan in enumerate(plans):
        it = plan.iteration
        iter_starts.append(t)
        fwd_start = t
        it_stall = 0.0
        # decoupled all-gathers: issued ahead of the fwd-stage grad comms
        # (they carry deadlines; grad comms only face a WaitAll)
        ag_done: Dict[int, float] = {}
        if ag_times is not None and (
            not ag_skip or idx == 0 or plans[idx - 1].update
        ):
            for b in range(n):
                link_id = ag_links[b] if ag_links is not None else 0
                s, e = links[link_id].transmit(fwd_start, ag_times[b])
                ag_done[b] = e
                if keep_timeline:
                    timeline.append((f"link{link_id}", s, e, f"G{b}@{it}"))
        # forward-stage comms: old tasks, resident locally, start at once
        fwd_ends: List[float] = []
        for link_id, tasks in ((0, plan.fwd_primary), (1, plan.fwd_secondary)):
            for task in tasks:
                s, e = links[link_id].transmit(fwd_start, times.comm[task.bucket])
                fwd_ends.append(e)
                pending_done[(task.bucket, task.origins)] = e
                if keep_timeline:
                    timeline.append((f"link{link_id}", s, e, f"C{task.bucket}~{task.origins}"))
        if ag_done and ag_mode == "burst":
            # the fused engine materializes every param before block 0
            burst_end = max(ag_done.values())
            it_stall += max(0.0, burst_end - t)
            t = max(t, burst_end)
        # forward compute (no per-bucket sync dependency: delayed updates;
        # streamed AGs add the one real dependency — bucket b's params)
        for b in range(n):
            if ag_mode == "streamed" and b in ag_done:
                it_stall += max(0.0, ag_done[b] - t)
                t = max(t, ag_done[b])
            s = t
            t += times.fwd[b]
            if keep_timeline:
                timeline.append(("compute", s, t, f"F{b}@{it}"))
        stalls.append(it_stall)
        # WaitAll(order) at forward end
        if fwd_ends:
            t = max(t, max(fwd_ends))
        # backward compute
        bwd_start = t
        ready: Dict[int, float] = {}
        for b in range(n - 1, -1, -1):
            s = t
            t += times.bwd[b]
            ready[b] = t
            if keep_timeline:
                timeline.append(("compute", s, t, f"B{b}@{it}"))
        # backward-stage comms
        sync_ends: List[float] = []
        for link_id, tasks in ((0, plan.bwd_primary), (1, plan.bwd_secondary)):
            for task in tasks:
                fresh = it in task.origins
                avail = ready[task.bucket] if fresh else bwd_start
                s, e = links[link_id].transmit(avail, times.comm[task.bucket])
                sync_ends.append(e)
                pending_done[(task.bucket, task.origins)] = e
                if keep_timeline:
                    timeline.append((f"link{link_id}", s, e, f"C{task.bucket}~{task.origins}"))
        # parameter update at iteration end: waits for the generation's syncs
        if plan.update:
            n_updates += 1
            gen_ends = [
                e
                for (b, origins), e in pending_done.items()
                if set(origins) & set(plan.update_origins)
            ]
            if gen_ends:
                t = max(t, max(gen_ends))

    compute = times.fwd_total + times.bwd_total
    warm = max(2, len(plans) // 4)
    span = (t - iter_starts[warm]) / max(len(plans) - warm, 1)
    updates = sum(1 for p in plans[warm:] if p.update) / max(len(plans) - warm, 1)
    return SimResult(
        name=name,
        iteration_time=span,
        compute_time=compute,
        bubble_fraction=max(0.0, 1.0 - compute / span),
        updates_per_iteration=updates,
        timeline=timeline if keep_timeline else None,
        iteration_durations=_durations(iter_starts, t),
        ag_stall_s=sum(stalls[warm:]) / max(len(plans) - warm, 1),
    )
