"""Convergence Preserver (paper §IV.C).

DeFT's delayed/merged updates are equivalent to training with a *variable
batch-size sequence*: every N iterations the optimizer applies m <= N
updates with batch sizes ``k_1*B, ..., k_m*B`` where ``sum(k_i) == N``.

Convergence impact is quantified with the Gaussian-random-walk-with-rebound
model of Yin et al. (KDD'17, "Small batch or large batch?"): the training
loss is a walker ``s_t`` that either steps toward the objective ``S*`` or
rebounds past it; the per-update step is Gaussian with mean ``eta*mu_t``
and std ``eta*sigma_t/sqrt(B)`` (larger batches -> less noise).  The
closed-form expected next state is

    E_B(s_{t+1}) = (s_t - S* - eta*mu_t) * (Phi(a) - Phi(-a))
                   + (eta*sigma_t/sqrt(B)) * sqrt(2/pi) * exp(-a^2/2)
                   + S*
    a = (s_t - S* - eta*mu_t) * sqrt(B) / (eta*sigma_t)

The Preserver rolls this forward over one schedule period under both the
fixed-B sequence O_B (N updates) and DeFT's sequence O_D (m updates with
batch k_i*B) and compares the expected final losses.  A ratio outside
``[1-eps, 1+eps]`` fails the check; the feedback loop (deft.py) then
enlarges the knapsack capacity (more communication per iteration -> higher
update frequency) and re-solves, up to 10 retries.

Decoupled-collective invariance (DESIGN.md §12): splitting each sync into
a reduce-scatter item (backward capacity) and a streamed all-gather item
(forward deadline) moves communication *placement* only — a late AG
stalls the forward (``SimResult.ag_stall_s``), it never delays or merges
an update, so the k-sequence and therefore this check are unchanged.
The Planner runs the walk against the schedule solved on the RS-side
profile (``rs_times``) and the verdict transfers to the decoupled plan
verbatim.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence, Tuple


def _phi(x: float) -> float:
    """Standard normal CDF."""
    return 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))


@dataclasses.dataclass(frozen=True)
class WalkParams:
    """Inputs of the Gaussian-walk model, collected by the Profiler during
    the trial-application window (paper Fig. 7: "convergence info").

    s0:      current training loss.
    s_star:  objective loss value S* (lowest reachable; 0 is conservative).
    eta:     learning rate.
    mu:      mean gradient step magnitude per unit batch (square-sum of the
             gradient in the paper's notation).
    sigma:   per-example noise std of the step.
    batch:   the base global batch size B.
    """

    s0: float
    s_star: float = 0.0
    eta: float = 0.01
    mu: float = 1.0
    sigma: float = 10.0
    batch: int = 256


def expected_next_state(s_t: float, batch_mult: float, p: WalkParams) -> float:
    """E_{k*B}(s_{t+1}) with rebound (Yin et al. eq. used by the paper)."""
    b_eff = max(p.batch * batch_mult, 1e-9)
    drift = p.eta * p.mu
    noise = p.eta * p.sigma / math.sqrt(b_eff)
    centered = s_t - p.s_star - drift
    if noise <= 1e-30:
        # deterministic limit: plain descent with rebound
        return abs(centered) + p.s_star
    a = centered / noise
    e = (
        centered * (_phi(a) - _phi(-a))
        + noise * math.sqrt(2.0 / math.pi) * math.exp(-0.5 * a * a)
        + p.s_star
    )
    return e


def rollout(batch_mults: Sequence[float], p: WalkParams) -> float:
    """Expected loss after applying updates with the given batch-size
    multipliers in order, starting from p.s0."""
    s = p.s0
    for k in batch_mults:
        s = expected_next_state(s, k, p)
    return s


@dataclasses.dataclass(frozen=True)
class PreserverVerdict:
    ratio: float            # E[O_B] / E[O_D]
    e_baseline: float       # expected loss, fixed-B sequence
    e_deft: float           # expected loss, DeFT variable sequence
    ok: bool
    eps: float


def verdict_ok(ratio: float, eps: float) -> bool:
    """The acceptance band is INCLUSIVE at both ends: a schedule whose
    expected-loss ratio lands exactly on 1 +/- eps passes (the paper
    treats eps as the tolerated deviation, not a strict bound)."""
    return (1.0 - eps) <= ratio <= (1.0 + eps)


def check_schedule(
    batch_size_sequence: Sequence[int],
    period: int,
    params: WalkParams,
    eps: float = 0.01,
) -> PreserverVerdict:
    """Compare O_D = (k_1, ..., k_m) against O_B = (1,)*period.

    Note the paper's Table V: O_D applies *fewer* updates, each with a
    k-times-larger effective batch (less noise per update but fewer noise-
    averaging opportunities); the ratio stays ~1 when the sequence is mild.
    """
    ks = list(batch_size_sequence)
    if not ks:
        # schedule produced no updates in a period -> divergent by definition
        return PreserverVerdict(
            ratio=float("inf"), e_baseline=0.0, e_deft=float("inf"), ok=False, eps=eps
        )
    if len(ks) == period and all(k == 1 for k in ks):
        # degenerate m == N: O_D *is* O_B — an exact no-op by construction,
        # reported as ratio 1.0 without rolling the walk out twice (the two
        # rollouts are the same float computation, but s_star-near traces
        # could make the ratio 0/0; the identity needs no arithmetic)
        e_b = rollout([1.0] * period, params)
        return PreserverVerdict(ratio=1.0, e_baseline=e_b, e_deft=e_b, ok=True, eps=eps)
    assert sum(ks) >= period or True  # merged generations may straddle periods
    e_b = rollout([1.0] * period, params)
    e_d = rollout([float(k) for k in ks], params)
    denom = e_d - params.s_star
    numer = e_b - params.s_star
    ratio = numer / denom if abs(denom) > 1e-30 else float("inf")
    return PreserverVerdict(
        ratio=ratio, e_baseline=e_b, e_deft=e_d, ok=verdict_ok(ratio, eps), eps=eps
    )


def estimate_walk_params_from_losses(
    losses: Sequence[float],
    eta: float,
    batch: int,
    s_star: float = 0.0,
) -> WalkParams:
    """Fit mu/sigma from an observed loss trace (the Profiler's convergence
    log): mu from the mean per-step decrease, sigma from the residual std.
    Used by the live training loop; benchmarks use synthetic WalkParams."""
    if len(losses) < 3:
        return WalkParams(s0=losses[-1] if losses else 1.0, eta=eta, batch=batch)
    deltas = [losses[i] - losses[i + 1] for i in range(len(losses) - 1)]
    mean_d = sum(deltas) / len(deltas)
    var_d = sum((d - mean_d) ** 2 for d in deltas) / max(len(deltas) - 1, 1)
    mu = max(mean_d / max(eta, 1e-12), 1e-9)
    sigma = math.sqrt(max(var_d, 1e-18)) * math.sqrt(batch) / max(eta, 1e-12)
    return WalkParams(
        s0=losses[-1], s_star=s_star, eta=eta, mu=mu, sigma=sigma, batch=batch
    )
