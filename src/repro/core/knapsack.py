"""0/1 knapsack machinery (paper §III.B-C).

The scheduling problem: items are bucket *communication times* (value ==
weight), the knapsack capacity is merged *computation time*.  Three solvers:

* ``naive_knapsack``       — exact DP on microsecond-scaled integers
                             (Problem 1).
* ``recursive_knapsack``   — Algorithm 1: dependency-aware refinement for
                             the backward stage.  Scheduling the comm of the
                             deepest (output-side) bucket leaves only the
                             backward time of shallower buckets to overlap
                             with, so the recursion also tries dropping the
                             last item while shrinking capacity by that
                             bucket's backward time, and keeps the better.
* ``greedy_multi_knapsack``— Problem 2 heuristic for heterogeneous links:
                             capacities sorted ascending, items placed
                             longest-first into the smallest knapsack with
                             room.
* ``deadline_knapsack``    — decoupled-collective extension (DESIGN.md
                             §12): all-gather items streamed against the
                             forward pass carry a *deadline* (the start of
                             the first forward block that consumes the
                             bucket); selection maximizes covered time
                             over EDF-feasible subsets.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import numpy as np

_SCALE = 1e6  # seconds -> integer microseconds for exact DP
# Bound the DP table: with n items the capacity axis is clamped to
# _MAX_DP_CELLS / n cells (the rescale loop below coarsens the integer
# unit).  1M cells keeps every solve a few ms with <=0.1% capacity error
# at the paper's scales (ms..s bucket times).
_MAX_DP_CELLS = 1_000_000

# The Solver re-solves near-identical knapsack instances every iteration
# of its 96-step horizon (same bucket times, a handful of distinct
# capacities), and the Planner's Preserver feedback loop repeats the whole
# horizon up to 10 times.  Memoizing the integer-domain DP short-circuits
# all of that; results are EXACT cache hits (keys are the already-scaled
# integer weights + capacity, so there is no float-tolerance issue).
_MEMO_ENABLED = True
_MEMO_SIZE = 1 << 14


def set_knapsack_memoization(enabled: bool) -> bool:
    """Toggle the DP memo caches (benchmarks/tests); returns prior state."""
    global _MEMO_ENABLED
    prev = _MEMO_ENABLED
    _MEMO_ENABLED = bool(enabled)
    return prev


def clear_knapsack_caches() -> None:
    _naive_knapsack_int.cache_clear()
    _deadline_knapsack_int.cache_clear()


def knapsack_cache_info():
    """functools cache stats of the memoized DP core."""
    return _naive_knapsack_int.cache_info()


def deadline_knapsack_cache_info():
    """functools cache stats of the memoized deadline-DP core."""
    return _deadline_knapsack_int.cache_info()


def _to_int(xs: Sequence[float]) -> List[int]:
    return [max(0, int(round(x * _SCALE))) for x in xs]


@functools.lru_cache(maxsize=_MEMO_SIZE)
def _naive_knapsack_int(w: Tuple[int, ...], cap: int) -> Tuple[int, ...]:
    """Exact 0/1 DP over integer weights (value == weight); memoized.

    vectorized classic 0/1 DP: `cand` reads the pre-update row, so each
    item is used at most once; `choice` records per-item improvements
    for the backtrack."""
    n = len(w)
    dp = np.zeros(cap + 1, np.int64)
    choice = np.zeros((n, cap + 1), bool)
    for i in range(n):
        wi = w[i]
        if wi == 0:
            choice[i, :] = True   # zero-weight item always fits
            continue
        if wi > cap:
            continue
        cand = dp[: cap + 1 - wi] + wi
        better = cand > dp[wi:]
        dp[wi:] = np.where(better, cand, dp[wi:])
        choice[i, wi:] = better
    # backtrack
    sel: List[int] = []
    c = cap
    for i in range(n - 1, -1, -1):
        if choice[i, c]:
            sel.append(i)
            c -= w[i]
            if c < 0:
                c = 0
    sel.reverse()
    return tuple(sel)


def naive_knapsack(times: Sequence[float], capacity: float) -> List[int]:
    """Exact 0/1 knapsack (value == weight). Returns selected item indices.

    The DP runs on microsecond-scaled integers and is memoized across
    calls (the scheduler solves near-identical instances every horizon
    iteration — see ``set_knapsack_memoization``)."""
    n = len(times)
    if n == 0 or capacity <= 0:
        return []
    w = _to_int(times)
    # round (not truncate) so an exactly-fitting item is not rejected by
    # float noise; weights above use the same rounding
    cap = int(round(capacity * _SCALE))
    if cap <= 0:
        return []
    # Rescale to keep the DP table bounded (profiled capacities are
    # hundreds of ms = ~1e6 integer cells; the table stays a few MB).
    # Nonzero items stay >= 1 after rescaling — a coarsened-to-zero item
    # is NOT free and must still compete for capacity.
    while n * cap > _MAX_DP_CELLS and cap > 1:
        w = [max(x // 10, 1) if x > 0 else 0 for x in w]
        cap //= 10
    if _MEMO_ENABLED:
        sel = list(_naive_knapsack_int(tuple(w), cap))
    else:
        sel = list(_naive_knapsack_int.__wrapped__(tuple(w), cap))
    # rounding error is bounded by one (possibly rescaled) integer unit
    # per item; keep the matching tolerance
    unit = max(round(capacity * _SCALE), 1) / max(cap, 1) / _SCALE
    assert sum(times[i] for i in sel) <= capacity * 1.001 + n * unit + 1e-6
    return sel


@functools.lru_cache(maxsize=_MEMO_SIZE)
def _deadline_knapsack_int(
    w: Tuple[int, ...], d: Tuple[int, ...], cap: int
) -> Tuple[int, ...]:
    """Deadline-constrained reachability DP over positive integer weights.

    Items arrive pre-sorted by deadline (EDF order — any feasible subset
    stays feasible when transmitted in deadline order, so restricting the
    DP to that order loses nothing).  State: the set of reachable
    cumulative link times; adding item i at cumulative time c requires
    ``c + w[i] <= min(d[i], cap)``.  The memo key includes the deadline
    tuple — two instances identical except for deadlines are *different*
    problems and must not alias in the cache.
    """
    n = len(w)
    reach = np.zeros(cap + 1, bool)
    reach[0] = True
    choice = np.zeros((n, cap + 1), bool)
    for i in range(n):
        wi = w[i]
        di = min(d[i], cap)
        if wi <= 0 or wi > di:
            continue
        cand = np.zeros(cap + 1, bool)
        cand[wi : di + 1] = reach[: di + 1 - wi]
        new = cand & ~reach
        choice[i] = new          # first setter of each cumulative time
        reach |= new
    best = int(np.flatnonzero(reach)[-1])
    sel: List[int] = []
    c = best
    for i in range(n - 1, -1, -1):
        if choice[i, c]:
            sel.append(i)
            c -= w[i]
    sel.reverse()
    return tuple(sel)


def deadline_knapsack(
    times: Sequence[float],
    deadlines: Sequence[float],
    capacity: float,
) -> List[int]:
    """Deadline-constrained 0/1 knapsack (value == weight).

    Items are link transfers issued back-to-back from time zero in
    deadline (EDF) order; a selected item must *finish* by its deadline
    or it stalls the consumer instead of hiding behind it.  Returns the
    selected original indices maximizing total covered time subject to
    the per-item deadlines and the overall ``capacity``.

    Used for the decoupled all-gather items (DESIGN.md §12): deadline =
    the forward-prefix time at which the first block consuming the
    bucket starts, capacity = the forward compute window.
    """
    n = len(times)
    if n == 0 or capacity <= 0:
        return []
    if len(deadlines) != n:
        raise ValueError(
            f"deadline_knapsack: {n} times but {len(deadlines)} deadlines"
        )
    order = sorted(range(n), key=lambda i: (deadlines[i], i))
    w = _to_int([times[i] for i in order])
    d = _to_int([min(deadlines[i], capacity) for i in order])
    cap = int(round(capacity * _SCALE))
    if cap <= 0:
        return []
    while n * cap > _MAX_DP_CELLS and cap > 1:
        w = [max(x // 10, 1) if x > 0 else 0 for x in w]
        d = [x // 10 for x in d]
        cap //= 10
    # zero-duration items consume no link time and can be issued at time
    # zero ahead of everything: always covered, kept out of the DP
    sel = [order[j] for j in range(n) if w[j] == 0]
    pos = [j for j in range(n) if w[j] > 0]
    if pos:
        wp = tuple(w[j] for j in pos)
        dp_key = tuple(d[j] for j in pos)
        if _MEMO_ENABLED:
            picked = _deadline_knapsack_int(wp, dp_key, cap)
        else:
            picked = _deadline_knapsack_int.__wrapped__(wp, dp_key, cap)
        sel += [order[pos[k]] for k in picked]
    sel.sort()
    # EDF feasibility of the float-domain selection, up to one (possibly
    # rescaled) integer unit per item of rounding slack
    unit = max(round(capacity * _SCALE), 1) / max(cap, 1) / _SCALE
    t = 0.0
    for i in sorted(sel, key=lambda j: (deadlines[j], j)):
        t += times[i]
        assert t <= min(deadlines[i], capacity) * 1.001 + n * unit + 1e-6, (
            "deadline_knapsack produced an EDF-infeasible selection"
        )
    return sel


def recursive_knapsack(
    comm_times: Sequence[float],
    remain_time: float,
    bwd_times: Sequence[float],
    _depth: int = 0,
) -> List[int]:
    """Algorithm 1 (RecursiveKnapsack).

    ``comm_times``/``bwd_times`` are ordered as produced by backward:
    position 0 is bucket N (output side, gradient ready first), the last
    position is the shallowest considered bucket.  ``order1`` solves the
    plain knapsack; ``order2`` drops the *last* element (the shallowest
    bucket, whose comm would only start after nearly all backward is done)
    and shrinks the capacity by the backward time of its predecessor, per
    the paper's ``RecursiveKnapsack(CommTimeList - C_N, remainTime -
    T_{N-1})`` step.  The better total wins.
    """
    n = len(comm_times)
    if n == 0 or remain_time <= 0:
        return []
    if sum(comm_times) <= remain_time:
        return list(range(n))   # everything fits; recursion cannot improve
    order1 = naive_knapsack(comm_times, remain_time)
    if n == 1 or _depth > 30:
        return order1
    shrink = bwd_times[n - 2] if n - 2 < len(bwd_times) else 0.0
    s1 = sum(comm_times[i] for i in order1)
    # Fast path: the recursive branch solves with capacity shrunk by the
    # predecessor's backward time, so its total can never exceed
    # remain_time - shrink.  If the plain solve already saturates that,
    # recursing cannot win — skip the whole subtree.
    if s1 >= remain_time - shrink:
        return order1
    order2 = recursive_knapsack(
        comm_times[: n - 1], remain_time - shrink, bwd_times, _depth + 1
    )
    s2 = sum(comm_times[i] for i in order2)
    return order1 if s1 >= s2 else order2


def greedy_multi_knapsack(
    times: Sequence[float], capacities: Sequence[float]
) -> Dict[int, List[int]]:
    """Problem 2 greedy heuristic (§III.C): returns {knapsack_id: item
    indices}, knapsack ids indexing ``capacities`` as given.  Placement:
    capacities ascending, items by time descending, each item into the
    smallest-capacity knapsack that still has room.  O(N*M)."""
    order_caps = sorted(range(len(capacities)), key=lambda k: capacities[k])
    remaining = {k: capacities[k] for k in order_caps}
    items = sorted(range(len(times)), key=lambda i: -times[i])
    placed: Dict[int, List[int]] = {k: [] for k in range(len(capacities))}
    for i in items:
        for k in order_caps:
            if times[i] <= remaining[k]:
                placed[k].append(i)
                remaining[k] -= times[i]
                break
    for k in placed:
        placed[k].sort()
    return placed


def knapsack_two_link(
    times: Sequence[float],
    primary_capacity: float,
    secondary_capacity: float,
) -> Tuple[List[int], List[int]]:
    """Two-knapsack selection (primary=ICI/NCCL, secondary=slow link).

    Returns (primary_items, secondary_items).  Uses the greedy heuristic,
    then locally improves the primary set with the exact DP over the items
    the greedy left out or placed on the primary link, re-offering any
    item the refinement evicted (or the greedy never placed) to the
    residual secondary capacity.  The refined split is adopted only when
    its *total* covered time beats the greedy's — comparing primary load
    alone could adopt a split that evicts greedy picks outright and
    covers less overall."""
    placed = greedy_multi_knapsack(times, [primary_capacity, secondary_capacity])
    primary, secondary = placed.get(0, []), placed.get(1, [])
    # refinement: re-solve the primary knapsack exactly over all items not
    # on the secondary link
    free = [i for i in range(len(times)) if i not in secondary]
    sub = naive_knapsack([times[i] for i in free], primary_capacity)
    primary2 = [free[j] for j in sub]
    # evicted greedy picks and never-placed items compete for what the
    # secondary link has left, longest-first (the greedy's own ordering)
    secondary2 = list(secondary)
    residual = secondary_capacity - sum(times[i] for i in secondary)
    for i in sorted(set(free) - set(primary2), key=lambda j: -times[j]):
        if times[i] <= residual:
            secondary2.append(i)
            residual -= times[i]
    covered = lambda prim, sec: (
        sum(times[i] for i in prim) + sum(times[i] for i in sec)
    )
    if covered(primary2, secondary2) > covered(primary, secondary):
        primary, secondary = primary2, secondary2
    return sorted(primary), sorted(secondary)
