"""DeFT two-stage communication scheduling (paper §III.B, Algorithm 2).

The scheduler is a deterministic state machine over two queues:

* **current task queue** — the unsynchronized tail of the *oldest* gradient
  generation.  When it empties, that generation is fully synchronized and a
  parameter update fires at the end of the iteration.
* **future task queue**  — gradients of newer iterations, merged bucket-wise
  (gradient accumulation) while they wait.

Each training iteration is handled by one of the paper's four cases:

* Case 1 (forward):   schedule current-queue comms into the forward compute
                      time (no data dependencies — plain knapsack /
                      two-link multi-knapsack).
* Case 2 (backward):  backward time cannot cover the current queue — fill
                      it greedily with current-queue comms; the fresh
                      gradients merge into the future queue.
* Case 3 (backward):  backward covers the whole current queue — schedule it
                      all, then fill the remaining capacity from the fresh
                      generation (merged with any future-queue content)
                      using Algorithm 1; leftovers become the new current
                      queue; parameter update fires.
* Case 4 (backward):  current queue already empty — Algorithm 1 directly on
                      the fresh (merged) generation; leftovers become the
                      new current queue; update fires for the previously
                      completed generation.

Running the machine for a fixed horizon yields a cycle; the cycle is the
**periodic schedule** consumed by the simulator, the Preserver (as a
variable-batch-size sequence) and the JAX train loop (as per-step bucket
masks).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.bucket import BucketTimes
from repro.core.knapsack import (
    knapsack_two_link,
    naive_knapsack,
    recursive_knapsack,
)
from repro.core.links import LinkModel


@dataclasses.dataclass(frozen=True)
class Task:
    """A bucket instance awaiting synchronization.

    bucket:  0-based bucket id (0 = input-most, matches paper bucket #1).
    origins: iteration ids whose gradients are merged into this tensor.
             Merging does NOT grow the tensor — that is the paper's whole
             communication-volume reduction.
    """

    bucket: int
    origins: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class IterationPlan:
    """What happens in one training iteration under the schedule."""

    iteration: int
    case: str                              # 'case1+caseK' label for logs
    fwd_primary: Tuple[Task, ...]          # synced during forward, fast link
    fwd_secondary: Tuple[Task, ...]        # synced during forward, slow link
    bwd_primary: Tuple[Task, ...]          # synced during backward, fast link
    bwd_secondary: Tuple[Task, ...]
    new_to_future: bool                    # fresh grads merged into future q
    update: bool
    update_origins: Tuple[int, ...]        # origins applied by the update

    @property
    def synced(self) -> Tuple[Task, ...]:
        return self.fwd_primary + self.fwd_secondary + self.bwd_primary + self.bwd_secondary

    @property
    def k(self) -> int:
        """Batch-size multiplier of the update fired this iteration."""
        return len(self.update_origins)


@dataclasses.dataclass
class SchedulerConfig:
    heterogeneous: bool = True     # second (slow) link available
    mu: float = 1.65               # primary/secondary speed ratio
    capacity_factor: float = 1.0   # Preserver feedback scales capacities
    horizon: int = 96              # iterations to run before cycle detection
    # per-link latency + inverse-bandwidth pricing; None = the legacy
    # scalar model (unit primary, ``mu``-scaled secondary, no latency) —
    # that path is kept literally so existing plans stay byte-identical
    link_models: Optional[Dict[int, LinkModel]] = None

    def models(self) -> Dict[int, LinkModel]:
        return self.link_models or LinkModel.pair_from_mu(self.mu)


class DeftScheduler:
    """The paper's Solver: runs Algorithm 2 over profiled bucket times."""

    def __init__(self, times: BucketTimes, cfg: Optional[SchedulerConfig] = None):
        self.times = times
        self.cfg = cfg or SchedulerConfig()
        self.n = times.n

    # ---- helpers -----------------------------------------------------------
    def _caps(self, compute_time: float) -> Tuple[float, float]:
        """(primary, secondary) capacity in *nominal* comm seconds.

        With the legacy scalar model the secondary capacity is ``c / mu``
        (a duration d fits iff ``d * mu <= c``).  With per-link models the
        same conversion uses the secondary's inverse bandwidth; the
        latency term cannot be folded into a capacity and is charged
        per-item by the selection helpers instead."""
        c = compute_time * self.cfg.capacity_factor
        if not self.cfg.heterogeneous:
            return c, 0.0
        if self.cfg.link_models is None:
            return c, c / self.cfg.mu
        models = self.cfg.models()
        lm0 = models.get(0, LinkModel())
        lm1 = models.get(1, LinkModel(0.0, self.cfg.mu))
        return c / max(lm0.inv_bw, 1e-12), c / max(lm1.inv_bw, 1e-12)

    def _sec_fill(
        self, ordered: List[Task], cap_s: float
    ) -> Tuple[List[Task], List[Task]]:
        """Longest-first greedy fill of the slow link; returns
        (secondary, remaining).  ``cap_s`` is in nominal seconds; with
        per-link models each placed item is additionally charged the
        secondary latency (converted to nominal units)."""
        times = [self.times.comm[t.bucket] for t in ordered]
        lat = 0.0
        if self.cfg.link_models is not None:
            lm1 = self.cfg.models().get(1, LinkModel())
            lat = lm1.latency / max(lm1.inv_bw, 1e-12)
        sec: List[Task] = []
        for i in sorted(range(len(ordered)), key=lambda j: -times[j]):
            if times[i] + lat <= cap_s:
                sec.append(ordered[i])
                cap_s -= times[i] + lat
        return sec, [t for t in ordered if t not in sec]

    def _select_two_link(
        self, tasks: List[Task], cap_p: float, cap_s: float
    ) -> Tuple[List[Task], List[Task], List[Task]]:
        """(primary, secondary, leftover) from a task list via Problem 2."""
        times = [self.times.comm[t.bucket] for t in tasks]
        if self.cfg.link_models is not None:
            # charge per-item link latencies (nominal units) by shrinking
            # the offered durations' headroom: items are priced at
            # duration + latency/inv_bw on each link
            models = self.cfg.models()
            lm0 = models.get(0, LinkModel())
            lm1 = models.get(1, LinkModel())
            lat_p = lm0.latency / max(lm0.inv_bw, 1e-12)
            lat_s = lm1.latency / max(lm1.inv_bw, 1e-12)
            if lat_p > 0.0 or lat_s > 0.0:
                # distinct per-link weights: greedy secondary fill first
                # (longest-first, true secondary cost), exact DP on the
                # primary over the rest at true primary cost
                sec, rest = self._sec_fill(tasks, cap_s)
                rest_w = [self.times.comm[t.bucket] + lat_p for t in rest]
                sel = naive_knapsack(rest_w, cap_p)
                prim = [rest[i] for i in sel]
                leftover = [t for t in rest if t not in prim]
                return prim, sec, leftover
        p_idx, s_idx = knapsack_two_link(times, cap_p, cap_s)
        chosen = set(p_idx) | set(s_idx)
        return (
            [tasks[i] for i in p_idx],
            [tasks[i] for i in s_idx],
            [tasks[i] for i in range(len(tasks)) if i not in chosen],
        )

    def _select_backward_recursive(
        self, tasks: List[Task], cap_p: float, cap_s: float
    ) -> Tuple[List[Task], List[Task], List[Task]]:
        """Algorithm 1 for the backward stage over a *fresh* generation.

        Fresh gradients become ready output-side-first, and bucket 0 (input
        layer) is excluded — its comm is the hard dependency DeFT delays.
        The secondary link is filled greedily first; the primary uses the
        dependency-aware recursion.
        """
        # order tasks in backward production order: bucket n-1 ... 1
        ordered = sorted(
            [t for t in tasks if t.bucket != 0], key=lambda t: -t.bucket
        )
        frozen = [t for t in tasks if t.bucket == 0]
        sec: List[Task] = []
        if cap_s > 0 and ordered:
            sec, ordered = self._sec_fill(ordered, cap_s)
        comm = [self.times.comm[t.bucket] for t in ordered]
        bwd = [self.times.bwd[t.bucket] for t in ordered]
        sel = recursive_knapsack(comm, cap_p, bwd)
        prim = [ordered[i] for i in sel]
        leftover = [t for t in ordered if t not in prim] + frozen
        return prim, sec, leftover

    @staticmethod
    def _merge(future: List[Task], fresh: List[Task]) -> List[Task]:
        """Bucket-wise merge of the future queue into a fresh generation
        (gradient accumulation — tensor size unchanged)."""
        by_bucket: Dict[int, Tuple[int, ...]] = {t.bucket: t.origins for t in future}
        out = []
        for t in fresh:
            extra = by_bucket.get(t.bucket, ())
            out.append(Task(t.bucket, tuple(sorted(extra + t.origins))))
        return out

    # ---- the state machine ---------------------------------------------------
    def run(self, n_iterations: Optional[int] = None) -> List[IterationPlan]:
        n_iterations = n_iterations or self.cfg.horizon
        t_ = self.times
        current_q: List[Task] = []
        future_q: List[Task] = []
        plans: List[IterationPlan] = []

        for it in range(n_iterations):
            case_label = []
            fwd_p: List[Task] = []
            fwd_s: List[Task] = []
            # ---------------- forward stage (Case 1) ----------------
            if current_q:
                case_label.append("case1")
                cap_p, cap_s = self._caps(t_.fwd_total)
                fwd_p, fwd_s, current_q = self._select_two_link(
                    current_q, cap_p, cap_s
                )
            # ---------------- backward stage ----------------
            fresh = [Task(b, (it,)) for b in range(self.n)]
            bwd_p: List[Task] = []
            bwd_s: List[Task] = []
            new_to_future = False
            update = False
            update_origins: Tuple[int, ...] = ()

            cap_p, cap_s = self._caps(t_.bwd_total)
            if not current_q:
                # -------- Case 4 --------
                case_label.append("case4")
                if future_q:
                    fresh = self._merge(future_q, fresh)
                    future_q = []
                # exclude the first-computed bucket's backward from capacity:
                # nothing is ready to communicate while it runs
                cap_p = max(cap_p - t_.bwd[self.n - 1] * self.cfg.capacity_factor, 0.0)
                bwd_p, bwd_s, leftover = self._select_backward_recursive(
                    fresh, cap_p, cap_s
                )
                current_q = leftover
                if not leftover:
                    # whole generation synced within its own iteration
                    update = True
                    update_origins = tuple(
                        sorted({o for t in fresh for o in t.origins})
                    )
            else:
                covered = naive_knapsack(
                    [t_.comm[t.bucket] for t in current_q], cap_p + cap_s
                )
                if len(covered) < len(current_q):
                    # -------- Case 2 --------
                    case_label.append("case2")
                    bwd_p, bwd_s, current_q = self._select_two_link(
                        current_q, cap_p, cap_s
                    )
                    future_q = self._merge(future_q, fresh) if future_q else fresh
                    new_to_future = True
                else:
                    # -------- Case 3 --------
                    case_label.append("case3")
                    old = list(current_q)
                    # schedule the whole current queue first (greedy split
                    # across the two links, secondary takes what fits)
                    bwd_p, bwd_s, residue = self._select_two_link(
                        old, cap_p, cap_s
                    )
                    if residue:
                        # bin-packing split failure despite total-capacity
                        # cover — degrade to Case 2 semantics for residue
                        case_label[-1] = "case2"
                        current_q = residue
                        future_q = self._merge(future_q, fresh) if future_q else fresh
                        new_to_future = True
                    else:
                        used_p = sum(t_.comm[t.bucket] for t in bwd_p)
                        used_s = sum(t_.comm[t.bucket] for t in bwd_s)
                        if future_q:
                            fresh = self._merge(future_q, fresh)
                            future_q = []
                        p2, s2, leftover = self._select_backward_recursive(
                            fresh, max(cap_p - used_p, 0.0), max(cap_s - used_s, 0.0)
                        )
                        bwd_p += p2
                        bwd_s += s2
                        current_q = leftover
                        update = True
                        update_origins = tuple(
                            sorted({o for t in old for o in t.origins})
                        )

            # ---- liveness fallback ----
            # §III.D guarantees every bucket fits the smallest knapsack via
            # re-partitioning; if a caller feeds un-partitioned buckets
            # larger than any capacity, the knapsacks select nothing and
            # the queues would starve.  Force the smallest pending bucket
            # through the primary link so the system always progresses
            # (the Preserver feedback then grows capacity as usual).
            if not (fwd_p or fwd_s or bwd_p or bwd_s) and current_q:
                forced = min(current_q, key=lambda t_k: t_.comm[t_k.bucket])
                current_q = [t for t in current_q if t is not forced]
                bwd_p.append(forced)
                case_label.append("forced")
                if not current_q:
                    update = True
                    update_origins = tuple(
                        sorted({o for o in forced.origins})
                    )

            # completed-in-forward generation: if the forward stage emptied
            # the queue and backward was Case 4, the emptied generation's
            # update fires now.
            if "case4" in case_label and (fwd_p or fwd_s) and not update:
                update = True
                update_origins = tuple(
                    sorted({o for t in (fwd_p + fwd_s) for o in t.origins})
                )

            plans.append(
                IterationPlan(
                    iteration=it,
                    case="+".join(case_label) or "case4",
                    fwd_primary=tuple(fwd_p),
                    fwd_secondary=tuple(fwd_s),
                    bwd_primary=tuple(bwd_p),
                    bwd_secondary=tuple(bwd_s),
                    new_to_future=new_to_future,
                    update=update,
                    update_origins=update_origins,
                )
            )
        return plans


# ---------------------------------------------------------------------------
# Periodic schedule extraction
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PhaseSpec:
    """One step of the periodic schedule in *train-step* terms (static —
    becomes a distinct compiled executable).

    route_new:   per-bucket routing of the freshly computed gradient:
                 'sync'    — all-reduce it this step (possibly merged with
                             the future accumulator),
                 'future'  — add into the future accumulator,
                 'current' — it becomes part of the new current generation
                             (leftover of Case 3/4), stored in cur_accum.
    sync_cur:    per-bucket mask — all-reduce the *current* accumulator.
    secondary:   per-bucket mask — the sync (new or cur) rides the slow
                 link (pod/DCN hierarchical all-reduce on multi-pod).
    rotate:      future accumulator becomes the current one after this step.
    do_update:   apply the optimizer with the completed generation.
    update_k:    number of merged origins in the applied gradient.
    """

    route_new: Tuple[str, ...]
    sync_cur: Tuple[bool, ...]
    secondary: Tuple[bool, ...]
    rotate: bool
    do_update: bool
    update_k: int
    # which accumulator feeds the update: 'cur' (an older generation
    # completed this step) or 'new' (Case 4: the fresh generation synced
    # fully within its own iteration).
    update_source: str = "cur"


@dataclasses.dataclass(frozen=True)
class DeftSchedule:
    """Periodic schedule: ``phases[i % period]`` drives step i."""

    plans: Tuple[IterationPlan, ...]       # one period worth of plans
    phases: Tuple[PhaseSpec, ...]
    period: int
    updates_per_period: int
    batch_size_sequence: Tuple[int, ...]   # k_i multipliers (Preserver input)

    @property
    def update_frequency(self) -> float:
        return self.updates_per_period / max(self.period, 1)

    @property
    def comm_volume_fraction(self) -> float:
        """Synced bucket-instances per period / (period * n_buckets)."""
        n = len(self.phases[0].route_new)
        synced = sum(len(p.synced) for p in self.plans)
        return synced / max(self.period * n, 1)


def _state_signature(plan: IterationPlan) -> Tuple:
    """Structure of an iteration used for cycle detection: bucket ids and
    *relative* origin offsets (absolute iteration numbers shift each cycle)."""

    def rel(tasks: Tuple[Task, ...]):
        return tuple(
            (t.bucket, tuple(plan.iteration - o for o in t.origins)) for t in tasks
        )

    return (
        plan.case,
        rel(plan.fwd_primary),
        rel(plan.fwd_secondary),
        rel(plan.bwd_primary),
        rel(plan.bwd_secondary),
        plan.new_to_future,
        plan.update,
        len(plan.update_origins),
    )


def _plan_to_phase(plan: IterationPlan, n_buckets: int) -> PhaseSpec:
    route = ["current"] * n_buckets   # default: leftover of a generation
    sync_cur = [False] * n_buckets
    secondary = [False] * n_buckets
    fresh_synced = {t.bucket for t in plan.synced if plan.iteration in t.origins}
    old_synced = {t.bucket for t in plan.synced if plan.iteration not in t.origins}
    sec_buckets = {
        t.bucket for t in (plan.fwd_secondary + plan.bwd_secondary)
    }
    for b in range(n_buckets):
        if b in fresh_synced:
            route[b] = "sync"
        elif plan.new_to_future:
            route[b] = "future"
        if b in old_synced:
            sync_cur[b] = True
        if b in sec_buckets:
            secondary[b] = True
    # the fresh generation rotates into `cur` whenever Case 3/4 ran this
    # iteration — also when the liveness fallback appended "+forced" (a
    # forced fresh-origin sync still belongs to the rotated generation;
    # matching on endswith() here used to leave rotate=False and strand
    # an update_source="new" phase with no generation to update from)
    labels = plan.case.split("+")
    rotate = "case3" in labels or "case4" in labels
    update_source = (
        "new" if plan.update and plan.iteration in plan.update_origins else "cur"
    )
    return PhaseSpec(
        route_new=tuple(route),
        sync_cur=tuple(sync_cur),
        secondary=tuple(secondary),
        rotate=rotate,
        do_update=plan.update,
        update_k=max(len(plan.update_origins), 1),
        update_source=update_source,
    )


def extract_schedule(
    plans: Sequence[IterationPlan], n_buckets: int, warmup: int = 16
) -> DeftSchedule:
    """Detect the steady-state cycle and package it as a DeftSchedule."""
    sigs = [_state_signature(p) for p in plans]
    body = sigs[warmup:]
    period = len(body)
    for p in range(1, len(body) // 2 + 1):
        if all(body[i] == body[i % p] for i in range(len(body))):
            period = p
            break
    cycle = tuple(plans[warmup : warmup + period])
    phases = tuple(_plan_to_phase(pl, n_buckets) for pl in cycle)
    updates = sum(1 for pl in cycle if pl.update)
    ks = tuple(pl.k for pl in cycle if pl.update)
    return DeftSchedule(
        plans=cycle,
        phases=phases,
        period=period,
        updates_per_period=updates,
        batch_size_sequence=ks,
    )
