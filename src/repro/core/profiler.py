"""Analytical profiler — the JAX analogue of the paper's Nsight-trace
reconstruction (§IV.B).

The paper profiles a running PyTorch job with Nsight Systems and rebuilds
operator logs into bucket-level forward/backward/communication times.  On
this CPU container the TPU is a *target*, so we derive the same bucket-level
quantities analytically from the architecture config and a hardware model,
and (when a dry-run compile is available) re-base the totals against
``compiled.cost_analysis()`` so the scheduler consumes compiler-grounded
numbers rather than napkin ones.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.bucket import Bucket, BucketTimes, build_buckets, model_layer_elems


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """TPU v5e-like chip + interconnect model (assignment constants)."""

    chip_flops: float = 197e12        # bf16 peak FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    ici_bw: float = 50e9              # bytes/s per link (primary)
    mu: float = 1.65                  # primary/secondary speed ratio (paper)
    mfu: float = 0.45                 # assumed compute efficiency
    dp_degree: int = 16               # devices participating in grad allreduce
    grad_bytes_per_elem: int = 4      # fp32 gradient sync

    @property
    def secondary_bw(self) -> float:
        return self.ici_bw / self.mu

    def allreduce_time(
        self,
        n_elements: int,
        link_bw: Optional[float] = None,
        bytes_per_elem: Optional[int] = None,
    ) -> float:
        """Ring all-reduce wall time for one gradient bucket.

        ``bytes_per_elem`` prices a narrower wire dtype (a
        :class:`~repro.core.precision.PrecisionPolicy` choice); the
        +20us launch latency is size-independent and does NOT scale."""
        bw = self.ici_bw if link_bw is None else link_bw
        d = self.dp_degree
        bpe = self.grad_bytes_per_elem if bytes_per_elem is None else bytes_per_elem
        vol = 2.0 * (d - 1) / d * n_elements * bpe
        # per-launch startup latency (the paper's motivation for fusion)
        return vol / bw + 20e-6

    def compute_time(self, flops: float) -> float:
        return flops / (self.chip_flops * self.mfu)


@dataclasses.dataclass(frozen=True)
class Profile:
    """Everything the Solver consumes."""

    cfg: ArchConfig
    hw: HardwareModel
    buckets: List[Bucket]
    times: BucketTimes

    @property
    def coverage_rate(self) -> float:
        return self.times.coverage_rate


def _layer_flops_fwd(cfg: ArchConfig, seq_len: int, per_device_batch: int) -> List[float]:
    """Forward FLOPs per 'layer entry' (embedding, decoder layers, head) —
    matches model_layer_elems ordering."""
    tokens = per_device_batch * seq_len
    specs = cfg.layer_specs()
    elems = model_layer_elems(cfg)
    out: List[float] = []
    # embedding lookup is gather (negligible matmul FLOPs); encoder flops
    # are folded in if enc-dec.
    enc_flops = 0.0
    if cfg.is_encoder_decoder:
        enc_flops = 2.0 * cfg.encoder_param_count() * tokens
    out.append(enc_flops + 2.0 * tokens * cfg.d_model)  # embed scale etc.
    hd = cfg.resolved_head_dim
    for i, spec in enumerate(specs):
        # matmul term: 2 * active params of this layer
        if spec.ffn == "moe" and cfg.moe and i >= cfg.moe.first_k_dense:
            me = cfg.moe
            de = me.d_expert or cfg.d_ff
            active = (
                cfg._attn_params(spec)
                + (me.experts_per_token + me.n_shared_experts) * 3 * cfg.d_model * de
                + cfg.d_model * me.n_experts
            )
        else:
            active = elems[1 + i]
        f = 2.0 * active * tokens
        # attention quadratic term
        if spec.kind in ("attn", "mla"):
            ctx = seq_len / 2
        elif spec.kind == "local_attn":
            ctx = min(cfg.sliding_window or seq_len, seq_len)
        elif spec.kind == "cross_attn":
            ctx = max(cfg.n_modal_tokens, 1)
        else:
            ctx = 0
        if ctx:
            if spec.kind == "mla":
                hde = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim + cfg.mla.v_head_dim
            else:
                hde = 2 * hd
            f += 2.0 * tokens * cfg.n_heads * ctx * hde
        out.append(f)
    # LM head
    out.append(2.0 * tokens * cfg.d_model * cfg.vocab_size * (0 if cfg.tie_embeddings else 1))
    if cfg.tie_embeddings:
        out[-1] = 2.0 * tokens * cfg.d_model * cfg.vocab_size  # tied head still matmuls
    return out


def profile_arch(
    cfg: ArchConfig,
    hw: HardwareModel = HardwareModel(),
    seq_len: int = 4096,
    per_device_batch: int = 1,
    partition_strategy: str = "deft",
    partition_elems: int = 6_500_000,
    rebase_total_flops: Optional[float] = None,
) -> Profile:
    """Build buckets and derive their fwd/bwd/comm times.

    rebase_total_flops: if given (from compiled.cost_analysis()), scale all
    per-layer FLOPs so their total matches the compiler's count.
    """
    layer_flops = _layer_flops_fwd(cfg, seq_len, per_device_batch)
    if rebase_total_flops:
        scale = rebase_total_flops / max(sum(layer_flops) * 3.0, 1.0)
        layer_flops = [f * scale for f in layer_flops]

    # smallest knapsack capacity ~ fwd_time / mu (paper §III.D)
    fwd_total = sum(hw.compute_time(f) for f in layer_flops)
    buckets = build_buckets(
        cfg,
        strategy=partition_strategy,
        partition_elems=partition_elems,
        comm_time_of=lambda n: hw.allreduce_time(n),
        max_comm_time=fwd_total / hw.mu if partition_strategy == "deft" else float("inf"),
    )

    layer_elems = model_layer_elems(cfg)
    # distribute layer flops to buckets proportionally to covered elements
    fwd, bwd, comm = [], [], []
    for b in buckets:
        f = 0.0
        for lid in b.layer_ids:
            share = b.n_elements / max(
                sum(bb.n_elements for bb in buckets if lid in bb.layer_ids), 1
            )
            f += layer_flops[lid if lid >= 0 else 0] * (
                share if b.split else 1.0 / _n_buckets_covering(buckets, lid)
            )
        fwd.append(hw.compute_time(f))
        bwd.append(hw.compute_time(2.0 * f))
        comm.append(hw.allreduce_time(b.n_elements))
    assert abs(sum(b.n_elements for b in buckets) - sum(layer_elems)) < max(layer_elems)
    return Profile(cfg=cfg, hw=hw, buckets=buckets, times=BucketTimes(tuple(fwd), tuple(bwd), tuple(comm)))


def _n_buckets_covering(buckets: Sequence[Bucket], lid: int) -> int:
    return max(1, sum(1 for b in buckets if lid in b.layer_ids))
