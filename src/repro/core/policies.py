"""Baseline communication-scheduling policies (Table III).

Each policy emits, per iteration, the *launch order* of the fresh gradient
buckets' all-reduces plus whether next-iteration forward of bucket ``b``
must wait for its communication (strict WFBP parameter dependency — true
for every baseline, eliminated by DeFT's delayed updates).

Buckets are 0-based with 0 = input-most; backward produces them in order
``n-1, ..., 0``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.core.bucket import BucketTimes


@dataclasses.dataclass(frozen=True)
class BaselinePolicy:
    """A launch-order policy.

    name:        scheme name.
    launch_order: bucket ids in the order the communication *queue* should
                  serve them once ready (earlier = higher priority).
    overlap_forward: whether comms may continue into next iteration's
                  forward (Bytescheduler/US-Byte yes; plain DDP no —
                  PyTorch DDP blocks the next step on all-reduce finish).
    """

    name: str
    launch_order: Sequence[int]
    overlap_forward: bool


def pytorch_ddp(times: BucketTimes) -> BaselinePolicy:
    """WFBP + tensor fusion: all-reduces launch in gradient-ready order
    (output to input) and the optimizer step (hence next forward) waits for
    all of them."""
    n = times.n
    return BaselinePolicy("pytorch-ddp", list(range(n - 1, -1, -1)), False)


def bytescheduler(times: BucketTimes) -> BaselinePolicy:
    """Priority (sequential) scheduling: smaller-index (input-side) tensors
    are prioritized so the next forward can start earliest; communications
    overlap next-iteration forward."""
    n = times.n
    return BaselinePolicy("bytescheduler", list(range(n)), True)


def usbyte(times: BucketTimes) -> BaselinePolicy:
    """US-Byte non-sequential greedy: order buckets to minimize the stall of
    next-iteration forward given unequal comm times.

    Greedy: process forward consumers in order 0..n-1; at each decision pick
    the not-yet-scheduled bucket with the *largest* comm time that still
    lets bucket b's comm finish before forward reaches layer b (estimated
    with cumulative forward prefix times); fall back to the smallest.  This
    mirrors the paper's description of a low-complexity greedy that beats
    strict priority order when tensor sizes vary."""
    n = times.n
    fwd_prefix = [0.0]
    for b in range(n):
        fwd_prefix.append(fwd_prefix[-1] + times.fwd[b])
    unscheduled = set(range(n))
    order: List[int] = []
    t_link = 0.0
    for consumer in range(n):
        if consumer not in unscheduled:
            continue
        deadline = fwd_prefix[consumer]  # fwd of layer `consumer` starts
        # candidates whose comm fits before the deadline
        fits = [b for b in unscheduled if t_link + times.comm[b] <= deadline]
        # always make sure `consumer` itself is eventually scheduled; pick
        # largest fitting, else the consumer (forced, stall accepted)
        while fits:
            pick = max(fits, key=lambda b: times.comm[b])
            order.append(pick)
            unscheduled.remove(pick)
            t_link += times.comm[pick]
            if pick == consumer:
                break
            fits = [b for b in unscheduled if t_link + times.comm[b] <= deadline]
        if consumer in unscheduled:
            order.append(consumer)
            unscheduled.remove(consumer)
            t_link += times.comm[consumer]
    order.extend(sorted(unscheduled))
    return BaselinePolicy("us-byte", order, True)


ALL_BASELINES = {
    "pytorch-ddp": pytorch_ddp,
    "bytescheduler": bytescheduler,
    "us-byte": usbyte,
}
