"""Decoder/encoder block assembly per LayerSpec.

A block = (norm -> sequence mixer -> residual) -> (norm -> FFN -> residual),
with gemma2-style post-norms when cfg.post_block_norm.  Variants:

* attn / local_attn — GQA self-attention (window for local).
* mla               — DeepSeek-V2 latent attention.
* cross_attn        —
    - enc-dec decoder (seamless): self-attn + cross-attn + FFN sublayers;
    - VLM (llama-3.2-vision): standalone *gated* cross-attention block.
* rglru             — Griffin recurrent block.
* rwkv              — RWKV-6 time-mix; its FFN sublayer is the RWKV
                      channel-mix (token-shifted squared-relu MLP).

``apply_block`` threads an optional per-block cache (decode) and returns
the MoE auxiliary loss (0 for dense).  All functions are shape-polymorphic
over batch/sequence and contain no Python-level branching on traced
values, so the same code lowers for train, prefill and decode.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec
from repro.models import attention as attn
from repro.models import recurrent as rec
from repro.models.common import apply_norm, init_ffn, init_norm, apply_ffn
from repro.models.moe import apply_moe, init_moe


def init_block(
    key, cfg, spec: LayerSpec, *, dense_ffn_width: Optional[int] = None,
    dtype=jnp.float32,
) -> Dict:
    kmix, kffn, kx = jax.random.split(key, 3)
    p: Dict = {"pre_norm": init_norm(cfg.norm, cfg.d_model, dtype)}
    if cfg.post_block_norm:
        p["post_mixer_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
        p["post_ffn_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)

    if spec.kind in ("attn", "local_attn"):
        p["mixer"] = attn.init_attention(kmix, cfg, dtype)
    elif spec.kind == "mla":
        p["mixer"] = attn.init_mla(kmix, cfg, dtype)
    elif spec.kind == "cross_attn":
        if cfg.is_encoder_decoder:
            k1, k2 = jax.random.split(kmix)
            p["mixer"] = attn.init_attention(k1, cfg, dtype)       # self
            p["cross"] = attn.init_cross_attention(k2, cfg, dtype)
            p["cross_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
        else:  # VLM gated cross block
            p["mixer"] = attn.init_cross_attention(kmix, cfg, dtype)
    elif spec.kind == "rglru":
        p["mixer"] = rec.init_rglru_block(kmix, cfg, dtype)
    elif spec.kind == "rwkv":
        p["mixer"] = rec.init_rwkv_timemix(kmix, cfg, dtype)
    else:
        raise ValueError(spec.kind)

    p["ffn_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
    if spec.kind == "rwkv":
        p["ffn"] = rec.init_rwkv_channelmix(kffn, cfg, dtype)
    elif spec.ffn == "moe":
        p["ffn"] = init_moe(kffn, cfg, dtype)
    else:
        p["ffn"] = init_ffn(kffn, cfg, d_ff=dense_ffn_width, dtype=dtype)
    return p


def init_block_cache(
    cfg, spec: LayerSpec, batch: int, max_len: int, *, dtype=jnp.float32,
    prefill_chunk: int = 1,
) -> Dict:
    c: Dict = {}
    if spec.kind == "attn":
        c["kv"] = attn.make_kv_cache(cfg, batch, max_len, dtype=dtype)
    elif spec.kind == "local_attn":
        c["kv"] = attn.make_kv_cache(
            cfg, batch, max_len, window=cfg.sliding_window, dtype=dtype,
            prefill_chunk=prefill_chunk,
        )
    elif spec.kind == "mla":
        c["kv"] = attn.make_mla_cache(cfg, batch, max_len, dtype=dtype)
    elif spec.kind == "cross_attn":
        if cfg.is_encoder_decoder:
            c["kv"] = attn.make_kv_cache(cfg, batch, max_len, dtype=dtype)
        m = max(cfg.n_modal_tokens, 1)
        hd = cfg.resolved_head_dim
        c["cross_k"] = jnp.zeros((batch, m, cfg.n_kv_heads, hd), dtype)
        c["cross_v"] = jnp.zeros((batch, m, cfg.n_kv_heads, hd), dtype)
    elif spec.kind == "rglru":
        c["state"] = rec.make_rglru_state(cfg, batch, dtype=dtype)
    elif spec.kind == "rwkv":
        c["state"] = rec.make_rwkv_state(cfg, batch, dtype=dtype)
    return c


def apply_block(
    p: Dict,
    x: jax.Array,
    *,
    cfg,
    spec: LayerSpec,
    pos: int | jax.Array = 0,
    cache: Optional[Dict] = None,
    memory: Optional[jax.Array] = None,   # cross-attn memory [B, M, d]
    fill_cross_cache: bool = False,       # prefill: project+store memory kv
    causal: bool = True,
    kv_length: Optional[jax.Array] = None,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    new_cache: Dict = dict(cache) if cache is not None else None
    aux = jnp.zeros((), jnp.float32)

    def norm(name, h):
        return apply_norm(p[name], h, cfg.norm)

    h = norm("pre_norm", x)
    if spec.kind in ("attn", "local_attn"):
        window = cfg.sliding_window if spec.kind == "local_attn" else 0
        out, kvc = attn.apply_self_attention(
            p["mixer"], h, cfg=cfg, window=window, causal=causal, pos=pos,
            cache=cache.get("kv") if cache else None, kv_length=kv_length,
        )
        if kvc is not None:
            new_cache["kv"] = kvc
    elif spec.kind == "mla":
        out, kvc = attn.apply_mla(
            p["mixer"], h, cfg=cfg, pos=pos,
            cache=cache.get("kv") if cache else None, kv_length=kv_length,
        )
        if kvc is not None:
            new_cache["kv"] = kvc
    elif spec.kind == "cross_attn" and cfg.is_encoder_decoder:
        out, kvc = attn.apply_self_attention(
            p["mixer"], h, cfg=cfg, causal=causal, pos=pos,
            cache=cache.get("kv") if cache else None, kv_length=kv_length,
        )
        if kvc is not None:
            new_cache["kv"] = kvc
    elif spec.kind == "cross_attn":  # VLM gated cross block
        kv = _resolve_cross_kv(p["mixer"], cache, new_cache, memory, cfg,
                               fill_cross_cache)
        out = attn.apply_cross_attention(p["mixer"], h, kv, cfg=cfg, gated=True)
    elif spec.kind == "rglru":
        out, st = rec.apply_rglru(
            p["mixer"], h, cfg=cfg, state=cache.get("state") if cache else None
        )
        if st is not None:
            new_cache["state"] = st
    elif spec.kind == "rwkv":
        out, st = rec.apply_rwkv_timemix(
            p["mixer"], h, cfg=cfg, state=cache.get("state") if cache else None
        )
        if st is not None:
            new_cache["state"] = st
    else:
        raise ValueError(spec.kind)

    if cfg.post_block_norm:
        out = norm("post_mixer_norm", out)
    x = x + out

    # enc-dec cross-attention sublayer
    if spec.kind == "cross_attn" and cfg.is_encoder_decoder:
        h = norm("cross_norm", x)
        kv = _resolve_cross_kv(p["cross"], cache, new_cache, memory, cfg,
                               fill_cross_cache)
        x = x + attn.apply_cross_attention(p["cross"], h, kv, cfg=cfg)

    # FFN sublayer
    h = norm("ffn_norm", x)
    if spec.kind == "rwkv":
        out, st = rec.apply_rwkv_channelmix(
            p["ffn"], h, state=new_cache.get("state") if cache else None
        )
        if st is not None:
            new_cache["state"] = st
    elif spec.ffn == "moe":
        out, aux = apply_moe(p["ffn"], h, cfg=cfg, capacity_factor=capacity_factor)
    else:
        out = apply_ffn(p["ffn"], h, cfg)
    if cfg.post_block_norm:
        out = norm("post_ffn_norm", out)
    x = x + out
    return x, new_cache, aux


def _resolve_cross_kv(mixer_p, cache, new_cache, memory, cfg, fill):
    """Cross-attention K/V: from memory at train/prefill; cached at decode."""
    if memory is not None:
        kv = attn.cross_kv(mixer_p, memory, cfg)
        if cache is not None and fill:
            new_cache["cross_k"], new_cache["cross_v"] = kv
        return kv
    assert cache is not None, "cross-attn needs memory or a filled cache"
    return cache["cross_k"], cache["cross_v"]
