"""Mixture-of-Experts FFN with capacity-based token dispatch.

TPU/expert-parallel design: the routed experts' weights are stacked
[E, d, d_e] and sharded over the 'model' mesh axis ('experts' logical
dim).  Tokens are *gathered* into per-expert queues of static capacity C
(sort-free scatter build), processed with one batched einsum over the
expert dim, and scatter-added back weighted by the router probabilities.
This keeps the compute O(tokens * top_k * expert_flops * capacity_factor)
— not O(tokens * n_experts) — and lowers to a clean gather/einsum/scatter
HLO that XLA shards as expert parallelism (the combine emits the expected
all-reduce over the expert axis).

Tokens overflowing an expert's capacity are dropped (standard practice;
the residual connection carries them).  Shared experts (DeepSeek-V2) are
plain dense FFNs applied to every token.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, gated_act
from repro.sharding import constrain


def init_moe(key, cfg, dtype=jnp.float32) -> Dict:
    me = cfg.moe
    d = cfg.d_model
    de = me.d_expert or cfg.d_ff
    keys = jax.random.split(key, 5)
    e = me.n_experts
    std = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(keys[0], d, e, dtype),
        # routed experts live under their own key so the sharding rules can
        # tell the [E, d, de] expert tensors from (scan-stacked) dense FFNs
        "experts": {
            "gate": (jax.random.normal(keys[1], (e, d, de)) * std).astype(dtype),
            "up": (jax.random.normal(keys[2], (e, d, de)) * std).astype(dtype),
            "down": (
                jax.random.normal(keys[3], (e, de, d)) / math.sqrt(de)
            ).astype(dtype),
        },
    }
    if me.n_shared_experts:
        ks = jax.random.split(keys[4], 3)
        ds = de * me.n_shared_experts
        p["shared"] = {
            "gate": dense_init(ks[0], d, ds, dtype),
            "up": dense_init(ks[1], d, ds, dtype),
            "down": dense_init(ks[2], ds, d, dtype),
        }
    return p


def apply_moe(
    p: Dict,
    x: jax.Array,                 # [B, S, d]
    *,
    cfg,
    capacity_factor: float = 1.25,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,d], aux load-balance loss scalar)."""
    me = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = me.n_experts, me.experts_per_token
    xt = x.reshape(t, d)

    logits = (xt @ p["router"]).astype(jnp.float32)       # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                # [T, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style)
    density = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0
    )  # fraction of tokens per expert (x k)
    mean_prob = jnp.mean(probs, axis=0)
    aux = me.router_aux_coef * e * jnp.sum(density / k * mean_prob)

    cap = int(max(1, math.ceil(t * k / e * capacity_factor)))

    # position of each (token, choice) in its expert queue
    choice_e = top_e.reshape(-1)                          # [T*k]
    choice_t = jnp.repeat(jnp.arange(t), k)
    choice_w = top_p.reshape(-1)
    onehot = jax.nn.one_hot(choice_e, e, dtype=jnp.int32)  # [T*k, E]
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)       # exclusive count
    pos = jnp.sum(pos_in_e * onehot, axis=-1)              # [T*k]

    # scatter the token ids into per-expert queues; pos >= cap (overflow)
    # is out of bounds and dropped by the scatter itself
    slot_token = jnp.full((e, cap), t, jnp.int32)          # t = sentinel
    slot_token = slot_token.at[choice_e, pos].set(choice_t, mode="drop")
    slot_token = constrain(slot_token, ("experts", None))
    # gather tokens (sentinel reads row of zeros)
    xp = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = xp[slot_token]                                    # [E, C, d]
    xe = constrain(xe, ("experts", None, None))

    # every per-expert intermediate is pinned to the expert-parallel axis —
    # left unconstrained, the SPMD partitioner sometimes replicates the
    # whole [E, C, d_ff] activation (hundreds of GiB at 160 experts)
    ex = p["experts"]
    gate = constrain(jnp.einsum("ecd,edf->ecf", xe, ex["gate"]),
                     ("experts", None, None))
    up = constrain(jnp.einsum("ecd,edf->ecf", xe, ex["up"]),
                   ("experts", None, None))
    act = (
        gated_act(cfg.ffn_activation, gate, up)
        if cfg.ffn_activation in ("silu", "gelu")
        else jax.nn.gelu(up, approximate=True)
    )
    act = constrain(act, ("experts", None, None))
    ye = jnp.einsum("ecf,efd->ecd", act, ex["down"])       # [E, C, d]
    ye = constrain(ye, ("experts", None, None))

    # combine: scatter-add back to tokens with routing weights
    slot_w = jnp.zeros((e, cap), jnp.float32)
    slot_w = slot_w.at[choice_e, pos].set(choice_w, mode="drop")
    out = jnp.zeros((t + 1, d), jnp.float32)
    out = out.at[slot_token.reshape(-1)].add(
        (ye * slot_w[..., None]).reshape(e * cap, d), mode="drop"
    )
    y = out[:t].astype(x.dtype)

    if me.n_shared_experts:
        sh = p["shared"]
        # 'batch' on the flattened token dim (batch-major) — None would
        # force replication (see common.apply_ffn)
        g = constrain(xt @ sh["gate"], ("batch", "ff"))
        u = constrain(xt @ sh["up"], ("batch", "ff"))
        y = y + gated_act(cfg.ffn_activation, g, u) @ sh["down"]

    return constrain(y.reshape(b, s, d), ("batch", None, "embed")), aux
