"""Recurrent sequence mixers: RG-LRU (Griffin / RecurrentGemma) and RWKV-6
(Finch) time-mix + channel-mix.

Both are linear recurrences with O(1)-per-token state, which is what makes
the ``long_500k`` decode shape feasible: the decode state is

* RG-LRU — hidden h [B, W] + causal-conv ring [B, conv_width-1, W];
* RWKV-6 — per-head matrix state S [B, H, D, D] + the token-shift buffers.

Training uses ``jax.lax.associative_scan`` for the RG-LRU (the recurrence
is an affine map, so it parallelizes log-depth) and a chunked
``jax.lax.scan`` for RWKV-6 (data-dependent per-channel decay; the Pallas
kernel in kernels/rwkv6 blocks it over sequence with state in VMEM).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (Griffin)
# ---------------------------------------------------------------------------
_C_RGLRU = 8.0  # the paper's fixed scalar c


def init_rglru_block(key, cfg, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    w = cfg.resolved_lru_width
    heads = cfg.n_heads
    bh = w // heads
    keys = jax.random.split(key, 7)
    # Lambda init so that a = exp(-c*softplus(L)*r) starts near 0.9..0.999
    lam = jax.random.uniform(keys[0], (w,), minval=0.9, maxval=0.999)
    a_param = jnp.log(jnp.exp(-jnp.log(lam) / _C_RGLRU) - 1.0)  # inv softplus
    return {
        "wx": dense_init(keys[1], d, w, dtype),
        "wgate": dense_init(keys[2], d, w, dtype),
        "conv_w": (jax.random.normal(keys[3], (cfg.conv1d_width, w)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        # block-diagonal gate projections: [heads, bh, bh]
        "w_rgate": (jax.random.normal(keys[4], (heads, bh, bh)) / math.sqrt(bh)).astype(dtype),
        "w_igate": (jax.random.normal(keys[5], (heads, bh, bh)) / math.sqrt(bh)).astype(dtype),
        "a_param": a_param.astype(dtype),
        "wo": dense_init(keys[6], w, d, dtype),
    }


def make_rglru_state(cfg, batch: int, dtype=jnp.float32) -> Dict:
    w = cfg.resolved_lru_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
    }


def _causal_conv1d(x, conv_w, conv_b, state: Optional[jax.Array]):
    """Per-channel causal conv. x [B,S,W]; conv_w [K,W]. state: last K-1
    inputs from the previous call (decode) or None (train, zero history)."""
    k = conv_w.shape[0]
    if state is None:
        hist = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        hist = state.astype(x.dtype)
    xx = jnp.concatenate([hist, x], axis=1)  # [B, S+K-1, W]
    out = sum(
        xx[:, i : i + x.shape[1]] * conv_w[i][None, None, :] for i in range(k)
    )
    new_state = xx[:, -(k - 1) :] if k > 1 else hist[:, :0]
    return out + conv_b[None, None, :], new_state


def _block_diag_gate(y, w_gate, heads):
    """y [B,S,W] -> sigmoid(block-diag proj). w_gate [H, bh, bh]."""
    b, s, w = y.shape
    bh = w // heads
    yh = y.reshape(b, s, heads, bh)
    g = jnp.einsum("bshi,hij->bshj", yh, w_gate)
    return jax.nn.sigmoid(g.reshape(b, s, w).astype(jnp.float32))


def apply_rglru(
    p: Dict,
    x: jax.Array,                  # [B, S, d]
    *,
    cfg,
    state: Optional[Dict] = None,  # decode state
) -> Tuple[jax.Array, Optional[Dict]]:
    b, s, d = x.shape
    heads = cfg.n_heads
    gate = jax.nn.gelu((x @ p["wgate"]).astype(jnp.float32), approximate=True)
    xr = x @ p["wx"]
    xr = constrain(xr, ("batch", None, "lru"))
    y, new_conv = _causal_conv1d(
        xr, p["conv_w"], p["conv_b"], state["conv"] if state else None
    )
    r = _block_diag_gate(y, p["w_rgate"], heads)          # recurrence gate
    i = _block_diag_gate(y, p["w_igate"], heads)          # input gate
    log_a = -_C_RGLRU * jax.nn.softplus(p["a_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) normalizer, computed stably via log
    norm = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bt = norm * (i * y.astype(jnp.float32))
    from repro.kernels.rglru import rglru_scan  # dispatcher (pallas/ref)

    h0 = state["h"] if state else None
    h, h_final = rglru_scan(bt, a, h0)
    new_state = None
    if state is not None:
        new_state = {"h": h_final, "conv": new_conv}
    out = (h * gate).astype(x.dtype) @ p["wo"]
    return constrain(out, ("batch", None, "embed")), new_state


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------
_DDLERP_RANK = 32


def init_rwkv_timemix(key, cfg, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    keys = jax.random.split(key, 14)
    p = {
        # token-shift base mixes (mu_x for the shared ddlerp + per-proj mus)
        "mu_base": (jax.random.uniform(keys[0], (5, d)) * 0.5).astype(dtype),
        # ddlerp low-rank adapters: A [d, 5*rank], B [5, rank, d]
        "ddlerp_a": dense_init(keys[1], d, 5 * _DDLERP_RANK, dtype),
        "ddlerp_b": (jax.random.normal(keys[2], (5, _DDLERP_RANK, d)) * 0.01).astype(dtype),
        "wr": dense_init(keys[3], d, d, dtype),
        "wk": dense_init(keys[4], d, d, dtype),
        "wv": dense_init(keys[5], d, d, dtype),
        "wg": dense_init(keys[6], d, d, dtype),
        # decay: w = exp(-exp(w0 + lora)); w0 init for half-life spread
        "w0": jnp.linspace(-6.0, -0.5, d).astype(dtype),
        "w_lora_a": dense_init(keys[7], d, 64, dtype),
        "w_lora_b": (jax.random.normal(keys[8], (64, d)) * 0.01).astype(dtype),
        "u": (jax.random.normal(keys[9], (h, hd)) * 0.1).astype(dtype),  # bonus
        "wo": dense_init(keys[10], d, d, dtype),
        "ln_scale": jnp.ones((d,), dtype),   # per-head groupnorm scale
        "ln_bias": jnp.zeros((d,), dtype),
    }
    return p


def make_rwkv_state(cfg, batch: int, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    return {
        "s": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "shift_tm": jnp.zeros((batch, d), dtype),   # last token (time mix)
        "shift_cm": jnp.zeros((batch, d), dtype),   # last token (channel mix)
    }


def _token_shift(x, last: Optional[jax.Array]):
    """Return previous-token tensor: [B,S,d]; position 0 uses `last`."""
    if last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([last[:, None, :], x[:, :-1]], axis=1)
    return prev


def _ddlerp(p, x, prev):
    """Data-dependent lerp producing the 5 mixed inputs (r,k,v,w,g)."""
    dx = prev - x
    base = x[:, :, None, :] + dx[:, :, None, :] * p["mu_base"][None, None]
    # low-rank data-dependent adjustment
    lora = jnp.tanh(x @ p["ddlerp_a"])                     # [B,S,5*rank]
    b_, s_, _ = lora.shape
    lora = lora.reshape(b_, s_, 5, _DDLERP_RANK)
    adj = jnp.einsum("bsfr,frd->bsfd", lora, p["ddlerp_b"])
    mixed = base + dx[:, :, None, :] * adj                 # [B,S,5,d]
    return [mixed[:, :, j] for j in range(5)]


def rwkv_recurrence(r, k, v, w, u, s0: Optional[jax.Array] = None):
    """RWKV-6 linear recurrence, per head.

    r,k,v: [B,S,H,D]; w: [B,S,H,D] decay in (0,1); u: [H,D] bonus.
    S_t = diag(w_t) S_{t-1} + k_t^T v_t         (S: [D_k, D_v])
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    Returns o [B,S,H,D], final state [B,H,D,D].
    """
    b, s, h, dd = r.shape
    s_init = jnp.zeros((b, h, dd, dd), jnp.float32) if s0 is None else s0

    def step(state, xs):
        rt, kt, vt, wt = xs  # each [B,H,D]
        kv = kt[..., :, None] * vt[..., None, :]          # [B,H,Dk,Dv]
        out = jnp.einsum(
            "bhk,bhkv->bhv", rt, state + u[None, :, :, None] * kv
        )
        new_state = wt[..., :, None] * state + kv
        return new_state, out

    xs = tuple(
        t.transpose(1, 0, 2, 3).astype(jnp.float32) for t in (r, k, v, w)
    )
    final, outs = jax.lax.scan(step, s_init, xs)
    return outs.transpose(1, 0, 2, 3), final  # [B,S,H,D]


def apply_rwkv_timemix(
    p: Dict,
    x: jax.Array,
    *,
    cfg,
    state: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    from repro.kernels.rwkv6 import rwkv6_mix  # dispatcher (pallas/ref)

    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    prev = _token_shift(x, state["shift_tm"] if state else None)
    xr, xk, xv, xw, xg = _ddlerp(p, x, prev)
    r = (xr @ p["wr"]).reshape(b, s, h, hd)
    k = (xk @ p["wk"]).reshape(b, s, h, hd)
    v = (xv @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    w_log = p["w0"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    ).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(b, s, h, hd)
    r = constrain(r, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "heads", None))
    v = constrain(v, ("batch", None, "heads", None))
    s0 = state["s"] if state else None
    o, s_final = rwkv6_mix(r, k, v, w, p["u"].astype(jnp.float32), s0)
    # per-head group norm
    o = o.reshape(b, s, h, hd)
    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + 64e-5)
    o = o.reshape(b, s, d) * p["ln_scale"].astype(jnp.float32) + p[
        "ln_bias"
    ].astype(jnp.float32)
    out = (o.astype(x.dtype) * g) @ p["wo"]
    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["s"] = s_final
        new_state["shift_tm"] = x[:, -1, :]
    return constrain(out, ("batch", None, "embed")), new_state


def init_rwkv_channelmix(key, cfg, dtype=jnp.float32) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    keys = jax.random.split(key, 3)
    return {
        "mu_k": (jax.random.uniform(keys[0], (d,)) * 0.5).astype(dtype),
        "wk": dense_init(keys[1], d, f, dtype),
        "wv": dense_init(keys[2], f, d, dtype),
    }


def apply_rwkv_channelmix(
    p: Dict,
    x: jax.Array,
    *,
    state: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    prev = _token_shift(x, state["shift_cm"] if state else None)
    xk = x + (prev - x) * p["mu_k"][None, None]
    k = jnp.square(jax.nn.relu(constrain(xk @ p["wk"], ("batch", None, "ff"))))
    out = constrain(k @ p["wv"], ("batch", None, "embed"))
    new_state = None
    if state is not None:
        new_state = dict(state)
        new_state["shift_cm"] = x[:, -1, :]
    return out, new_state
