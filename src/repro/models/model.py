"""Full model: init / forward / prefill / decode over any ArchConfig.

Layer-stack assembly
--------------------
The decoder stack is organized as

    prefix  — the first ``moe.first_k_dense`` layers (dense-FFN variants of
              the pattern), unrolled;
    stack   — floor((n - prefix - tail) / period) whole pattern periods,
              with per-position weights stacked over periods and the
              period body run under ``jax.lax.scan`` (HLO size stays
              O(period), which is what keeps 512-device dry-run compiles
              tractable at 100 layers);
    tail    — the remainder layers, unrolled.

Gradients w.r.t. stacked leaves come back stacked, so one leaf == one
DeFT gradient bucket covering all periods of that weight — matching the
paper's "less than 20 items" knapsack regime.

Encoder-decoder (seamless) carries a separate scanned encoder over the
(stub-frontend) modality embeddings; VLM cross-attention layers consume
the modality embeddings directly as memory.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models.blocks import apply_block, init_block, init_block_cache
from repro.models.common import (
    apply_norm,
    cross_entropy_loss,
    dense_init,
    embed_init,
    init_norm,
    softcap,
)
from repro.sharding import constrain
from repro.util.flags import scan_unroll_enabled


# ---------------------------------------------------------------------------
# Stack layout
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StackLayout:
    prefix_specs: Tuple[LayerSpec, ...]
    period: int
    n_periods: int
    tail_specs: Tuple[LayerSpec, ...]

    @property
    def n_layers(self) -> int:
        return (
            len(self.prefix_specs)
            + self.period * self.n_periods
            + len(self.tail_specs)
        )


def stack_layout(cfg: ArchConfig) -> StackLayout:
    specs = cfg.layer_specs()
    n = len(specs)
    prefix = cfg.moe.first_k_dense if cfg.moe else 0
    p = cfg.pattern_period
    n_periods = (n - prefix) // p
    tail = n - prefix - n_periods * p
    return StackLayout(
        prefix_specs=specs[:prefix],
        period=p,
        n_periods=n_periods,
        tail_specs=specs[n - tail :] if tail else (),
    )


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    lay = stack_layout(cfg)
    keys = jax.random.split(key, 6)
    params: Dict[str, Any] = {
        "embed": {"table": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)},
        "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = {"w": dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)}

    # prefix (dense-FFN variants; deepseek-v2 layer 0 keeps the big d_ff)
    kp = jax.random.split(keys[2], max(len(lay.prefix_specs), 1))
    params["prefix"] = tuple(
        init_block(
            kp[i], cfg, dataclasses.replace(spec, ffn="dense"),
            dense_ffn_width=cfg.d_ff, dtype=dtype,
        )
        for i, spec in enumerate(lay.prefix_specs)
    )

    # scanned stack: one stacked tree per pattern position
    stack = []
    for j in range(lay.period):
        spec = cfg.layer_pattern[j]
        kj = jax.random.split(jax.random.fold_in(keys[3], j), max(lay.n_periods, 1))
        blocks = [
            init_block(kj[i], cfg, spec, dtype=dtype) for i in range(lay.n_periods)
        ]
        stack.append(_stack_trees(blocks) if blocks else {})
    params["stack"] = tuple(stack)

    kt = jax.random.split(keys[4], max(len(lay.tail_specs), 1))
    params["tail"] = tuple(
        init_block(kt[i], cfg, spec, dtype=dtype)
        for i, spec in enumerate(lay.tail_specs)
    )

    if cfg.is_encoder_decoder:
        ke = jax.random.split(keys[5], cfg.n_encoder_layers + 1)
        enc_blocks = [
            init_block(ke[i], cfg, LayerSpec("attn", "dense"), dtype=dtype)
            for i in range(cfg.n_encoder_layers)
        ]
        params["encoder"] = {
            "stack": _stack_trees(enc_blocks),
            "final_norm": init_norm(cfg.norm, cfg.d_model, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------
def init_cache(
    cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.float32,
    prefill_chunk: int = 1,
) -> Dict:
    kw = dict(dtype=dtype, prefill_chunk=prefill_chunk)
    lay = stack_layout(cfg)
    cache: Dict[str, Any] = {
        "prefix": tuple(
            init_block_cache(cfg, dataclasses.replace(s, ffn="dense"), batch,
                             max_len, **kw)
            for s in lay.prefix_specs
        ),
        "stack": tuple(
            _stack_trees(
                [
                    init_block_cache(cfg, cfg.layer_pattern[j], batch, max_len,
                                     **kw)
                    for _ in range(lay.n_periods)
                ]
            )
            if lay.n_periods
            else {}
            for j in range(lay.period)
        ),
        "tail": tuple(
            init_block_cache(cfg, s, batch, max_len, **kw)
            for s in lay.tail_specs
        ),
    }
    return cache


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def encode(params, cfg: ArchConfig, modal_embeds: jax.Array,
           unroll: bool = False) -> jax.Array:
    """Run the (bidirectional) encoder over stub-frontend embeddings."""
    assert cfg.is_encoder_decoder
    x = constrain(modal_embeds, ("batch", "modal", "embed"))
    spec = LayerSpec("attn", "dense")

    def body(x, block_p):
        x, _, _ = apply_block(block_p, x, cfg=cfg, spec=spec, causal=False)
        return x, None

    x, _ = jax.lax.scan(
        body, x, params["encoder"]["stack"],
        unroll=cfg.n_encoder_layers if (unroll or scan_unroll_enabled()) else 1,
    )
    return apply_norm(params["encoder"]["final_norm"], x, cfg.norm)


def forward(
    params,
    cfg: ArchConfig,
    tokens: jax.Array,                  # [B, S] int32
    *,
    memory: Optional[jax.Array] = None,  # [B, M, d] modality/encoder memory
    cache: Optional[Dict] = None,
    pos: int | jax.Array = 0,
    kv_length: Optional[jax.Array] = None,
    fill_cross_cache: bool = False,
    capacity_factor: float = 1.25,
    remat: bool = True,
    head: bool = True,
    unroll: bool = False,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (logits [B,S,V], new_cache, aux_loss); with ``head=False``
    the final-norm hidden states [B,S,d] replace the logits (the chunked
    loss path applies the LM head itself)."""
    lay = stack_layout(cfg)
    x = params["embed"]["table"][tokens]
    if cfg.embedding_multiplier != 1.0:
        x = x * jnp.asarray(cfg.embedding_multiplier, x.dtype)
    x = constrain(x, ("batch", None, "embed"))
    aux = jnp.zeros((), jnp.float32)

    block_kw = dict(
        cfg=cfg, pos=pos, memory=memory, fill_cross_cache=fill_cross_cache,
        kv_length=kv_length, capacity_factor=capacity_factor,
    )

    def run_block(p, x, spec, c):
        return apply_block(p, x, spec=spec, cache=c, **block_kw)

    maybe_ckpt = (
        jax.checkpoint(run_block, static_argnums=(2,)) if remat else run_block
    )

    new_prefix = []
    for i, spec in enumerate(lay.prefix_specs):
        spec_d = dataclasses.replace(spec, ffn="dense")
        c = cache["prefix"][i] if cache is not None else None
        x, nc, a = maybe_ckpt(params["prefix"][i], x, spec_d, c)
        new_prefix.append(nc)
        aux = aux + a

    def period_body(carry, xs):
        x, aux = carry
        stacked_p, stacked_c = xs
        new_cs = []
        for j in range(lay.period):
            c = stacked_c[j] if stacked_c is not None else None
            x, nc, a = maybe_ckpt(stacked_p[j], x, cfg.layer_pattern[j], c)
            aux = aux + a
            new_cs.append(nc if nc is not None else {})
        return (x, aux), tuple(new_cs)

    new_stack = None
    if lay.n_periods:
        stacked_c = tuple(cache["stack"]) if cache is not None else None
        xs = (tuple(params["stack"]), stacked_c)
        if cache is None:
            xs = (tuple(params["stack"]), None)
            (x, aux), _ = jax.lax.scan(
                lambda c, p: (period_body(c, (p, None))[0], None), (x, aux),
                xs[0],
                unroll=lay.n_periods if (unroll or scan_unroll_enabled()) else 1,
            )
        else:
            (x, aux), new_stack = jax.lax.scan(
                period_body, (x, aux), xs,
                unroll=lay.n_periods if (unroll or scan_unroll_enabled()) else 1,
            )

    new_tail = []
    for i, spec in enumerate(lay.tail_specs):
        c = cache["tail"][i] if cache is not None else None
        x, nc, a = maybe_ckpt(params["tail"][i], x, spec, c)
        new_tail.append(nc)
        aux = aux + a

    x = apply_norm(params["final_norm"], x, cfg.norm)
    if not head:
        new_cache = None
        if cache is not None:
            new_cache = {
                "prefix": tuple(new_prefix),
                "stack": new_stack if new_stack is not None else cache["stack"],
                "tail": tuple(new_tail),
            }
        return x, new_cache, aux
    logits = head_logits(params, cfg, x)

    new_cache = None
    if cache is not None:
        new_cache = {
            "prefix": tuple(new_prefix),
            "stack": new_stack if new_stack is not None else cache["stack"],
            "tail": tuple(new_tail),
        }
    return logits, new_cache, aux


# ---------------------------------------------------------------------------
# LM head + loss
# ---------------------------------------------------------------------------
def head_logits(params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Final-norm hidden states -> vocab logits (+ softcap)."""
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T
    else:
        logits = x @ params["head"]["w"]
    logits = constrain(logits, ("batch", None, "vocab"))
    if cfg.final_logit_softcap:
        logits = softcap(logits, cfg.final_logit_softcap)
    return logits


def chunked_ce(
    params,
    cfg: ArchConfig,
    x: jax.Array,                     # [B, S, d] final-norm hidden states
    targets: jax.Array,               # [B, S] int32
    mask: Optional[jax.Array],        # [B, S] or None
    chunk: int,
    unroll: bool = False,
) -> jax.Array:
    """Sequence-chunked LM head + cross entropy.

    The [B, S, V] logits tensor dominates train-step memory at production
    shapes (gemma2-2b train_4k: ~4 TB of f32 logits+softmax temporaries
    globally); computing head+CE per sequence chunk under jax.checkpoint
    caps the live logits buffer at [B, chunk, V] in both passes."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else (
            jnp.pad(jnp.ones((b, s), jnp.float32), ((0, 0), (0, pad)))
        )
    elif mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    n = x.shape[1] // chunk
    xs = x.reshape(b, n, chunk, d).swapaxes(0, 1)
    ys = targets.reshape(b, n, chunk).swapaxes(0, 1)
    ms = mask.reshape(b, n, chunk).swapaxes(0, 1)
    xs = constrain(xs, (None, "batch", None, "embed"))

    @jax.checkpoint
    def body(carry, sl):
        xc, yc, mc = sl
        xc = constrain(xc, ("batch", None, "embed"))
        logits = head_logits(params, cfg, xc).astype(jnp.float32)
        logits = constrain(logits, ("batch", None, "vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mc)), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ys, ms), unroll=n if (unroll or scan_unroll_enabled()) else 1,
    )
    return total / jnp.maximum(count, 1.0)


# ---------------------------------------------------------------------------
# Train / serve entry points
# ---------------------------------------------------------------------------
def loss_fn(
    params,
    cfg: ArchConfig,
    batch: Dict[str, jax.Array],
    *,
    capacity_factor: float = 1.25,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (+ MoE aux). batch: tokens, labels, and the
    optional stub-frontend 'memory' embeddings (audio frames / image
    patches).  ``loss_chunk > 0`` switches to the sequence-chunked LM-head
    path (memory: see chunked_ce)."""
    memory = batch.get("memory")
    if cfg.is_encoder_decoder:
        memory = encode(params, cfg, memory, unroll=unroll)
    if loss_chunk:
        x, _, aux = forward(
            params, cfg, batch["tokens"], memory=memory,
            capacity_factor=capacity_factor, remat=remat, head=False,
            unroll=unroll,
        )
        mask = batch.get("mask")
        loss = chunked_ce(
            params, cfg, x[:, :-1], batch["labels"][:, 1:],
            mask[:, 1:] if mask is not None else None, loss_chunk,
            unroll=unroll,
        )
    else:
        logits, _, aux = forward(
            params, cfg, batch["tokens"], memory=memory,
            capacity_factor=capacity_factor, remat=remat, unroll=unroll,
        )
        loss = cross_entropy_loss(
            logits[:, :-1], batch["labels"][:, 1:], batch.get("mask")
        )
    return loss + aux, {"ce": loss, "aux": aux}


def prefill(
    params, cfg: ArchConfig, tokens: jax.Array, cache: Dict,
    *, memory: Optional[jax.Array] = None, capacity_factor: float = 1.25,
    unroll: bool = False,
) -> Tuple[jax.Array, Dict]:
    """Fill the cache with a prompt; returns (last-position logits, cache)."""
    if cfg.is_encoder_decoder and memory is not None:
        memory = encode(params, cfg, memory, unroll=unroll)
    logits, cache, _ = forward(
        params, cfg, tokens, memory=memory, cache=cache, pos=0,
        fill_cross_cache=True, capacity_factor=capacity_factor, remat=False,
        unroll=unroll,
    )
    return logits[:, -1], cache


def decode_step(
    params, cfg: ArchConfig, token: jax.Array, cache: Dict, pos: jax.Array,
    *, kv_length: Optional[jax.Array] = None, capacity_factor: float = 1.25,
    unroll: bool = False,
) -> Tuple[jax.Array, Dict]:
    """One decode step: token [B] int32, absolute position ``pos``."""
    logits, cache, _ = forward(
        params, cfg, token[:, None], cache=cache, pos=pos, kv_length=kv_length,
        capacity_factor=capacity_factor, remat=False, unroll=unroll,
    )
    return logits[:, 0], cache
