"""Shared model primitives: norms, RoPE, activations, initializers.

Pure functions over explicit parameter dicts — no module framework.  All
weights are created by ``init_*`` helpers taking a PRNG key and returning
plain jnp arrays; forward helpers take ``(params, x, ...)``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init (std = 1/sqrt(d_in))."""
    std = 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * std).astype(
        dtype
    )


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d)) * 0.02).astype(
        dtype
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(kind: str, d: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1+scale)
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def apply_norm(p, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"].astype(jnp.float32))
        return y.astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_per_head(x: jax.Array, scale: Optional[jax.Array], eps: float = 1e-6):
    """qk-norm: RMS-normalize the last (head) dim. scale: [head_dim] or None."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """[head_dim/2] inverse frequencies."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, n_heads, head_dim]; positions: [..., seq] int32.

    Rotates pairs (x[2i], x[2i+1]) — NOT the half-split convention — which
    matches the reference Griffin/Gemma implementations and is internally
    self-consistent for train/prefill/decode.
    """
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)  # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., seq, half]
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin
    y2 = x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------
def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def gated_act(kind: str, gate: jax.Array, up: jax.Array) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(gate) * up
    if kind == "gelu":
        return jax.nn.gelu(gate, approximate=True) * up
    raise ValueError(kind)


def ffn_param_shapes(cfg, d_ff: Optional[int] = None) -> Tuple[str, ...]:
    return ("gate", "up", "down") if cfg.ffn_activation in ("silu", "gelu") else (
        "up",
        "down",
    )


def init_ffn(key, cfg, d_ff: Optional[int] = None, dtype=jnp.float32):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    if cfg.ffn_activation in ("silu", "gelu"):
        return {
            "gate": dense_init(keys[0], d, f, dtype),
            "up": dense_init(keys[1], d, f, dtype),
            "down": dense_init(keys[2], f, d, dtype),
        }
    return {
        "up": dense_init(keys[0], d, f, dtype),
        "down": dense_init(keys[1], f, d, dtype),
    }


def apply_ffn(p, x: jax.Array, cfg) -> jax.Array:
    from repro.sharding import constrain

    # NOTE: the leading dim must be named 'batch' — with_sharding_constraint
    # treats None dims as FORCED-REPLICATED, and an unnamed batch dim made
    # the partitioner all-gather the global batch into every FFN matmul
    # (54 GiB f32/step at gemma2-2b train_4k; see EXPERIMENTS.md §Perf).
    names_in = ["batch"] + [None] * (x.ndim - 2)
    if cfg.ffn_activation in ("silu", "gelu"):
        gate = constrain(x @ p["gate"], (*names_in, "ff"))
        up = constrain(x @ p["up"], (*names_in, "ff"))
        h = gated_act(cfg.ffn_activation, gate, up)
    else:  # plain (non-gated) GELU MLP — starcoder2 / seamless / rwkv-style
        h = jax.nn.gelu(constrain(x @ p["up"], (*names_in, "ff")), approximate=True)
    return constrain(h @ p["down"], (*names_in, "embed"))


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """Mean token cross-entropy; logits [..., V], labels int32 [...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
