"""Attention mixers: GQA self-attention (full / sliding-window / softcap /
qk-norm), cross-attention, and Multi-head Latent Attention (DeepSeek-V2).

Each mixer exposes ``init_*`` (params) and ``apply_*`` (forward) plus cache
constructors for the decode path:

* full attention      — KV cache [B, S_max, KV, D], written at ``pos``.
* sliding window      — ring-buffer cache [B, window, KV, D] (O(window)
                        state: this is what makes long_500k runnable for
                        local-attention architectures).
* MLA                 — *latent* cache [B, S_max, kv_lora + rope_dim];
                        decode uses the absorbed-matmul formulation so the
                        per-step cost is O(S * (kv_lora + rope)) per head,
                        never materializing full K/V.
* cross attention     — K/V of the (static) memory computed at prefill and
                        reused every decode step.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.sharded_decode import sharded_flash_decode
from repro.models.common import apply_rope, dense_init, rms_norm_per_head
from repro.sharding import constrain
from repro.util.flags import sharded_decode_enabled


def _use_sharded_decode(cache_k: jax.Array) -> bool:
    """Opt-in distributed-softmax decode over a sequence-sharded cache."""
    if not sharded_decode_enabled():
        return False
    mesh = jax.sharding.get_abstract_mesh()
    names = getattr(mesh, "axis_names", ()) if mesh is not None else ()
    if "model" not in names:
        return False
    n = dict(mesh.shape)["model"]
    return cache_k.shape[1] % n == 0 and cache_k.shape[1] >= n


# ---------------------------------------------------------------------------
# GQA self-attention / cross-attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg, dtype=jnp.float32) -> Dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv_, ko, kn = jax.random.split(key, 5)
    p = {
        "wq": dense_init(kq, d, cfg.n_heads * hd, dtype),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(kv_, d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dtype),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(p, xq, xkv, cfg):
    b, sq, _ = xq.shape
    sk = xkv.shape[1]
    hd = cfg.resolved_head_dim
    q = (xq @ p["wq"]).reshape(b, sq, cfg.n_heads, hd)
    k = (xkv @ p["wk"]).reshape(b, sk, cfg.n_kv_heads, hd)
    v = (xkv @ p["wv"]).reshape(b, sk, cfg.n_kv_heads, hd)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv", None))
    v = constrain(v, ("batch", None, "kv", None))
    if cfg.use_qk_norm:
        q = rms_norm_per_head(q, p["q_norm"])
        k = rms_norm_per_head(k, p["k_norm"])
    return q, k, v


def make_kv_cache(
    cfg, batch: int, max_len: int, window: int = 0, dtype=jnp.float32,
    prefill_chunk: int = 1,
):
    """window > 0 -> ring buffer.  The ring must hold ``window +
    prefill_chunk - 1`` positions so a chunked prefill never clobbers keys
    still visible to queries in the same chunk; decode (chunk=1) needs
    exactly ``window``.  Small contexts (max_len <= that) fall back to a
    plain full cache."""
    size = min(max_len, window + prefill_chunk - 1) if window else max_len
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
    }


def apply_self_attention(
    p: Dict,
    x: jax.Array,                       # [B, S, d]
    *,
    cfg,
    window: int = 0,
    causal: bool = True,
    pos: int | jax.Array = 0,           # absolute position of x[:, 0]
    cache: Optional[Dict] = None,       # decode: updated in place (functionally)
    kv_length: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    b, s, d = x.shape
    q, k, v = _project_qkv(p, x, x, cfg)
    qpos = pos + jnp.arange(s)
    q = apply_rope(q, qpos, cfg.rope_theta)
    k = apply_rope(k, qpos, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        size = cache["k"].shape[1]
        if (
            window
            and isinstance(pos, int)
            and pos + s > size                  # this call wraps the ring
            and size < window + s - 1           # ...and the ring is too small
        ):
            raise ValueError(
                f"ring cache ({size}) too small for window={window} with "
                f"chunk={s}; init it with prefill_chunk>={s}"
            )
        if window:
            # ring buffer write at pos % size
            idx = (pos + jnp.arange(s)) % size
            ck = cache["k"].at[:, idx].set(k)
            cv = cache["v"].at[:, idx].set(v)
            new_cache = {"k": ck, "v": cv}
            # linearize the ring for attention: roll so that the oldest
            # retained position comes first; compute absolute positions.
            newest = pos + s - 1
            oldest = jnp.maximum(newest - size + 1, 0)
            # absolute position stored in slot j is the largest p <= newest
            # with p % size == j
            slot = jnp.arange(size)
            slot_pos = newest - ((newest - slot) % size)
            att = _ring_attention(
                q, ck, cv, qpos, slot_pos, oldest, window,
                cfg.attn_logit_softcap,
            )
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
            new_cache = {"k": ck, "v": cv}
            length = kv_length if kv_length is not None else pos + s
            if s == 1 and _use_sharded_decode(ck):
                att = sharded_flash_decode(
                    q, ck, cv, length, softcap=cfg.attn_logit_softcap,
                )
            else:
                att = flash_attention(
                    q, ck, cv, causal=causal, softcap=cfg.attn_logit_softcap,
                    q_offset=pos, kv_length=jnp.broadcast_to(length, (b,)),
                    impl="ref",
                )
    else:
        att = flash_attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.attn_logit_softcap, q_offset=0,
        )
    att = constrain(att, ("batch", None, "heads", None))
    out = att.reshape(b, s, -1) @ p["wo"]
    return constrain(out, ("batch", None, "embed")), new_cache


def kv_size_needed(window: int, q_len: int) -> int:
    return window + q_len - 1


def _ring_attention(q, ck, cv, qpos, slot_pos, oldest, window, softcap_v):
    """Attention over a ring-buffer cache with absolute slot positions."""
    b, s, h, hd = q.shape
    kvh = ck.shape[2]
    group = h // kvh
    kf = jnp.repeat(ck.astype(jnp.float32), group, axis=2)
    vf = jnp.repeat(cv.astype(jnp.float32), group, axis=2)
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32) / jnp.sqrt(hd), kf
    )
    if softcap_v:
        scores = softcap_v * jnp.tanh(scores / softcap_v)
    valid = (slot_pos[None, :] <= qpos[:, None]) & (slot_pos[None, :] >= oldest)
    valid &= slot_pos[None, :] > qpos[:, None] - window  # window semantics
    scores = jnp.where(valid[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec decoder sublayer / VLM gated layer)
# ---------------------------------------------------------------------------
def init_cross_attention(key, cfg, dtype=jnp.float32) -> Dict:
    p = init_attention(key, cfg, dtype)
    p["gate"] = jnp.zeros((), dtype)  # VLM-style tanh gate (starts closed)
    return p


def cross_kv(p: Dict, memory: jax.Array, cfg):
    """Project the (static) memory to K/V once; reused across decode."""
    b, m, _ = memory.shape
    hd = cfg.resolved_head_dim
    k = (memory @ p["wk"]).reshape(b, m, cfg.n_kv_heads, hd)
    v = (memory @ p["wv"]).reshape(b, m, cfg.n_kv_heads, hd)
    if cfg.use_qk_norm:
        k = rms_norm_per_head(k, p["k_norm"])
    return constrain(k, ("batch", "modal", "kv", None)), constrain(
        v, ("batch", "modal", "kv", None)
    )


def apply_cross_attention(
    p: Dict,
    x: jax.Array,
    kv: Tuple[jax.Array, jax.Array],
    *,
    cfg,
    gated: bool = False,
) -> jax.Array:
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    q = constrain(q, ("batch", None, "heads", None))
    if cfg.use_qk_norm:
        q = rms_norm_per_head(q, p["q_norm"])
    k, v = kv
    att = flash_attention(q, k, v, causal=False, softcap=cfg.attn_logit_softcap)
    out = att.reshape(b, s, -1) @ p["wo"]
    if gated:
        out = jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out
    return constrain(out, ("batch", None, "embed"))


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------
def init_mla(key, cfg, dtype=jnp.float32) -> Dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    keys = jax.random.split(key, 8)
    return {
        "wdq": dense_init(keys[0], d, m.q_lora_rank, dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "wuq": dense_init(keys[1], m.q_lora_rank, h * qk_head, dtype),
        "wdkv": dense_init(keys[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "wuk": dense_init(keys[3], m.kv_lora_rank, h * m.qk_nope_head_dim, dtype),
        "wuv": dense_init(keys[4], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "wo": dense_init(keys[5], h * m.v_head_dim, d, dtype),
    }


def make_mla_cache(cfg, batch: int, max_len: int, dtype=jnp.float32):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def _mla_q(p, x, cfg, qpos):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rms_norm_per_head(x @ p["wdq"], p["q_norm"])
    q = (cq @ p["wuq"]).reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q = constrain(q, ("batch", None, "heads", None))
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, qpos, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, cfg, kpos):
    m = cfg.mla
    dkv = x @ p["wdkv"]
    ckv = rms_norm_per_head(dkv[..., : m.kv_lora_rank], p["kv_norm"])
    k_rope = dkv[..., m.kv_lora_rank :]
    # shared-across-heads rope key: add a singleton head dim for rotation
    k_rope = apply_rope(k_rope[:, :, None, :], kpos, cfg.rope_theta)[:, :, 0, :]
    return ckv, k_rope


def apply_mla(
    p: Dict,
    x: jax.Array,
    *,
    cfg,
    pos: int | jax.Array = 0,
    cache: Optional[Dict] = None,
    kv_length: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Train/prefill: expanded K/V. Decode (cache given): absorbed matmuls
    against the latent cache."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qpos = pos + jnp.arange(s)
    q_nope, q_rope = _mla_q(p, x, cfg, qpos)
    ckv, k_rope = _mla_latent(p, x, cfg, qpos)

    if cache is None:
        # expanded path
        k_nope = (ckv @ p["wuk"]).reshape(b, s, h, m.qk_nope_head_dim)
        vv = (ckv @ p["wuv"]).reshape(b, s, h, m.v_head_dim)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.qk_rope_head_dim))],
            axis=-1,
        )
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        att = flash_attention(q_full, k_full, vv, causal=True)
        out = att.reshape(b, s, -1) @ p["wo"]
        return constrain(out, ("batch", None, "embed")), None

    # absorbed decode path
    cckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, pos, axis=1)
    ckrope = jax.lax.dynamic_update_slice_in_dim(cache["krope"], k_rope, pos, axis=1)
    new_cache = {"ckv": cckv, "krope": ckrope}
    length = kv_length if kv_length is not None else pos + s
    smax = cckv.shape[1]
    # absorb W_uk into q: q_lat [b, s, h, kv_lora]
    wuk = p["wuk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, wuk)
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (
        jnp.einsum("bshl,bkl->bhsk", q_lat.astype(jnp.float32), cckv.astype(jnp.float32))
        + jnp.einsum("bshd,bkd->bhsk", q_rope.astype(jnp.float32), ckrope.astype(jnp.float32))
    ) * scale
    kpos_all = jnp.arange(smax)
    valid = (kpos_all[None, :] <= qpos[:, None]) & (kpos_all[None, :] < length)
    scores = jnp.where(valid[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhsk,bkl->bshl", probs, cckv.astype(jnp.float32))
    wuv = p["wuv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    att = jnp.einsum("bshl,lhd->bshd", out_lat, wuv).astype(x.dtype)
    out = att.reshape(b, s, -1) @ p["wo"]
    return constrain(out, ("batch", None, "embed")), new_cache
