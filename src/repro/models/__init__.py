from repro.models.model import (
    decode_step,
    encode,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
    stack_layout,
)

__all__ = [
    "decode_step", "encode", "forward", "init_cache", "init_params",
    "loss_fn", "prefill", "stack_layout",
]
