"""repro: DeFT (flexible communication scheduling) reproduction on JAX.

Importing any ``repro.*`` module activates the jax version-compat shims
(see ``repro.util.jax_compat``) so the new-jax API surface used across
the codebase and tests also runs on the older jax pinned in the CI
container.
"""
from repro.util.jax_compat import install as _install_jax_compat

_install_jax_compat()
