from repro.data.pipeline import SyntheticDataset, make_batch, batch_spec

__all__ = ["SyntheticDataset", "make_batch", "batch_spec"]
