"""Deterministic synthetic data pipeline.

Seeded, shardable, per-host reproducible: batch ``i`` is a pure function
of ``(seed, i)`` via ``jax.random.fold_in``, so every host materializes
exactly its shard without coordination and restarts are bit-reproducible
from the step counter (no data-loader state in checkpoints).

Token streams follow a Zipfian-ish distribution with a deterministic
n-gram structure (next token depends on the previous one through a seeded
permutation + noise), so models have something learnable — loss curves in
the convergence experiments actually descend, which the DeFT-vs-DDP
equivalence tests rely on.

Modality frontends are STUBS per the assignment: for audio/vision archs
the batch carries precomputed frame/patch embeddings of the right shape.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def _zipf_logits(vocab: int, exponent: float = 1.1) -> jax.Array:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -exponent * jnp.log(ranks)


def make_batch(
    cfg: ArchConfig,
    seed: int,
    step: int,
    batch: int,
    seq_len: int,
    *,
    structured: bool = True,
    dtype=jnp.float32,
) -> Dict[str, jax.Array]:
    """One global batch: tokens/labels (+ stub modality embeddings)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k_tok, k_mem, k_perm, k_noise = jax.random.split(key, 4)
    v = cfg.vocab_size
    if structured:
        # Markov-ish stream: tok[t+1] = perm[tok[t]] with prob ~0.7 else zipf
        perm = jax.random.permutation(
            jax.random.PRNGKey(seed + 1), jnp.arange(v)
        )
        first = jax.random.categorical(
            k_tok, _zipf_logits(v)[None, :].repeat(batch, 0)
        )

        def step_fn(tok, k):
            kk, kc = jax.random.split(k)
            follow = jax.random.bernoulli(kk, 0.7, (batch,))
            rand = jax.random.categorical(
                kc, _zipf_logits(v)[None, :].repeat(batch, 0)
            )
            nxt = jnp.where(follow, perm[tok], rand)
            return nxt, nxt

        _, toks = jax.lax.scan(
            step_fn, first, jax.random.split(k_noise, seq_len - 1)
        )
        tokens = jnp.concatenate([first[None], toks], axis=0).T
    else:
        tokens = jax.random.categorical(
            k_tok, _zipf_logits(v)[None, None, :], shape=(batch, seq_len)
        )
    tokens = tokens.astype(jnp.int32)
    out = {"tokens": tokens, "labels": tokens}
    if cfg.modality != "text":
        out["memory"] = (
            jax.random.normal(k_mem, (batch, cfg.n_modal_tokens, cfg.d_model))
            * 0.02
        ).astype(dtype)
    return out


def batch_spec(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for a batch (dry-run input_specs)."""
    spec = {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    }
    if cfg.modality != "text":
        spec["memory"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_modal_tokens, cfg.d_model), dtype
        )
    return spec


@dataclasses.dataclass
class SyntheticDataset:
    """Iterator facade over make_batch with a step counter."""

    cfg: ArchConfig
    seed: int
    batch: int
    seq_len: int
    structured: bool = True
    dtype: object = jnp.float32
    step: int = 0

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        b = make_batch(
            self.cfg, self.seed, self.step, self.batch, self.seq_len,
            structured=self.structured, dtype=self.dtype,
        )
        self.step += 1
        return b
