from repro.optim.optimizers import (
    OptimizerSpec,
    SegmentHParams,
    adamw,
    apply_updates,
    init_opt_state,
    leaf_hparams,
    sgd_momentum,
)

__all__ = [
    "OptimizerSpec",
    "SegmentHParams",
    "adamw",
    "sgd_momentum",
    "init_opt_state",
    "apply_updates",
    "leaf_hparams",
]
