from repro.optim.optimizers import (
    OptimizerSpec,
    adamw,
    apply_updates,
    init_opt_state,
    sgd_momentum,
)

__all__ = [
    "OptimizerSpec",
    "adamw",
    "sgd_momentum",
    "init_opt_state",
    "apply_updates",
]
