"""Optimizers in pure JAX (pytree in, pytree out) with first-class support
for DeFT's delayed updates.

DeFT's update with a merged gradient of k batches is *identical math* to
gradient accumulation: the accumulated gradient sum is divided by k before
the optimizer transform (see ``apply_updates(..., grad_scale=1/k)``).  The
optimizer step counter advances once per applied update, not per data
batch — exactly how PyTorch-side gradient accumulation behaves, which is
the equivalence Preserver reasons about.

State is a pytree mirroring params, suitable for ZeRO-1-style sharding of
(m, v) over the DP axis via PartitionSpecs from sharding/specs.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerSpec:
    name: str                       # 'adamw' | 'sgd'
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9           # sgd only
    grad_clip: float = 1.0          # global-norm clip; 0 disables
    # Per-leaf hyperparameter segments (see leaf_hparams).  'all' decays
    # every leaf (historical behavior); 'matrix' restricts weight decay
    # to ndim >= 2 leaves (norm scales / biases stay undecayed).
    decay_mask: str = "all"
    # lr multiplier for ndim < 2 leaves (norms/biases); 1.0 = no-op.
    ndim1_lr_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class SegmentHParams:
    """Static optimizer hyperparameters of one parameter leaf.

    This is the *segment metadata* the fused bucket-update kernels
    consume (kernels/bucket_update): each leaf's span inside a flat
    bucket buffer becomes one segment of the static segment-id map, and
    (lr_scale, weight_decay) are the only per-segment knobs the update
    math needs.  The per-leaf reference path (apply_updates) derives its
    behavior from the same tuples, so fused == reference by construction.
    """

    lr_scale: float
    weight_decay: float


def leaf_hparams(
    spec: OptimizerSpec, shapes
) -> Tuple[SegmentHParams, ...]:
    """Per-leaf (lr_scale, weight_decay) from the spec's segment rules.

    ``shapes`` is a sequence of leaf shapes in ``tree_flatten`` order (or
    a sequence of array-likes with ``.shape``).  Defaults reproduce the
    historical uniform behavior exactly.
    """
    out = []
    for s in shapes:
        shape = tuple(getattr(s, "shape", s))
        ndim = len(shape)
        wd = spec.weight_decay
        if spec.decay_mask == "matrix" and ndim < 2:
            wd = 0.0
        elif spec.decay_mask not in ("all", "matrix"):
            raise ValueError(f"unknown decay_mask {spec.decay_mask!r}")
        scale = spec.ndim1_lr_scale if ndim < 2 else 1.0
        out.append(SegmentHParams(lr_scale=scale, weight_decay=wd))
    return tuple(out)


def adamw(lr: float = 1e-3, **kw) -> OptimizerSpec:
    return OptimizerSpec("adamw", lr=lr, **kw)


def sgd_momentum(lr: float = 1e-2, momentum: float = 0.9, **kw) -> OptimizerSpec:
    return OptimizerSpec("sgd", lr=lr, momentum=momentum, **kw)


def init_opt_state(spec: OptimizerSpec, params, dtype=jnp.float32) -> Dict[str, Any]:
    """Moment buffers default to f32; giant models may pass bf16 (the
    dry-run does for the 236B/400B MoEs) — apply_updates computes in f32
    and casts back to the stored dtype."""
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, dtype), params)
    if spec.name == "adamw":
        return {"step": jnp.zeros((), jnp.int32), "m": zeros(), "v": zeros()}
    if spec.name == "sgd":
        return {"step": jnp.zeros((), jnp.int32), "m": zeros()}
    raise ValueError(spec.name)


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(
    spec: OptimizerSpec,
    params,
    grads,
    state: Dict[str, Any],
    *,
    grad_scale: float | jax.Array = 1.0,
    lr_scale: float | jax.Array = 1.0,
) -> Tuple[Any, Dict[str, Any]]:
    """One optimizer step.  grad_scale multiplies the raw gradient first
    (DeFT: 1/(dp_size * k) for a k-merged, psum'd gradient)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * grad_scale, grads)
    if spec.grad_clip:
        gn = _global_norm(grads)
        clip = jnp.minimum(1.0, spec.grad_clip / jnp.maximum(gn, 1e-12))
        grads = jax.tree.map(lambda g: g * clip, grads)

    step = state["step"] + 1
    lr = spec.lr * lr_scale

    # per-leaf hparam segments (same metadata the fused kernels consume),
    # rebuilt as pytrees of python floats aligned with params
    treedef = jax.tree_util.tree_structure(params)
    hps = leaf_hparams(spec, jax.tree_util.tree_leaves(params))
    wd_tree = jax.tree_util.tree_unflatten(
        treedef, [hp.weight_decay for hp in hps]
    )
    sc_tree = jax.tree_util.tree_unflatten(
        treedef, [hp.lr_scale for hp in hps]
    )

    if spec.name == "adamw":
        b1, b2 = spec.beta1, spec.beta2
        m = jax.tree.map(
            lambda m_, g: (b1 * m_.astype(jnp.float32) + (1 - b1) * g).astype(m_.dtype),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda v_, g: (b2 * v_.astype(jnp.float32) + (1 - b2) * g * g).astype(v_.dtype),
            state["v"], grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_, wd, sc):
            m_ = m_.astype(jnp.float32)
            v_ = v_.astype(jnp.float32)
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + spec.eps)
            if wd:
                u = u + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - (lr * sc) * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v, wd_tree, sc_tree)
        return new_params, {"step": step, "m": m, "v": v}

    if spec.name == "sgd":
        m = jax.tree.map(
            lambda m_, g: (spec.momentum * m_.astype(jnp.float32) + g).astype(m_.dtype),
            state["m"], grads,
        )

        def upd(p, m_, wd, sc):
            u = m_.astype(jnp.float32)
            if wd:
                u = u + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - (lr * sc) * u).astype(p.dtype)

        return (
            jax.tree.map(upd, params, m, wd_tree, sc_tree),
            {"step": step, "m": m},
        )

    raise ValueError(spec.name)
