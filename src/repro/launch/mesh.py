"""Production mesh construction.

A function, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro.util.jax_compat import install as _install_jax_compat

_install_jax_compat()


def _auto_axis_types(n: int):
    """axis_types kwarg value across jax versions.

    Newer jax exposes ``jax.sharding.AxisType`` natively; on older jax the
    compat shim provides a stand-in and ``jax.make_mesh`` ignores the
    kwarg (0.4.x meshes are implicitly all-Auto).
    """
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single v5e pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto_axis_types(len(axes)))


def make_elastic_mesh(device_rows):
    """Mesh over an EXPLICIT device subset — the elastic control plane's
    scale-down/up builds these (DESIGN.md §10): ``device_rows[i]`` is
    the tuple of model-axis devices of data-parallel shard ``i``, so a
    4->2 scale-down passes the two surviving rows and the dead devices
    simply stop appearing in any sharding.

    ``jax.make_mesh`` always spans ``jax.devices()``; this constructs
    ``jax.sharding.Mesh`` directly from the survivor array instead."""
    import numpy as np

    arr = np.array(device_rows, dtype=object)
    if arr.ndim == 1:
        arr = arr.reshape(-1, 1)
    try:
        return jax.sharding.Mesh(
            arr, ("data", "model"), axis_types=_auto_axis_types(2)
        )
    except (TypeError, AttributeError):
        # older jax: Mesh has no axis_types kwarg, or expects a dict form
        # — match what the make_mesh compat shim produces (all-Auto)
        return jax.sharding.Mesh(arr, ("data", "model"))


def make_debug_mesh(data: int = 4, model: int = 2, pod: int = 0):
    """Small CPU mesh for tests/examples (needs forced host device count)."""
    if pod:
        return jax.make_mesh(
            (pod, data, model),
            ("pod", "data", "model"),
            axis_types=_auto_axis_types(3),
        )
    return jax.make_mesh(
        (data, model), ("data", "model"), axis_types=_auto_axis_types(2)
    )


# ---------------------------------------------------------------------------
# Per-link communication chains (DESIGN.md §14)
# ---------------------------------------------------------------------------
def ring_chain(n: int, link: int) -> tuple:
    """Device-order chain (axis indices, DeAR-style ring reordering) for
    ``link`` over ``n`` data-parallel positions.

    Link 0 is the natural axis order — the ordering XLA's single-axis
    collectives already use, so primary traffic keeps its fabric.  Link
    ``l`` > 0 interleaves with stride ``l + 1`` (evens-then-odds for the
    first secondary link: ``[0, 2, ..., 1, 3, ...]``), which on a
    multi-NIC torus maps neighbor hops onto a *different* physical cable
    set than the natural ring — the DeAR observation that decoupled
    stages on distinct device orders stop contending for the same links.
    Falls back to a rotation when the stride pattern degenerates (it
    never does for n >= 3, but n <= 2 has only one ring)."""
    if n <= 0:
        raise ValueError(f"ring_chain needs n >= 1, got {n}")
    if link <= 0 or n <= 2:
        return tuple(range(n))
    stride = link + 1
    chain = [p for s in range(stride) for p in range(s, n, stride)]
    if len(set(chain)) != n:
        chain = [(p + link) % n for p in range(n)]
    return tuple(chain)


def link_chains(n: int, n_links: int = 2) -> dict:
    """``{link_id: chain}`` for every link — the topology input the
    runtime's chain collectives and the planner's per-link pricing
    share."""
    return {link: ring_chain(n, link) for link in range(n_links)}
