import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh x mode)
combination against the production mesh without allocating a byte of
model memory (ShapeDtypeStruct inputs with NamedShardings).

The two XLA lines above MUST run before any other import — jax locks the
device count on first init, and the production meshes need 512 placeholder
host devices.

Cost methodology (see EXPERIMENTS.md §Dry-run):

* The FULL config is lowered with rolled scans — that compile is the
  memory evidence (buffer reuse across scan iterations matches a real
  run) and the gradient-sync collective evidence (grad all-reduces act on
  stacked leaves OUTSIDE the layer scan, so the rolled HLO counts them
  exactly).
* XLA cost_analysis counts a while-loop body once regardless of trip
  count, so FLOPs / bytes / total collective bytes come from TWO small
  fully-unrolled variants (1 and 2 scan periods) extrapolated linearly to
  the full depth: ``est(N) = c1 + (N - 1) * (c2 - c1)``.  The fixed parts
  (embedding, LM head + chunked CE, prefix/tail layers, encoder) cancel
  exactly in the delta.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all              # 40-combo baseline
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi # 512-chip pass
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --mode deft
"""
import argparse
import dataclasses
import functools
import json
import pathlib
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, SHAPES, config_for_shape, get_shape
from repro.core.deft import Planner, PlanRequest
from repro.core.scheduler import SchedulerConfig
from repro.core.profiler import HardwareModel
from repro.launch.analysis import (
    analyse_compiled,
    collective_bytes,
    model_flops_for,
)
from repro.launch.inputs import serve_input_specs, train_input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.model import stack_layout
from repro.optim.optimizers import adamw
from repro.serve.steps import decode_serve_step, prefill_serve_step
from repro.sharding.specs import needs_fsdp
from repro.train.bucketing import assign_buckets, leaf_bucket_times
from repro.train.steps import ddp_train_step, deft_phase_step, deft_rs_phase_step
from repro.util.flags import sharded_decode, unroll_scans

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"
# Sequence-chunked LM-head/CE (see models.model.chunked_ce): caps the live
# logits buffer at [B, chunk, V] — full [B,S,V] f32 logits do not fit HBM
# at the production train shape for the 256k-vocab archs.
LOSS_CHUNK = 512


def _mesh_desc(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def _pick_phase(schedule):
    """Most representative phase: prefer one that syncs + updates."""
    best = schedule.phases[0]
    best_score = -1
    for ph in schedule.phases:
        score = sum(r == "sync" for r in ph.route_new) + sum(ph.sync_cur)
        score += 100 * ph.do_update
        if score > best_score:
            best, best_score = ph, score
    return best


def _variant_cfg(cfg, k: int):
    """Same architecture with k scanned periods (prefix/tail preserved)."""
    lay = stack_layout(cfg)
    n = len(lay.prefix_specs) + k * lay.period + len(lay.tail_specs)
    return dataclasses.replace(cfg, n_layers=n)


def _metrics(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        **{f"coll_{k}": float(v) for k, v in coll.items()},
    }


def lower_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mode: Optional[str] = None,
    partition_elems: int = 50_000_000,
    verbose: bool = True,
    extrapolate: bool = True,
    opts: tuple = (),
):
    """Lower + compile one combination; returns (Roofline, seconds) or a
    skip-marker dict."""
    shape = get_shape(shape_name)
    cfg = config_for_shape(arch, shape_name)
    if shape_name == "long_500k" and not cfg.supports_long_context():
        return {"arch": arch, "shape": shape_name,
                "skip": "full-attention arch at 500k context (see DESIGN.md)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    mode = mode or ("ddp" if shape.kind == "train" else shape.kind)
    opt = adamw(1e-3)
    fsdp = needs_fsdp(cfg.name)
    if shape.kind == "train" and mode == "deft" and fsdp and not multi_pod:
        return {"arch": arch, "shape": shape_name,
                "skip": "DeFT-RS needs the multi-pod mesh for FSDP archs"}
    if shape.kind == "train" and mode == "deft" and fsdp and multi_pod:
        return {"arch": arch, "shape": shape_name,
                "skip": "DeFT-RS at 512 devices aborts inside XLA's SPMD "
                        "partitioner (CHECK in ExpandDeviceGroupsWithIota, "
                        "partial-manual shard_map over 'pod' + FSDP 'data'; "
                        "repros on both FSDP archs). The identical step "
                        "compiles and trains on small meshes — see "
                        "tests/test_multidevice.py. Upstream XLA issue; "
                        "documented in EXPERIMENTS.md §Dry-run."}
    layout = "dp" if "dp-only" in opts else "tp"
    micro = 0
    for o in opts:
        if o.startswith("microbatch"):
            micro = int(o.split("=")[1])
    t0 = time.time()

    def build(cfg_x):
        """Lower the mode's step for (a possibly depth-reduced) cfg_x."""
        if shape.kind == "train":
            if mode == "deft":
                dp = (2 if fsdp else 16 * (2 if multi_pod else 1))
                state, batch = train_input_specs(
                    cfg_x, shape, mesh, multi_pod=multi_pod, opt_spec=opt,
                    deft=True, accum_devices=dp,
                    accum_dtype=jnp.bfloat16 if fsdp else jnp.float32,
                )
                bucket_of, nb = assign_buckets(state["params"], cfg_x,
                                               partition_elems)
                hw = HardwareModel(dp_degree=dp)
                times = leaf_bucket_times(
                    state["params"], cfg_x, bucket_of, nb, hw, shape.seq_len,
                    max(shape.global_batch // dp, 1),
                )
                # dryrun only needs a representative phase: solve without
                # the Preserver feedback loop
                schedule = Planner().plan(
                    PlanRequest(times=times, preserve=False)).schedule
                phase = _pick_phase(schedule)
                impl = deft_rs_phase_step if fsdp else deft_phase_step
                kw = dict(cfg=cfg_x, opt_spec=opt, phase=phase,
                          bucket_of_leaf=bucket_of, mesh=mesh,
                          loss_chunk=LOSS_CHUNK)
                if not fsdp:
                    kw["multi_pod"] = multi_pod
                fn = jax.jit(functools.partial(impl, **kw), donate_argnums=(0,))
                return fn.lower(state, batch)
            fn = jax.jit(functools.partial(
                ddp_train_step, cfg=cfg_x, opt_spec=opt,
                multi_pod=multi_pod, fsdp=fsdp, loss_chunk=LOSS_CHUNK,
                layout=layout, microbatch=micro,
            ), donate_argnums=(0,))
            state, batch = train_input_specs(
                cfg_x, shape, mesh, multi_pod=multi_pod, opt_spec=opt,
                layout=layout,
            )
            return fn.lower(state, batch)
        if shape.kind == "prefill":
            specs = serve_input_specs(cfg_x, shape, mesh, multi_pod=multi_pod)
            fn = jax.jit(functools.partial(
                prefill_serve_step, cfg=cfg_x, multi_pod=multi_pod,
            ), donate_argnums=(2,))
            kw = {"memory": specs["memory"]} if "memory" in specs else {}
            return fn.lower(specs["params"], specs["tokens"], specs["cache"], **kw)
        specs = serve_input_specs(cfg_x, shape, mesh, multi_pod=multi_pod)
        fn = jax.jit(functools.partial(
            decode_serve_step, cfg=cfg_x, multi_pod=multi_pod,
        ), donate_argnums=(2,))
        return fn.lower(specs["params"], specs["token"], specs["cache"],
                        specs["pos"])

    # The mesh context must be active while TRACING so the model's
    # logical-axis with_sharding_constraints resolve (otherwise the SPMD
    # partitioner free-wheels on every activation).
    # ---- full config, rolled scans: memory + grad-sync evidence ----
    with jax.set_mesh(mesh), sharded_decode("sharded-decode" in opts):
        compiled = build(cfg).compile()
    t_full = time.time() - t0
    rolled = _metrics(compiled)

    # ---- two small unrolled variants: exact per-period cost delta ----
    lay = stack_layout(cfg)
    est = dict(rolled)
    t_var = 0.0
    if extrapolate and lay.n_periods >= 2:
        tv = time.time()
        with jax.set_mesh(mesh), unroll_scans(), \
                sharded_decode("sharded-decode" in opts):
            m1 = _metrics(build(_variant_cfg(cfg, 1)).compile())
            m2 = _metrics(build(_variant_cfg(cfg, 2)).compile())
        est = {
            k: m1[k] + (lay.n_periods - 1) * (m2[k] - m1[k]) for k in m1
        }
        t_var = time.time() - tv

    roof = analyse_compiled(
        compiled,
        arch=arch,
        shape=shape_name,
        mesh_desc=_mesh_desc(multi_pod),
        mode=mode,
        n_chips=n_chips,
        model_flops=model_flops_for(cfg, shape),
    )
    roof.extra = {
        "rolled": rolled,
        "estimated": est,
        "n_periods": lay.n_periods,
        "wall_full_s": t_full,
        "wall_variants_s": t_var,
    }
    roof.hlo_flops = est["flops"]
    roof.hlo_bytes = est["bytes"]
    roof.coll_bytes = est["coll_total"]
    roof.coll_breakdown = {
        k.removeprefix("coll_"): int(v) for k, v in est.items()
        if k.startswith("coll_")
    }

    if verbose:
        ma = compiled.memory_analysis()
        print(f"--- {arch} x {shape_name} x {_mesh_desc(multi_pod)} [{mode}] "
              f"(full {t_full:.0f}s, variants {t_var:.0f}s)")
        print(f"    memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB")
        print(f"    est cost: flops={roof.hlo_flops:.3e} bytes={roof.hlo_bytes:.3e}")
        print(f"    est collectives: { {k: f'{v/2**30:.2f}GiB' for k, v in roof.coll_breakdown.items()} }")
        print(f"    rolled grad-sync view: "
              f"{ {k.removeprefix('coll_'): f'{v/2**30:.2f}GiB' for k, v in rolled.items() if k.startswith('coll_')} }")
        print(f"    roofline: compute={roof.t_compute*1e3:.2f}ms "
              f"memory={roof.t_memory*1e3:.2f}ms "
              f"collective={roof.t_collective*1e3:.2f}ms "
              f"-> {roof.dominant}-bound, useful={roof.useful_flops_ratio:.2f}")
    return roof, time.time() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default=None)
    ap.add_argument("--shape", choices=[s.name for s in SHAPES], default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--mode", choices=["ddp", "deft"], default=None,
                    help="train_4k only; serve shapes use their own step")
    ap.add_argument("--all", action="store_true", help="sweep all archs x shapes")
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="skip the unrolled variant compiles")
    ap.add_argument("--opt", default="",
                    help="comma list of beyond-paper optimizations: "
                         "sharded-decode, dp-only, microbatch=N")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    archs = ARCH_NAMES if (args.all or args.arch is None) else (args.arch,)
    shapes = [s.name for s in SHAPES] if (args.all or args.shape is None) else (args.shape,)
    meshes = {"single": (False,), "multi": (True,), "both": (False, True)}[args.mesh]

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    n_ok = n_skip = n_fail = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape_name in shapes:
                opts = tuple(o for o in args.opt.split(",") if o)
                tag = f"{arch}_{shape_name}_{_mesh_desc(multi_pod)}" + (
                    f"_{args.mode}" if args.mode else ""
                ) + ("_" + "-".join(opts) if opts else "")
                try:
                    res = lower_one(
                        arch, shape_name, multi_pod=multi_pod, mode=args.mode,
                        extrapolate=not args.no_extrapolate, opts=opts,
                    )
                    if isinstance(res, dict):  # skip marker
                        print(f"--- {tag}: SKIP ({res['skip']})")
                        (out_dir / f"{tag}.json").write_text(json.dumps(res))
                        n_skip += 1
                        continue
                    roof, secs = res
                    payload = roof.to_json()
                    payload["wall_seconds"] = secs
                    (out_dir / f"{tag}.json").write_text(json.dumps(payload, indent=1))
                    n_ok += 1
                except Exception as e:
                    n_fail += 1
                    print(f"--- {tag}: FAIL {type(e).__name__}: {e}")
                    traceback.print_exc()
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
