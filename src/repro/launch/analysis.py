"""Compiled-artifact analysis: collective bytes, roofline terms.

``cost_analysis()`` gives HLO FLOPs and bytes; collective traffic is NOT in
cost_analysis, so we parse the compiled HLO text and sum the operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.

Hardware constants are the assignment's TPU-v5e numbers:
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12         # bf16 per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind operand bytes summed over the module.

    Matches instruction lines of the form
      ``%x = TYPE[dims] all-reduce(TYPE[dims] %a, ...), ...``
    and sums the *operand* shapes (falling back to the result shape when
    operands are printed without types).  ``*-start`` variants (async
    collectives) are counted; their ``*-done`` halves are skipped to avoid
    double counting.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", s)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for c in _COLLECTIVES:
            # result shape then opcode, e.g. "bf16[8,128]{1,0} all-reduce("
            if re.search(rf"\}}?\s{c}(-start)?\(", rhs) or re.search(
                rf"\]\s{c}(-start)?\(", rhs
            ):
                kind = c
                break
        if kind is None:
            continue
        if f"{kind}-done" in rhs:
            continue
        # operand shapes: shapes appearing inside the call parens
        paren = rhs.find("(")
        operand_text = rhs[paren:]
        shapes = _SHAPE_RE.findall(operand_text)
        total = sum(_shape_bytes(d, dims) for d, dims in shapes)
        if total == 0:
            # fall back to the result shape(s) before the opcode
            shapes = _SHAPE_RE.findall(rhs[:paren])
            total = sum(_shape_bytes(d, dims) for d, dims in shapes)
        out[kind] += total
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    """Per-step roofline terms (seconds) on the target hardware."""

    arch: str
    shape: str
    mesh: str                     # '16x16' | '2x16x16'
    mode: str                     # 'ddp' | 'deft' | 'prefill' | 'decode'
    n_chips: int
    hlo_flops: float              # whole-program FLOPs (per device program)
    hlo_bytes: float              # bytes accessed (per device program)
    coll_bytes: float             # collective operand bytes (per device)
    coll_breakdown: Dict[str, int]
    bytes_per_device: float       # peak memory from memory_analysis
    model_flops: float            # 6*N(active)*D useful training FLOPs
    links_per_chip: float = 2.0   # usable ICI links on a 2-D torus axis slice
    extra: Dict = dataclasses.field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (ICI_BW * self.links_per_chip)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.hlo_flops * self.n_chips
        return self.model_flops / total if total else 0.0

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            dominant=self.dominant,
            useful_flops_ratio=self.useful_flops_ratio,
        )
        return d


def analyse_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_desc: str,
    mode: str,
    n_chips: int,
    model_flops: float,
) -> Roofline:
    """Extract roofline terms from a compiled executable.

    cost_analysis flops/bytes on an SPMD executable are per-device program
    costs; collective bytes parsed from HLO are likewise per device.
    """
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older jax: one dict per device
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_ = float(ca.get("bytes accessed", 0.0))
    try:
        ma = compiled.memory_analysis()
        bytes_dev = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:  # pragma: no cover - backend-dependent
        bytes_dev = 0.0
    coll = collective_bytes(compiled.as_text())
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        mode=mode,
        n_chips=n_chips,
        hlo_flops=flops,
        hlo_bytes=bytes_,
        coll_bytes=float(coll["total"]),
        coll_breakdown=coll,
        bytes_per_device=bytes_dev,
        model_flops=model_flops,
    )


def model_flops_for(cfg, shape) -> float:
    """Useful training FLOPs per step: 6*N_active*tokens (dense matmul
    term only — the classic MFU numerator); decode/prefill use 2*N*tokens
    (forward only)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    n = cfg.active_params()
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def format_roofline_row(r: Roofline) -> str:
    return (
        f"| {r.arch} | {r.shape} | {r.mode} | {r.mesh} | "
        f"{r.t_compute*1e3:.2f} | {r.t_memory*1e3:.2f} | "
        f"{r.t_collective*1e3:.2f} | {r.dominant} | "
        f"{r.useful_flops_ratio:.2f} | {r.bytes_per_device/2**30:.2f} |"
    )
