"""Dry-run input specs: ShapeDtypeStruct stand-ins for every model input.

Nothing here allocates device memory — parameters/optimizer state come
from ``jax.eval_shape`` over the real init functions and carry
``NamedSharding``s, so ``jax.jit(...).lower(**specs)`` sees exactly the
shapes+shardings a real launch would.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import config_for_shape, get_shape
from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape
from repro.data.pipeline import batch_spec
from repro.models.model import init_cache, init_params
from repro.optim.optimizers import OptimizerSpec, init_opt_state
from repro.serve.steps import cache_specs
from repro.sharding.specs import batch_axes, needs_fsdp, param_rules, spec_tree


def _with_shardings(tree, specs, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)
        ),
        tree,
        specs,
    )


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init_params(k, cfg, dtype=dtype), key)


def param_shardings_specs(params_sds, cfg: ArchConfig, mesh, multi_pod: bool):
    rules = param_rules(cfg.name, multi_pod)
    return spec_tree(params_sds, rules, mesh)


def train_input_specs(
    cfg: ArchConfig,
    shape: InputShape,
    mesh,
    *,
    multi_pod: bool = False,
    opt_spec: Optional[OptimizerSpec] = None,
    deft: bool = False,
    accum_devices: int = 1,
    param_dtype=jnp.bfloat16,
    opt_dtype=jnp.bfloat16,
    accum_dtype=jnp.float32,
    layout: str = "tp",
) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(state_specs, batch_specs) for lowering a train step."""
    from repro.optim.optimizers import adamw

    opt_spec = opt_spec or adamw()
    params = abstract_params(cfg, dtype=param_dtype)
    pspecs = spec_tree(params, param_rules(cfg.name, multi_pod, layout), mesh)
    opt = jax.eval_shape(
        lambda p: init_opt_state(opt_spec, p, dtype=opt_dtype), params
    )
    ospecs = {
        "step": P(),
        **{k: pspecs for k in opt if k != "step"},
    }
    state = {"params": params, "opt": opt}
    sspecs = {"params": pspecs, "opt": ospecs}
    if deft:
        dp = batch_axes(multi_pod) if not needs_fsdp(cfg.name) else ("pod",)
        dp_joint = dp if len(dp) > 1 else dp[0]
        acc = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                (accum_devices,) + l.shape, accum_dtype
            ),
            params,
        )
        # device axis leads; the rest keeps the parameter's model-axis
        # sharding so accumulators never replicate what params shard.
        accspec = jax.tree.map(lambda spec: P(dp_joint, *tuple(spec)), pspecs)
        state["cur"] = acc
        state["fut"] = acc
        sspecs["cur"] = accspec
        sspecs["fut"] = accspec
    state = _with_shardings(state, sspecs, mesh)

    batch = batch_spec(cfg, shape.global_batch, shape.seq_len, dtype=param_dtype)
    dp = batch_axes(multi_pod, layout)
    dp = dp if len(dp) > 1 else dp[0]
    bspecs = jax.tree.map(
        lambda sds: P(*((dp,) + (None,) * (len(sds.shape) - 1))), batch
    )
    batch = _with_shardings(batch, bspecs, mesh)
    return state, batch


def serve_input_specs(
    cfg: ArchConfig,
    shape: InputShape,
    mesh,
    *,
    multi_pod: bool = False,
    param_dtype=jnp.bfloat16,
    cache_dtype=jnp.bfloat16,
) -> Dict[str, Any]:
    """Specs for prefill (tokens + empty cache) or decode (token + full
    cache + pos)."""
    params = abstract_params(cfg, dtype=param_dtype)
    pspecs = param_shardings_specs(params, cfg, mesh, multi_pod)
    params = _with_shardings(params, pspecs, mesh)

    b = shape.global_batch
    chunk = shape.seq_len if shape.kind == "prefill" else 1
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, shape.seq_len, dtype=cache_dtype,
                           prefill_chunk=chunk)
    )
    cspecs = cache_specs(cache, mesh, multi_pod)
    cache = _with_shardings(cache, cspecs, mesh)

    dp = batch_axes(multi_pod)
    dp = dp if len(dp) > 1 else dp[0]
    bdim = dp if b % _dp_size(mesh, multi_pod) == 0 else None

    out: Dict[str, Any] = {"params": params, "cache": cache}
    if shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct(
            (b, shape.seq_len), jnp.int32,
            sharding=NamedSharding(mesh, P(bdim, None)),
        )
    else:
        out["token"] = jax.ShapeDtypeStruct(
            (b,), jnp.int32, sharding=NamedSharding(mesh, P(bdim))
        )
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32,
                                          sharding=NamedSharding(mesh, P()))
    if cfg.modality != "text" and shape.kind == "prefill":
        # decode reuses the cross-attention K/V cached at prefill
        out["memory"] = jax.ShapeDtypeStruct(
            (b, cfg.n_modal_tokens, cfg.d_model), param_dtype,
            sharding=NamedSharding(mesh, P(bdim, None, None)),
        )
    return out


def _dp_size(mesh, multi_pod: bool) -> int:
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = shape.get("data", 1)
    if multi_pod:
        n *= shape.get("pod", 1)
    return n
