"""Launch layer: production meshes, dry-run input specs, drivers."""
