"""Serving driver: batched prefill + autoregressive decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --requests 8 --prompt-len 48 --gen 24
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, reduce_for_smoke
from repro.launch.mesh import make_debug_mesh
from repro.models.model import init_params
from repro.serve.steps import (
    decode_serve_step,
    make_serve_cache,
    prefill_serve_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    n_dev = jax.device_count()
    mesh = make_debug_mesh(data=max(n_dev // 2, 1), model=min(n_dev, 2))
    key = jax.random.PRNGKey(args.seed)
    b = args.requests
    max_len = args.prompt_len + args.gen

    with mesh:
        params = init_params(key, cfg)
        cache = make_serve_cache(cfg, b, max_len, dtype=jnp.float32,
                                 prefill_chunk=args.prompt_len)
        prompts = jax.random.randint(key, (b, args.prompt_len), 0,
                                     cfg.vocab_size)
        memory = None
        if cfg.modality != "text":
            memory = jax.random.normal(
                key, (b, max(cfg.n_modal_tokens, 1), cfg.d_model)
            )

        prefill_fn = jax.jit(functools.partial(prefill_serve_step, cfg=cfg))
        decode_fn = jax.jit(
            functools.partial(decode_serve_step, cfg=cfg),
            donate_argnums=(2,),
        )

        t0 = time.time()
        logits, cache = prefill_fn(params, prompts, cache, memory=memory)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        t_prefill = time.time() - t0

        out_tokens = [token]
        t0 = time.time()
        for i in range(args.gen - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            logits, cache = decode_fn(params, token, cache, pos)
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                token = jax.random.categorical(
                    sub, logits / args.temperature, axis=-1
                ).astype(jnp.int32)
            else:
                token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out_tokens.append(token)
        t_decode = time.time() - t0
        gen = jnp.stack(out_tokens, axis=1)
        print(f"arch={cfg.name} requests={b} prompt={args.prompt_len} "
              f"gen={args.gen}")
        print(f"prefill {t_prefill*1e3:.1f}ms; decode "
              f"{t_decode / max(args.gen - 1, 1) * 1e3:.1f}ms/token "
              f"({b * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
        print("first request tokens:", gen[0].tolist())


if __name__ == "__main__":
    main()
