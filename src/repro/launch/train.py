"""Training driver: DDP baseline or the full DeFT pipeline
(Profiler -> Solver -> Preserver -> per-phase compiled steps).

On this CPU container it drives reduced configs over the debug mesh (set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before launch for
a multi-device mesh); pointed at a TPU slice it drives the same code over
``make_production_mesh()``.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --smoke \
        --scheduler deft --steps 60
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp

from repro.adapt import (
    AdaptConfig,
    AdaptiveController,
    BandwidthDrop,
    RepartitionConfig,
    Repartitioner,
    SyntheticTelemetrySource,
)
from repro.checkpoint.checkpoint import (
    latest_step,
    load_layout_descriptor,
    restore as restore_ckpt,
    save as save_ckpt,
    save_layout_descriptor,
    saved_keys,
    schedule_digest,
    valid_steps,
)
from repro.configs import ARCH_NAMES, get_config, reduce_for_smoke
from repro.core.bucket import BucketTimes
from repro.core.deft import Planner, PlanRequest
from repro.core.preserver import WalkParams
from repro.core.profiler import HardwareModel
from repro.core.scheduler import SchedulerConfig
from repro.data.pipeline import SyntheticDataset, batch_spec
from repro.elastic import (
    CapacityReturn,
    DeviceDrop,
    ElasticController,
    ElasticCoordinator,
    ElasticHalt,
    FaultScenario,
    HealthMonitor,
    StragglerSlowdown,
)
from repro.models.model import init_params
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.obs import Tracer, format_event
from repro.optim.optimizers import adamw
from repro.sharding.specs import needs_fsdp
from repro.train.bucketing import (
    assign_buckets,
    build_bucket_layout,
    build_leaf_time_model,
    coverage_rescale,
    leaf_bucket_times,
)
from repro.train.runtime import DeftRuntime, RuntimeConfig, make_ddp_step
from repro.train.steps import init_train_state


def build_schedule(
    params,
    cfg,
    *,
    dp: int,
    seq_len: int,
    per_device_batch: int,
    partition_elems: int,
    coverage_rate: float = 0.0,
    heterogeneous: bool = True,
    mu: float = 1.65,
    eps: float = 0.01,
    max_retries: int = 10,
    wire_precision: str = "f32",
    master_dtype: str = "f32",
):
    """Leaf-bucket profile -> Solver -> Preserver feedback loop.

    coverage_rate > 0 rescales the analytic comm times to that CR — used
    by examples/tests to reproduce a paper regime (VGG-like CR=2, GPT-2
    CR=1) on arbitrary model sizes.  ``wire_precision`` engages the §13
    per-bucket precision ladder ('auto') or forces a uniform wire dtype;
    the returned ``PlanResult`` carries the adopted policy.
    """
    bucket_of, nb = assign_buckets(params, cfg, partition_elems)
    hw = HardwareModel(dp_degree=dp)
    times = leaf_bucket_times(params, cfg, bucket_of, nb, hw, seq_len,
                              per_device_batch)
    if coverage_rate > 0:
        scale = coverage_rescale(times, coverage_rate)
        times = BucketTimes(times.fwd, times.bwd,
                            tuple(c * scale for c in times.comm))
    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    res = Planner().plan(PlanRequest(
        times=times, walk=walk, heterogeneous=heterogeneous, mu=mu,
        eps=eps, max_retries=max_retries,
        wire_precision=wire_precision, master_dtype=master_dtype,
    ))
    return bucket_of, nb, times, res


def restore_runtime_state(runtime, ckpt_dir: str, params_abs):
    """Restore the newest *usable* checkpoint into ``runtime``'s resident
    state.  Returns ``(state, start_step)`` or ``(None, 0)`` when nothing
    on disk restores.

    Hardened resume semantics (DESIGN.md §10):

    * incomplete/torn checkpoints (a writer killed mid-save) never appear
      — ``valid_steps`` admits only atomically-committed steps;
    * a step that still fails to restore (e.g. a stale sidecar naming a
      layout the arrays don't match) falls back to the previous valid
      step with a warning instead of aborting the run;
    * a schedule-digest mismatch in the sidecar means the saved
      mid-cycle accumulator position is meaningless under the running
      schedule: the gather cache is dropped and the cycle restarts at
      the checkpoint step (cycle-start restore) with a clear warning —
      never a crash, never a silent mid-cycle misread.
    """
    layout = runtime.layout
    run_digest = schedule_digest(runtime.schedule)
    for last in reversed(valid_steps(ckpt_dir)):
        try:
            src_layout, next_phase, src_digest = \
                load_layout_descriptor(ckpt_dir, last, params_abs)
            if src_layout is None:
                src_layout, next_phase, src_digest = layout, 0, ""
            digest_ok = (not src_digest) or src_digest == run_digest
            # read the gather cache only if the checkpoint has one AND
            # the layout + schedule both match (tree_to_state re-inits
            # it cold otherwise; a digest mismatch restarts the cycle,
            # which re-gathers anyway)
            has_pg = any(k.startswith("pgather")
                         for k in saved_keys(ckpt_dir, last))
            ts = restore_ckpt(
                ckpt_dir, last,
                runtime.checkpoint_struct(
                    src_layout,
                    with_pgather=(has_pg and src_layout == layout
                                  and digest_ok),
                ),
            )
            # cross-layout restores route cur/fut through the
            # LayoutTransition span remap inside tree_to_state
            state = runtime.tree_to_state(ts, src_layout=src_layout)
        except Exception as e:      # torn arrays, stale sidecar, ...
            print(f"resume: checkpoint step {last} unusable "
                  f"({type(e).__name__}: {e}); trying the previous one")
            continue
        # continue mid-cycle ONLY under the byte-identical schedule (a
        # phase sequence that merely shares the period would misread the
        # mid-generation accumulators), and only if the gather cache the
        # resumed position may read was actually saved
        same_cycle = (
            src_layout == layout
            and src_digest == run_digest
            and (not runtime.stats()["gather_skip"] or has_pg)
        )
        runtime.reset_cycle(last - next_phase if same_cycle else last)
        if src_digest and not digest_ok:
            print(f"resume: WARNING schedule digest mismatch at step "
                  f"{last} (saved {src_digest}, running {run_digest}) — "
                  f"gather cache dropped, cycle restarted at the "
                  f"checkpoint step")
        print(f"resumed checkpoint step {last}"
              + (" (re-packed from a different layout)"
                 if src_layout != layout else "")
              + ("" if same_cycle else " (cycle restarted)"))
        return state, last
    return None, 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--scheduler", choices=["ddp", "deft"], default="deft")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--coverage-rate", type=float, default=1.8,
                    help="synthetic CR for the DeFT schedule (0 = analytic)")
    ap.add_argument("--partition-elems", type=int, default=200_000)
    ap.add_argument("--adapt", action="store_true",
                    help="online control plane: telemetry -> drift "
                         "detection -> replan -> phase hot-swap")
    ap.add_argument("--adapt-drop-step", type=int, default=0,
                    help="with --adapt: inject a synthetic bandwidth drop "
                         "at this step (0 = use real measured wall times)")
    ap.add_argument("--adapt-drop-scale", type=float, default=3.0,
                    help="comm slowdown factor of the injected drop")
    ap.add_argument("--adapt-repartition", action="store_true",
                    help="with --adapt: replans may change the bucket "
                         "partition itself — the runtime re-packs the "
                         "flat state at a cycle boundary, no restart")
    ap.add_argument("--elastic", action="store_true",
                    help="fault-tolerant control plane: per-shard health "
                         "monitoring -> Preserver-gated mesh scale-down/up "
                         "via a cycle-boundary repack, zero restart")
    ap.add_argument("--elastic-drop-step", type=int, default=0,
                    help="with --elastic: inject a device-drop fault at "
                         "this step (0 = none)")
    ap.add_argument("--elastic-drop-shards", default="",
                    help="comma-separated origin shard ids the injected "
                         "drop kills (default: the last data row)")
    ap.add_argument("--elastic-return-step", type=int, default=0,
                    help="with --elastic: the dropped shards come back at "
                         "this step (scale-up trigger; 0 = never)")
    ap.add_argument("--elastic-straggler-step", type=int, default=0,
                    help="with --elastic: one shard starts running slow "
                         "at this step (0 = none)")
    ap.add_argument("--elastic-straggler-shard", type=int, default=0)
    ap.add_argument("--elastic-straggler-factor", type=float, default=3.0)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="auto-checkpoint cadence in steps (0 = only at "
                         "the end); with --elastic this bounds lost work "
                         "on an unsurvivable fault")
    ap.add_argument("--compute-dtype", choices=["f32", "bf16"],
                    default="f32",
                    help="forward/backward precision of the flat engines "
                         "(the master copy stays f32)")
    ap.add_argument("--wire-precision",
                    choices=["auto", "f32", "bf16", "int8"],
                    default="f32",
                    help="gradient wire precision (DESIGN.md §13): "
                         "'auto' lets the planner pick a per-bucket "
                         "policy from the knapsack-priced ladder, gated "
                         "by the precision-aware Preserver; a dtype "
                         "forces that uniform wire")
    ap.add_argument("--master-dtype", choices=["f32", "bf16sr"],
                    default="f32",
                    help="resident master-param dtype: 'bf16sr' keeps "
                         "params at bf16 with seeded stochastic-rounded "
                         "updates (flat engine only; moments stay f32)")
    ap.add_argument("--decoupled", action="store_true",
                    help="stream per-bucket all-gathers into the forward "
                         "instead of the phase-start burst (DESIGN.md §12; "
                         "needs an FSDP arch)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--data", type=int, default=0, help="debug mesh data axis")
    ap.add_argument("--model", type=int, default=0, help="debug mesh model axis")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="record step/phase/collective/control-plane "
                         "spans and export a Chrome-trace (Perfetto-"
                         "loadable) JSON to this path")
    ap.add_argument("--ckpt", default="", help="checkpoint dir (optional)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint from --ckpt "
                         "before training (a checkpoint written under a "
                         "different bucket layout is re-packed through "
                         "the LayoutTransition)")
    args = ap.parse_args()
    if args.elastic and args.adapt:
        ap.error("--elastic and --adapt are mutually exclusive: the "
                 "elastic controller owns replanning while it owns the "
                 "mesh (DESIGN.md §10)")
    if args.elastic and args.scheduler != "deft":
        ap.error("--elastic needs --scheduler deft (the migration path "
                 "repacks the flat DeFT state)")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    # one tracer for the whole run: runtime step/phase spans, controller
    # replans, elastic lifecycle — all in one clock domain (DESIGN.md §11)
    tracer = Tracer() if args.trace else None
    n_dev = jax.device_count()
    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        data = args.data or max(n_dev // 2, 1)
        model = args.model or (n_dev // data)
        mesh = make_debug_mesh(data=data, model=model)
    dp = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]
    fsdp = needs_fsdp(cfg.name)
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(args.seed)

    print(f"arch={cfg.name} params={cfg.total_params():,} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    ds = SyntheticDataset(cfg, args.seed, args.batch, args.seq)

    with jax.set_mesh(mesh):
        runtime = None
        start_step = 0
        if args.scheduler == "ddp":
            state = init_train_state(key, cfg, opt)
            # donated: params/opt update in place instead of copying
            step_fn = make_ddp_step(cfg, opt, fsdp=fsdp)
            if args.resume and args.ckpt:
                last = latest_step(args.ckpt)
                if last is not None:
                    state = restore_ckpt(args.ckpt, last, state)
                    start_step = last
                    print(f"resumed checkpoint step {last}")
        else:
            # shape-only probe: bucketing/layout never read values, so an
            # eval_shape tree avoids materializing a throwaway full state
            params_abs = jax.eval_shape(
                lambda k: init_params(k, cfg), jax.random.PRNGKey(0)
            )
            bucket_of, nb, times, plan = build_schedule(
                params_abs, cfg, dp=dp, seq_len=args.seq,
                per_device_batch=max(args.batch // dp, 1),
                partition_elems=args.partition_elems,
                coverage_rate=args.coverage_rate,
                wire_precision=args.wire_precision,
                master_dtype=args.master_dtype,
            )
            schedule, verdict, scfg = (
                plan.schedule, plan.verdict, plan.scheduler_cfg
            )
            print(f"deft: {nb} buckets, CR={times.coverage_rate:.2f}, "
                  f"period={schedule.period}, "
                  f"updates/period={schedule.updates_per_period}, "
                  f"batch-size seq={schedule.batch_size_sequence}, "
                  f"preserver ratio={verdict.ratio:.4f} "
                  f"(capacity x{scfg.capacity_factor:.2f})")
            if plan.precision is not None:
                print(f"precision: wire={plan.precision.describe()} "
                      f"master={plan.precision.master}")
            # FSDP archs run the sharded flat engine: the layout pads
            # every bucket so it splits into dp equal lane-aligned spans
            layout = build_bucket_layout(params_abs, bucket_of, nb,
                                         shard_count=dp if fsdp else 1)
            if plan.precision is not None:
                layout = layout.with_precision(plan.precision)
            compute_dtype = (jnp.bfloat16 if args.compute_dtype == "bf16"
                             else None)
            rcfg = RuntimeConfig(
                fsdp=fsdp, compute_dtype=compute_dtype,
                decoupled=args.decoupled,
                master_dtype=(args.master_dtype
                              if args.master_dtype != "f32" else None),
            )
            runtime = DeftRuntime(cfg, opt, schedule, layout, mesh,
                                  config=rcfg, tracer=tracer)
            state = None
            if args.resume and args.ckpt:
                state, start_step = restore_runtime_state(
                    runtime, args.ckpt, params_abs
                )
            if state is None:
                state = runtime.init_state(
                    key, dtype=compute_dtype or jnp.float32
                )
            t_c = time.time()
            # AOT phase cache against abstract batch specs: no data batch
            # is consumed, so step 0 still trains on the stream's batch 0
            runtime.compile(state, batch_spec(cfg, args.batch, args.seq))
            st = runtime.stats()
            print(f"compiled {runtime.n_unique_phases} unique phases "
                  f"(period {runtime.period}) in {time.time() - t_c:.1f}s; "
                  f"max collectives in a phase: "
                  f"{st['max_collectives_in_a_phase']} "
                  f"(vs {layout.n_leaves} per-leaf); "
                  f"update engine: "
                  f"{'flat/' + st['update_impl'] if st['flat_state'] else 'per-leaf tree'}"
                  + (f" (sharded 1/{st['shards']})"
                     if st.get("sharded_state") else ""))

        # ---- online adaptive control plane (--adapt) ------------------
        controller = None
        telemetry_src = None
        repartitioner = None
        run_base = None          # scale-1 run times after a repartition
        if args.adapt and runtime is not None:
            if args.adapt_repartition:
                model = build_leaf_time_model(
                    params_abs, cfg, HardwareModel(dp_degree=dp),
                    args.seq, max(args.batch // dp, 1),
                )
                if args.coverage_rate > 0:
                    model = model.with_coverage_rate(
                        bucket_of, nb, args.coverage_rate
                    )
                repartitioner = Repartitioner(
                    model,
                    RepartitionConfig(
                        base_partition_elems=args.partition_elems
                    ),
                )
            controller = AdaptiveController(
                times, schedule, scfg,
                cfg=AdaptConfig(eta=1e-3, warmup_steps=4, check_every=4,
                                cooldown_steps=2 * schedule.period,
                                wire_precision=args.wire_precision),
                repartitioner=repartitioner,
                bucket_of=bucket_of if repartitioner else None,
                tracer=runtime.tracer,
                precision=plan.precision,
            )
            if args.adapt_drop_step > 0:
                telemetry_src = SyntheticTelemetrySource(
                    times,
                    BandwidthDrop(step=args.adapt_drop_step,
                                  comm_scale=args.adapt_drop_scale),
                )
                print(f"adapt: synthetic bandwidth drop "
                      f"x{args.adapt_drop_scale} at step "
                      f"{args.adapt_drop_step}")

        # ---- fault-tolerant elastic control plane (--elastic) ---------
        elastic = None
        scenario = None
        if args.elastic and runtime is not None:

            def model_for(width: int):
                m = build_leaf_time_model(
                    params_abs, cfg, HardwareModel(dp_degree=width),
                    args.seq, max(args.batch // width, 1),
                )
                if args.coverage_rate > 0:
                    m = m.with_coverage_rate(bucket_of, nb,
                                             args.coverage_rate)
                return m

            walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0,
                              batch=256)
            elastic = ElasticCoordinator(
                runtime,
                ElasticController(model_for, bucket_of, nb, walk=walk,
                                  scheduler_cfg=scfg),
                HealthMonitor(dp),
                params_abs=params_abs,
                batch_spec=batch_spec(cfg, args.batch, args.seq),
                checkpoint_dir=args.ckpt,
            )
            faults = []
            if args.elastic_drop_step > 0:
                shards = tuple(
                    int(s) for s in args.elastic_drop_shards.split(",") if s
                ) or (dp - 1,)
                faults.append(DeviceDrop(args.elastic_drop_step, shards))
                if args.elastic_return_step > 0:
                    faults.append(
                        CapacityReturn(args.elastic_return_step, shards)
                    )
            if args.elastic_straggler_step > 0:
                faults.append(StragglerSlowdown(
                    args.elastic_straggler_step,
                    args.elastic_straggler_shard,
                    args.elastic_straggler_factor,
                ))
            if faults:
                scenario = FaultScenario(n_shards=dp, events=tuple(faults))
                print("elastic: injected faults: " + "; ".join(
                    f"{type(e).__name__}@{e.step}" for e in faults))

        # a preemption signal (SIGTERM/SIGUSR1, what cluster managers
        # send before reclaiming the host) checkpoints and exits cleanly
        preempted = {"sig": None}
        if args.elastic or args.ckpt:
            def _on_preempt(signum, frame):
                preempted["sig"] = signum

            signal.signal(signal.SIGTERM, _on_preempt)
            signal.signal(signal.SIGUSR1, _on_preempt)

        t0 = time.time()
        # a resumed run continues the data stream where it left off —
        # otherwise steps N.. would retrain on batches 0.. and diverge
        # from the uninterrupted trajectory
        ds.step = start_step
        last_step = start_step + args.steps - 1
        halted = False
        for step in range(start_step, start_step + args.steps):
            if preempted["sig"] is not None:
                print(f"preemption signal {preempted['sig']}: "
                      f"checkpointing and exiting cleanly")
                if args.ckpt:
                    if elastic is not None:
                        path = elastic.emergency_checkpoint(step, state)
                    else:
                        tree_state = (runtime.state_to_tree(state)
                                      if runtime else state)
                        path = save_ckpt(args.ckpt, step, tree_state)
                        if runtime is not None:
                            save_layout_descriptor(
                                args.ckpt, step, runtime.layout,
                                next_phase=runtime.phase_in_cycle(step),
                                digest=schedule_digest(runtime.schedule),
                            )
                    print(f"checkpoint -> {path}")
                halted = True
                last_step = step - 1
                break
            batch = next(ds)
            t_s = time.perf_counter()
            try:
                if runtime is None:
                    state, m = step_fn(state, batch)
                elif elastic is not None:
                    state, m = elastic.step(step, state, batch)
                    runtime = elastic.runtime   # migrations swap it
                else:
                    state, m = runtime.step(step, state, batch)
            except ElasticHalt as e:
                # the degradation ladder bottomed out; the emergency
                # checkpoint (if --ckpt) is on disk — exit cleanly
                print(f"elastic: {e}")
                halted = True
                last_step = step - 1
                break
            if tracer is not None:
                tracer.add("step", f"step{step}", t_s, tracer.now(),
                           step=step)
            if elastic is not None:
                jax.block_until_ready(m["loss"])
                wall = time.perf_counter() - t_s
                if scenario is not None:
                    obs = scenario.observe(step, wall)
                    if obs.notices:
                        for ev in elastic.notice_preemption(
                                step, obs.notices):
                            print(format_event(ev))
                    if obs.returned:
                        elastic.notice_capacity(step, obs.returned)
                        print(f"elastic: capacity returned: "
                              f"shards {obs.returned}")
                    walls = obs.walls
                else:
                    walls = (wall,) * elastic.n_origin
                for ev in elastic.observe(step, walls):
                    print(format_event(ev))
            if controller is not None:
                if telemetry_src is not None:
                    wall = telemetry_src.wall_time(
                        step, controller.schedule, controller.scheduler_cfg,
                        runtime.last_phase,
                        # the priced view: synthetic walls must reflect
                        # the installed wire precision or every replan
                        # after a downgrade reads as fresh drift
                        solve_times=controller.wire_times(),
                        run_base=run_base,
                    )
                    cold = None     # synthetic walls: no dispatch pollution
                else:
                    jax.block_until_ready(m["loss"])
                    wall = time.perf_counter() - t_s
                    # first-dispatch tag: a wall that includes an
                    # executable's one-off lazy work never enters the EMAs
                    cold = runtime.last_dispatch_first
                event = controller.observe(
                    step, runtime.last_phase, wall, loss=float(m["loss"]),
                    cold=cold,
                )
                if event is not None:
                    print(format_event(event))
                    if event.changed:
                        new_layout = None
                        if repartitioner is not None:
                            # ALWAYS stage the layout the controller's
                            # installed view assumes — an earlier
                            # partition swap may have been superseded
                            # before it installed, and a schedule solved
                            # for partition B must never compile against
                            # layout A.  prepare_swap no-ops the repack
                            # when this equals the installed layout.
                            new_layout = build_bucket_layout(
                                params_abs, controller.bucket_of,
                                controller.times.n,
                                shard_count=dp if fsdp else 1,
                            )
                        if event.partition_changed:
                            run_base = repartitioner.base_times_for(
                                event.partition
                            )
                        # a precision change rides on the layout: same
                        # partition, different wire policy (pure-alias
                        # repack, DESIGN.md §13)
                        if new_layout is not None:
                            new_layout = new_layout.with_precision(
                                controller.precision
                            )
                        elif event.precision_changed:
                            new_layout = runtime.layout.with_precision(
                                controller.precision
                            )
                        runtime.prepare_swap(
                            event.schedule, state,
                            batch_spec(cfg, args.batch, args.seq),
                            background=True,
                            layout=new_layout,
                        )
            if args.ckpt and args.ckpt_every > 0 \
                    and (step + 1 - start_step) % args.ckpt_every == 0:
                tree_state = (runtime.state_to_tree(state)
                              if runtime else state)
                save_ckpt(args.ckpt, step + 1, tree_state)
                if runtime is not None:
                    save_layout_descriptor(
                        args.ckpt, step + 1, runtime.layout,
                        next_phase=runtime.phase_in_cycle(step + 1),
                        digest=schedule_digest(runtime.schedule),
                    )
            if (step - start_step) % max(args.steps // 10, 1) == 0 \
                    or step == last_step:
                print(f"step {step:4d} loss={float(m['loss']):.4f} "
                      f"updated={bool(m['updated'])}")
        dt = time.time() - t0
        print(f"{args.steps} steps in {dt:.1f}s "
              f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
        if runtime is not None and args.adapt:
            st = runtime.stats()
            print(f"adapt: {st['replans']} replans, {st['hot_swaps']} "
                  f"hot-swaps ({st['layout_swaps']} layout-changing), "
                  f"{st['cached_phases']} cached phases, "
                  f"{st['steps_per_s']:.2f} steps/s (dispatch)")
            for sw in st["swap_log"]:
                print("  " + format_event(sw))
            for ev in (controller.events if controller else []):
                print("  " + format_event(ev))
        if elastic is not None:
            st = elastic.stats()
            print(f"elastic: members={st['members']} "
                  f"spares={st['spares']} "
                  f"{len(st['migrations'])} migrations, "
                  f"{len(st['fault_events'])} fault events")
            for mig in st["migrations"]:
                print("  " + format_event(mig))

    if args.ckpt and not halted:
        # checkpoint boundary: the flat-resident runtime state unflattens
        # to the tree form HERE and nowhere in the steady-state loop
        tree_state = runtime.state_to_tree(state) if runtime else state
        path = save_ckpt(args.ckpt, last_step + 1, tree_state)
        if runtime is not None:
            # the layout sidecar lets a later run restore this state
            # under a DIFFERENT partition / shard count (DESIGN.md §9)
            save_layout_descriptor(
                args.ckpt, last_step + 1, runtime.layout,
                next_phase=runtime.phase_in_cycle(last_step + 1),
                digest=schedule_digest(runtime.schedule),
            )
        print(f"checkpoint -> {path}")

    if tracer is not None:
        tracer.export_chrome_trace(args.trace)
        ts = tracer.stats()
        dropped = (f", {ts['dropped']} dropped (ring full)"
                   if ts["dropped"] else "")
        print(f"trace -> {args.trace} ({ts['retained']} spans{dropped})")


if __name__ == "__main__":
    main()
