"""Online adaptive control plane: telemetry -> calibration -> drift
detection -> Preserver-gated replanning -> DeftRuntime hot-swap.

Closes the paper's Fig. 7 feedback loop *during* training instead of only
before step 0: measured per-phase wall times re-base the analytical
profile, the Solver re-plans off the hot path, and the runtime swaps the
compiled phase set at a period boundary (DESIGN.md §7).
"""
from repro.adapt.calibrate import (
    CalibratedProfile,
    calibrate,
    fit_scales,
    fit_secondary_scale,
    scale_times,
    schedule_plans,
    steady_phase_durations,
)
from repro.adapt.controller import AdaptConfig, AdaptiveController, ReplanEvent
from repro.adapt.repartition import (
    PartitionCandidate,
    RepartitionConfig,
    Repartitioner,
    candidate_solve_table,
    dp_partition,
    exposed_makespan,
)
from repro.adapt.scenario import (
    BandwidthDrop,
    SyntheticTelemetrySource,
    run_control_loop,
)
from repro.adapt.telemetry import (
    ShardTelemetry,
    StepSample,
    Telemetry,
    TelemetryConfig,
)

__all__ = [
    "AdaptConfig",
    "AdaptiveController",
    "BandwidthDrop",
    "CalibratedProfile",
    "PartitionCandidate",
    "RepartitionConfig",
    "Repartitioner",
    "ReplanEvent",
    "ShardTelemetry",
    "StepSample",
    "SyntheticTelemetrySource",
    "Telemetry",
    "TelemetryConfig",
    "candidate_solve_table",
    "calibrate",
    "dp_partition",
    "exposed_makespan",
    "fit_scales",
    "fit_secondary_scale",
    "run_control_loop",
    "scale_times",
    "schedule_plans",
    "steady_phase_durations",
]
