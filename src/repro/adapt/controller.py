"""Drift detection + Preserver-gated replanning (the online Fig. 7 loop).

The controller sits between :class:`DeftRuntime` and the planner.  Every
step the train loop feeds it (step id, phase-in-cycle, wall seconds,
loss); every ``check_every`` steps — once telemetry is warm — it:

1. **calibrates**: fits (comp_scale, comm_scale) so the simulated
   per-phase durations of the installed plan match the measured EMAs
   (:mod:`repro.adapt.calibrate`);
2. **detects drift**: either fitted scale deviating from 1 beyond
   ``drift_threshold`` (the plan's timing assumptions are wrong), or the
   Preserver verdict flipping when re-checked under *measured*
   ``WalkParams`` fit from the observed loss trace (the plan's
   convergence assumptions are wrong);
3. **replans**: re-runs the Solver + Preserver feedback loop
   (:meth:`repro.core.deft.Planner.plan`) on the calibrated bucket
   times.  The knapsack memo cache (core/knapsack.py) makes consecutive
   replans over a drifting-but-similar profile cheap — the solver
   re-solves mostly cache-hit instances.

A replan yields a :class:`ReplanEvent`; when the new schedule's phases
differ from the installed ones the caller hands it to
``DeftRuntime.prepare_swap`` for background compile + period-boundary
hot-swap.  All of this runs off the hot path: the controller does pure
Python (simulator + DP) work, never touches device state, and a
``cooldown`` keeps it from thrashing while new telemetry accumulates.
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING, List, Optional, Sequence, Tuple

from repro.adapt.calibrate import (
    CalibratedProfile,
    calibrate,
    planned_phase_durations,
    scale_times,
)
from repro.adapt.telemetry import Telemetry, TelemetryConfig
from repro.core.bucket import BucketTimes
from repro.core.deft import Planner, PlanRequest
from repro.core.precision import PrecisionPolicy, apply_wire_precision
from repro.core.preserver import (
    PreserverVerdict,
    WalkParams,
    check_schedule,
    estimate_walk_params_from_losses,
)
from repro.core.scheduler import DeftSchedule, SchedulerConfig
from repro.obs.trace import Tracer

if TYPE_CHECKING:   # the controller only duck-types the repartitioner
    from repro.adapt.repartition import PartitionCandidate, Repartitioner


@dataclasses.dataclass(frozen=True)
class AdaptConfig:
    """Control-plane constants (DESIGN.md §7 documents the choices)."""

    # telemetry
    ring_size: int = 256
    ema_alpha: float = 0.25
    warmup_steps: int = 8
    # drift detection
    check_every: int = 8          # steps between calibration passes
    drift_threshold: float = 0.25 # |scale - 1| that triggers a replan
    # what the drift screen + calibration consume (DESIGN.md §11):
    # 'ema'        — per-phase EMA wall times (legacy; smooth, laggy)
    # 'divergence' — the obs layer's latest-sample per-phase durations
    #                (raw predicted-vs-actual divergence; reacts a full
    #                EMA settling time earlier after a step change)
    drift_source: str = "ema"
    cooldown_steps: int = 16      # min steps between replans
    min_loss_samples: int = 12    # before the measured-WalkParams check
    # replanning (mirrors the Planner's feedback-loop defaults)
    eps: float = 0.01
    max_retries: int = 10
    capacity_growth: float = 1.2
    # measured-WalkParams fit inputs
    eta: float = 1e-3             # learning rate fed to the walk fit
    base_batch: int = 256
    # wire precision (DESIGN.md §13).  'f32' keeps precision FROZEN —
    # the default controller never touches the wire (an explicitly
    # installed policy is still re-priced and re-gated each replan).
    # 'auto' opts precision in as an escalation lever: a replan whose
    # calibrated comm_scale reaches ``precision_comm_scale`` (a
    # bandwidth collapse rather than mild drift) walks the downgrade
    # ladder — shedding wire bytes is cheaper than surrendering
    # coverage to a starved link; short of the bar, the installed
    # policy is kept as-is.  'bf16'/'int8' force that uniform wire on
    # every replan (collapse still escalates to the full ladder).
    wire_precision: str = "f32"
    precision_comm_scale: float = 1.5


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """One control-plane action, in human-readable terms."""

    step: int
    trigger: str                   # 'timing-drift' | 'preserver-flip'
    profile: CalibratedProfile
    old_coverage_rate: float
    new_coverage_rate: float
    old_period: int
    new_period: int
    old_batch_seq: tuple
    new_batch_seq: tuple
    verdict: PreserverVerdict      # Preserver verdict of the NEW schedule
    schedule: DeftSchedule
    scheduler_cfg: SchedulerConfig
    times: BucketTimes             # calibrated times the replan consumed
    changed: bool                  # new phases differ from installed ones
    replan_s: float                # wall seconds spent solving
    # ---- partition-change replans (repartitioner attached) --------------
    old_n_buckets: int = 0
    new_n_buckets: int = 0
    # the adopted candidate when it differs from the installed partition
    # (None = the replan kept the current partition)
    partition: Optional["PartitionCandidate"] = None
    candidate_solves: Tuple = ()   # CandidateSolve table, input order
    # ---- precision replans (DESIGN.md §13) ------------------------------
    # None = precision planning did not engage (wire stayed at f32)
    old_precision: Optional[PrecisionPolicy] = None
    new_precision: Optional[PrecisionPolicy] = None
    wire_bytes_scale: float = 1.0  # new policy wire bytes / all-f32 bytes

    @property
    def partition_changed(self) -> bool:
        return self.partition is not None

    @property
    def precision_changed(self) -> bool:
        old = self.old_precision.wire if self.old_precision else None
        new = self.new_precision.wire if self.new_precision else None
        return old != new

    @property
    def coverage_delta(self) -> float:
        return self.new_coverage_rate - self.old_coverage_rate

    def describe(self) -> str:
        out = (
            f"step {self.step:5d}  {self.trigger:<14s} "
            f"comp x{self.profile.comp_scale:.2f} "
            f"comm x{self.profile.comm_scale:.2f}  "
            f"CR {self.old_coverage_rate:.2f}->{self.new_coverage_rate:.2f} "
            f"(d{self.coverage_delta:+.2f})  "
            f"period {self.old_period}->{self.new_period}  "
            f"k-seq {self.old_batch_seq}->{self.new_batch_seq}  "
            f"preserver ratio={self.verdict.ratio:.4f} "
            f"ok={self.verdict.ok}  "
            f"{'SWAP' if self.changed else 'no-op'} "
            f"({self.replan_s * 1e3:.0f} ms)"
        )
        if self.partition_changed:
            out += (
                f"  REPARTITION {self.old_n_buckets}->"
                f"{self.new_n_buckets} buckets [{self.partition.tag}]"
            )
        if self.precision_changed:
            old = (
                self.old_precision.describe() if self.old_precision
                else "f32"
            )
            new = (
                self.new_precision.describe() if self.new_precision
                else "f32"
            )
            out += (
                f"  PRECISION {old}->{new} "
                f"(bytes x{self.wire_bytes_scale:.2f})"
            )
        return out


class AdaptiveController:
    """Owns telemetry + the installed plan's planning-time view."""

    def __init__(
        self,
        times: BucketTimes,
        schedule: DeftSchedule,
        scheduler_cfg: SchedulerConfig,
        walk: Optional[WalkParams] = None,
        cfg: Optional[AdaptConfig] = None,
        repartitioner: Optional["Repartitioner"] = None,
        bucket_of: Optional[Sequence[int]] = None,
        tracer: Optional[Tracer] = None,
        precision: Optional[PrecisionPolicy] = None,
    ):
        self.cfg = cfg or AdaptConfig()
        self.tracer = tracer
        self.times = times                   # what the installed plan assumed
        self.schedule = schedule
        self.scheduler_cfg = scheduler_cfg
        # the installed wire-precision policy (None = all-f32); replans
        # that adopt a different one report it on the ReplanEvent so the
        # caller can hot-swap layout.with_precision(...) alongside the
        # schedule
        self.precision = precision
        self.walk = walk or WalkParams(
            s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256
        )
        # all replans route through the unified Planner facade
        self.planner = Planner()
        # ---- optional repartitioning (DESIGN.md §9) ----------------------
        # With a repartitioner attached, every replan ALSO considers a
        # grid of alternative bucket partitions; ``bucket_of`` names the
        # installed one.  ``times`` must come from the repartitioner's
        # LeafTimeModel (same partition, same CR rescale) so candidate
        # times stay commensurable with the calibrated baseline.
        self.repartitioner = repartitioner
        self.bucket_of = tuple(bucket_of) if bucket_of is not None else None
        if repartitioner is not None and self.bucket_of is None:
            raise ValueError(
                "repartitioning needs bucket_of (the installed partition)"
            )
        # cumulative calibrated drift vs the LeafTimeModel's base times —
        # candidate partitions are priced at these scales
        self._cum_comp = 1.0
        self._cum_comm = 1.0
        self.telemetry = Telemetry(
            schedule.period,
            TelemetryConfig(
                ring_size=self.cfg.ring_size,
                ema_alpha=self.cfg.ema_alpha,
                warmup_steps=self.cfg.warmup_steps,
            ),
        )
        self.events: List[ReplanEvent] = []
        self._last_replan_step = -(10**9)
        self._last_check_step = -(10**9)

    # ---- the per-step hook ----------------------------------------------
    def observe(
        self,
        step: int,
        phase: int,
        wall_s: float,
        loss: Optional[float] = None,
        updated: bool = False,
        cold: Optional[bool] = None,
    ) -> Optional[ReplanEvent]:
        """Feed one step's telemetry; returns a ReplanEvent when this step
        triggered a replan (caller decides whether to hot-swap).  ``cold``
        is the runtime's first-dispatch tag (``last_dispatch_first``) —
        see :meth:`Telemetry.record`."""
        self.telemetry.record(step, phase, wall_s, loss, updated, cold=cold)
        if step - self._last_check_step < self.cfg.check_every:
            return None
        if step - self._last_replan_step < self.cfg.cooldown_steps:
            return None
        if not self.telemetry.ready():
            return None
        self._last_check_step = step
        return self._check(step)

    def wire_times(self) -> BucketTimes:
        """The installed plan's on-the-wire timing view: the planning
        baseline re-priced by the installed precision policy.  Measured
        wall times reflect the quantized wire, so the drift screen and
        the calibration fit must compare against THIS, not the f32
        baseline — otherwise an installed bf16 wire reads as a
        permanent comm_scale ~0.5 'drift'."""
        if self.precision is None or self.precision.all_f32:
            return self.times
        return apply_wire_precision(self.times, self.precision)

    # ---- drift detection -------------------------------------------------
    def measured_phase_durations(self) -> List[Optional[float]]:
        """Per-phase durations the drift screen and calibration consume:
        the phase EMAs (``drift_source='ema'``) or the obs layer's
        latest-sample view (``'divergence'`` — no smoothing lag)."""
        if self.cfg.drift_source == "divergence":
            # deferred: obs.attribution imports adapt.calibrate, so a
            # top-level import here would be circular via the packages
            from repro.obs.attribution import latest_phase_durations
            return latest_phase_durations(
                self.telemetry.samples(), self.schedule.period
            )
        return self.telemetry.phase_times()

    def duration_deviation(self) -> float:
        """Cheap steady-state screen: largest relative deviation of a
        phase's measured duration from the planned one.  Only when this
        exceeds the drift threshold is the full 2-D calibration fit worth
        paying for (both are off the hot path; this keeps the common
        nothing-drifted check at ~zero cost)."""
        planned = planned_phase_durations(
            self.wire_times(), self.scheduler_cfg, self.schedule.period
        )
        dev = 0.0
        for p, m in zip(planned, self.measured_phase_durations()):
            if m is not None and p > 1e-12:
                dev = max(dev, abs(m - p) / p)
        return dev

    def _check(self, step: int) -> Optional[ReplanEvent]:
        trigger: Optional[str] = None
        # once a measured walk exists, EVERY replan solves under it —
        # mixing the planned walk into timing replans and the measured
        # walk into flip replans makes consecutive replans oscillate
        # between the two convergence models
        measured_walk = self.measured_walk()
        walk = measured_walk or self.walk
        profile: Optional[CalibratedProfile] = None
        if self.duration_deviation() > self.cfg.drift_threshold:
            profile = calibrate(
                self.wire_times(),
                self.scheduler_cfg,
                self.schedule.period,
                self.measured_phase_durations(),
            )
            if profile.drift > self.cfg.drift_threshold:
                trigger = "timing-drift"
        if trigger is None and measured_walk is not None:
            v = check_schedule(
                self.schedule.batch_size_sequence,
                self.schedule.period,
                measured_walk,
                eps=self.cfg.eps,
            )
            if not v.ok:
                trigger = "preserver-flip"
        if trigger is None:
            return None
        if profile is None:
            profile = calibrate(
                self.wire_times(),
                self.scheduler_cfg,
                self.schedule.period,
                self.measured_phase_durations(),
            )
        return self._replan(step, trigger, profile, walk)

    def measured_walk(self) -> Optional[WalkParams]:
        """WalkParams fit from the observed loss trace (the paper's
        'convergence info' edge of Fig. 7); None until enough samples."""
        losses = self.telemetry.losses()
        if len(losses) < self.cfg.min_loss_samples:
            return None
        return estimate_walk_params_from_losses(
            losses, eta=self.cfg.eta, batch=self.cfg.base_batch
        )

    # ---- replanning ------------------------------------------------------
    def _replan(
        self,
        step: int,
        trigger: str,
        profile: CalibratedProfile,
        walk: WalkParams,
    ) -> ReplanEvent:
        t0 = time.perf_counter()
        tr0 = self.tracer.now() if self.tracer is not None else 0.0
        chosen: Optional["PartitionCandidate"] = None
        solves: Tuple = ()
        # the planner re-prices precision itself, so it consumes the
        # UNPRICED f32 baseline re-based by the fitted drift scales;
        # profile.times is the priced view x scales (what the wire saw)
        replan_times = scale_times(
            self.times, profile.comp_scale, profile.comm_scale
        )
        new_times = replan_times
        # precision is opt-in: cfg.wire_precision='f32' keeps the wire
        # frozen no matter what the link does (the pre-§13 contract).
        # When opted in, a bandwidth collapse (calibrated comm_scale at
        # or past the escalation bar) unlocks the full ladder for this
        # replan; short of the bar, 'auto' keeps the already-installed
        # policy, re-priced and re-gated as-is (precision=... path)
        wire_req = self.cfg.wire_precision
        collapse = profile.comm_scale >= self.cfg.precision_comm_scale
        if wire_req != "f32" and collapse:
            wire_req = "auto"
        elif wire_req == "auto":
            wire_req = "f32"    # no collapse: hold the current policy
        forced = self.precision if wire_req == "f32" else None
        if forced is not None and self.repartitioner is not None:
            # a repartition may change n_buckets, invalidating a forced
            # per-bucket policy — let the ladder re-derive one instead
            forced, wire_req = None, "auto"
        if self.repartitioner is None:
            res = self.planner.plan(PlanRequest(
                times=replan_times,
                walk=walk,
                wire_precision="f32" if forced is not None else wire_req,
                precision=forced,
                heterogeneous=self.scheduler_cfg.heterogeneous,
                mu=self.scheduler_cfg.mu,
                eps=self.cfg.eps,
                max_retries=self.cfg.max_retries,
                capacity_growth=self.cfg.capacity_growth,
            ))
            schedule, verdict, scfg = (
                res.schedule, res.verdict, res.scheduler_cfg
            )
        else:
            # candidate-partition path: the installed partition competes
            # against the repartitioner's grid, every candidate priced at
            # the CUMULATIVE calibrated drift and gated by the Preserver
            cum_comp = self._cum_comp * profile.comp_scale
            cum_comm = self._cum_comm * profile.comm_scale
            cands = self.repartitioner.candidates(
                self.bucket_of, self.times.n,
                comp_scale=cum_comp, comm_scale=cum_comm,
            )
            pairs = []
            for c in cands:
                if c.tag == "current":
                    pairs.append((c.tag, replan_times))
                else:
                    pairs.append((c.tag, self.repartitioner.times_for(
                        c, comp_scale=cum_comp, comm_scale=cum_comm
                    )))
            res = self.planner.plan(PlanRequest(
                candidates=tuple(pairs),
                walk=walk,
                wire_precision="f32" if forced is not None else wire_req,
                precision=forced,
                baseline_tag="current",
                min_gain=self.repartitioner.cfg.min_gain,
                heterogeneous=self.scheduler_cfg.heterogeneous,
                mu=self.scheduler_cfg.mu,
                eps=self.cfg.eps,
                max_retries=self.cfg.max_retries,
                capacity_growth=self.cfg.capacity_growth,
            ))
            solves = res.candidates
            best = next(
                s for s in solves if s.tag == res.winner_tag
            )
            schedule, verdict, scfg = (
                best.schedule, best.verdict, best.scheduler_cfg
            )
            new_times = best.times
            if best.tag != "current":
                chosen = next(c for c in cands if c.tag == best.tag)
            if res.precision is not None:
                # precision rides on top of the winning partition: the
                # winning policy's solve supersedes the f32 one
                schedule, verdict, scfg = (
                    res.schedule, res.verdict, res.scheduler_cfg
                )
        new_precision = res.precision
        wscale = 1.0
        for s in res.precision_candidates:
            if s.policy == res.precision:
                wscale = s.wire_bytes_scale
        old_wire = self.precision.wire if self.precision else None
        new_wire = new_precision.wire if new_precision else None
        replan_s = time.perf_counter() - t0
        event = ReplanEvent(
            step=step,
            trigger=trigger,
            profile=profile,
            old_coverage_rate=self.times.coverage_rate,
            new_coverage_rate=new_times.coverage_rate,
            old_period=self.schedule.period,
            new_period=schedule.period,
            old_batch_seq=tuple(self.schedule.batch_size_sequence),
            new_batch_seq=tuple(schedule.batch_size_sequence),
            verdict=verdict,
            schedule=schedule,
            scheduler_cfg=scfg,
            times=new_times,
            changed=(
                chosen is not None
                or schedule.phases != self.schedule.phases
                or old_wire != new_wire
            ),
            replan_s=replan_s,
            old_n_buckets=self.times.n,
            new_n_buckets=new_times.n,
            partition=chosen,
            candidate_solves=solves,
            old_precision=self.precision,
            new_precision=new_precision,
            wire_bytes_scale=wscale,
        )
        if self.tracer is not None:
            # the ReplanEvent as a trace span covering the solve
            self.tracer.add(
                "replan", trigger, tr0, self.tracer.now(), step=step,
                comp_scale=profile.comp_scale,
                comm_scale=profile.comm_scale,
                old_coverage_rate=event.old_coverage_rate,
                new_coverage_rate=event.new_coverage_rate,
                old_period=event.old_period,
                new_period=event.new_period,
                changed=event.changed,
                repartition=event.partition_changed,
                precision=(
                    new_precision.describe() if new_precision else "f32"
                ),
            )
        self.events.append(event)
        self._last_replan_step = step
        # the calibrated profile becomes the baseline the next check
        # compares against EVEN when the phases came out identical (a
        # no-op replan): the drift was real and is now accounted for —
        # without this the same deviation would re-trigger every
        # cooldown.  Telemetry re-keys at the new period; the widened
        # warm-up also swallows the old schedule's tail steps that run
        # before the runtime installs the swap at a cycle boundary.
        old_period = self.schedule.period
        self.times = new_times
        self.schedule = schedule
        self.scheduler_cfg = scfg
        self.precision = new_precision
        self._cum_comp *= profile.comp_scale
        self._cum_comm *= profile.comm_scale
        if chosen is not None:
            self.bucket_of = chosen.bucket_of
        self.telemetry.rebase(schedule.period, extra_warmup=old_period)
        return event

    # ---- reporting -------------------------------------------------------
    def stats(self) -> dict:
        return {
            "replans": len(self.events),
            "swaps_requested": sum(1 for e in self.events if e.changed),
            "repartitions": sum(
                1 for e in self.events if e.partition_changed
            ),
            "precision_changes": sum(
                1 for e in self.events if e.precision_changed
            ),
            "wire_precision": (
                self.precision.describe() if self.precision else "f32"
            ),
            "triggers": [e.trigger for e in self.events],
            "last_comp_scale": (
                self.events[-1].profile.comp_scale if self.events else 1.0
            ),
            "last_comm_scale": (
                self.events[-1].profile.comm_scale if self.events else 1.0
            ),
        }
