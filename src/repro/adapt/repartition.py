"""Online repartitioning: candidate bucket partitions for the replanner.

DeFT's third lever is fixing "imbalanced communication/computation times
of tensors caused by partitioning/fusion strategies": when calibration
reveals the effective compute/comm ratio moved (a bandwidth drop, an MFU
mis-estimate), the best *partition* — not just the best schedule over the
installed partition — may change, the exact failure mode MG-WFBP shows
for naive merge choices.  This module generates the candidate partitions
the controller feeds to :meth:`repro.core.deft.Planner.plan` as a
candidate grid.

Everything here is pure Python off the hot path: a
:class:`~repro.train.bucketing.LeafTimeModel` (frozen per-leaf timing
atoms, built once from the parameter tree's shapes) re-aggregates bucket
times for any candidate partition, scaled by the cumulative calibrated
(comp, comm) drift.  Candidates come from two generators:

* the legacy ``partition_elems`` factor grid (greedy model-order fill at
  a handful of bucket-size targets), and
* :func:`dp_partition` — an exact per-boundary DP over the leaf order
  that minimizes :func:`exposed_makespan`, the serialized-link
  backward-overlap surrogate (MG-WFBP's objective).  The greedy fill
  only controls bucket *size*; the DP places each boundary where the
  compute/comm overlap actually wants it, which is the partition lever
  the paper's third failure mode is about.

The runtime side — re-packing the flat state into the chosen partition's
:class:`BucketLayout` at a cycle boundary — lives in
``DeftRuntime.prepare_swap(..., layout=...)`` (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.bucket import BucketTimes
from repro.train.bucketing import LeafTimeModel


def exposed_makespan(
    model: LeafTimeModel,
    bucket_of_leaf: Sequence[int],
    n_buckets: int,
    *,
    comp_scale: float = 1.0,
    comm_scale: float = 1.0,
) -> float:
    """Serialized-link backward-overlap makespan of a partition.

    The surrogate the boundary DP optimizes: backward visits buckets in
    reverse model order; each bucket's grad-sync becomes launchable when
    its backward finishes and the (single, serialized) link transmits
    launchable buckets FIFO.  The returned value is when the last sync
    lands — total backward plus whatever communication stayed exposed.
    Latency-bearing ``allreduce_time`` pricing means over-splitting
    penalizes itself.  Cheap (O(n_leaves)), exact for the surrogate, and
    deliberately simulator-free: the real simulator ranks the surviving
    candidates downstream in the Planner.
    """
    bwd = [0.0] * n_buckets
    elems = [0] * n_buckets
    for i, b in enumerate(bucket_of_leaf):
        bwd[b] += 2.0 * model.fwd_s[i]
        elems[b] += model.elems[i]
    c_scale = model.comm_scale * comm_scale
    t = 0.0        # backward clock
    free = 0.0     # link free time
    for b in reversed(range(n_buckets)):
        t += bwd[b] * comp_scale
        free = max(free, t) + model.hw.allreduce_time(elems[b]) * c_scale
    return free


def dp_partition(
    model: LeafTimeModel,
    *,
    comp_scale: float = 1.0,
    comm_scale: float = 1.0,
    max_buckets: Optional[int] = None,
) -> Tuple[Tuple[int, ...], int]:
    """Exact boundary placement: the contiguous (model-order) partition
    minimizing :func:`exposed_makespan`, by DP over leaf boundaries.

    Works in backward processing order (reverse model order), where the
    makespan obeys ``finish(s..e) = max(finish(prefix), bwd_prefix[e]) +
    comm(s..e)`` — monotone in ``finish(prefix)``, so minimizing the
    finish time at every boundary is optimal substructure and an
    O(n_leaves^2) sweep is exact over ALL boundary placements (the
    greedy fill can only ever produce one of them, hence DP <= greedy
    under the surrogate — the property tests pin this).  ``max_buckets``
    optionally bounds the bucket count (adds a segment-count DP
    dimension).  Returns ``(bucket_of_leaf, n_buckets)`` in
    :func:`~repro.train.bucketing.greedy_fill_partition` shape.
    """
    order = model.order
    L = len(order)
    if L == 0:
        return (), 0
    # per-position atoms in backward processing order
    proc = tuple(reversed(order))
    bwd_pfx = [0.0] * (L + 1)
    el_pfx = [0] * (L + 1)
    for p, leaf in enumerate(proc):
        bwd_pfx[p + 1] = bwd_pfx[p] + 2.0 * model.fwd_s[leaf] * comp_scale
        el_pfx[p + 1] = el_pfx[p] + model.elems[leaf]
    c_scale = model.comm_scale * comm_scale

    def comm(s: int, e: int) -> float:
        return model.hw.allreduce_time(el_pfx[e] - el_pfx[s]) * c_scale

    INF = float("inf")
    if max_buckets is None:
        # unbounded: the finish time is monotone in the prefix's finish
        # time, so one O(L^2) sweep suffices — no segment-count state
        dp = [INF] * (L + 1)
        back = [0] * (L + 1)
        dp[0] = 0.0
        for e in range(1, L + 1):
            for s in range(e):
                f = max(dp[s], bwd_pfx[e]) + comm(s, e)
                if f < dp[e]:
                    dp[e], back[e] = f, s
        bounds = [L]
        while bounds[-1] > 0:
            bounds.append(back[bounds[-1]])
        bounds.reverse()                  # 0 = bounds[0] < ... < L
        k_best = len(bounds) - 1
    else:
        # bounded: layered DP, dp[k][e] = best finish covering proc[:e]
        # with exactly k segments — O(L^2 * max_buckets)
        kmax = min(max_buckets, L)
        dpk = [[INF] * (L + 1) for _ in range(kmax + 1)]
        backk: dict = {}
        dpk[0][0] = 0.0
        for k in range(1, kmax + 1):
            for e in range(1, L + 1):
                best, arg = INF, -1
                for s in range(k - 1, e):
                    prev = dpk[k - 1][s]
                    if prev == INF:
                        continue
                    f = max(prev, bwd_pfx[e]) + comm(s, e)
                    if f < best:
                        best, arg = f, s
                dpk[k][e] = best
                if arg >= 0:
                    backk[(k, e)] = arg
        k_best = min(range(1, kmax + 1), key=lambda k: dpk[k][L])
        bounds = [L]
        k, e = k_best, L
        while k > 0:
            e = backk[(k, e)]
            bounds.append(e)
            k -= 1
        bounds.reverse()                  # 0 = bounds[0] < ... < L
    # proc segment j (earliest backward) is model-order bucket
    # k_best - 1 - j; emit flat-leaf-indexed assignment
    bucket_of = [0] * L
    for j in range(k_best):
        for p in range(bounds[j], bounds[j + 1]):
            bucket_of[proc[p]] = k_best - 1 - j
    return tuple(bucket_of), k_best


@dataclasses.dataclass(frozen=True)
class PartitionCandidate:
    """One candidate leaf->bucket partition, in layout-buildable terms."""

    tag: str
    partition_elems: int
    bucket_of: Tuple[int, ...]
    n_buckets: int


@dataclasses.dataclass(frozen=True)
class RepartitionConfig:
    """Knobs of the candidate generator."""

    base_partition_elems: int
    # grid of partition_elems multipliers tried around the installed one
    factors: Tuple[float, ...] = (0.5, 1.0, 2.0, 4.0)
    # relative simulated-iteration-time gain required to switch partitions
    # (a repack is cheap but not free; near-ties must not thrash)
    min_gain: float = 0.02
    # add the exact boundary-DP candidate (dp_partition) to the grid
    use_dp: bool = True
    # optional bucket-count cap for the DP (None: latency self-regulates)
    dp_max_buckets: Optional[int] = None


class Repartitioner:
    """Candidate partitions + their calibrated bucket times.

    The controller owns one of these when ``--adapt-repartition`` is on;
    every replan asks for the current candidate set, solves each through
    the Preserver-gated feedback loop, and adopts the winner.  Candidates
    are deduplicated by their ``bucket_of`` assignment (two factors that
    greedy-fill into the same partition are the same candidate), and the
    installed partition is always candidate ``"current"``.
    """

    def __init__(self, model: LeafTimeModel, cfg: RepartitionConfig):
        self.model = model
        self.cfg = cfg

    def candidates(
        self,
        current_bucket_of: Sequence[int],
        current_n_buckets: int,
        *,
        comp_scale: float = 1.0,
        comm_scale: float = 1.0,
    ) -> List[PartitionCandidate]:
        """The candidate superset: installed partition, the legacy
        greedy factor grid, and (``use_dp``) the exact boundary DP
        priced at the cumulative calibrated scales — the DP boundaries
        shift with the comp/comm ratio, which is the whole point of
        repartitioning on drift."""
        out = [PartitionCandidate(
            tag="current",
            partition_elems=self.cfg.base_partition_elems,
            bucket_of=tuple(current_bucket_of),
            n_buckets=current_n_buckets,
        )]
        seen = {out[0].bucket_of}
        for f in self.cfg.factors:
            elems = max(int(self.cfg.base_partition_elems * f), 1)
            bucket_of, nb = self.model.partition(elems)
            if bucket_of in seen:
                continue
            seen.add(bucket_of)
            out.append(PartitionCandidate(
                tag=f"elems-x{f:g}",
                partition_elems=elems,
                bucket_of=bucket_of,
                n_buckets=nb,
            ))
        if self.cfg.use_dp:
            bucket_of, nb = dp_partition(
                self.model,
                comp_scale=comp_scale, comm_scale=comm_scale,
                max_buckets=self.cfg.dp_max_buckets,
            )
            if nb and bucket_of not in seen:
                seen.add(bucket_of)
                out.append(PartitionCandidate(
                    tag="dp",
                    partition_elems=self.cfg.base_partition_elems,
                    bucket_of=bucket_of,
                    n_buckets=nb,
                ))
        return out

    def times_for(
        self,
        cand: PartitionCandidate,
        *,
        comp_scale: float = 1.0,
        comm_scale: float = 1.0,
    ) -> BucketTimes:
        """Candidate bucket times under the cumulative calibrated
        scales (what the world looks like NOW for that partition)."""
        return self.model.bucket_times(
            cand.bucket_of, cand.n_buckets,
            comp_scale=comp_scale, comm_scale=comm_scale,
        )

    def base_times_for(self, cand: PartitionCandidate) -> BucketTimes:
        """Candidate bucket times at scale 1 (the pre-drift analytic
        profile — what synthetic telemetry replays need as run-base)."""
        return self.model.bucket_times(cand.bucket_of, cand.n_buckets)


def candidate_solve_table(solves) -> str:
    """Human-readable one-line-per-candidate summary of a
    candidate-grid :meth:`~repro.core.deft.Planner.plan` result
    (explorer / logs)."""
    rows = []
    for s in solves:
        rows.append(
            f"    {s.tag:<12s} n={s.times.n:2d} "
            f"iter={s.iteration_time * 1e3:8.2f}ms "
            f"period={s.schedule.period} "
            f"k-seq={s.schedule.batch_size_sequence} "
            f"preserver={'ok' if s.verdict.ok else 'REJECT'}"
        )
    return "\n".join(rows)
