"""Online repartitioning: candidate bucket partitions for the replanner.

DeFT's third lever is fixing "imbalanced communication/computation times
of tensors caused by partitioning/fusion strategies": when calibration
reveals the effective compute/comm ratio moved (a bandwidth drop, an MFU
mis-estimate), the best *partition* — not just the best schedule over the
installed partition — may change, the exact failure mode MG-WFBP shows
for naive merge choices.  This module generates the candidate partitions
the controller feeds to :meth:`repro.core.deft.Planner.plan` as a
candidate grid.

Everything here is pure Python off the hot path: a
:class:`~repro.train.bucketing.LeafTimeModel` (frozen per-leaf timing
atoms, built once from the parameter tree's shapes) re-aggregates bucket
times for any greedy partition at a grid of ``partition_elems`` factors,
scaled by the cumulative calibrated (comp, comm) drift.  The runtime side
— re-packing the flat state into the chosen partition's
:class:`BucketLayout` at a cycle boundary — lives in
``DeftRuntime.prepare_swap(..., layout=...)`` (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.bucket import BucketTimes
from repro.train.bucketing import LeafTimeModel


@dataclasses.dataclass(frozen=True)
class PartitionCandidate:
    """One candidate leaf->bucket partition, in layout-buildable terms."""

    tag: str
    partition_elems: int
    bucket_of: Tuple[int, ...]
    n_buckets: int


@dataclasses.dataclass(frozen=True)
class RepartitionConfig:
    """Knobs of the candidate generator."""

    base_partition_elems: int
    # grid of partition_elems multipliers tried around the installed one
    factors: Tuple[float, ...] = (0.5, 1.0, 2.0, 4.0)
    # relative simulated-iteration-time gain required to switch partitions
    # (a repack is cheap but not free; near-ties must not thrash)
    min_gain: float = 0.02


class Repartitioner:
    """Candidate partitions + their calibrated bucket times.

    The controller owns one of these when ``--adapt-repartition`` is on;
    every replan asks for the current candidate set, solves each through
    the Preserver-gated feedback loop, and adopts the winner.  Candidates
    are deduplicated by their ``bucket_of`` assignment (two factors that
    greedy-fill into the same partition are the same candidate), and the
    installed partition is always candidate ``"current"``.
    """

    def __init__(self, model: LeafTimeModel, cfg: RepartitionConfig):
        self.model = model
        self.cfg = cfg

    def candidates(
        self,
        current_bucket_of: Sequence[int],
        current_n_buckets: int,
    ) -> List[PartitionCandidate]:
        out = [PartitionCandidate(
            tag="current",
            partition_elems=self.cfg.base_partition_elems,
            bucket_of=tuple(current_bucket_of),
            n_buckets=current_n_buckets,
        )]
        seen = {out[0].bucket_of}
        for f in self.cfg.factors:
            elems = max(int(self.cfg.base_partition_elems * f), 1)
            bucket_of, nb = self.model.partition(elems)
            if bucket_of in seen:
                continue
            seen.add(bucket_of)
            out.append(PartitionCandidate(
                tag=f"elems-x{f:g}",
                partition_elems=elems,
                bucket_of=bucket_of,
                n_buckets=nb,
            ))
        return out

    def times_for(
        self,
        cand: PartitionCandidate,
        *,
        comp_scale: float = 1.0,
        comm_scale: float = 1.0,
    ) -> BucketTimes:
        """Candidate bucket times under the cumulative calibrated
        scales (what the world looks like NOW for that partition)."""
        return self.model.bucket_times(
            cand.bucket_of, cand.n_buckets,
            comp_scale=comp_scale, comm_scale=comm_scale,
        )

    def base_times_for(self, cand: PartitionCandidate) -> BucketTimes:
        """Candidate bucket times at scale 1 (the pre-drift analytic
        profile — what synthetic telemetry replays need as run-base)."""
        return self.model.bucket_times(cand.bucket_of, cand.n_buckets)


def candidate_solve_table(solves) -> str:
    """Human-readable one-line-per-candidate summary of a
    candidate-grid :meth:`~repro.core.deft.Planner.plan` result
    (explorer / logs)."""
    rows = []
    for s in solves:
        rows.append(
            f"    {s.tag:<12s} n={s.times.n:2d} "
            f"iter={s.iteration_time * 1e3:8.2f}ms "
            f"period={s.schedule.period} "
            f"k-seq={s.schedule.batch_size_sequence} "
            f"preserver={'ok' if s.verdict.ok else 'REJECT'}"
        )
    return "\n".join(rows)
