"""Measurement calibration: re-base the analytical profile from telemetry.

The Solver planned against an analytical :class:`BucketTimes` derived from
``HardwareModel`` napkin constants (peak FLOP/s * assumed MFU, nominal ICI
bandwidth).  Once the job is running we observe per-phase wall times; this
module inverts the timeline model to recover the two effective scalars the
hardware model got wrong:

* ``comp_scale`` — measured compute time / analytic (an MFU error),
* ``comm_scale`` — measured communication time / analytic (a bandwidth
  error, e.g. a congested or degraded link).

The forward model is the same discrete-event simulator the planner's
figures use: per-phase duration = f(BucketTimes scaled by (a, b), the
installed schedule's plans).  Because exposed communication is a
``max(0, ...)`` of overlap, the inverse is not linear — we fit (a, b) by a
coarse-to-fine grid search in log space against the measured per-phase
EMAs, which is exact enough (a few percent) at the 2-parameter scale and
costs a few hundred cheap simulator evaluations, all off the hot path.

The result is a :class:`CalibratedProfile`: re-based ``BucketTimes`` (what
the Solver re-consumes), an effective ``HardwareModel`` (ici_bw / mfu
re-fit — what a human reads in logs), and the rms fit residual.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.bucket import BucketTimes
from repro.core.links import LinkModel
from repro.core.profiler import HardwareModel
from repro.core.scheduler import DeftScheduler, IterationPlan, SchedulerConfig
from repro.core.simulator import simulate_deft

# cycle-extraction warm-up used by extract_schedule (core/scheduler.py):
# plans[_WARMUP + p + j*period] is the j-th occurrence of cycle phase p.
_WARMUP = 16


def scale_times(
    times: BucketTimes, comp_scale: float, comm_scale: float
) -> BucketTimes:
    return BucketTimes(
        tuple(f * comp_scale for f in times.fwd),
        tuple(b * comp_scale for b in times.bwd),
        tuple(c * comm_scale for c in times.comm),
    )


_PLANS_MEMO: dict = {}


def schedule_plans(
    times: BucketTimes, scfg: SchedulerConfig, horizon: Optional[int] = None
) -> List[IterationPlan]:
    """Regenerate the horizon plan list the installed schedule was cut
    from (same Solver inputs -> same deterministic plans).  Memoized —
    the controller re-derives the same plan list every check."""
    key = (
        times.fwd, times.bwd, times.comm,
        scfg.heterogeneous, scfg.mu, scfg.capacity_factor,
        horizon or scfg.horizon,
    )
    if key not in _PLANS_MEMO:
        if len(_PLANS_MEMO) > 256:
            _PLANS_MEMO.clear()
        _PLANS_MEMO[key] = DeftScheduler(times, scfg).run(
            horizon or scfg.horizon
        )
    return _PLANS_MEMO[key]


def fit_horizon(period: int) -> int:
    """Plan-list length for calibration fits: enough post-warm-up cycles
    to average, far shorter than the Solver's full 96-step horizon."""
    return _WARMUP + 4 * max(period, 1)


def steady_phase_durations(
    plans: Sequence[IterationPlan],
    run_times: BucketTimes,
    period: int,
    *,
    mu: float,
    heterogeneous: bool,
    link_models: Optional[Dict[int, LinkModel]] = None,
) -> Tuple[float, ...]:
    """Steady-state wall seconds of each cycle phase when the given plans
    execute under ``run_times`` (which may differ from the times the plans
    were solved for — that difference IS the drift being measured).
    ``link_models`` prices each link separately (DESIGN.md §14); None
    keeps the legacy scalar-``mu`` secondary."""
    sim = simulate_deft(
        run_times, plans, mu=mu, heterogeneous=heterogeneous,
        link_models=link_models,
    )
    durs = sim.iteration_durations
    out = []
    for p in range(period):
        occ = [
            durs[i]
            for i in range(_WARMUP + p, len(durs), period)
        ]
        # drop the last, possibly update-tail-truncated occurrence when
        # there are enough samples
        if len(occ) > 2:
            occ = occ[:-1]
        out.append(sum(occ) / max(len(occ), 1))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class CalibratedProfile:
    """The measurement-re-based profile the replanner consumes."""

    comp_scale: float           # measured / analytic compute time
    comm_scale: float           # measured / analytic comm time
    times: BucketTimes          # analytic times re-based by the scales
    hw: HardwareModel           # effective hardware model (logs / humans)
    residual: float             # rms per-phase fit residual, seconds
    planned: Tuple[float, ...]  # per-phase durations the plan assumed
    measured: Tuple[float, ...] # per-phase durations telemetry saw
    # per-link refinement (DESIGN.md §14): the secondary link's residual
    # inverse-bandwidth multiplier on top of comm_scale, and the fitted
    # LinkModels a heterogeneity-aware replan consumes
    # (``PlanRequest.link_models``).  1.0 / None without the refinement.
    sec_scale: float = 1.0
    link_models: Optional[Dict[int, LinkModel]] = None

    @property
    def drift(self) -> float:
        """Largest relative deviation of any fitted scale from 1."""
        return max(abs(self.comp_scale - 1.0), abs(self.comm_scale - 1.0),
                   abs(self.sec_scale - 1.0))


def _rms(xs: Sequence[float]) -> float:
    return math.sqrt(sum(x * x for x in xs) / max(len(xs), 1))


def planned_phase_durations(
    planned_times: BucketTimes, scfg: SchedulerConfig, period: int
) -> Tuple[float, ...]:
    """Per-phase durations the installed plan *assumed* — the cheap
    baseline the controller's steady-state fast path compares EMAs
    against before paying for a full 2-D fit."""
    plans = schedule_plans(planned_times, scfg, horizon=fit_horizon(period))
    return steady_phase_durations(
        plans, planned_times, period,
        mu=scfg.mu, heterogeneous=scfg.heterogeneous,
    )


def fit_scales(
    planned_times: BucketTimes,
    scfg: SchedulerConfig,
    period: int,
    measured: Sequence[Optional[float]],
    *,
    span: float = 32.0,
    coarse: int = 9,
    refine_rounds: int = 2,
    link_models: Optional[Dict[int, LinkModel]] = None,
) -> Tuple[float, float, float]:
    """Fit (comp_scale, comm_scale) so the simulated per-phase durations
    of the installed plans match the measured EMAs.  Log-space grid over
    ``[1/span, span]``, refined ``refine_rounds`` times around the best
    cell.  Returns (comp_scale, comm_scale, rms_residual).
    ``link_models`` fixes per-link pricing inside the forward model (the
    coordinate-descent partner of :func:`fit_secondary_scale`)."""
    plans = schedule_plans(planned_times, scfg, horizon=fit_horizon(period))
    obs = [(i, m) for i, m in enumerate(measured[:period]) if m is not None]
    if not obs:
        return 1.0, 1.0, 0.0
    # Exposed comm is max(0, .)-shaped: a link that got FASTER than
    # planned overlaps completely and becomes invisible, leaving whole
    # regions of (a, b) with identical predictions.  A small pull toward
    # (1, 1) makes the fit pick the least-surprising member of such a
    # plateau instead of an arbitrary corner that would read as drift.
    reg = 1e-3 * sum(m for _, m in obs) / len(obs)

    def loss(a: float, b: float) -> float:
        pred = steady_phase_durations(
            plans, scale_times(planned_times, a, b), period,
            mu=scfg.mu, heterogeneous=scfg.heterogeneous,
            link_models=link_models,
        )
        return _rms([pred[i] - m for i, m in obs]) + reg * (
            abs(math.log(a)) + abs(math.log(b))
        )

    best = (1.0, 1.0)
    best_l = loss(*best)
    lo_a = lo_b = -math.log(span)
    hi_a = hi_b = math.log(span)
    for _ in range(1 + refine_rounds):
        grid_a = [lo_a + (hi_a - lo_a) * i / (coarse - 1) for i in range(coarse)]
        grid_b = [lo_b + (hi_b - lo_b) * i / (coarse - 1) for i in range(coarse)]
        for la in grid_a:
            for lb in grid_b:
                l = loss(math.exp(la), math.exp(lb))
                if l < best_l:
                    best_l, best = l, (math.exp(la), math.exp(lb))
        # shrink the window around the current best cell
        ca, cb = math.log(best[0]), math.log(best[1])
        wa = (hi_a - lo_a) / (coarse - 1)
        wb = (hi_b - lo_b) / (coarse - 1)
        lo_a, hi_a = ca - wa, ca + wa
        lo_b, hi_b = cb - wb, cb + wb
    return best[0], best[1], best_l


def fit_secondary_scale(
    planned_times: BucketTimes,
    scfg: SchedulerConfig,
    period: int,
    measured: Sequence[Optional[float]],
    comp_scale: float,
    comm_scale: float,
    *,
    span: float = 8.0,
    coarse: int = 9,
    refine_rounds: int = 2,
) -> Tuple[float, Optional[Dict[int, LinkModel]], float]:
    """Per-link refinement of the 2-D fit (DESIGN.md §14).

    The joint ``comm_scale`` moves BOTH links together, so a
    secondary-only degradation (the common case: the slow host/DCN path
    congests while the primary fabric holds) aliases into it.  With the
    global scales pinned, this 1-D stage fits the secondary link's
    residual inverse-bandwidth multiplier by re-simulating the installed
    plans under per-link :class:`LinkModel` pricing.  Returns
    ``(sec_scale, link_models, rms_residual)`` — the models carry the
    fitted multiplier on top of the config's base models (latency terms
    preserved) and feed ``PlanRequest.link_models`` for the replan.
    ``(1.0, None, 0.0)`` when the setup is homogeneous or unobserved.
    """
    obs = [(i, m) for i, m in enumerate(measured[:period]) if m is not None]
    if not obs or not scfg.heterogeneous:
        return 1.0, None, 0.0
    plans = schedule_plans(planned_times, scfg, horizon=fit_horizon(period))
    run = scale_times(planned_times, comp_scale, comm_scale)
    base = scfg.models()
    reg = 1e-3 * sum(m for _, m in obs) / len(obs)

    def models_for(s: float) -> Dict[int, LinkModel]:
        return {
            lid: (m if lid == 0
                  else LinkModel(m.latency, m.inv_bw * s))
            for lid, m in base.items()
        }

    def loss(s: float) -> float:
        pred = steady_phase_durations(
            plans, run, period, mu=scfg.mu,
            heterogeneous=scfg.heterogeneous,
            link_models=models_for(s),
        )
        return _rms([pred[i] - m for i, m in obs]) + reg * abs(math.log(s))

    best_s = 1.0
    best_l = loss(best_s)
    lo, hi = -math.log(span), math.log(span)
    for _ in range(1 + refine_rounds):
        for i in range(coarse):
            ls = lo + (hi - lo) * i / (coarse - 1)
            l = loss(math.exp(ls))
            if l < best_l:
                best_l, best_s = l, math.exp(ls)
        w = (hi - lo) / (coarse - 1)
        c = math.log(best_s)
        lo, hi = c - w, c + w
    return best_s, models_for(best_s), best_l


def calibrate(
    planned_times: BucketTimes,
    scfg: SchedulerConfig,
    period: int,
    measured: Sequence[Optional[float]],
    hw: Optional[HardwareModel] = None,
    *,
    per_link: bool = False,
) -> CalibratedProfile:
    """Fit the effective scales and package the re-based profile.

    ``per_link=True`` adds the staged secondary-link refinement
    (:func:`fit_secondary_scale`): the profile then carries fitted
    :class:`LinkModel` s and its residual is the per-link fit's."""
    hw = hw or HardwareModel()
    a, b, resid = fit_scales(planned_times, scfg, period, measured)
    sec_scale, link_models = 1.0, None
    if per_link and scfg.heterogeneous:
        # coordinate descent: a secondary-only slowdown aliases into the
        # joint (a, b) fit, so alternate the 1-D per-link stage with
        # (a, b) re-fits under the fitted LinkModels until both views of
        # the measurements agree.  Two alternations suffice — each stage
        # is a regularized global grid search, not a local step.
        for _ in range(2):
            sec_scale, link_models, resid = fit_secondary_scale(
                planned_times, scfg, period, measured, a, b
            )
            if link_models is None:
                break
            a, b, resid = fit_scales(
                planned_times, scfg, period, measured,
                link_models=link_models,
            )
        if link_models is not None:
            sec_scale, link_models, resid = fit_secondary_scale(
                planned_times, scfg, period, measured, a, b
            )
    planned = planned_phase_durations(planned_times, scfg, period)
    eff_hw = dataclasses.replace(
        hw,
        # comm time scales inversely with bandwidth; compute time inversely
        # with achieved MFU.  These are *effective* values (they absorb
        # whatever the 2-scalar model cannot separate), for logs and for
        # re-profiling at a different shape.
        ici_bw=hw.ici_bw / max(b, 1e-9),
        mfu=hw.mfu / max(a, 1e-9),
    )
    return CalibratedProfile(
        comp_scale=a,
        comm_scale=b,
        times=scale_times(planned_times, a, b),
        hw=eff_hw,
        residual=resid,
        planned=planned,
        measured=tuple(
            m if m is not None else p for m, p in zip(measured, planned)
        ),
        sec_scale=sec_scale,
        link_models=link_models,
    )
