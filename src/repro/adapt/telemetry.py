"""Runtime telemetry for the online adaptive control plane (DESIGN.md §7).

The paper closes its Fig. 7 loop with "convergence loss quantification"
collected from the *running* job; our analogue is a small, allocation-free
store the train loop feeds after every :meth:`DeftRuntime.step`:

* a **ring buffer** of per-step samples (step id, phase-in-cycle, wall
  seconds, loss) bounded by ``ring_size`` — the control plane never holds
  more than a constant amount of history;
* **per-phase EMA** of wall time, keyed by the phase's position in the
  installed schedule's cycle — this is what calibration compares against
  the planned per-phase durations;
* **warm-up skip** — the first ``warmup_steps`` samples after a (re)start
  are recorded in the ring but excluded from the EMAs, so compile jitter
  and cold caches right after start or a hot-swap never read as drift.

The store is schedule-relative: after a hot-swap the runtime's cycle and
period change, so :meth:`rebase` re-keys the per-phase EMAs (and re-arms
the warm-up) while keeping the loss trace, which is schedule-independent.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional


@dataclasses.dataclass(frozen=True)
class StepSample:
    """One observed training step."""

    step: int
    phase: int              # position in the installed schedule's cycle
    wall_s: float
    loss: Optional[float] = None
    updated: bool = False


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    ring_size: int = 256
    ema_alpha: float = 0.25     # weight of the newest sample
    warmup_steps: int = 8       # samples skipped after (re)start / rebase


class Telemetry:
    """Ring-buffer store with per-phase EMA smoothing and warm-up skip."""

    def __init__(self, n_phases: int, cfg: Optional[TelemetryConfig] = None):
        self.cfg = cfg or TelemetryConfig()
        self._ring: Deque[StepSample] = collections.deque(
            maxlen=self.cfg.ring_size
        )
        self._losses: Deque[float] = collections.deque(
            maxlen=self.cfg.ring_size
        )
        self.n_recorded = 0
        self.rebase(n_phases)

    # ---- lifecycle ------------------------------------------------------
    def rebase(self, n_phases: int, extra_warmup: int = 0) -> None:
        """Re-key the per-phase EMAs for a new schedule (hot-swap) and
        re-arm the warm-up skip.  The loss trace survives — convergence is
        a property of training, not of the schedule.

        ``extra_warmup`` widens the re-armed skip: the controller rebases
        at *replan* time, but the runtime installs the new schedule up to
        one old period later, so the old schedule's tail steps (recorded
        under the new schedule's phase keys) must also fall inside the
        warm-up window or they would pollute the fresh EMAs."""
        self.n_phases = n_phases
        self._ema: List[Optional[float]] = [None] * n_phases
        self._ema_n: List[int] = [0] * n_phases
        self._since_rebase = -max(extra_warmup, 0)

    # ---- recording ------------------------------------------------------
    def record(
        self,
        step: int,
        phase: int,
        wall_s: float,
        loss: Optional[float] = None,
        updated: bool = False,
        cold: Optional[bool] = None,
    ) -> StepSample:
        """``cold`` is the runtime's first-dispatch tag (DESIGN.md §11):
        ``True`` means this wall time includes an executable's one-off
        lazy work and must never enter the EMAs.  When the tag is
        available (not ``None``) it REPLACES the fixed ``warmup_steps``
        count — warm samples enter the EMAs immediately — while the
        rebase ``extra_warmup`` window (``_since_rebase <= 0``) still
        guards the old schedule's tail steps after a hot-swap.  With
        ``cold=None`` (no tag) the legacy fixed-count skip applies."""
        sample = StepSample(step, phase, wall_s, loss, updated)
        self._ring.append(sample)
        self.n_recorded += 1
        if loss is not None:
            self._losses.append(float(loss))
        self._since_rebase += 1
        if cold is True:
            return sample                      # first-dispatch pollution
        if cold is None:
            if self._since_rebase <= self.cfg.warmup_steps:
                return sample                  # warm-up skip (fixed count)
        elif self._since_rebase <= 0:
            return sample                      # post-rebase tail window
        if 0 <= phase < self.n_phases:
            prev = self._ema[phase]
            a = self.cfg.ema_alpha
            self._ema[phase] = (
                wall_s if prev is None else a * wall_s + (1.0 - a) * prev
            )
            self._ema_n[phase] += 1
        return sample

    # ---- queries --------------------------------------------------------
    def phase_time(self, phase: int) -> Optional[float]:
        """EMA wall seconds of one phase; None until it has a sample."""
        return self._ema[phase]

    def phase_times(self) -> List[Optional[float]]:
        return list(self._ema)

    def phase_samples(self, phase: int) -> int:
        return self._ema_n[phase]

    def ready(self, min_per_phase: int = 1) -> bool:
        """Every phase of the cycle has at least ``min_per_phase``
        post-warm-up samples — calibration would otherwise compare
        against holes."""
        return all(n >= min_per_phase for n in self._ema_n)

    def losses(self, n: Optional[int] = None) -> List[float]:
        xs = list(self._losses)
        return xs if n is None else xs[-n:]

    def samples(self, n: Optional[int] = None) -> List[StepSample]:
        xs = list(self._ring)
        return xs if n is None else xs[-n:]

    def __len__(self) -> int:
        return len(self._ring)


class ShardTelemetry:
    """Per-shard step-time and collective-latency store (DESIGN.md §10).

    :class:`Telemetry` is schedule-relative — it keys EMAs by cycle
    phase and cannot say *which device* is slow.  The elastic health
    monitor instead needs a per-shard view: one EMA of step wall time
    and one of collective latency per data-parallel shard, plus the
    monotonic-clock timestamp of each shard's last heartbeat (the
    absolute-timeout dead-device policy reads it).

    The clock is injected (``now``), never sampled — fault scenarios
    replay deterministically.  ``warmup_steps`` samples per shard are
    heartbeat-only (recorded but excluded from the EMAs) so compile
    jitter after a start or a mesh change never reads as a straggler.
    """

    def __init__(self, n_shards: int, cfg: Optional[TelemetryConfig] = None):
        self.cfg = cfg or TelemetryConfig()
        self.rebase(n_shards)

    # ---- lifecycle ------------------------------------------------------
    def rebase(self, n_shards: int) -> None:
        """Re-key for a new shard count (elastic scale-down/up) and
        re-arm the per-shard warm-up."""
        self.n_shards = n_shards
        self._step_ema: List[Optional[float]] = [None] * n_shards
        self._coll_ema: List[Optional[float]] = [None] * n_shards
        self._n: List[int] = [0] * n_shards
        self._seen: List[int] = [0] * n_shards
        self._last_seen: List[Optional[float]] = [None] * n_shards

    # ---- recording ------------------------------------------------------
    def record(
        self,
        shard: int,
        wall_s: float,
        collective_s: Optional[float] = None,
        now: Optional[float] = None,
    ) -> None:
        """One heartbeat from ``shard``: its step wall seconds, optional
        collective-phase seconds, and the monotonic clock it arrived at."""
        if not 0 <= shard < self.n_shards:
            raise IndexError(f"shard {shard} out of range 0..{self.n_shards - 1}")
        if now is not None:
            self._last_seen[shard] = now
        self._seen[shard] += 1
        if self._seen[shard] <= self.cfg.warmup_steps:
            return                                  # warm-up skip
        a = self.cfg.ema_alpha

        def ema(prev: Optional[float], x: float) -> float:
            return x if prev is None else a * x + (1.0 - a) * prev

        self._step_ema[shard] = ema(self._step_ema[shard], wall_s)
        if collective_s is not None:
            self._coll_ema[shard] = ema(self._coll_ema[shard], collective_s)
        self._n[shard] += 1

    def heartbeat(self, shard: int, now: float) -> None:
        """Timestamp-only liveness signal (no timing sample) — a shard
        that is alive but produced no usable measurement this step."""
        self._last_seen[shard] = now

    # ---- queries --------------------------------------------------------
    def step_time(self, shard: int) -> Optional[float]:
        return self._step_ema[shard]

    def collective_time(self, shard: int) -> Optional[float]:
        return self._coll_ema[shard]

    def last_seen(self, shard: int) -> Optional[float]:
        return self._last_seen[shard]

    def samples(self, shard: int) -> int:
        """Post-warm-up samples recorded for ``shard``."""
        return self._n[shard]

    def median_step_time(
        self, shards: Optional[List[int]] = None
    ) -> Optional[float]:
        """Median step-time EMA over ``shards`` (default: all) — the
        straggler policy's 'healthy peer' reference.  A median (not a
        mean) keeps one runaway shard from dragging its own yardstick."""
        idx = range(self.n_shards) if shards is None else shards
        xs = sorted(
            t for i in idx if (t := self._step_ema[i]) is not None
        )
        if not xs:
            return None
        m = len(xs) // 2
        return xs[m] if len(xs) % 2 else 0.5 * (xs[m - 1] + xs[m])

    def median_collective_time(
        self, shards: Optional[List[int]] = None
    ) -> Optional[float]:
        idx = range(self.n_shards) if shards is None else shards
        xs = sorted(
            t for i in idx if (t := self._coll_ema[i]) is not None
        )
        if not xs:
            return None
        m = len(xs) // 2
        return xs[m] if len(xs) % 2 else 0.5 * (xs[m - 1] + xs[m])
