"""Pallas TPU kernels for the compute hot spots the DeFT schedule overlaps
against: flash attention (causal/window/softcap/GQA), the RG-LRU linear
recurrence, and the RWKV-6 chunked recurrence.  Each subpackage ships
kernel.py (pl.pallas_call + BlockSpec), ops.py (dispatching wrapper) and
ref.py (pure-jnp oracle); tests sweep shapes/dtypes in interpret mode.
"""
