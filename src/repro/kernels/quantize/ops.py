"""Dispatcher + the ONE wire-cast site for precision policies (§13).

* ``stochastic_round_bf16`` / ``quantize_int8`` / ``dequantize_int8`` —
  Pallas on TPU, lax twin elsewhere, ``REPRO_QUANTIZE=pallas|ref|
  interpret`` override (interpret = Pallas under the interpreter, the
  CI/CPU way to exercise the kernels).
* ``cast_compute`` — the single compute/wire downcast both flat engines
  route through (replicated buffer views AND the sharded pre-gather
  cast — the PR-4 asymmetry fix).  A plain ``astype``: deterministic
  and bit-identical to the legacy inline casts.
* ``quantize_dequantize_int8`` — the int8 reduce-scatter edge.  An int8
  ring sum would overflow at the first hop, so the RS collective runs
  in f32 over values that HAVE passed through the int8 grid; the wire
  volume the knapsack priced is what the quantized representation
  occupies, and obs attribution accounts bytes from that representation
  (DESIGN.md §13 documents this as value-exact emulation).  The AG edge
  genuinely gathers int8 values + per-row scales.
* ``wire_seed`` — per-(step, bucket) deterministic seed so stochastic
  rounding is reproducible and identical on every replica.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import (
    dequantize_int8_pallas,
    quantize_int8_pallas,
    stochastic_round_bf16_pallas,
)
from repro.kernels.quantize.ref import (
    dequantize_int8_ref,
    quantize_int8_ref,
    stochastic_round_bf16_ref,
)

_IMPLS = ("pallas", "ref", "interpret")


@functools.lru_cache(maxsize=1)
def default_quantize_impl() -> str:
    """'pallas' on TPU backends, 'ref' elsewhere; REPRO_QUANTIZE
    overrides (read once per process; unknown values raise)."""
    env = os.environ.get("REPRO_QUANTIZE", "").strip().lower()
    if env:
        if env not in _IMPLS:
            raise ValueError(
                f"REPRO_QUANTIZE={env!r}: expected one of {_IMPLS}"
            )
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def stochastic_round_bf16(
    x: jax.Array, seed, n_valid: Optional[int] = None,
    impl: Optional[str] = None,
) -> jax.Array:
    """f32[padded] -> bf16[padded], unbiased seeded rounding, zero tail."""
    impl = impl or default_quantize_impl()
    if impl in ("pallas", "interpret"):
        return stochastic_round_bf16_pallas(
            x, seed, n_valid, interpret=(impl == "interpret")
        )
    if impl == "ref":
        return stochastic_round_bf16_ref(x, seed, n_valid)
    raise ValueError(f"unknown quantize impl {impl!r}")


def quantize_int8(
    x: jax.Array, n_valid: Optional[int] = None,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """f32[padded] -> (int8[padded], f32[rows] blockwise scales)."""
    impl = impl or default_quantize_impl()
    if impl in ("pallas", "interpret"):
        return quantize_int8_pallas(
            x, n_valid, interpret=(impl == "interpret")
        )
    if impl == "ref":
        return quantize_int8_ref(x, n_valid)
    raise ValueError(f"unknown quantize impl {impl!r}")


def dequantize_int8(
    q: jax.Array, scale: jax.Array, n_valid: Optional[int] = None,
    impl: Optional[str] = None,
) -> jax.Array:
    impl = impl or default_quantize_impl()
    if impl in ("pallas", "interpret"):
        return dequantize_int8_pallas(
            q, scale, n_valid, interpret=(impl == "interpret")
        )
    if impl == "ref":
        return dequantize_int8_ref(q, scale, n_valid)
    raise ValueError(f"unknown quantize impl {impl!r}")


def quantize_dequantize_int8(
    x: jax.Array, n_valid: Optional[int] = None,
    impl: Optional[str] = None,
) -> jax.Array:
    """Project onto the blockwise int8 grid (the RS-edge emulation)."""
    q, s = quantize_int8(x, n_valid, impl)
    return dequantize_int8(q, s, n_valid, impl)


def cast_compute(x: jax.Array, dtype) -> jax.Array:
    """THE downcast both flat engines use for compute/wire dtype views.

    Kept a bare ``astype`` on purpose: it must stay bit-identical to
    the legacy inline casts it replaced (runtime.py's `_cast_compute`
    buffer views and the sharded engine's pre-gather cast), which
    tests/test_quantize.py pins."""
    if dtype is None or x.dtype == jnp.dtype(dtype):
        return x
    return x.astype(dtype)


def wire_seed(step, bucket: int):
    """Deterministic per-(step, bucket) stochastic-rounding seed.

    Same on every replica (derived from broadcast scalars only), so SR
    masters stay replica-identical; distinct per bucket and step so no
    two casts reuse a rounding pattern."""
    s = jnp.asarray(step, jnp.uint32)
    return s * jnp.uint32(2654435761) + jnp.uint32(bucket + 1)
