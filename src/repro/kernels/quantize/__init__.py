from repro.kernels.quantize.ops import (
    cast_compute,
    default_quantize_impl,
    dequantize_int8,
    quantize_dequantize_int8,
    quantize_int8,
    stochastic_round_bf16,
    wire_seed,
)

__all__ = [
    "cast_compute",
    "default_quantize_impl",
    "dequantize_int8",
    "quantize_dequantize_int8",
    "quantize_int8",
    "stochastic_round_bf16",
    "wire_seed",
]
