"""Pure-JAX reference / fallback for the wire-precision kernels.

Twin of kernel.py with the SAME expressions in the SAME order, so the
Pallas kernels (under ``interpret=True`` on CPU) bit-match these — the
property tests in tests/test_quantize.py pin that.

Two primitives (DESIGN.md §13):

* **Blockwise int8** — each 128-lane row quantizes against its own
  absmax (``scale = absmax / 127``); dequantize multiplies back.  The
  per-row scale bounds the elementwise error at ``scale / 2``.
* **Stochastic-rounded bf16** — f32 -> bf16 rounding whose direction is
  decided by 16 uniform bits added to the mantissa before truncation:
  unbiased (E[round(x)] == x) so a resident low-precision master does
  not drift systematically.  The bits come from a counter-based
  murmur3-finalizer hash of (flat element index, seed) written in plain
  uint32 ops — identical in the kernel and here, fully deterministic,
  and independent of grid/block geometry.

Padded tails: every entry point takes ``n_valid`` and forces the tail
to ZERO on output — hostile tail values can never leak through a wire
cast (the flat engines' invariant is zero tails everywhere).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_LANES = 128

# murmur3 fmix32 constants + golden-ratio seed spread
_M1 = 0x85EBCA6B
_M2 = 0xC2B2AE35
_GOLDEN = 0x9E3779B9


def _hash_u32(idx: jax.Array, seed: jax.Array) -> jax.Array:
    """Counter-based uniform u32 from (element index, seed): murmur3
    finalizer over the seed-offset index.  uint32 arithmetic wraps."""
    x = idx.astype(jnp.uint32) + seed.astype(jnp.uint32) * jnp.uint32(_GOLDEN)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> 16)
    return x


def _flat_index(shape2d: Tuple[int, int], base_row: int = 0) -> jax.Array:
    rows, lanes = shape2d
    return (
        (jax.lax.broadcasted_iota(jnp.int32, shape2d, 0) + base_row) * lanes
        + jax.lax.broadcasted_iota(jnp.int32, shape2d, 1)
    )


def _shape2d(x: jax.Array) -> Tuple[int, int]:
    padded = x.shape[0]
    assert padded % _LANES == 0, (
        f"flat buffer length {padded} not a {_LANES}-lane multiple"
    )
    return (padded // _LANES, _LANES)


def stochastic_round_bf16_ref(
    x: jax.Array, seed, n_valid: Optional[int] = None
) -> jax.Array:
    """f32[padded] -> bf16[padded], stochastic rounding, zero tail."""
    shape2d = _shape2d(x)
    n_valid = x.shape[0] if n_valid is None else n_valid
    x2 = x.reshape(shape2d)
    idx = _flat_index(shape2d)
    r = _hash_u32(idx, jnp.asarray(seed)) & jnp.uint32(0xFFFF)
    bits = jax.lax.bitcast_convert_type(x2.astype(jnp.float32), jnp.uint32)
    rounded = (bits + r) & jnp.uint32(0xFFFF0000)
    y = jax.lax.bitcast_convert_type(rounded, jnp.float32)
    y = jnp.where(idx < n_valid, y, 0.0)
    return y.astype(jnp.bfloat16).reshape(x.shape)


def quantize_int8_ref(
    x: jax.Array, n_valid: Optional[int] = None
) -> Tuple[jax.Array, jax.Array]:
    """f32[padded] -> (int8[padded], f32[rows] per-row scales)."""
    shape2d = _shape2d(x)
    n_valid = x.shape[0] if n_valid is None else n_valid
    idx = _flat_index(shape2d)
    x2 = jnp.where(idx < n_valid, x.reshape(shape2d), 0.0)
    absmax = jnp.max(jnp.abs(x2), axis=1, keepdims=True)
    # explicit reciprocal multiply (not /127): XLA rewrites division by a
    # constant into this anyway on some paths — writing it out keeps the
    # ref and the Pallas kernel bit-identical on every backend
    scale = jnp.where(absmax > 0.0, absmax * jnp.float32(1.0 / 127.0), 1.0)
    q = jnp.clip(jnp.round(x2 / scale), -127.0, 127.0).astype(jnp.int8)
    return q.reshape(x.shape), scale[:, 0]


def dequantize_int8_ref(
    q: jax.Array, scale: jax.Array, n_valid: Optional[int] = None
) -> jax.Array:
    """(int8[padded], f32[rows]) -> f32[padded], zero tail."""
    shape2d = _shape2d(q)
    n_valid = q.shape[0] if n_valid is None else n_valid
    idx = _flat_index(shape2d)
    y = q.reshape(shape2d).astype(jnp.float32) * scale[:, None]
    y = jnp.where(idx < n_valid, y, 0.0)
    return y.reshape(q.shape)
