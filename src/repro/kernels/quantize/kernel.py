"""Pallas TPU kernels for the wire-precision casts (DESIGN.md §13).

Same layout discipline as kernels/bucket_update: the flat bucket buffer
reshapes to (rows, 128) lanes and tiles over a 1-D grid of row blocks;
a 2-D broadcasted iota against the static valid length zeroes the
padded tail.  The stochastic-rounding randomness is a counter-based
murmur3-finalizer hash of the GLOBAL flat element index (derived from
``program_id`` inside the kernel), so the bits are independent of the
grid/block geometry and bit-match the pure-JAX twin in ref.py under the
interpreter — determinism the low-precision resident master depends on.

The int8 per-row scales come back as a (rows, 128) row-broadcast array
(every lane of a row carries the row's scale) because a (rows, 1)
output is not a legal TPU tile; ops.py slices lane 0.  Wire-byte
accounting prices scales at 4 bytes/row regardless.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quantize.ref import _GOLDEN, _M1, _M2

_LANES = 128


def _iota2(shape2d):
    return (
        jax.lax.broadcasted_iota(jnp.int32, shape2d, 0) * _LANES
        + jax.lax.broadcasted_iota(jnp.int32, shape2d, 1)
    )


def _hash_u32(idx, seed):
    # identical expression order to ref._hash_u32 (bit-match contract)
    x = idx.astype(jnp.uint32) + seed.astype(jnp.uint32) * jnp.uint32(_GOLDEN)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(_M1)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(_M2)
    x = x ^ (x >> 16)
    return x


def _grid(rows: int, block_rows: int):
    block_rows = min(block_rows, rows)
    return (pl.cdiv(rows, block_rows),), block_rows


def _sr_bf16_kernel(seed_ref, x_ref, o_ref, *, n_valid, block_rows):
    shape = (block_rows, _LANES)
    base = pl.program_id(0) * block_rows * _LANES
    idx = base + _iota2(shape)
    seed = seed_ref[0, 0]
    r = _hash_u32(idx, seed) & jnp.uint32(0xFFFF)
    bits = jax.lax.bitcast_convert_type(
        x_ref[...].astype(jnp.float32), jnp.uint32
    )
    rounded = (bits + r) & jnp.uint32(0xFFFF0000)
    y = jax.lax.bitcast_convert_type(rounded, jnp.float32)
    y = jnp.where(idx < n_valid, y, 0.0)
    o_ref[...] = y.astype(jnp.bfloat16)


def stochastic_round_bf16_pallas(
    x: jax.Array,
    seed,
    n_valid: Optional[int] = None,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    padded = x.shape[0]
    assert padded % _LANES == 0, padded
    rows = padded // _LANES
    n_valid = padded if n_valid is None else n_valid
    grid, block_rows = _grid(rows, block_rows)
    seed_row = jnp.full((1, _LANES), jnp.asarray(seed, jnp.uint32))
    out = pl.pallas_call(
        functools.partial(
            _sr_bf16_kernel, n_valid=n_valid, block_rows=block_rows
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, _LANES), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), jnp.bfloat16),
        interpret=interpret,
    )(seed_row, x.reshape(rows, _LANES))
    return out.reshape(padded)


def _quant_int8_kernel(x_ref, q_ref, s_ref, *, n_valid, block_rows):
    shape = (block_rows, _LANES)
    base = pl.program_id(0) * block_rows * _LANES
    idx = base + _iota2(shape)
    x = jnp.where(idx < n_valid, x_ref[...].astype(jnp.float32), 0.0)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    # reciprocal multiply, same expression as ref.py (bit-match contract)
    scale = jnp.where(absmax > 0.0, absmax * jnp.float32(1.0 / 127.0), 1.0)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = jnp.broadcast_to(scale, shape)


def quantize_int8_pallas(
    x: jax.Array,
    n_valid: Optional[int] = None,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    padded = x.shape[0]
    assert padded % _LANES == 0, padded
    rows = padded // _LANES
    n_valid = padded if n_valid is None else n_valid
    grid, block_rows = _grid(rows, block_rows)
    row_spec = pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0))
    q, s = pl.pallas_call(
        functools.partial(
            _quant_int8_kernel, n_valid=n_valid, block_rows=block_rows
        ),
        grid=grid,
        in_specs=[row_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows, _LANES), jnp.int8),
            jax.ShapeDtypeStruct((rows, _LANES), jnp.float32),
        ],
        interpret=interpret,
    )(x.reshape(rows, _LANES))
    return q.reshape(padded), s[:, 0]


def _dequant_int8_kernel(q_ref, s_ref, o_ref, *, n_valid, block_rows):
    shape = (block_rows, _LANES)
    base = pl.program_id(0) * block_rows * _LANES
    idx = base + _iota2(shape)
    y = q_ref[...].astype(jnp.float32) * s_ref[...]
    o_ref[...] = jnp.where(idx < n_valid, y, 0.0)


def dequantize_int8_pallas(
    q: jax.Array,
    scale: jax.Array,
    n_valid: Optional[int] = None,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    padded = q.shape[0]
    assert padded % _LANES == 0, padded
    rows = padded // _LANES
    n_valid = padded if n_valid is None else n_valid
    grid, block_rows = _grid(rows, block_rows)
    row_spec = pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0))
    s2 = jnp.broadcast_to(scale[:, None], (rows, _LANES))
    out = pl.pallas_call(
        functools.partial(
            _dequant_int8_kernel, n_valid=n_valid, block_rows=block_rows
        ),
        grid=grid,
        in_specs=[row_spec, row_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), jnp.float32),
        interpret=interpret,
    )(q.reshape(rows, _LANES), s2)
    return out.reshape(padded)
