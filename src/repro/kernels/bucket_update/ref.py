"""Pure-JAX reference / fallback for the fused bucket-update kernels.

One fused elementwise expression over the whole flat bucket buffer —
numerically the same math, in the same order, as the Pallas kernel
(kernel.py), so the two are bit-comparable.  XLA compiles this to a
single fused loop per bucket, which is also the production path on CPU
and on jaxlibs without the Pallas bucket-update gate (DESIGN.md §8).

Scalar packing (``scalars`` is a (1, 128) f32 row, see ops.SCALARS_*):
    [0] grad_scale   1/(n_dp * k) of the merged gradient
    [1] clip         global-norm clip factor (1.0 when disabled)
    [2] lr           spec.lr * lr_scale (dynamic schedules ride here)
    [3] bc1          1 - beta1**step   (adam)
    [4] bc2          1 - beta2**step   (adam)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import OptimizerSpec


def _keep_tail(new: jax.Array, old: jax.Array, n_valid: int) -> jax.Array:
    """Restore the padded tail to its input value.  The tail is < one
    pad_multiple (tiny), so patching the slice costs O(tail) instead of
    a whole-buffer select — same result as the kernel's tile mask."""
    if n_valid >= new.shape[0]:
        return new
    return jax.lax.dynamic_update_slice(new, old[n_valid:], (n_valid,))


def bucket_update_ref(
    spec: OptimizerSpec,
    p: jax.Array,                      # f32[padded] params
    m: jax.Array,                      # f32[padded] momentum
    v: Optional[jax.Array],            # f32[padded] variance (adam) | None
    g: jax.Array,                      # f32[padded] merged raw gradient
    scalars: jax.Array,                # f32[1, 128] dynamic scalars
    *,
    n_valid: int,
    uniform: Optional[Tuple[float, float]],        # (lr_scale, wd) | None
    elem_hparams: Optional[Tuple[jax.Array, jax.Array]] = None,
    zero_grads: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array], Optional[jax.Array]]:
    """One optimizer step over one flat bucket buffer.

    Returns (p', m', v'|None, zeroed-g|None).  The padded tail
    [n_valid, padded) is masked: p/m/v keep their (zero) tail values no
    matter what rides in the tail of ``g``.  Sharded spans arrive with
    ``n_valid == len(p)`` and a pre-masked gradient (ops.py), making
    ``_keep_tail`` a no-op.
    """
    gscale, clip, lr = scalars[0, 0], scalars[0, 1], scalars[0, 2]
    if uniform is not None:
        sc, wd = uniform
    else:
        sc, wd = elem_hparams                      # f32[padded] each

    ghat = (g * gscale) * clip
    if spec.name == "sgd":
        m_new = spec.momentum * m + ghat
        u = m_new
        if (uniform is None) or wd:
            u = u + wd * p
        p_new = p - (lr * sc) * u
        v_new = None
    elif spec.name == "adamw":
        bc1, bc2 = scalars[0, 3], scalars[0, 4]
        b1, b2 = spec.beta1, spec.beta2
        m_new = b1 * m + (1 - b1) * ghat
        v_new = b2 * v + (1 - b2) * ghat * ghat
        u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + spec.eps)
        if (uniform is None) or wd:
            u = u + wd * p
        p_new = p - (lr * sc) * u
        v_new = _keep_tail(v_new, v, n_valid)
    else:
        raise ValueError(spec.name)

    p_new = _keep_tail(p_new, p, n_valid)
    m_new = _keep_tail(m_new, m, n_valid)
    gz = jnp.zeros_like(g) if zero_grads else None
    return p_new, m_new, v_new, gz
