"""Dispatcher + whole-state entry point for the fused bucket updates.

* ``bucket_update``       — one bucket: Pallas on TPU, pure-JAX ``lax``
                            fallback elsewhere (CPU, old-jaxlib,
                            ``REPRO_BUCKET_UPDATE=ref`` override).
* ``apply_bucket_updates``— the flat-resident optimizer step the
                            DeftRuntime update phases call: global-norm
                            clip across all buckets, then one fused
                            launch per bucket, step counter advanced
                            once per applied (delayed) update.

The delayed-update semantics live in the *caller's* PhaseSpec: the
gradient buffers arriving here are the merged k-batch accumulators the
schedule synchronized at this phase, and ``grad_scale = 1/(n_dp * k)``
recovers gradient-accumulation math exactly (see optim/optimizers.py).
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.bucket_update.kernel import bucket_update_pallas
from repro.kernels.bucket_update.ref import bucket_update_ref
from repro.kernels.bucket_update.segments import BucketSegments
from repro.kernels.quantize import stochastic_round_bf16, wire_seed
from repro.optim.optimizers import OptimizerSpec

# scalar-row layout (f32[1, 128], lanes 5..127 are zero padding)
SCALARS_GRAD_SCALE = 0
SCALARS_CLIP = 1
SCALARS_LR = 2
SCALARS_BC1 = 3
SCALARS_BC2 = 4
_N_SCALARS = 5


_IMPLS = ("pallas", "ref", "interpret")


@functools.lru_cache(maxsize=1)
def default_bucket_update_impl() -> str:
    """'pallas' on TPU backends, 'ref' elsewhere.  Override with
    REPRO_BUCKET_UPDATE=pallas|ref|interpret (interpret = Pallas kernel
    under the interpreter — the CI/CPU way to exercise the kernel).
    Read ONCE per process (cached); an unknown value raises instead of
    silently running the wrong implementation."""
    env = os.environ.get("REPRO_BUCKET_UPDATE", "").strip().lower()
    if env:
        if env not in _IMPLS:
            raise ValueError(
                f"REPRO_BUCKET_UPDATE={env!r}: expected one of {_IMPLS}"
            )
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def pack_scalars(
    spec: OptimizerSpec,
    step_new: jax.Array,
    *,
    grad_scale,
    clip,
    lr_scale=1.0,
) -> jax.Array:
    """Dynamic per-update scalars as one (1, 128) f32 row (SCALARS_*)."""
    lr = spec.lr * lr_scale
    vals = [grad_scale, clip, lr]
    if spec.name == "adamw":
        sf = step_new.astype(jnp.float32)
        vals += [1 - spec.beta1 ** sf, 1 - spec.beta2 ** sf]
    else:
        vals += [0.0, 0.0]
    row = jnp.stack([jnp.asarray(x, jnp.float32) for x in vals])
    return jnp.concatenate(
        [row, jnp.zeros((128 - _N_SCALARS,), jnp.float32)]
    ).reshape(1, 128)


def bucket_update(
    spec: OptimizerSpec,
    p: jax.Array,
    m: jax.Array,
    v: Optional[jax.Array],
    g: jax.Array,
    scalars: jax.Array,
    *,
    n_valid: int,
    uniform: Optional[Tuple[float, float]],
    elem_hparams: Optional[Tuple[jax.Array, jax.Array]] = None,
    zero_grads: bool = False,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array], Optional[jax.Array]]:
    """One fused optimizer step over one flat bucket buffer."""
    impl = impl or default_bucket_update_impl()
    if impl in ("pallas", "interpret"):
        return bucket_update_pallas(
            spec, p, m, v, g, scalars,
            n_valid=n_valid, uniform=uniform, elem_hparams=elem_hparams,
            zero_grads=zero_grads, interpret=(impl == "interpret"),
        )
    if impl == "ref":
        return bucket_update_ref(
            spec, p, m, v, g, scalars,
            n_valid=n_valid, uniform=uniform, elem_hparams=elem_hparams,
            zero_grads=zero_grads,
        )
    raise ValueError(f"unknown bucket-update impl {impl!r}")


def init_flat_opt_state(
    spec: OptimizerSpec, buf_sizes: Sequence[int]
) -> Dict[str, Any]:
    """Flat-resident twin of optimizers.init_opt_state: per-bucket f32
    moment buffers instead of a params-shaped tree."""
    zeros = lambda: tuple(jnp.zeros((s,), jnp.float32) for s in buf_sizes)
    out: Dict[str, Any] = {"step": jnp.zeros((), jnp.int32), "m": zeros()}
    if spec.name == "adamw":
        out["v"] = zeros()
    elif spec.name != "sgd":
        raise ValueError(spec.name)
    return out


def apply_bucket_updates(
    spec: OptimizerSpec,
    segments: BucketSegments,
    pbuf: Sequence[jax.Array],
    gbuf: Sequence[jax.Array],
    opt: Dict[str, Any],
    *,
    grad_scale=1.0,
    lr_scale=1.0,
    zero_grads: bool = False,
    impl: Optional[str] = None,
    shard_id: Optional[jax.Array] = None,
    norm_psum=None,
    master_dtype: Optional[str] = None,
) -> Tuple[
    Tuple[jax.Array, ...], Dict[str, Any], Optional[Tuple[jax.Array, ...]]
]:
    """Apply one (delayed) optimizer update across all bucket buffers.

    Mirrors optimizers.apply_updates on the flat representation: scale
    by ``grad_scale``, clip by the global norm across every bucket, then
    one fused kernel launch per bucket.  With ``zero_grads`` the zeroed
    gradient buffers come back fused from the same launches (the
    accumulator reset of the delayed-update schedule).

    **Sharded mode** (``shard_id`` given — the RS/FSDP flat engine,
    DESIGN.md §8): every buffer is one device's contiguous shard span
    (``layout.shard_sizes[b]`` elements, starting at global offset
    ``shard_id * span``).  ``shard_id`` may be a traced per-device index
    (``jax.lax.axis_index`` inside shard_map) — all shapes stay static.
    The padded tail occupies the *trailing* spans (a small bucket can be
    all tail on several shards), so per-span validity is
    device-dependent: instead of the kernels' static mask, EVERY
    gradient span is pre-masked against the global valid length (a
    fused elementwise select) and the kernels run unmasked over the
    whole span.  ``norm_psum`` must sum the squared-norm contribution across
    the shard axis (each device only sees 1/N of the gradient) — without
    it the clip factor would be computed from a single shard.

    **bf16sr master** (``master_dtype='bf16sr'``, DESIGN.md §13): the
    incoming param buffers are bf16 residents; they upcast to f32 for
    the fused kernels and the updated buffers round back down through
    the seeded stochastic-rounding kernel (seed = (step, bucket), so
    replicas agree and no two updates reuse a rounding pattern).  The
    moments stay f32.
    """
    layout = segments.layout
    adam = spec.name == "adamw"
    sharded = shard_id is not None
    if master_dtype not in (None, "f32", "bf16sr"):
        raise ValueError(f"master_dtype={master_dtype!r}")
    bf16sr = master_dtype == "bf16sr"
    if bf16sr:
        pbuf = [p.astype(jnp.float32) for p in pbuf]
    # layout.shards == 1 is the degenerate single-shard case (1-device
    # FSDP smoke runs): spans are the whole buffers and the sharded path
    # reduces to the unsharded one bit-for-bit.  A layout whose shard
    # count mismatches the actual mesh is rejected by DeftRuntime's
    # constructor — here the layout's own span math is authoritative.
    if sharded and spec.grad_clip and norm_psum is None:
        raise ValueError(
            "sharded update with grad_clip needs norm_psum: each device "
            "sees 1/N of the gradient, so a local norm would mis-clip "
            "every shard differently and silently diverge params — pass "
            "the shard-axis psum (or an identity for single-shard "
            "benchmarking/tests)"
        )

    def shard_mask(b: int) -> Optional[jax.Array]:
        """bool[span] validity of this device's span of bucket ``b``
        (None when the bucket has no padded tail at all)."""
        span = layout.shard_sizes[b]
        if layout.sizes[b] >= layout.buf_sizes[b]:
            return None
        base = shard_id.astype(jnp.int32) * span
        return (base + jnp.arange(span, dtype=jnp.int32)) < layout.sizes[b]

    if sharded:
        masks = [shard_mask(b) for b in range(layout.n_buckets)]
        gbuf = [
            g if masks[b] is None else jnp.where(masks[b], g, 0.0)
            for b, g in enumerate(gbuf)
        ]

    if spec.grad_clip:
        # norm over the VALID spans only — the padded tails are zero by
        # construction, but the kernels' tail mask promises that even
        # hostile tail values cannot leak into params, and an unmasked
        # norm would funnel them through the clip scalar.  Sharded mode
        # already pre-masked the gradient; the per-shard sums are summed
        # across the shard axis by ``norm_psum``.
        if sharded:
            sq = [jnp.sum(jnp.square(g * grad_scale)) for g in gbuf]
            total = jnp.sum(jnp.stack(sq))
            if norm_psum is not None:
                total = norm_psum(total)
            gn = jnp.sqrt(total)
        else:
            sq = [
                jnp.sum(jnp.square(g[: layout.sizes[b]] * grad_scale))
                for b, g in enumerate(gbuf)
            ]
            gn = jnp.sqrt(jnp.sum(jnp.stack(sq)))
        clip = jnp.minimum(1.0, spec.grad_clip / jnp.maximum(gn, 1e-12))
    else:
        clip = jnp.float32(1.0)
    step_new = opt["step"] + 1
    scalars = pack_scalars(
        spec, step_new, grad_scale=grad_scale, clip=clip, lr_scale=lr_scale
    )

    new_p: List[jax.Array] = []
    new_m: List[jax.Array] = []
    new_v: List[jax.Array] = []
    zeroed: List[jax.Array] = []
    for b in range(layout.n_buckets):
        uniform = segments.uniform(b)
        elem = None
        if uniform is None:
            sc, wd = segments.element_hparams(b)
            sc, wd = jnp.asarray(sc), jnp.asarray(wd)
            if sharded:
                span = layout.shard_sizes[b]
                start = shard_id.astype(jnp.int32) * span
                sc = jax.lax.dynamic_slice(sc, (start,), (span,))
                wd = jax.lax.dynamic_slice(wd, (start,), (span,))
            elem = (sc, wd)
        # sharded spans run the kernels unmasked (n_valid == span): the
        # gradient tail is pre-masked above and p/m/v tails are zero by
        # the engine's invariant, so a zero update keeps them zero
        n_valid = layout.shard_sizes[b] if sharded else layout.sizes[b]
        p2, m2, v2, gz = bucket_update(
            spec,
            pbuf[b],
            opt["m"][b],
            opt["v"][b] if adam else None,
            gbuf[b],
            scalars,
            n_valid=n_valid,
            uniform=uniform,
            elem_hparams=elem,
            zero_grads=zero_grads,
            impl=impl,
        )
        if bf16sr:
            if p2.shape[0] % 128 == 0:
                p2 = stochastic_round_bf16(p2, wire_seed(step_new, b))
            else:   # a span the 128-lane kernels cannot tile
                p2 = p2.astype(jnp.bfloat16)
        new_p.append(p2)
        new_m.append(m2)
        if adam:
            new_v.append(v2)
        if zero_grads:
            zeroed.append(gz)
    new_opt: Dict[str, Any] = {"step": step_new, "m": tuple(new_m)}
    if adam:
        new_opt["v"] = tuple(new_v)
    return (
        tuple(new_p),
        new_opt,
        tuple(zeroed) if zero_grads else None,
    )
