"""Pallas TPU kernels: fused optimizer update over a whole flat bucket.

One launch applies SGD-momentum or Adam to an entire bucket buffer —
params, moments and the merged gradient are the per-bucket flat f32
buffers of ``BucketLayout`` reshaped to (rows, 128) lanes and tiled over
a 1-D grid of row blocks.  Everything a per-leaf optimizer pays per
tensor (launch, dispatch, tree bookkeeping) is paid once per bucket.

* **Masked tail** — buffers are padded to a lane multiple; a 2-D iota
  against the static valid length keeps the tail at its (zero) value
  even if garbage rides in the gradient tail.  On the sharded flat
  engine the operand is one device's shard span and the valid length is
  device-dependent, so the caller pre-masks the gradient and passes
  ``n_valid == span`` (the mask compiles away — see ops.py).
* **Segment hparams** — per-leaf (lr_scale, weight_decay) arrive either
  as compile-time scalars (uniform buckets, the default — no O(params)
  constants) or as materialized per-element arrays blocked like the
  buffers (see segments.py).
* **Fused zeroing** — with ``zero_grads`` the kernel also writes zeros
  through an output aliased to the gradient buffer, so the delayed-update
  accumulator reset costs no extra pass.
* Dynamic scalars (grad scale, clip, lr, bias corrections) ride in one
  (1, 128) f32 row broadcast to every program (ops.SCALARS_* layout).

In-place semantics come from ``input_output_aliases`` (gated on
jax_compat.PALLAS_BUCKET_ALIAS_OK — on older jaxlibs the jit-level
donation still reuses the buffers).  The pure-JAX twin in ref.py computes
the same expressions in the same order, so the two bit-match.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.optim.optimizers import OptimizerSpec
from repro.util.jax_compat import PALLAS_BUCKET_ALIAS_OK

_LANES = 128

# the layout pads buffers in units of train.bucketing.PAD_MULTIPLE; the
# two constants must agree (imported lazily there to keep kernels free
# of train-package imports — verified here instead of at a distance)
def _check_lane_width() -> None:
    from repro.train.bucketing import PAD_MULTIPLE

    assert PAD_MULTIPLE == _LANES, (PAD_MULTIPLE, _LANES)


def _update_kernel(
    *refs,
    spec: OptimizerSpec,
    n_valid: int,
    rows_total: int,
    block_rows: int,
    uniform: Optional[Tuple[float, float]],
    zero_grads: bool,
):
    """Shared SGD/Adam body on one (block_rows, 128) tile."""
    adam = spec.name == "adamw"
    i = 0
    scal_ref = refs[i]; i += 1
    p_ref = refs[i]; i += 1
    m_ref = refs[i]; i += 1
    v_ref = refs[i] if adam else None
    i += 1 if adam else 0
    g_ref = refs[i]; i += 1
    if uniform is None:
        sc_ref = refs[i]; i += 1
        wd_ref = refs[i]; i += 1
    p_out = refs[i]; i += 1
    m_out = refs[i]; i += 1
    if adam:
        v_out = refs[i]; i += 1
    if zero_grads:
        g_out = refs[i]; i += 1

    pid = pl.program_id(0)
    base = pid * block_rows * _LANES
    idx = base + (
        jax.lax.broadcasted_iota(jnp.int32, (block_rows, _LANES), 0) * _LANES
        + jax.lax.broadcasted_iota(jnp.int32, (block_rows, _LANES), 1)
    )
    masked = n_valid < rows_total * _LANES
    mask = idx < n_valid

    gscale = scal_ref[0, 0]
    clip = scal_ref[0, 1]
    lr = scal_ref[0, 2]
    if uniform is not None:
        sc, wd = uniform
    else:
        sc, wd = sc_ref[...], wd_ref[...]

    p = p_ref[...]
    m = m_ref[...]
    g = g_ref[...]
    ghat = (g * gscale) * clip
    if adam:
        bc1, bc2 = scal_ref[0, 3], scal_ref[0, 4]
        b1, b2 = spec.beta1, spec.beta2
        v = v_ref[...]
        m_new = b1 * m + (1 - b1) * ghat
        v_new = b2 * v + (1 - b2) * ghat * ghat
        u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + spec.eps)
    else:
        m_new = spec.momentum * m + ghat
        u = m_new
    if (uniform is None) or wd:
        u = u + wd * p
    p_new = p - (lr * sc) * u

    if masked:
        p_new = jnp.where(mask, p_new, p)
        m_new = jnp.where(mask, m_new, m)
        if adam:
            v_new = jnp.where(mask, v_new, v)
    p_out[...] = p_new
    m_out[...] = m_new
    if adam:
        v_out[...] = v_new
    if zero_grads:
        g_out[...] = jnp.zeros_like(g)


def bucket_update_pallas(
    spec: OptimizerSpec,
    p: jax.Array,
    m: jax.Array,
    v: Optional[jax.Array],
    g: jax.Array,
    scalars: jax.Array,
    *,
    n_valid: int,
    uniform: Optional[Tuple[float, float]],
    elem_hparams: Optional[Tuple[jax.Array, jax.Array]] = None,
    zero_grads: bool = False,
    block_rows: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array], Optional[jax.Array]]:
    """Fused bucket update, one pallas_call.  Same contract as
    ref.bucket_update_ref (flat f32[padded] buffers in/out)."""
    adam = spec.name == "adamw"
    if spec.name not in ("adamw", "sgd"):
        raise ValueError(spec.name)
    _check_lane_width()
    padded = p.shape[0]
    assert padded % _LANES == 0, (
        f"bucket buffer length {padded} not a lane multiple; build the "
        f"layout with pad_multiple={_LANES}"
    )
    rows = padded // _LANES
    block_rows = min(block_rows, rows)
    grid = (pl.cdiv(rows, block_rows),)

    shape2d = (rows, _LANES)
    row_spec = pl.BlockSpec((block_rows, _LANES), lambda i: (i, 0))
    scal_spec = pl.BlockSpec((1, _LANES), lambda i: (0, 0))

    operands = [scalars]
    in_specs = [scal_spec]
    for x in (p, m) + ((v,) if adam else ()) + (g,):
        operands.append(x.reshape(shape2d))
        in_specs.append(row_spec)
    if uniform is None:
        sc_arr, wd_arr = elem_hparams
        operands += [sc_arr.reshape(shape2d), wd_arr.reshape(shape2d)]
        in_specs += [row_spec, row_spec]

    n_out = (3 if adam else 2) + (1 if zero_grads else 0)
    out_shape = [jax.ShapeDtypeStruct(shape2d, jnp.float32)] * n_out
    out_specs = [row_spec] * n_out

    # operand k of (p, m, [v], g) aliases output k: in-place update
    aliases = {}
    if PALLAS_BUCKET_ALIAS_OK and not interpret:
        n_state = 3 if adam else 2
        aliases = {1 + k: k for k in range(n_state)}
        if zero_grads:
            aliases[1 + n_state] = n_state    # g -> zeroed accumulator

    kernel = functools.partial(
        _update_kernel,
        spec=spec,
        n_valid=n_valid,
        rows_total=rows,
        block_rows=block_rows,
        uniform=uniform,
        zero_grads=zero_grads,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*operands)
    out = [o.reshape(padded) for o in out]
    p_new, m_new = out[0], out[1]
    v_new = out[2] if adam else None
    gz = out[-1] if zero_grads else None
    return p_new, m_new, v_new, gz
