from repro.kernels.bucket_update.kernel import bucket_update_pallas
from repro.kernels.bucket_update.ops import (
    apply_bucket_updates,
    bucket_update,
    default_bucket_update_impl,
    init_flat_opt_state,
    pack_scalars,
)
from repro.kernels.bucket_update.ref import bucket_update_ref
from repro.kernels.bucket_update.segments import BucketSegments, build_segments

__all__ = [
    "BucketSegments",
    "build_segments",
    "bucket_update",
    "bucket_update_pallas",
    "bucket_update_ref",
    "apply_bucket_updates",
    "init_flat_opt_state",
    "pack_scalars",
    "default_bucket_update_impl",
]
