"""Static segment-id map: per-leaf optimizer hyperparameters on flat
bucket buffers.

Each bucket buffer is a concatenation of leaf spans plus a zero tail
(``BucketLayout.padded_sizes``).  The update kernels need two per-element
quantities — an lr scale and a weight-decay coefficient — that are
constant *within* a leaf span.  ``BucketSegments`` freezes that mapping
at plan time:

* ``segment_ids(b)`` — int32[padded] leaf-ordinal per element (the
  segment-id map proper; the zero tail is segment ``-1``);
* ``element_hparams(b)`` — the map materialized to per-element f32
  (scale, weight_decay) arrays, tail masked to scale 0;
* ``uniform(b)`` — the fast path: when every leaf in a bucket shares the
  same (lr_scale, weight_decay) — true for the default OptimizerSpec —
  the kernels take the hparams as compile-time scalars and only the tail
  mask is computed in-kernel (an iota compare), so no O(params) constant
  arrays enter the compiled graph.

Hyperparameters come from :func:`repro.optim.optimizers.leaf_hparams`,
the same source the per-leaf reference path uses — fused and reference
updates agree by construction.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Tuple

import numpy as np

from repro.optim.optimizers import OptimizerSpec, SegmentHParams, leaf_hparams

if TYPE_CHECKING:  # import would cycle: train.runtime imports this package
    from repro.train.bucketing import BucketLayout


@dataclasses.dataclass(frozen=True)
class BucketSegments:
    """Frozen per-bucket segment metadata for the update kernels."""

    layout: "BucketLayout"
    hparams: Tuple[SegmentHParams, ...]     # per leaf, tree_flatten order

    def uniform(self, b: int) -> Optional[Tuple[float, float]]:
        """(lr_scale, weight_decay) if all leaves of bucket ``b`` agree,
        else None (the kernel then takes materialized element arrays)."""
        hps = {
            (self.hparams[i].lr_scale, self.hparams[i].weight_decay)
            for i in self.layout.leaves[b]
        }
        if len(hps) == 1:
            return next(iter(hps))
        return None

    def segment_ids(self, b: int) -> np.ndarray:
        """int32[padded] element -> leaf ordinal within the bucket;
        the padded tail is segment -1."""
        lay = self.layout
        padded = lay.buf_sizes[b]
        ids = np.full((padded,), -1, np.int32)
        for ordinal, (i, off) in enumerate(zip(lay.leaves[b], lay.offsets[b])):
            n = int(np.prod(lay.shapes[i], dtype=np.int64)) \
                if lay.shapes[i] else 1
            ids[off:off + n] = ordinal
        return ids

    def element_hparams(self, b: int) -> Tuple[np.ndarray, np.ndarray]:
        """The segment-id map materialized to per-element f32 arrays
        (lr_scale, weight_decay); tail elements get scale 0 / wd 0."""
        lay = self.layout
        ids = self.segment_ids(b)
        leaf_ids = lay.leaves[b]
        sc = np.array(
            [self.hparams[i].lr_scale for i in leaf_ids] + [0.0], np.float32
        )
        wd = np.array(
            [self.hparams[i].weight_decay for i in leaf_ids] + [0.0],
            np.float32,
        )
        # ids == -1 (tail) indexes the trailing sentinel entry
        return sc[ids], wd[ids]

    def element_hparams_shard(
        self, b: int, shard: int, n_shards: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """``element_hparams`` sliced to one contiguous shard span of the
        sharded flat engine (DESIGN.md §8): shard ``s`` of bucket ``b``
        owns global elements ``[s * span, (s + 1) * span)`` with
        ``span = buf_sizes[b] // n_shards``.  Static twin of the traced
        per-device slice the RS update path takes (ops.py slices the
        same full arrays with the device's shard index)."""
        padded = self.layout.buf_sizes[b]
        if padded % n_shards:
            raise ValueError(
                f"bucket {b}: buffer length {padded} does not split into "
                f"{n_shards} shards — build the layout with "
                f"shard_count={n_shards}"
            )
        span = padded // n_shards
        sc, wd = self.element_hparams(b)
        return sc[shard * span:(shard + 1) * span], \
            wd[shard * span:(shard + 1) * span]


def build_segments(
    layout: "BucketLayout", spec: OptimizerSpec
) -> BucketSegments:
    """Segment metadata for ``layout`` under ``spec``'s per-leaf rules.

    Memoized per (layout, spec): a layout-changing hot-swap rebuilds the
    segment maps for the NEW layout while the old cycle finishes, and a
    later replan that returns to a previously-seen layout reuses its
    segments exactly like the runtime reuses its compiled phases.  Both
    arguments are frozen tuple dataclasses, so the key is cheap and the
    memo can never alias two different layouts.
    """
    key = (layout, spec)
    hit = _SEGMENTS_MEMO.get(key)
    if hit is None:
        if len(_SEGMENTS_MEMO) > 64:
            _SEGMENTS_MEMO.clear()
        hit = BucketSegments(
            layout=layout, hparams=leaf_hparams(spec, layout.shapes)
        )
        _SEGMENTS_MEMO[key] = hit
    return hit


_SEGMENTS_MEMO: dict = {}
