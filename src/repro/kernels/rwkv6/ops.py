"""Dispatcher for the RWKV-6 time-mix recurrence.

* TPU        -> Pallas chunked kernel.
* elsewhere  -> chunked-jnp (same math as the kernel: intra-chunk matmuls
                + lax.scan over chunk states) for long sequences, or the
                sequential oracle for short ones / decode.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import rwkv6_pallas
from repro.kernels.rwkv6.ref import rwkv6_reference

_CHUNK = 32
_REF_MAX_SEQ = 128  # sequential scan is fine below this


def _chunked_jnp(r, k, v, w, u, s0, chunk: int = _CHUNK):
    """Chunked formulation in plain jnp (mirrors kernel.py)."""
    b, s, h, d = r.shape
    pad = (-s) % chunk
    if pad:
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (r, k, v))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    sp = r.shape[1]
    nc = sp // chunk

    def to_chunks(t):
        return (
            t.reshape(b, nc, chunk, h, d)
            .transpose(1, 0, 3, 2, 4)
            .astype(jnp.float32)
        )  # [nc, B, H, T, D]

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    s_init = jnp.zeros((b, h, d, d), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    tpos = jnp.arange(chunk)[:, None]
    ipos = jnp.arange(chunk)[None, :]

    def step(state, xs):
        rt, kt, vt, wt = xs                           # [B,H,T,D]
        logw = jnp.log(jnp.maximum(wt, 1e-30))
        lw_inc = jnp.cumsum(logw, axis=2)
        lw_exc = lw_inc - logw
        rd = rt * jnp.exp(lw_exc)
        kd = kt * jnp.exp(-lw_inc)
        a = jnp.einsum("bhtd,bhid->bhti", rd, kd)
        a = jnp.where(ipos < tpos, a, 0.0)
        diag = jnp.sum(rt * (u[None, :, None, :] * kt), axis=-1)
        a = a + jnp.where(ipos == tpos, diag[..., None], 0.0)
        o = jnp.einsum("bhti,bhid->bhtd", a, vt) + jnp.einsum(
            "bhtd,bhdv->bhtv", rd, state
        )
        lw_end = lw_inc[:, :, -1:, :]
        k_end = kt * jnp.exp(lw_end - lw_inc)
        state = jnp.exp(lw_end[:, :, 0, :])[..., :, None] * state + jnp.einsum(
            "bhtk,bhtv->bhkv", k_end, vt
        )
        return state, o

    final, outs = jax.lax.scan(step, s_init, (rc, kc, vc, wc))
    o = outs.transpose(1, 0, 3, 2, 4).reshape(b, sp, h, d)[:, :s]
    return o, final


def rwkv6_mix(
    r: jax.Array,                # [B, S, H, D]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,                # [H, D]
    s0: Optional[jax.Array] = None,
    *,
    impl: Optional[str] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    b, s, h, d = r.shape
    if impl is None:
        if jax.default_backend() == "tpu" and s % _CHUNK == 0:
            impl = "pallas"
        elif s <= _REF_MAX_SEQ:
            impl = "ref"
        else:
            impl = "chunked"
    if impl == "ref":
        return rwkv6_reference(r, k, v, w, u, s0)
    if impl == "chunked":
        return _chunked_jnp(r, k, v, w, u, s0)
    if impl == "pallas":
        def flat(t):
            return t.transpose(0, 2, 1, 3).reshape(b * h, s, d)
        s0_ = (
            jnp.zeros((b * h, d, d), jnp.float32)
            if s0 is None
            else s0.reshape(b * h, d, d)
        )
        u_ = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, d)
        o, sf = rwkv6_pallas(
            flat(r), flat(k), flat(v), flat(w), u_, s0_, interpret=interpret
        )
        return (
            o.reshape(b, h, s, d).transpose(0, 2, 1, 3),
            sf.reshape(b, h, d, d),
        )
    raise ValueError(impl)
