"""Pallas TPU kernel for the RWKV-6 recurrence, sequence-chunked.

TPU adaptation: instead of a token-by-token scan (latency-bound), the
sequence is processed in chunks of T tokens.  Within a chunk the
recurrence unrolls into dense matmuls (MXU work) using cumulative
per-channel decays:

    lw[t]   = sum_{i<=t} log w[i]                  (exclusive for queries)
    A[t,i]  = (r[t] * exp(lw[t-1])) . (k[i] * exp(-lw[i]))   for i < t
    A[t,t]  = r[t] . (u * k[t])
    o       = A @ v  +  (r * exp(lw_excl)) @ S_in
    S_out   = diag(exp(lw[T-1])) S_in + (k * exp(lw[T-1] - lw))^T @ v

The head state S [D, D] lives in VMEM scratch and persists across the
sequential chunk grid dimension.  Chunk length is bounded (default 32) so
``exp(-lw)`` stays within fp32 range for the fastest-decaying channels.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
    o_ref, sfin_ref,
    state_ref,
    *,
    chunk: int,
    num_chunks: int,
):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)       # [T, D]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)       # [D]

    logw = jnp.log(jnp.maximum(w, 1e-30))
    lw_inc = jnp.cumsum(logw, axis=0)      # inclusive
    lw_exc = lw_inc - logw                 # exclusive

    rd = r * jnp.exp(lw_exc)               # [T, D]
    kd = k * jnp.exp(-lw_inc)
    a = jax.lax.dot_general(
        rd, kd, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                       # [T, T]
    tpos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ipos = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    a = jnp.where(ipos < tpos, a, 0.0)     # strict lower triangular
    diag = jnp.sum(r * (u[None, :] * k), axis=-1)  # [T]
    a = a + jnp.where(ipos == tpos, diag[:, None], 0.0)

    s_in = state_ref[...]                  # [D, D]
    o = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + jax.lax.dot_general(
        rd, s_in, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0, ...] = o.astype(o_ref.dtype)

    lw_end = lw_inc[-1]                    # [D]
    k_end = k * jnp.exp(lw_end[None, :] - lw_inc)
    s_out = jnp.exp(lw_end)[:, None] * s_in + jax.lax.dot_general(
        k_end, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state_ref[...] = s_out

    @pl.when(ic == num_chunks - 1)
    def _fin():
        sfin_ref[0, ...] = s_out.astype(sfin_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_pallas(
    r: jax.Array,                # [BH, S, D] (batch*heads flattened)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,                # [BH, D]
    s0: jax.Array,               # [BH, D, D]
    *,
    chunk: int = 32,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    bh, s, d = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    kernel = functools.partial(_rwkv6_kernel, chunk=chunk, num_chunks=nc)
    seq_spec = pl.BlockSpec((1, chunk, d), lambda i, ic: (i, ic, 0))
    o, sfin = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, d), lambda i, ic: (i, 0)),
            pl.BlockSpec((1, d, d), lambda i, ic: (i, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, d, d), lambda i, ic: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return o, sfin
