"""Pure-jnp oracle for the RWKV-6 time-mix recurrence (Finch).

Per head with state S [D_k, D_v]:

    o_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t

with data-dependent per-channel decay w_t in (0,1).  Plain sequential
``lax.scan`` over time.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rwkv6_reference(
    r: jax.Array,                # [B, S, H, D]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,                # [B, S, H, D] decay in (0, 1)
    u: jax.Array,                # [H, D] bonus
    s0: Optional[jax.Array] = None,  # [B, H, D, D]
) -> Tuple[jax.Array, jax.Array]:
    b, s, h, d = r.shape
    state0 = jnp.zeros((b, h, d, d), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    def step(state, xs):
        rt, kt, vt, wt = xs  # [B, H, D]
        kv = kt[..., :, None] * vt[..., None, :]          # [B,H,Dk,Dv]
        out = jnp.einsum("bhk,bhkv->bhv", rt, state + u[None, :, :, None] * kv)
        state = wt[..., :, None] * state + kv
        return state, out

    xs = tuple(
        t.transpose(1, 0, 2, 3).astype(jnp.float32) for t in (r, k, v, w)
    )
    final, outs = jax.lax.scan(step, state0, xs)
    return outs.transpose(1, 0, 2, 3), final
