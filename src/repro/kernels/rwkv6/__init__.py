from repro.kernels.rwkv6.ops import rwkv6_mix
from repro.kernels.rwkv6.ref import rwkv6_reference
from repro.kernels.rwkv6.kernel import rwkv6_pallas

__all__ = ["rwkv6_mix", "rwkv6_reference", "rwkv6_pallas"]
