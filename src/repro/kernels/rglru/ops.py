"""Dispatcher for the RG-LRU scan.

* TPU            -> Pallas kernel (sequence-blocked, state in VMEM).
* elsewhere      -> ``jax.lax.associative_scan`` (log-depth parallel scan;
                    also the production path inside pjit since XLA shards
                    it over batch/width).
The sequential-scan oracle lives in ref.py for testing.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rglru.kernel import rglru_scan_pallas
from repro.kernels.rglru.ref import rglru_scan_reference


def _associative(b, a, h0):
    if h0 is not None:
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0[:, None, :].astype(b.dtype), b], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        hh = hh[:, 1:]
    return hh, hh[:, -1]


def rglru_scan(
    b: jax.Array,
    a: jax.Array,
    h0: Optional[jax.Array] = None,
    *,
    impl: Optional[str] = None,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t h_{t-1} + b_t. Returns (h [B,S,W], h_final [B,W])."""
    if impl is None:
        impl = "pallas" if jax.default_backend() == "tpu" else "associative"
    b32 = b.astype(jnp.float32)
    a32 = a.astype(jnp.float32)
    if impl == "pallas":
        return rglru_scan_pallas(b32, a32, h0, interpret=interpret)
    if impl == "associative":
        return _associative(b32, a32, h0)
    if impl == "ref":
        return rglru_scan_reference(b32, a32, h0)
    raise ValueError(impl)
