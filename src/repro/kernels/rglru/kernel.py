"""Pallas TPU kernel for the RG-LRU recurrence.

TPU adaptation: the recurrence is elementwise over the width dim (VPU
work, no MXU), so the kernel tiles (batch, width) across the grid and
blocks the *sequence* into VMEM-resident chunks; the carried state h
lives in a VMEM scratch buffer that persists across the sequential chunk
grid dimension.  Within a chunk the scan is an unrolled first-order
recurrence over vectors of width ``block_w`` — sequential in time (a
linear scan is latency-bound by construction) but fully vectorized over
width, which is the axis TPUs care about.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(b_ref, a_ref, h0_ref, h_out_ref, hfin_ref, state_ref, *,
                  block_s: int, num_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = h0_ref[...].astype(jnp.float32)

    def step(t, h):
        at = a_ref[0, t, :].astype(jnp.float32)
        bt = b_ref[0, t, :].astype(jnp.float32)
        h = at * h + bt
        h_out_ref[0, t, :] = h.astype(h_out_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, state_ref[0])
    state_ref[0, :] = h

    @pl.when(ic == num_chunks - 1)
    def _fin():
        hfin_ref[0, :] = h.astype(hfin_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_s", "block_w", "interpret")
)
def rglru_scan_pallas(
    b: jax.Array,                 # [B, S, W]
    a: jax.Array,                 # [B, S, W]
    h0: Optional[jax.Array] = None,
    *,
    block_s: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    bsz, s, w = b.shape
    if h0 is None:
        h0 = jnp.zeros((bsz, w), jnp.float32)
    block_s = min(block_s, s)
    block_w = min(block_w, w)
    assert s % block_s == 0 and w % block_w == 0
    nc = s // block_s
    nw = w // block_w
    kernel = functools.partial(_rglru_kernel, block_s=block_s, num_chunks=nc)
    h, hfin = pl.pallas_call(
        kernel,
        # width is embarrassingly parallel; chunks are sequential (inner dim)
        grid=(bsz * nw, nc),
        in_specs=[
            pl.BlockSpec(
                (1, block_s, block_w),
                lambda i, ic, nw=nw: (i // nw, ic, i % nw),
            ),
            pl.BlockSpec(
                (1, block_s, block_w),
                lambda i, ic, nw=nw: (i // nw, ic, i % nw),
            ),
            pl.BlockSpec((1, block_w), lambda i, ic, nw=nw: (i // nw, i % nw)),
        ],
        out_specs=[
            pl.BlockSpec(
                (1, block_s, block_w),
                lambda i, ic, nw=nw: (i // nw, ic, i % nw),
            ),
            pl.BlockSpec((1, block_w), lambda i, ic, nw=nw: (i // nw, i % nw)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, w), jnp.float32),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(b, a, h0)
    return h, hfin
