from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_scan_reference
from repro.kernels.rglru.kernel import rglru_scan_pallas

__all__ = ["rglru_scan", "rglru_scan_reference", "rglru_scan_pallas"]
