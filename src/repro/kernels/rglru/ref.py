"""Pure-jnp oracle for the RG-LRU linear recurrence.

h_t = a_t * h_{t-1} + b_t, elementwise over the width dim.  The oracle is
a plain sequential ``lax.scan``; the production path uses
``jax.lax.associative_scan`` (log-depth) and the Pallas kernel blocks the
sequence with the state held in VMEM.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rglru_scan_reference(
    b: jax.Array,                 # [B, S, W] input term b_t
    a: jax.Array,                 # [B, S, W] decay a_t in (0, 1)
    h0: Optional[jax.Array] = None,  # [B, W]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (h [B,S,W], h_final [B,W])."""
    bsz, s, w = b.shape
    init = jnp.zeros((bsz, w), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h

    final, hs = jax.lax.scan(
        step,
        init,
        (a.transpose(1, 0, 2).astype(jnp.float32), b.transpose(1, 0, 2).astype(jnp.float32)),
    )
    return hs.transpose(1, 0, 2), final
