"""Dispatching wrapper for attention.

``flash_attention`` picks the right implementation per platform and shape:

* ``pallas``   — the TPU kernel (kernel.py); interpret=True on CPU tests.
* ``blocked``  — pure-jnp blockwise online-softmax (lax.scan over kv
                 chunks; dynamic-sliced kv window for local attention) —
                 O(S) memory, used for long prefill on non-TPU backends
                 and as the lowering the dry-run roofline sees.
* ``ref``      — the naive oracle (ref.py), used for short sequences where
                 the O(S^2) score tensor is cheap and autodiff through it
                 is the fastest option.

All impls share the layout q [B, Sq, H, D], k/v [B, Sk, KV, D].
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash import flash_global, flash_local
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_reference

# Naive-path threshold: above this the O(S^2) score tensor dominates step
# memory (4k seq at per-device batch 16 is already ~8.6 GB f32), so the
# blockwise paths take over.  Short sequences (unit tests, decode) keep the
# naive oracle, which autodiffs fastest.
_REF_MAX_SEQ = 1024


def _blocked_global(
    q, k, v, *, causal: bool, softcap: float, q_offset: int, chunk: int
) -> jax.Array:
    """Online-softmax scan over kv chunks (no window)."""
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    group = h // kvh
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = k.shape[1] // chunk
    kc = k.reshape(b, nk, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32) / jnp.sqrt(d)
    qpos = q_offset + jnp.arange(sq)

    def step(carry, xs):
        acc, m, l = carry
        ic, kblk, vblk = xs
        kf = jnp.repeat(kblk.astype(jnp.float32), group, axis=2)
        vf = jnp.repeat(vblk.astype(jnp.float32), group, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        kpos = ic * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < sk
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vf)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(
        step, (acc0, m0, l0), (jnp.arange(nk), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def _blocked_local(
    q, k, v, *, window: int, softcap: float, q_offset: int, block_q: int
) -> jax.Array:
    """Sliding-window attention: per q-block dynamic slice of the kv range
    [q_start - window + 1, q_start + block_q) — FLOPs O(S * window), not
    O(S^2)."""
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    pad_q = (-sq) % block_q
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nq = q.shape[1] // block_q
    span = window + block_q  # kv positions any query in the block can see
    # pad kv on both sides so every dynamic slice is in-bounds (the last q
    # block's span can run one block past the sequence end)
    pad_left = span
    kp = jnp.pad(k, ((0, 0), (pad_left, block_q), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (pad_left, block_q), (0, 0), (0, 0)))
    qb = q.reshape(b, nq, block_q, h, d).transpose(1, 0, 2, 3, 4)

    def per_block(iq, qblk):
        # absolute kv start of the visible span for this q block
        q_start = q_offset + iq * block_q
        kv_start = q_start - window + 1  # may be negative; padding absorbs
        start = kv_start + pad_left
        kblk = jax.lax.dynamic_slice(kp, (0, start, 0, 0), (b, span, kvh, d))
        vblk = jax.lax.dynamic_slice(vp, (0, start, 0, 0), (b, span, kvh, d))
        kpos = kv_start + jnp.arange(span)
        qpos = q_start + jnp.arange(block_q)
        valid = (kpos[None, :] >= 0) & (kpos[None, :] < sk)
        valid &= kpos[None, :] <= qpos[:, None]
        valid &= kpos[None, :] > qpos[:, None] - window
        out = _masked_naive(qblk, kblk, vblk, valid, softcap)
        return out

    outs = jax.vmap(per_block)(jnp.arange(nq), qb)  # [nq, B, bq, H, D]
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, nq * block_q, h, d)
    return out[:, :sq]


def _masked_naive(q, k, v, mask, softcap):
    b, sq, h, d = q.shape
    group = h // k.shape[2]
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) / jnp.sqrt(d), kf)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vf).astype(q.dtype)


def flash_attention(
    q: jax.Array,  # [B, Sq, H, D]
    k: jax.Array,  # [B, Sk, KV, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    kv_length: Optional[jax.Array] = None,
    impl: Optional[str] = None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Dispatching attention entry point used by the models."""
    sq, sk = q.shape[1], k.shape[1]
    if impl is None:
        if jax.default_backend() == "tpu" and kv_length is None and sq > 1:
            impl = "pallas"
        elif sk <= _REF_MAX_SEQ or sq == 1 or kv_length is not None:
            impl = "ref"
        elif window and window < sk:
            impl = "blocked_local"
        else:
            impl = "blocked"

    if impl == "pallas":
        bq = min(block_q, sq)
        bk = min(block_kv, sk)
        out = flash_attention_pallas(
            q.transpose(0, 2, 1, 3),
            k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3),
            causal=causal,
            window=window,
            softcap=softcap,
            block_q=bq,
            block_kv=bk,
            interpret=interpret,
        )
        return out.transpose(0, 2, 1, 3)
    if impl == "ref":
        return attention_reference(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, kv_length=kv_length,
        )
    if impl == "blocked_local":
        assert window and causal
        return flash_local(
            q, k, v, window, softcap, q_offset, min(block_q, sq)
        )
    if impl == "blocked":
        return flash_global(
            q, k, v, causal, softcap, q_offset, min(block_kv, sk)
        )
    raise ValueError(f"unknown impl {impl!r}")
