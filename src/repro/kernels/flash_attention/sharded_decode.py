"""Distributed flash-decode: attention of ONE query position against a
KV cache whose *sequence* dimension is sharded over the 'model' mesh axis.

Decode is cache-bandwidth-bound; sequence-sharding the cache parallelizes
the reads — but left to the SPMD partitioner, the einsum+softmax graph
all-gathers the whole cache every step (qwen3-4b decode_32k baseline:
72 GiB of all-gather per decoded token).  The correct schedule is the
classic distributed online softmax, written here as an explicit shard_map:

    per shard:  s = q·k_loc, m_loc = max(s), then
    global:     m = pmax(m_loc),  l = psum(sum e^{s-m}),
                out = psum(e^{s-m}·v_loc) / l

Collective traffic per step drops to O(B·H·D) (the partial accumulators)
— ~300 KB instead of the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

_NEG = -1e30


def sharded_flash_decode(
    q: jax.Array,          # [B, 1, H, D]   (replicated over 'model')
    k: jax.Array,          # [B, S, KV, D]  (S sharded over 'model')
    v: jax.Array,          # [B, S, KV, Dv]
    length,                # scalar or [B] — number of valid positions
    *,
    softcap: float = 0.0,
    axis: str = "model",
) -> jax.Array:
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or axis not in getattr(mesh, "axis_names", ()):
        raise ValueError("sharded_flash_decode needs a mesh with 'model'")
    n_shards = dict(mesh.shape)[axis]
    b, sq, h, d = q.shape
    s_total = k.shape[1]
    kvh = k.shape[2]
    assert sq == 1 and s_total % n_shards == 0
    s_loc = s_total // n_shards
    length = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b,))

    # Global KV positions enter as an operand sharded like the cache
    # instead of being derived from jax.lax.axis_index: axis_index lowers
    # to a PartitionId instruction that the SPMD partitioner rejects
    # inside partial-manual regions (jaxlib < 0.5), and an explicit iota
    # operand partitions fine everywhere.
    positions = jnp.arange(s_total, dtype=jnp.int32)

    def body(qb, kb, vb, lenb, kpos):
        q5 = qb.reshape(b, sq, kvh, h // kvh, d).astype(jnp.float32)
        q5 = q5 / jnp.sqrt(d)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q5, kb.astype(jnp.float32))
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        valid = kpos[None, :] < lenb[:, None]               # [B, s_loc]
        s = jnp.where(valid[:, None, None, None, :], s, _NEG)
        m_loc = jnp.max(s, axis=-1)                         # [B,KVH,G,1]
        m = jax.lax.pmax(m_loc, axis)
        p = jnp.exp(s - m[..., None])
        l = jax.lax.psum(jnp.sum(p, axis=-1), axis)
        acc = jax.lax.psum(
            jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32)), axis
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        dv = vb.shape[-1]
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)

    out = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P(None, axis, None, None), P(None, axis, None, None),
                  P(), P(axis)),
        out_specs=P(),
        axis_names={axis},
        check_vma=False,
    )(q, k, v, length, positions)
    return out.astype(q.dtype)
