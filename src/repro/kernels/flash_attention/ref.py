"""Pure-jnp oracle for flash attention.

Naive O(S^2)-memory attention with every mask/feature the models need:
causal, sliding window, logit soft-capping, GQA head grouping.  This is
the ground truth the Pallas kernel and the blocked-jnp path are tested
against (tests/test_kernels_attention.py sweeps shapes/dtypes).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax


def attention_reference(
    q: jax.Array,            # [B, Sq, H, D]
    k: jax.Array,            # [B, Sk, KV, D]
    v: jax.Array,            # [B, Sk, KV, D]
    *,
    causal: bool = True,
    window: int = 0,          # 0 = unlimited; else causal sliding window
    softcap: float = 0.0,
    q_offset: int = 0,        # absolute position of q[0] (decode/prefill)
    kv_length: Optional[jax.Array] = None,  # valid kv prefix length [B]
) -> jax.Array:
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0
    group = h // kv
    qf = q.astype(jnp.float32) / jnp.sqrt(d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to full heads
    kf = jnp.repeat(kf, group, axis=2)
    vf = jnp.repeat(vf, group, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    qpos = q_offset + jnp.arange(sq)[:, None]          # [Sq, 1]
    kpos = jnp.arange(sk)[None, :]                     # [1, Sk]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    mask = mask[None, None]
    if kv_length is not None:
        mask = mask & (kpos[None, None] < kv_length[:, None, None, None])
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)
