"""Memory-efficient blockwise attention with a flash-style custom VJP.

This is the pure-jnp twin of the Pallas TPU kernel (kernel.py): identical
blocking structure, identical recompute-based backward.  It exists because

* the multi-pod dry-run lowers on the CPU backend, where a ``pallas_call``
  cannot lower non-interpreted — the roofline must see the blockwise
  compute/memory profile, not an O(S^2) naive softmax;
* plain autodiff through a blockwise online-softmax scan saves the per-
  chunk probability matrices as VJP residuals — O(S^2) memory again.  The
  custom VJP stores only (q, k, v, out, lse) = O(S·d) and recomputes
  scores per chunk in the backward pass, exactly like flash attention.

Two variants:

``flash_global``  one kv-chunk scan over the whole sequence (causal or
                  bidirectional; optional logit softcap).  Causal masking
                  is applied per chunk; masked chunks still compute
                  (static shapes), so causal FLOPs are ~2x the ideal —
                  the TPU kernel skips them via its grid, noted in the
                  roofline analysis.
``flash_local``   sliding-window: a scan over q blocks, each attending to
                  a statically-sized kv span (window + block) via dynamic
                  slice — FLOPs O(S * window), which is what makes 32k+
                  prefill with a 2-4k window tractable.

GQA is handled by folding the q heads into (kv_head, group).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.util.flags import scan_unroll_enabled

_NEG = -1e30


def _fold_gqa(q: jax.Array, kvh: int) -> jax.Array:
    """[B, Sq, H, D] -> [B, Sq, KVH, G, D]."""
    b, sq, h, d = q.shape
    return q.reshape(b, sq, kvh, h // kvh, d)


def _chunk_mask(qpos, kpos, *, causal: bool, window: int, sk: int):
    m = kpos[None, :] < sk
    if causal:
        m = m & (kpos[None, :] <= qpos[:, None])
    if window:
        m = m & (kpos[None, :] > qpos[:, None] - window)
    return m  # [Sq, C]


def _scores(q5f, kf, softcap: float):
    """q5f [B,Sq,KVH,G,D] (pre-scaled), kf [B,C,KVH,D] -> s [B,KVH,G,Sq,C]
    (+ tanh(s_raw/cap) when softcapped, for the backward chain rule)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q5f, kf)
    if softcap:
        t = jnp.tanh(s / softcap)
        return softcap * t, t
    return s, None


# ---------------------------------------------------------------------------
# Global (full / causal) attention
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_global(q, k, v, causal: bool, softcap: float, q_offset: int,
                 chunk: int):
    out, _ = _global_fwd_impl(q, k, v, causal, softcap, q_offset, chunk)
    return out


def _global_fwd_impl(q, k, v, causal, softcap, q_offset, chunk):
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    dv = v.shape[-1]                   # MLA: d_qk (192) != d_v (128)
    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = kp.shape[1] // chunk
    kc = kp.reshape(b, nk, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nk, chunk, kvh, dv).transpose(1, 0, 2, 3, 4)
    q5f = _fold_gqa(q, kvh).astype(jnp.float32) / jnp.sqrt(d)
    qpos = q_offset + jnp.arange(sq)

    def step(carry, xs):
        acc, m, l = carry
        ic, kblk, vblk = xs
        kf = kblk.astype(jnp.float32)
        vf = vblk.astype(jnp.float32)
        s, _ = _scores(q5f, kf, softcap)
        kpos = ic * chunk + jnp.arange(chunk)
        mask = _chunk_mask(qpos, kpos, causal=causal, window=0, sk=sk)
        s = jnp.where(mask[None, None, None], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vf)
        return (acc, m_new, l), None

    g = h // kvh
    acc0 = jnp.zeros((b, kvh, g, sq, dv), jnp.float32)
    m0 = jnp.full((b, kvh, g, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (jnp.arange(nk), kc, vc),
                                  unroll=scan_unroll_enabled())
    l_safe = jnp.maximum(l, 1e-30)
    out5 = acc / l_safe[..., None]                       # [B,KVH,G,Sq,Dv]
    out = out5.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv).astype(q.dtype)
    lse = m + jnp.log(l_safe)                            # [B,KVH,G,Sq]
    return out, lse


def _global_fwd(q, k, v, causal, softcap, q_offset, chunk):
    out, lse = _global_fwd_impl(q, k, v, causal, softcap, q_offset, chunk)
    return out, (q, k, v, out, lse)


def _global_bwd(causal, softcap, q_offset, chunk, res, gout):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    dv = v.shape[-1]
    g = h // kvh
    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = kp.shape[1] // chunk
    kc = kp.reshape(b, nk, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nk, chunk, kvh, dv).transpose(1, 0, 2, 3, 4)

    scale = 1.0 / jnp.sqrt(d)
    q5f = _fold_gqa(q, kvh).astype(jnp.float32) * scale
    g5 = _fold_gqa(gout, kvh).astype(jnp.float32)        # [B,Sq,KVH,G,D]
    o5 = _fold_gqa(out, kvh).astype(jnp.float32)
    # D_i = sum_d g_i * o_i  (the softmax-grad diagonal term)
    delta = jnp.einsum("bqhgd,bqhgd->bhgq", g5, o5)
    qpos = q_offset + jnp.arange(sq)

    def step(dq, xs):
        ic, kblk, vblk = xs
        kf = kblk.astype(jnp.float32)
        vf = vblk.astype(jnp.float32)
        s, t = _scores(q5f, kf, softcap)
        kpos = ic * chunk + jnp.arange(chunk)
        mask = _chunk_mask(qpos, kpos, causal=causal, window=0, sk=sk)
        s = jnp.where(mask[None, None, None], s, _NEG)
        p = jnp.exp(s - lse[..., None])                  # [B,KVH,G,Sq,C]
        dv_c = jnp.einsum("bhgqk,bqhgd->bkhd", p, g5)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", g5, vf)
        ds = p * (dp - delta[..., None])
        if softcap:
            ds = ds * (1.0 - t * t)
        ds = jnp.where(mask[None, None, None], ds, 0.0)
        dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kf) * scale
        dk_c = jnp.einsum("bhgqk,bqhgd->bkhd", ds, q5f)
        return dq, (dk_c, dv_c)

    dq0 = jnp.zeros((b, sq, kvh, g, d), jnp.float32)
    dq5, (dkc, dvc) = jax.lax.scan(step, dq0, (jnp.arange(nk), kc, vc),
                                   unroll=scan_unroll_enabled())
    dq = dq5.reshape(b, sq, h, d).astype(q.dtype)
    dk = dkc.transpose(1, 0, 2, 3, 4).reshape(b, nk * chunk, kvh, d)[:, :sk]
    dv_ = dvc.transpose(1, 0, 2, 3, 4).reshape(b, nk * chunk, kvh, dv)[:, :sk]
    return dq, dk.astype(k.dtype), dv_.astype(v.dtype)


flash_global.defvjp(_global_fwd, _global_bwd)


# ---------------------------------------------------------------------------
# Sliding-window attention (q-block outer loop, static kv span)
# ---------------------------------------------------------------------------
def _local_geometry(q, k, window: int, block_q: int):
    b, sq, h, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    pad_q = (-sq) % block_q
    nq = (sq + pad_q) // block_q
    span = window + block_q
    return b, sq, h, d, sk, block_q, pad_q, nq, span


def _local_block(q5f, kblk, vblk, qpos, kpos, softcap, sk, window):
    """Exact softmax over one q block's visible span.  Returns out5, p, t
    (p/t reused by the backward)."""
    kf = kblk.astype(jnp.float32)
    vf = vblk.astype(jnp.float32)
    s, t = _scores(q5f, kf, softcap)
    mask = (kpos[None, :] >= 0) & _chunk_mask(
        qpos, kpos, causal=True, window=window, sk=sk
    )
    s = jnp.where(mask[None, None, None], s, _NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out5 = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out5, p, t, mask


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_local(q, k, v, window: int, softcap: float, q_offset: int,
                block_q: int):
    out, _ = _local_fwd_impl(q, k, v, window, softcap, q_offset, block_q)
    return out


def _pad_kv(k, span, block_q):
    return jnp.pad(k, ((0, 0), (span, block_q), (0, 0), (0, 0)))


def _local_fwd_impl(q, k, v, window, softcap, q_offset, block_q):
    b, sq, h, d, sk, block_q, pad_q, nq, span = _local_geometry(
        q, k, window, block_q
    )
    kvh = k.shape[2]
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    q5 = _fold_gqa(qp, kvh).astype(jnp.float32) / jnp.sqrt(d)
    qb = q5.reshape(b, nq, block_q, kvh, h // kvh, d).transpose(1, 0, 2, 3, 4, 5)
    kp = _pad_kv(k, span, block_q)
    vp = _pad_kv(v, span, block_q)

    def step(_, xs):
        iq, qblk = xs
        q_start = q_offset + iq * block_q
        kv_start = q_start - window + 1
        start = kv_start + span
        kblk = jax.lax.dynamic_slice(kp, (0, start, 0, 0), (b, span, kvh, d))
        vblk = jax.lax.dynamic_slice(vp, (0, start, 0, 0), (b, span, kvh, d))
        kpos = kv_start + jnp.arange(span)
        qpos = q_start + jnp.arange(block_q)
        out5, _, _, _ = _local_block(qblk, kblk, vblk, qpos, kpos, softcap,
                                     sk, window)
        return None, out5

    _, outs = jax.lax.scan(step, None, (jnp.arange(nq), qb),
                           unroll=scan_unroll_enabled())
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * block_q, h, d)
    return out[:, :sq].astype(q.dtype), None


def _local_fwd(q, k, v, window, softcap, q_offset, block_q):
    out, _ = _local_fwd_impl(q, k, v, window, softcap, q_offset, block_q)
    return out, (q, k, v)


def _local_bwd(window, softcap, q_offset, block_q, res, gout):
    q, k, v = res
    b, sq, h, d, sk, block_q, pad_q, nq, span = _local_geometry(
        q, k, window, block_q
    )
    kvh = k.shape[2]
    g = h // kvh
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    gp = jnp.pad(gout, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    scale = 1.0 / jnp.sqrt(d)
    q5 = _fold_gqa(qp, kvh).astype(jnp.float32) * scale
    g5 = _fold_gqa(gp, kvh).astype(jnp.float32)
    qb = q5.reshape(b, nq, block_q, kvh, g, d).transpose(1, 0, 2, 3, 4, 5)
    gb = g5.reshape(b, nq, block_q, kvh, g, d).transpose(1, 0, 2, 3, 4, 5)
    kp = _pad_kv(k, span, block_q)
    vp = _pad_kv(v, span, block_q)
    dkp0 = jnp.zeros(kp.shape, jnp.float32)
    dvp0 = jnp.zeros(vp.shape, jnp.float32)

    def step(carry, xs):
        dkp, dvp = carry
        iq, qblk, gblk = xs
        q_start = q_offset + iq * block_q
        kv_start = q_start - window + 1
        start = kv_start + span
        kblk = jax.lax.dynamic_slice(kp, (0, start, 0, 0), (b, span, kvh, d))
        vblk = jax.lax.dynamic_slice(vp, (0, start, 0, 0), (b, span, kvh, d))
        kpos = kv_start + jnp.arange(span)
        qpos = q_start + jnp.arange(block_q)
        out5, p, t, mask = _local_block(qblk, kblk, vblk, qpos, kpos, softcap,
                                        sk, window)
        vf = vblk.astype(jnp.float32)
        kf = kblk.astype(jnp.float32)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", gblk, vf)
        delta = jnp.einsum("bqhgd,bqhgd->bhgq", gblk, out5)
        ds = p * (dp - delta[..., None])
        if softcap:
            ds = ds * (1.0 - t * t)
        ds = jnp.where(mask[None, None, None], ds, 0.0)
        dq_blk = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kf) * scale
        dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qblk)
        dv_blk = jnp.einsum("bhgqk,bqhgd->bkhd", p, gblk)
        dk_old = jax.lax.dynamic_slice(dkp, (0, start, 0, 0), (b, span, kvh, d))
        dv_old = jax.lax.dynamic_slice(dvp, (0, start, 0, 0), (b, span, kvh, d))
        dkp = jax.lax.dynamic_update_slice(dkp, dk_old + dk_blk, (0, start, 0, 0))
        dvp = jax.lax.dynamic_update_slice(dvp, dv_old + dv_blk, (0, start, 0, 0))
        return (dkp, dvp), dq_blk

    (dkp, dvp), dqb = jax.lax.scan(
        step, (dkp0, dvp0), (jnp.arange(nq), qb, gb),
        unroll=scan_unroll_enabled(),
    )
    dq = dqb.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * block_q, h, d)[:, :sq]
    dk = dkp[:, span : span + sk]
    dv = dvp[:, span : span + sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_local.defvjp(_local_fwd, _local_bwd)
