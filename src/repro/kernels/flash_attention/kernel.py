"""Pallas TPU flash attention (blockwise online-softmax).

TPU-native adaptation: q/k/v tiles are staged HBM->VMEM by BlockSpec, the
score matmul hits the MXU with 128-aligned tiles, and the online-softmax
running state (m, l, acc) lives in VMEM scratch carried across the
innermost (kv) grid dimension.  Causal and sliding-window blocks that are
fully masked are skipped with ``pl.when`` — the skip is structural (the
MXU work is never issued), which is what makes local attention
sub-quadratic on the long_500k path.

Layout: q [B, H, Sq, D], k/v [B, KV, Sk, D]; GQA is handled in the
BlockSpec index_map (head h reads kv head h // (H // KV)).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref,  # VMEM tiles
    acc_ref, m_ref, l_ref,       # scratch
    *,
    causal: bool,
    window: int,
    softcap: float,
    block_q: int,
    block_kv: int,
    sm_scale: float,
    num_kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_kv

    # Structural skip: block entirely above the diagonal (causal) or
    # entirely left of the window.
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window:
        needed = jnp.logical_and(
            needed, k_start + block_kv - 1 > q_start - window
        )

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)                     # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )                                                        # [bq, bk]
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                      # [bq]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_kv", "interpret"
    ),
)
def flash_attention_pallas(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, KV, Sk, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    assert h % kvh == 0
    group = h // kvh
    block_q = min(block_q, sq)
    block_kv = min(block_kv, sk)
    assert sq % block_q == 0 and sk % block_kv == 0, (sq, block_q, sk, block_kv)
    nq, nk = sq // block_q, sk // block_kv
    sm_scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_kv=block_kv,
        sm_scale=sm_scale,
        num_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, d),
                lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_kv, d),
                lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
