from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.flash_attention.kernel import flash_attention_pallas

__all__ = ["flash_attention", "attention_reference", "flash_attention_pallas"]
