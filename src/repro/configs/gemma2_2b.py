"""gemma2-2b [dense] — alternating local(4096)/global attention, attn and
final logit soft-capping, pre+post RMSNorm. [arXiv:2408.00118]

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    citation="arXiv:2408.00118",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    layer_pattern=(
        LayerSpec("local_attn", "dense"),
        LayerSpec("attn", "dense"),
    ),
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norm=True,
    rope_theta=10_000.0,
    norm="rmsnorm",
    ffn_activation="gelu",
    embedding_multiplier=48.0,  # sqrt(2304) = 48
    tie_embeddings=True,
)

# long_500k variant: all-local layers (window 4096) so the decode state is
# O(window), documented in DESIGN.md §long_500k applicability.
LONG_CONTEXT_CONFIG = ArchConfig(
    **{
        **{f.name: getattr(CONFIG, f.name) for f in CONFIG.__dataclass_fields__.values()},  # type: ignore[attr-defined]
        "name": "gemma2-2b-longctx",
        "layer_pattern": (LayerSpec("local_attn", "dense"),),
    }
)
