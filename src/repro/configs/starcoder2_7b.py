"""starcoder2-7b [dense] — GQA + RoPE + sliding window 4096, LayerNorm,
non-gated GELU MLP. [arXiv:2402.19173]

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    citation="arXiv:2402.19173",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49_152,
    # StarCoder2-7B trains with a 4k sliding window over a 16k context.
    layer_pattern=(LayerSpec("local_attn", "dense"),),
    sliding_window=4096,
    rope_theta=1_000_000.0,
    norm="layernorm",
    ffn_activation="gelu_mlp",
    tie_embeddings=True,
)
