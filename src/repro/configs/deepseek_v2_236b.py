"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared + 160 routed
experts top-6. [arXiv:2405.04434]

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400; first layer dense
(d_ff 12288).
"""
from repro.configs.base import ArchConfig, LayerSpec, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    citation="arXiv:2405.04434",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=12288,                # dense-FFN hidden (layer 0)
    vocab_size=102_400,
    layer_pattern=(LayerSpec("mla", "moe"),),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=160,
        experts_per_token=6,
        n_shared_experts=2,
        d_expert=1536,
        first_k_dense=1,
        router_aux_coef=0.003,
    ),
    rope_theta=10_000.0,
    norm="rmsnorm",
    ffn_activation="silu",
    tie_embeddings=False,
)
