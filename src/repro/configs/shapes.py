"""The four assigned input shapes.

``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers the batched
prefill; ``decode_32k`` and ``long_500k`` lower ``serve_step`` — ONE new
token against a KV cache / recurrent state of ``seq_len``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def get_shape(name: str) -> InputShape:
    return SHAPES_BY_NAME[name]
