"""Architecture configuration schema.

Every assigned architecture is expressed as an :class:`ArchConfig`: a
transformer backbone described by a *repeating layer pattern* of
:class:`LayerSpec` entries.  The pattern is tiled to ``n_layers`` (with a
remainder prefix handled by the model code), which lets the model stack be
built with ``jax.lax.scan`` over whole pattern periods — keeping the lowered
HLO size O(period), not O(n_layers), which matters for the 512-device
dry-run compiles.

The config also carries everything the analytical profiler needs to derive
per-bucket compute/communication times for the DeFT scheduler (parameter
counts per layer, FLOPs per token, activation bytes).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# Attention-ish sequence mixers.
ATTN_KINDS = ("attn", "local_attn", "mla", "cross_attn")
# Recurrent (attention-free) sequence mixers — these make long_500k feasible.
RECURRENT_KINDS = ("rglru", "rwkv")


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating pattern.

    kind: sequence-mixer type —
        'attn'        full (global) causal self-attention
        'local_attn'  sliding-window causal self-attention
        'mla'         multi-head latent attention (DeepSeek-V2)
        'cross_attn'  cross-attention to encoder / modality memory
                      (paired with a self-attention sublayer in enc-dec
                      decoders; standalone gated layer for VLM)
        'rglru'       RG-LRU gated linear recurrence (Griffin/RecurrentGemma)
        'rwkv'        RWKV-6 time-mix recurrence
    ffn: feed-forward type — 'dense' | 'moe'
    """

    kind: str = "attn"
    ffn: str = "dense"

    def __post_init__(self):
        assert self.kind in ATTN_KINDS + RECURRENT_KINDS, self.kind
        assert self.ffn in ("dense", "moe"), self.ffn


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    n_shared_experts: int = 0
    d_expert: int = 0            # per-expert FFN hidden size
    router_aux_coef: float = 0.001
    # Layers at the start of the stack that stay dense even if the pattern
    # says 'moe' (DeepSeek-V2 keeps layer 0 dense).
    first_k_dense: int = 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2, arXiv:2405.04434)."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    citation: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    layer_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    head_dim: int = 0           # 0 -> d_model // n_heads

    # --- attention details -------------------------------------------------
    rope_theta: float = 10_000.0
    use_qk_norm: bool = False
    sliding_window: int = 0     # window size for 'local_attn' layers
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0

    # --- norms / FFN --------------------------------------------------------
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    post_block_norm: bool = False   # gemma2-style post-norms
    ffn_activation: str = "silu"    # silu (gated) | gelu (gated) | gelu_mlp
    tie_embeddings: bool = True
    embedding_multiplier: float = 1.0   # gemma scales embeds by sqrt(d_model)

    # --- optional sub-configs ----------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None

    # --- recurrence (RG-LRU / RWKV-6) ---------------------------------------
    lru_width: int = 0          # 0 -> d_model
    conv1d_width: int = 4       # temporal conv in recurrentgemma recurrent blk

    # --- encoder-decoder / multimodal ---------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # modality of the *frontend* whose embeddings we consume pre-computed.
    modality: str = "text"      # text | audio | vision
    n_modal_tokens: int = 0     # length of stub modality memory (per example)

    # ------------------------------------------------------------------------
    def __post_init__(self):
        assert self.family in ("dense", "moe", "ssm", "hybrid", "audio", "vlm")
        assert self.n_heads % self.n_kv_heads == 0 or self.mla is not None
        if self.moe is not None:
            assert any(s.ffn == "moe" for s in self.layer_pattern)

    # --- derived quantities --------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def pattern_period(self) -> int:
        return len(self.layer_pattern)

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """The pattern tiled out to n_layers (decoder stack)."""
        reps = math.ceil(self.n_layers / self.pattern_period)
        return (self.layer_pattern * reps)[: self.n_layers]

    def is_recurrent(self) -> bool:
        """True if the arch has at least one recurrent mixer layer."""
        return any(s.kind in RECURRENT_KINDS for s in self.layer_pattern)

    def supports_long_context(self) -> bool:
        """long_500k is runnable iff no layer needs a full-length KV cache."""
        if self.is_encoder_decoder:
            # enc-dec decoder layers carry a full self-attention sublayer.
            return False
        return all(
            s.kind in RECURRENT_KINDS + ("local_attn", "cross_attn")
            for s in self.layer_pattern
        )

    def has_decode_step(self) -> bool:
        """Encoder-only models have no autoregressive decode."""
        return True  # all assigned archs are decoders or enc-dec

    # --- parameter accounting (used by profiler + bucketing) -----------------
    def _attn_params(self, spec: LayerSpec) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        if spec.kind == "mla":
            m = self.mla
            qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = d * m.q_lora_rank                      # q down
            p += m.q_lora_rank * self.n_heads * qk_head  # q up
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)  # kv down (+rope k)
            p += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += self.n_heads * m.v_head_dim * d       # o proj
            return p
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        p = q + kv + o
        if spec.kind == "cross_attn":
            p += d  # gating scalar-ish (negligible); keep symmetric count
        return p

    def _recurrent_params(self, spec: LayerSpec) -> int:
        d, w = self.d_model, self.resolved_lru_width
        if spec.kind == "rglru":
            # input/gate projections d->w (x2), conv1d, lru gates (a, input
            # gate: w x w/heads block-diag ~ 2*w*w/heads), out proj w->d
            heads = self.n_heads
            return 2 * d * w + self.conv1d_width * w + 2 * w * (w // heads) + w * d + w
        # rwkv6 time-mix: r,k,v,g,o projections + decay/mix params
        return 5 * d * d + 6 * d + 2 * d * 32  # lora-ish ddlerp params

    def _ffn_params(self, spec: LayerSpec, layer_idx: int) -> int:
        d = self.d_model
        if spec.ffn == "moe" and self.moe and layer_idx >= self.moe.first_k_dense:
            me = self.moe
            de = me.d_expert or self.d_ff
            per_expert = 3 * d * de  # gated: up, gate, down
            total = (me.n_experts + me.n_shared_experts) * per_expert
            total += d * me.n_experts  # router
            return total
        mult = 3 if self.ffn_activation in ("silu", "gelu") else 2
        return mult * d * self.d_ff

    def layer_param_counts(self) -> Tuple[int, ...]:
        """Parameter count of each decoder layer, input->output order."""
        counts = []
        for i, spec in enumerate(self.layer_specs()):
            if spec.kind in RECURRENT_KINDS:
                mix = self._recurrent_params(spec)
            else:
                mix = self._attn_params(spec)
                if spec.kind == "cross_attn" and self.family == "vlm":
                    pass  # standalone cross layer: same proj sizes
            ffn = self._ffn_params(spec, i)
            norms = 2 * self.d_model * (2 if self.post_block_norm else 1)
            counts.append(mix + ffn + norms)
        return tuple(counts)

    def embed_params(self) -> int:
        p = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            p *= 2
        return p

    def encoder_param_count(self) -> int:
        if not self.is_encoder_decoder:
            return 0
        # encoder layers: self-attn + dense FFN, same dims
        per = self._attn_params(LayerSpec("attn")) + self._ffn_params(
            LayerSpec("attn", "dense"), 0
        ) + 2 * self.d_model
        return per * self.n_encoder_layers

    def total_params(self) -> int:
        return (
            sum(self.layer_param_counts())
            + self.embed_params()
            + self.encoder_param_count()
            + self.d_model  # final norm
        )

    def active_params(self) -> int:
        """Parameters touched per token (MoE: only routed-active experts)."""
        if self.moe is None:
            return self.total_params()
        me = self.moe
        de = me.d_expert or self.d_ff
        per_expert = 3 * self.d_model * de
        n_moe_layers = sum(
            1
            for i, s in enumerate(self.layer_specs())
            if s.ffn == "moe" and i >= me.first_k_dense
        )
        inactive = n_moe_layers * (me.n_experts - me.experts_per_token) * per_expert
        return self.total_params() - inactive

    # --- FLOPs per token (fwd). bwd ~ 2x fwd. -------------------------------
    def flops_per_token_fwd(self, seq_len: int, causal: bool = True) -> float:
        """Matmul FLOPs per token of forward pass (attention score term
        included, averaged over positions for causal)."""
        f = 2.0 * self.active_params()  # dense matmul term: 2*N_active
        # attention quadratic term
        hd = self.resolved_head_dim
        for spec in self.layer_specs():
            if spec.kind in ("attn", "mla"):
                ctx = seq_len / 2 if causal else seq_len
            elif spec.kind == "local_attn":
                ctx = min(self.sliding_window or seq_len, seq_len)
            elif spec.kind == "cross_attn":
                ctx = max(self.n_modal_tokens, 1)
            else:
                # recurrence: linear state update ~ O(w * w/heads) per token,
                # already approximated by param-count term.
                continue
            nh = self.n_heads
            if spec.kind == "mla":
                hd_eff = self.mla.qk_nope_head_dim + self.mla.qk_rope_head_dim
                f += 2.0 * nh * ctx * (hd_eff + self.mla.v_head_dim)
            else:
                f += 2.0 * nh * ctx * 2 * hd
        return f
