"""llama4-maverick-400b-a17b [moe] — interleaved dense/MoE layers, 128
routed experts top-1 + 1 shared expert, early-fusion multimodal text
backbone. [hf:meta-llama/Llama-4-Scout-17B-16E / Llama-4-Maverick card]

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
"""
from repro.configs.base import ArchConfig, LayerSpec, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    # Maverick interleaves dense and MoE FFN layers 1:1.
    layer_pattern=(
        LayerSpec("attn", "dense"),
        LayerSpec("attn", "moe"),
    ),
    moe=MoEConfig(
        n_experts=128,
        experts_per_token=1,
        n_shared_experts=1,
        d_expert=8192,
        router_aux_coef=0.001,
    ),
    rope_theta=500_000.0,
    use_qk_norm=True,
    norm="rmsnorm",
    ffn_activation="silu",
    tie_embeddings=False,
)
