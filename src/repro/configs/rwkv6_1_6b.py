"""rwkv6-1.6b [ssm] — Finch: data-dependent decay linear recurrence,
attention-free. [arXiv:2404.05892]

24L d_model=2048 d_ff=7168 vocab=65536; time-mix head size 64.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    citation="arXiv:2404.05892",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # time-mix heads: d_model / 64
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65_536,
    layer_pattern=(LayerSpec("rwkv", "dense"),),
    norm="layernorm",
    ffn_activation="gelu_mlp",   # rwkv channel-mix is a square-relu 2-mat MLP
    tie_embeddings=False,
)
