"""deepseek-7b [dense] — llama-architecture. [arXiv:2401.02954]

30L d_model=4096 32H (kv=32, i.e. MHA) d_ff=11008 vocab=102400.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    citation="arXiv:2401.02954",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102_400,
    layer_pattern=(LayerSpec("attn", "dense"),),
    rope_theta=10_000.0,
    norm="rmsnorm",
    ffn_activation="silu",
    tie_embeddings=False,
)
