"""seamless-m4t-large-v2 [audio] — encoder-decoder text/unit backbone of
SeamlessM4T v2. [arXiv:2308.11596]

24L(enc)+24L(dec) d_model=1024 16H d_ff=8192 vocab=256206.
The speech frontend (w2v-BERT conformer) is a STUB per the assignment: the
model consumes precomputed frame embeddings of shape (batch, n_frames, d).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    citation="arXiv:2308.11596",
    n_layers=24,               # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    layer_pattern=(LayerSpec("cross_attn", "dense"),),  # self+cross per layer
    is_encoder_decoder=True,
    n_encoder_layers=24,
    modality="audio",
    n_modal_tokens=1024,       # stub: ~20s of speech at 50 fps
    rope_theta=10_000.0,       # decoder self-attn positions
    norm="layernorm",
    ffn_activation="gelu_mlp",
    tie_embeddings=True,
)
