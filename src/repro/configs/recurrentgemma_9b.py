"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 recurrent:attn
pattern. [arXiv:2402.19427 (Griffin), RecurrentGemma model card]

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, window 2048.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    citation="arXiv:2402.19427",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    # Griffin block pattern: (recurrent, recurrent, local attention)
    layer_pattern=(
        LayerSpec("rglru"),
        LayerSpec("rglru"),
        LayerSpec("local_attn"),
    ),
    sliding_window=2048,
    rope_theta=10_000.0,
    norm="rmsnorm",
    ffn_activation="gelu",
    embedding_multiplier=64.0,  # sqrt(d_model) = 64
    lru_width=4096,
    conv1d_width=4,
)
