"""Architecture registry: ``get_config('<arch-id>')`` plus smoke-test
reductions of every config (same family/pattern, tiny dims)."""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, LayerSpec, MLAConfig, MoEConfig
from repro.configs.shapes import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    SHAPES_BY_NAME,
    TRAIN_4K,
    InputShape,
    get_shape,
)

from repro.configs import (  # noqa: E402  (import order: registry modules)
    deepseek_7b,
    deepseek_v2_236b,
    gemma2_2b,
    llama4_maverick_400b_a17b,
    llama_3_2_vision_90b,
    qwen3_4b,
    recurrentgemma_9b,
    rwkv6_1_6b,
    seamless_m4t_large_v2,
    starcoder2_7b,
)

_REGISTRY: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        recurrentgemma_9b,
        deepseek_7b,
        starcoder2_7b,
        deepseek_v2_236b,
        rwkv6_1_6b,
        seamless_m4t_large_v2,
        llama4_maverick_400b_a17b,
        gemma2_2b,
        llama_3_2_vision_90b,
        qwen3_4b,
    )
}
# gemma2 long-context variant (all-local) used only for long_500k.
_REGISTRY[gemma2_2b.LONG_CONTEXT_CONFIG.name] = gemma2_2b.LONG_CONTEXT_CONFIG

ARCH_NAMES = tuple(
    n for n in _REGISTRY if not n.endswith("-longctx")
)  # the 10 assigned ids


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def config_for_shape(name: str, shape_name: str) -> ArchConfig:
    """Arch config to use for a given input shape (handles the gemma2
    long-context sliding-window variant substitution)."""
    cfg = get_config(name)
    if shape_name == "long_500k" and name == "gemma2-2b":
        return _REGISTRY["gemma2-2b-longctx"]
    return cfg


def reduce_for_smoke(cfg: ArchConfig, n_layers: int = 2) -> ArchConfig:
    """Shrink a config to smoke-test size: <=2 layers (one pattern period if
    longer), d_model<=512, <=4 experts, tiny vocab — same family and block
    types, runnable on CPU in one forward/train step."""
    n_layers = max(n_layers, min(len(cfg.layer_pattern), 3))
    d_model = 256
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    head_dim = 32
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            n_experts=4,
            experts_per_token=min(cfg.moe.experts_per_token, 2),
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            d_expert=128,
            first_k_dense=min(cfg.moe.first_k_dense, 1),
        )
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(
            kv_lora_rank=64,
            q_lora_rank=96,
            qk_nope_head_dim=32,
            qk_rope_head_dim=16,
            v_head_dim=32,
        )
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=512,
        vocab_size=512,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        moe=moe,
        mla=mla,
        lru_width=d_model if cfg.lru_width else 0,
        n_encoder_layers=2 if cfg.is_encoder_decoder else 0,
        n_modal_tokens=16 if cfg.n_modal_tokens else 0,
        embedding_multiplier=(
            float(int(d_model**0.5)) if cfg.embedding_multiplier != 1.0 else 1.0
        ),
    )


__all__ = [
    "ArchConfig",
    "LayerSpec",
    "MLAConfig",
    "MoEConfig",
    "InputShape",
    "SHAPES",
    "SHAPES_BY_NAME",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "ARCH_NAMES",
    "get_config",
    "get_shape",
    "config_for_shape",
    "reduce_for_smoke",
]
