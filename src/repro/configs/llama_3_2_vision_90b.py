"""llama-3.2-vision-90b [vlm] — llama3 text decoder with gated
cross-attention image layers interleaved. [hf:meta-llama/Llama-3.2-11B-Vision]

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
The 100 layers comprise 80 self-attention layers + 20 gated cross-attention
layers (1 cross per 4 self, matching the 11B card's 1:5 layer ratio).
The ViT vision encoder + projector is a STUB per the assignment: the model
consumes precomputed patch embeddings (batch, n_patches, d_model).
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    layer_pattern=(
        LayerSpec("attn", "dense"),
        LayerSpec("attn", "dense"),
        LayerSpec("attn", "dense"),
        LayerSpec("attn", "dense"),
        LayerSpec("cross_attn", "dense"),
    ),
    modality="vision",
    n_modal_tokens=1601,       # 1 tile x (40x40 patches + cls) per image
    rope_theta=500_000.0,
    norm="rmsnorm",
    ffn_activation="silu",
    tie_embeddings=False,
)
