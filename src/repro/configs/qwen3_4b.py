"""qwen3-4b [dense] — GQA with per-head qk RMSNorm. [hf:Qwen/Qwen3-8B]

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.
"""
from repro.configs.base import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    citation="hf:Qwen/Qwen3-8B",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151_936,
    layer_pattern=(LayerSpec("attn", "dense"),),
    use_qk_norm=True,
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    ffn_activation="silu",
    tie_embeddings=True,
)
