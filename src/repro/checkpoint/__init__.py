from repro.checkpoint.checkpoint import (
    latest_step,
    restore,
    save,
    saved_keys,
)

__all__ = ["save", "restore", "latest_step", "saved_keys"]
