"""Pytree checkpointing: npz arrays + json tree metadata.

Leaves are flattened with '/'-joined key paths into a single compressed
.npz; the tree structure, dtypes and non-array leaves live in a sidecar
json.  Restore rebuilds the exact pytree (tuples stay tuples).  Writes are
atomic (tmp + rename) so a crashed save never corrupts the latest step.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save(directory: str, step: int, tree, *, name: str = "ckpt") -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    treedef = jax.tree_util.tree_structure(tree)
    meta = {"step": step, "treedef": str(treedef), "keys": sorted(arrays)}
    base = os.path.join(directory, f"{name}_{step:08d}")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz.tmp")
    # write through the handle — np.savez would silently append ".npz" to a
    # path not ending in it, leaving the temp file empty after the rename
    with os.fdopen(fd, "wb") as f:
        np.savez_compressed(f, **arrays)
    os.replace(tmp, base + ".npz")
    with open(base + ".json.tmp", "w") as f:
        json.dump(meta, f)
    os.replace(base + ".json.tmp", base + ".json")
    return base + ".npz"


def restore(directory: str, step: int, like, *, name: str = "ckpt"):
    """Restore into the structure of ``like`` (shapes/dtypes verified)."""
    base = os.path.join(directory, f"{name}_{step:08d}")
    with np.load(base + ".npz") as data:
        flat = {k: data[k] for k in data.files}
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for (path, leaf) in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        out.append(jax.numpy.asarray(arr, dtype=getattr(leaf, "dtype", None)))
    return jax.tree_util.tree_unflatten(treedef, out)


def saved_keys(directory: str, step: int, *, name: str = "ckpt") -> list:
    """Flattened leaf key paths a checkpoint holds (from its sidecar
    meta) — lets callers probe for optional leaves (e.g. the runtime's
    gather cache) without depending on this module's on-disk layout."""
    base = os.path.join(directory, f"{name}_{step:08d}")
    with open(base + ".json") as f:
        return list(json.load(f)["keys"])


def latest_step(directory: str, *, name: str = "ckpt") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    pat = re.compile(rf"{re.escape(name)}_(\d+)\.npz$")
    steps = [
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := pat.match(f))
    ]
    return max(steps) if steps else None
