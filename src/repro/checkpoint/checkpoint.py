"""Pytree checkpointing: npz arrays + json tree metadata.

Leaves are flattened with '/'-joined key paths into a single compressed
.npz; the tree structure, dtypes and non-array leaves live in a sidecar
json.  Restore rebuilds the exact pytree (tuples stay tuples).

Writes are atomic AND ordered (DESIGN.md §10): both files are staged in
a private temp dir on the same filesystem, then renamed into place npz
first, json sidecar LAST — the sidecar is the commit marker.  A crash at
any point leaves either the previous step intact or an uncommitted
orphan; :func:`latest_step` only ever returns steps that pass
:func:`is_complete` (sidecar present, npz readable, every sidecar key
present in the archive), so a kill mid-save can never poison a resume.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import zipfile
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save(directory: str, step: int, tree, *, name: str = "ckpt") -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    treedef = jax.tree_util.tree_structure(tree)
    meta = {"step": step, "treedef": str(treedef), "keys": sorted(arrays)}
    base = os.path.join(directory, f"{name}_{step:08d}")
    # stage BOTH files in a temp dir, then rename npz first and the json
    # sidecar last: the sidecar commits the step (is_complete), so a
    # crash between the two renames leaves an orphan npz that
    # latest_step skips, never a half-trusted checkpoint
    tmpdir = tempfile.mkdtemp(dir=directory, prefix=f".{name}_{step:08d}_")
    try:
        npz_tmp = os.path.join(tmpdir, "arrays.npz")
        # write through a handle — np.savez would silently append ".npz"
        # to a path not ending in it
        with open(npz_tmp, "wb") as f:
            np.savez_compressed(f, **arrays)
        json_tmp = os.path.join(tmpdir, "meta.json")
        with open(json_tmp, "w") as f:
            json.dump(meta, f)
        os.replace(npz_tmp, base + ".npz")
        os.replace(json_tmp, base + ".json")
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)
    return base + ".npz"


def restore(directory: str, step: int, like, *, name: str = "ckpt"):
    """Restore into the structure of ``like`` (shapes/dtypes verified)."""
    base = os.path.join(directory, f"{name}_{step:08d}")
    with np.load(base + ".npz") as data:
        flat = {k: data[k] for k in data.files}
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = jax.tree_util.tree_flatten_with_path(like)[0]
    out = []
    for (path, leaf) in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        out.append(jax.numpy.asarray(arr, dtype=getattr(leaf, "dtype", None)))
    return jax.tree_util.tree_unflatten(treedef, out)


def saved_keys(directory: str, step: int, *, name: str = "ckpt") -> list:
    """Flattened leaf key paths a checkpoint holds (from its sidecar
    meta) — lets callers probe for optional leaves (e.g. the runtime's
    gather cache) without depending on this module's on-disk layout."""
    base = os.path.join(directory, f"{name}_{step:08d}")
    with open(base + ".json") as f:
        return list(json.load(f)["keys"])


def is_complete(directory: str, step: int, *, name: str = "ckpt") -> bool:
    """A checkpoint step is complete iff its json sidecar exists (the
    commit marker), its npz opens as a zip (a truncated write loses the
    central directory at the END of the file), and every key the sidecar
    promises is present in the archive."""
    base = os.path.join(directory, f"{name}_{step:08d}")
    if not (os.path.isfile(base + ".npz") and os.path.isfile(base + ".json")):
        return False
    try:
        with open(base + ".json") as f:
            meta = json.load(f)
        with zipfile.ZipFile(base + ".npz") as z:
            names = set(z.namelist())
        # npz archive members carry a ".npy" suffix
        return all(f"{k}.npy" in names for k in meta.get("keys", []))
    except Exception:
        return False


def _steps_on_disk(directory: str, name: str) -> List[int]:
    pat = re.compile(rf"{re.escape(name)}_(\d+)\.npz$")
    return sorted({
        int(m.group(1))
        for f in os.listdir(directory)
        if (m := pat.match(f))
    })


def valid_steps(directory: str, *, name: str = "ckpt") -> List[int]:
    """All COMPLETE checkpoint steps, ascending — the hardened resume
    walks this newest-first with per-step fallback."""
    if not os.path.isdir(directory):
        return []
    return [
        s for s in _steps_on_disk(directory, name)
        if is_complete(directory, s, name=name)
    ]


def latest_step(directory: str, *, name: str = "ckpt") -> Optional[int]:
    """Newest complete checkpoint step; incomplete/corrupt steps (a
    crash mid-save, a torn npz) are skipped, never returned."""
    steps = valid_steps(directory, name=name)
    return steps[-1] if steps else None


# ---------------------------------------------------------------------------
# Layout / schedule sidecars (cross-layout + mid-cycle resume, DESIGN.md §9)
# ---------------------------------------------------------------------------
def schedule_digest(schedule) -> str:
    """Deterministic fingerprint of a schedule's phase structure —
    PhaseSpecs are frozen dataclasses of primitives, so their repr is
    stable across processes."""
    import hashlib

    return hashlib.sha1(repr(schedule.phases).encode()).hexdigest()[:16]


def save_layout_descriptor(
    directory: str, step: int, layout, next_phase: int = 0,
    digest: str = "",
) -> None:
    """Sidecar json naming the BucketLayout a checkpoint was written
    under, so a restore under a DIFFERENT layout (changed partition or
    shard count) can route the flat accumulators through a
    LayoutTransition (DESIGN.md §9).  ``next_phase`` + the schedule
    ``digest`` record the cycle position the next step would have run,
    letting a resume under the IDENTICAL schedule continue mid-cycle
    (the accumulators were saved mid-generation) instead of restarting
    the cycle."""
    path = os.path.join(directory, f"layout_{step:08d}.json")
    doc = {"bucket_of": list(layout.bucket_of_leaf),
           "n_buckets": layout.n_buckets,
           "shards": layout.shards,
           "next_phase": next_phase,
           "schedule_digest": digest}
    if getattr(layout, "precision", None) is not None:
        # §13: the wire/master policy is part of the layout — a resume
        # must rebuild the same resident master dtype and wire plan
        doc["precision"] = {"wire": list(layout.precision.wire),
                            "master": layout.precision.master}
    with open(path + ".tmp", "w") as f:
        json.dump(doc, f)
    os.replace(path + ".tmp", path)


def load_layout_descriptor(directory: str, step: int, params_abs):
    """Rebuild the checkpoint's BucketLayout + cycle position + schedule
    digest from its sidecar; (None, 0, "") when the checkpoint predates
    descriptors."""
    from repro.train.bucketing import build_bucket_layout

    path = os.path.join(directory, f"layout_{step:08d}.json")
    if not os.path.exists(path):
        return None, 0, ""
    with open(path) as f:
        d = json.load(f)
    precision = None
    if d.get("precision") is not None:
        from repro.core.precision import PrecisionPolicy

        precision = PrecisionPolicy(
            wire=tuple(d["precision"]["wire"]),
            master=d["precision"]["master"],
        )
    layout = build_bucket_layout(params_abs, tuple(d["bucket_of"]),
                                 d["n_buckets"], shard_count=d["shards"],
                                 precision=precision)
    return layout, int(d.get("next_phase", 0)), \
        str(d.get("schedule_digest", ""))
