"""Span -> paper-metric attribution (DESIGN.md §11).

The planner reasons in the paper's vocabulary — coverage rate, bubbles,
knapsack capacity — but the running job only produces wall-clock spans.
This module closes the loop in both directions:

* **live path** (:func:`attribute`, :func:`attribute_trace`): align the
  measured per-phase durations against the installed schedule's
  predicted per-phase durations, fit the two calibration scales
  (``adapt/calibrate.py``), and re-run the timeline simulator at the
  calibrated scales to report *measured* coverage rate, per-bucket
  bubble seconds, and knapsack capacity utilization — plus the raw
  predicted-vs-actual divergence per phase and per bucket, which is the
  early-warning signal the controller's EMA smoothing delays.
* **closure path** (:func:`spans_from_sim`,
  :func:`sim_metrics_from_spans`): a ``SimResult`` timeline converts to
  synthetic spans and back; the reconstructed iteration time / bubble
  fraction / coverage rate must reproduce the simulator's own numbers
  (the ground-truth closure test in ``tests/test_obs.py``).

Alignment rules (§11): measured phase durations are *schedule-relative*
(keyed by position in the installed cycle, re-based on hot-swap exactly
like ``Telemetry``); predicted durations use the same ``_WARMUP``/
period slicing as ``steady_phase_durations``; first-dispatch spans are
excluded (compile pollution, see the ``first`` span tag).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.adapt.calibrate import (
    fit_horizon,
    fit_scales,
    planned_phase_durations,
    schedule_plans,
    scale_times,
)
from repro.core.bucket import BucketTimes
from repro.core.links import effective_mu
from repro.core.scheduler import DeftSchedule, SchedulerConfig
from repro.core.simulator import SimResult, simulate_deft
from repro.obs.trace import Span, Tracer


# ---------------------------------------------------------------------------
# SimResult -> spans (closure path; also the explorer's --trace export)
# ---------------------------------------------------------------------------

def spans_from_sim(sim: SimResult) -> List[Span]:
    """Convert a kept timeline into spans.

    Iteration (``step``) bounds are reconstructed exactly: iteration
    ``it`` starts where its ``F0@it`` compute op starts (the simulator
    appends ``iter_starts`` immediately before forward compute), and the
    final iteration ends at ``start + iteration_durations[-1]``.
    Compute ops become ``compute`` spans, link transmissions become
    ``collective`` spans tagged with their bucket and link.
    """
    if sim.timeline is None:
        raise ValueError(
            "SimResult has no timeline — simulate with keep_timeline=True"
        )
    spans: List[Span] = []
    starts: Dict[int, float] = {}
    for stream, s, e, label in sim.timeline:
        if stream == "compute":
            op = label[0]
            bucket_s, it_s = label[1:].split("@")
            b, it = int(bucket_s), int(it_s)
            if op == "F" and b == 0:
                starts[it] = s
            spans.append(Span(
                "compute", label, s, e, step=it,
                attrs=(("bucket", b), ("op", op)),
            ))
        else:  # link0 / link1
            link = int(stream[len("link"):])
            body = label[1:]
            # split item model (§12): G{bucket}@{iter} is a streamed
            # all-gather item, C… the grad-sync (RS/all-reduce) item
            op = "ag" if label[0] == "G" else "grad"
            if "~" in body:          # DeFT: C{bucket}~{origins}
                bucket_s, origins = body.split("~", 1)
                it = None
            else:                    # baseline C / AG: {bucket}@{iter}
                bucket_s, it_s = body.split("@", 1)
                origins, it = "", int(it_s)
            spans.append(Span(
                "collective", label, s, e, step=it,
                track=f"sim-link{link}",
                attrs=(("bucket", int(bucket_s)), ("link", link),
                       ("origins", origins), ("op", op)),
            ))
    n = len(sim.iteration_durations)
    for it in range(n):
        t0 = starts[it]
        t1 = starts[it + 1] if it + 1 in starts else t0 + sim.iteration_durations[it]
        spans.append(Span("step", f"iter{it}", t0, t1, step=it))
    spans.sort(key=lambda sp: (sp.t0, sp.t1, sp.name))
    return spans


def _clip(intervals: Iterable[Tuple[float, float]], a: float, b: float
          ) -> List[Tuple[float, float]]:
    out = []
    for s, e in intervals:
        s2, e2 = max(s, a), min(e, b)
        if e2 > s2:
            out.append((s2, e2))
    return out


def _merge(intervals: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    out: List[Tuple[float, float]] = []
    for s, e in sorted(intervals):
        if out and s <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], e))
        else:
            out.append((s, e))
    return out


def timeline_bubbles(
    spans: Sequence[Span], t_a: float, t_b: float
) -> Tuple[float, Dict[int, float], Dict[int, float]]:
    """Decompose compute-stream idle time inside ``[t_a, t_b]``.

    Returns ``(total_idle_s, exposed_by_bucket, busy_by_link)``:
    ``exposed_by_bucket[b]`` is the portion of compute-idle time that a
    collective of bucket ``b`` was occupying a link for — the paper's
    per-bucket *bubble* (comm that slipped out of its knapsack slot and
    stalled compute).  Overlapping links may attribute the same idle
    moment to two buckets; the attribution is per-cause, not a
    partition.  ``busy_by_link`` is wall busy-seconds per link id.
    """
    busy = _merge(_clip(
        [(sp.t0, sp.t1) for sp in spans if sp.kind == "compute"], t_a, t_b
    ))
    idle: List[Tuple[float, float]] = []
    cur = t_a
    for s, e in busy:
        if s > cur:
            idle.append((cur, s))
        cur = max(cur, e)
    if cur < t_b:
        idle.append((cur, t_b))
    total_idle = sum(e - s for s, e in idle)

    exposed: Dict[int, float] = {}
    link_busy: Dict[int, float] = {}
    for sp in spans:
        if sp.kind != "collective":
            continue
        args = sp.args
        b = int(args.get("bucket", -1))
        link = int(args.get("link", 0))
        for s, e in _clip([(sp.t0, sp.t1)], t_a, t_b):
            link_busy[link] = link_busy.get(link, 0.0) + (e - s)
            for is_, ie in idle:
                ov = min(e, ie) - max(s, is_)
                if ov > 0:
                    exposed[b] = exposed.get(b, 0.0) + ov
    return total_idle, exposed, link_busy


@dataclasses.dataclass(frozen=True)
class SimSpanMetrics:
    """Paper metrics reconstructed purely from spans."""

    n_iterations: int
    warm: int
    iteration_time: float           # steady-state seconds/iteration
    compute_time: float             # F+B seconds of one iteration
    bubble_fraction: float          # (iter - compute) / iter
    coverage_rate: float            # workload CR: sum_b comm_b / compute
    effective_coverage_rate: float  # transmitted (volume-reduced) CR
    per_bucket_comm: Dict[int, float]       # nominal grad-sync seconds
    per_bucket_bubble: Dict[int, float]     # exposed s/iter by bucket
    total_idle_per_iter: float
    link_busy_per_iter: Dict[int, float]    # wall busy s/iter by link
    # split item model (§12): nominal all-gather seconds per bucket
    # (empty for fused-chain timelines)
    per_bucket_ag: Dict[int, float] = dataclasses.field(default_factory=dict)


def sim_metrics_from_spans(
    spans: Sequence[Span],
    *,
    mu: float = 1.0,
    warm: Optional[int] = None,
) -> SimSpanMetrics:
    """Reproduce the simulator's steady-state numbers from spans alone.

    ``warm`` defaults to the DeFT convention ``max(2, n // 4)``; pass
    ``2`` for baseline-policy spans.  ``mu`` converts secondary-link
    wall time back to nominal (primary-link) comm seconds.
    """
    steps = sorted((sp for sp in spans if sp.kind == "step"),
                   key=lambda sp: sp.t0)
    if len(steps) < 3:
        raise ValueError("need at least 3 step spans for steady state")
    n = len(steps)
    if warm is None:
        warm = max(2, n // 4)
    # identical arithmetic to simulate_deft: (t_end - start_warm) / count
    iteration_time = (steps[-1].t1 - steps[warm].t0) / max(n - warm, 1)

    # compute seconds of one iteration, bucket-ascending F then B (the
    # summation order BucketTimes.fwd_total + bwd_total uses)
    comp = [sp for sp in spans
            if sp.kind == "compute" and sp.step == steps[warm].step]
    fwd = sorted((sp for sp in comp if sp.args["op"] == "F"),
                 key=lambda sp: sp.args["bucket"])
    bwd = sorted((sp for sp in comp if sp.args["op"] == "B"),
                 key=lambda sp: sp.args["bucket"])
    compute = (sum(sp.duration for sp in fwd)
               + sum(sp.duration for sp in bwd))

    # nominal per-bucket comm: any occurrence (merging never grows the
    # tensor, so every transmission of bucket b has the same nominal
    # cost); AG items (§12) are tracked separately — they price forward
    # streaming, not the grad-sync knapsack
    per_bucket_comm: Dict[int, float] = {}
    per_bucket_ag: Dict[int, float] = {}
    for sp in spans:
        if sp.kind != "collective":
            continue
        args = sp.args
        b = int(args["bucket"])
        nominal = sp.duration / (mu if int(args.get("link", 0)) else 1.0)
        if args.get("op") == "ag":
            per_bucket_ag.setdefault(b, nominal)
        else:
            per_bucket_comm.setdefault(b, nominal)

    t_a, t_b = steps[warm].t0, steps[-1].t1
    iters = max(n - warm, 1)
    total_idle, exposed, link_busy = timeline_bubbles(spans, t_a, t_b)

    transmitted = 0.0
    for sp in spans:
        if sp.kind != "collective":
            continue
        for s, e in _clip([(sp.t0, sp.t1)], t_a, t_b):
            link = int(sp.args.get("link", 0))
            transmitted += (e - s) / (mu if link else 1.0)

    comm_total = sum(per_bucket_comm.values())
    return SimSpanMetrics(
        n_iterations=n,
        warm=warm,
        iteration_time=iteration_time,
        compute_time=compute,
        bubble_fraction=max(0.0, 1.0 - compute / iteration_time),
        coverage_rate=comm_total / max(compute, 1e-12),
        effective_coverage_rate=(transmitted / iters) / max(compute, 1e-12),
        per_bucket_comm=per_bucket_comm,
        per_bucket_bubble={b: v / iters for b, v in sorted(exposed.items())},
        total_idle_per_iter=total_idle / iters,
        link_busy_per_iter={k: v / iters for k, v in sorted(link_busy.items())},
        per_bucket_ag=per_bucket_ag,
    )


# ---------------------------------------------------------------------------
# live path: measured per-phase durations -> paper metrics
# ---------------------------------------------------------------------------

def latest_phase_durations(
    samples: Sequence, period: int
) -> List[Optional[float]]:
    """Most recent wall seconds per cycle phase from a sample trail
    (``Telemetry.samples()``).  No smoothing — this is the raw signal
    whose divergence leads the EMA by design."""
    out: List[Optional[float]] = [None] * period
    for s in samples:
        if 0 <= s.phase < period:
            out[s.phase] = s.wall_s
    return out


def phase_divergence(
    planned: Sequence[float], measured: Sequence[Optional[float]]
) -> Tuple[Optional[float], ...]:
    """Signed relative (measured - planned) / planned per phase."""
    out: List[Optional[float]] = []
    for p, m in zip(planned, measured):
        out.append(None if m is None else (m - p) / max(p, 1e-12))
    return tuple(out)


def bucket_divergence(
    schedule: DeftSchedule,
    divergence: Sequence[Optional[float]],
    ag_plan=None,
) -> Dict[int, float]:
    """Mean per-phase divergence over the phases in which each bucket
    communicates — 'which bucket's communication slipped' at cycle
    resolution.  Under the split item model (§12) a bucket participates
    both in the phases where its grad-sync item lands AND in the phases
    where ``ag_plan`` streams its all-gather item."""
    n = len(schedule.phases[0].route_new)
    ag_phases = set()
    if ag_plan is not None:
        ag_phases = {(i.bucket, i.phase) for i in ag_plan.items}
    out: Dict[int, float] = {}
    for b in range(n):
        ds = [
            d
            for t, (ph, d) in enumerate(zip(schedule.phases, divergence))
            if d is not None
            and (ph.sync_cur[b] or ph.route_new[b] == "sync"
                 or (b, t) in ag_phases)
        ]
        if ds:
            out[b] = sum(ds) / len(ds)
    return out


@dataclasses.dataclass(frozen=True)
class Attribution:
    """The live report: paper metrics measured against the plan."""

    period: int
    planned_cr: float
    measured_cr: float               # CR at the calibrated scales
    comp_scale: float
    comm_scale: float
    residual: float                  # rms calibration residual, seconds
    planned_phase_s: Tuple[float, ...]
    measured_phase_s: Tuple[Optional[float], ...]
    divergence: Tuple[Optional[float], ...]      # per phase, signed
    per_bucket_divergence: Dict[int, float]
    iteration_time: float            # simulated at calibrated scales
    bubble_fraction: float
    per_bucket_bubble: Dict[int, float]          # exposed s/iter
    capacity_utilization: Dict[str, float]       # knapsack fill per link

    @property
    def max_divergence(self) -> float:
        """Largest absolute per-phase divergence (0 when unmeasured)."""
        return max((abs(d) for d in self.divergence if d is not None),
                   default=0.0)

    @property
    def cr_error(self) -> float:
        """Relative measured-vs-planned coverage-rate error."""
        return abs(self.measured_cr - self.planned_cr) / max(
            self.planned_cr, 1e-12
        )


def attribute(
    measured: Sequence[Optional[float]],
    times: BucketTimes,
    scfg: SchedulerConfig,
    schedule: DeftSchedule,
    ag_plan=None,
) -> Attribution:
    """Align measured per-phase durations against the plan.

    ``measured[p]`` is the observed wall seconds of cycle phase ``p``
    (EMA or latest-sample; ``None`` where unobserved); ``times``/``scfg``
    are the *planned* profile the installed ``schedule`` was solved
    from.  Fits the calibration scales, then re-runs the timeline
    simulator at those scales to express the measurement in the paper's
    metrics.

    Decoupled plans (§12) pass their ``AgStreamPlan``: the calibrated
    re-simulation then streams the AG items (stall semantics) and the
    per-bucket divergence attributes slip to AG phases as well — with
    ``times`` being the RS-side profile the schedule was solved on.
    """
    period = schedule.period
    planned = planned_phase_durations(times, scfg, period)
    div = phase_divergence(planned, measured)
    a, b, resid = fit_scales(times, scfg, period, measured)
    run_times = scale_times(times, a, b)

    ag_kw = {}
    if ag_plan is not None and ag_plan.items:
        durs = [0.0] * times.n
        links_ = [0] * times.n
        t0 = ag_plan.items[0].phase
        for item in ag_plan.items_for_phase(t0):
            durs[item.bucket] = item.duration * b   # comm-scale calibrated
            links_[item.bucket] = item.link
        ag_kw = dict(ag_times=tuple(durs), ag_links=tuple(links_))
    plans = schedule_plans(times, scfg, horizon=fit_horizon(period))
    sim = simulate_deft(
        run_times, plans, mu=scfg.mu,
        heterogeneous=scfg.heterogeneous, keep_timeline=True,
        link_models=scfg.link_models, **ag_kw,
    )
    # with per-link LinkModels (§14) the wall-to-nominal conversion for
    # secondary spans uses the models' bandwidth ratio, not the scalar mu
    mu_eff = (effective_mu(scfg.models())
              if scfg.link_models is not None else scfg.mu)
    m = sim_metrics_from_spans(
        spans_from_sim(sim), mu=mu_eff, warm=max(2, len(plans) // 4)
    )

    # knapsack capacities per iteration (scheduler._caps semantics, in
    # nominal comm seconds): primary gets compute * capacity_factor,
    # secondary the same over mu; utilization = nominal comm scheduled
    # into the window / capacity.
    cap_p = m.compute_time * scfg.capacity_factor
    util: Dict[str, float] = {}
    if cap_p > 0:
        busy0 = m.link_busy_per_iter.get(0, 0.0)
        util["link0"] = busy0 / cap_p
        if scfg.heterogeneous:
            busy1 = m.link_busy_per_iter.get(1, 0.0) / max(mu_eff, 1e-12)
            util["link1"] = busy1 / (cap_p / mu_eff)

    return Attribution(
        period=period,
        planned_cr=times.coverage_rate,
        measured_cr=run_times.coverage_rate,
        comp_scale=a,
        comm_scale=b,
        residual=resid,
        planned_phase_s=planned,
        measured_phase_s=tuple(measured[:period]),
        divergence=div,
        per_bucket_divergence=bucket_divergence(schedule, div,
                                                ag_plan=ag_plan),
        iteration_time=m.iteration_time,
        bubble_fraction=m.bubble_fraction,
        per_bucket_bubble=m.per_bucket_bubble,
        capacity_utilization=util,
    )


def measured_phase_durations_from_trace(
    tracer: Tracer, period: int
) -> List[Optional[float]]:
    """Mean per-cycle-phase duration of recorded ``phase`` spans,
    excluding first-dispatch spans (``first`` tag — compile pollution)."""
    acc: Dict[int, List[float]] = {}
    for sp in tracer.spans("phase"):
        if sp.phase is None or not 0 <= sp.phase < period:
            continue
        if sp.args.get("first"):
            continue
        acc.setdefault(sp.phase, []).append(sp.duration)
    return [
        (sum(acc[p]) / len(acc[p])) if acc.get(p) else None
        for p in range(period)
    ]


def attribute_trace(
    tracer: Tracer,
    times: BucketTimes,
    scfg: SchedulerConfig,
    schedule: DeftSchedule,
) -> Attribution:
    """:func:`attribute` over the ``phase`` spans in a live trace."""
    measured = measured_phase_durations_from_trace(tracer, schedule.period)
    return attribute(measured, times, scfg, schedule)


# ---------------------------------------------------------------------------
# wire-byte attribution (§13): did the wire carry the bytes the plan priced?
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WireBytesReport:
    """Measured-vs-planned bytes on the wire per cycle phase.

    ``planned_per_phase`` is what the *current* plan prices (layout
    precision applied to each phase's synced buckets);
    ``measured_per_phase`` is what the executed collectives actually
    shipped, read back from the runtime's ``collective-group`` spans.
    The two diverge exactly when execution lags the plan — e.g. steps
    that ran on a stale layout while a precision hot-swap compiled —
    so ``ok`` is the end-to-end check that the policy the knapsack
    priced is the policy the wire carried.
    """

    period: int
    planned_per_phase: Tuple[int, ...]
    measured_per_phase: Tuple[Optional[float], ...]  # mean over cycles
    precisions: Tuple[Optional[str], ...]            # span wire tags
    # per-link split (§14): (primary, secondary) bytes per phase — did
    # the traffic the knapsack placed on each link actually ride it?
    # None when the runtime predates per-link spans or no split was
    # requested; unobserved phases are None entries in measured_split.
    planned_split: Optional[Tuple[Tuple[int, int], ...]] = None
    measured_split: Optional[
        Tuple[Optional[Tuple[float, float]], ...]
    ] = None

    @property
    def planned_per_cycle(self) -> int:
        return sum(self.planned_per_phase)

    @property
    def measured_per_cycle(self) -> float:
        """Observed bytes per cycle (unobserved phases assume plan)."""
        return sum(
            m if m is not None else float(p)
            for m, p in zip(self.measured_per_phase, self.planned_per_phase)
        )

    @property
    def max_abs_error(self) -> float:
        """Largest absolute measured-planned byte gap over phases."""
        return max(
            (abs(m - p) for m, p in
             zip(self.measured_per_phase, self.planned_per_phase)
             if m is not None),
            default=0.0,
        )

    @property
    def max_abs_split_error(self) -> float:
        """Largest per-link |measured - planned| byte gap over observed
        phases; 0 when no split was recorded."""
        if self.planned_split is None or self.measured_split is None:
            return 0.0
        return max(
            (max(abs(m[0] - p[0]), abs(m[1] - p[1]))
             for m, p in zip(self.measured_split, self.planned_split)
             if m is not None),
            default=0.0,
        )

    @property
    def ok(self) -> bool:
        """Every observed phase shipped exactly the planned bytes —
        in total AND per link when a split is recorded."""
        return self.max_abs_error == 0.0 and self.max_abs_split_error == 0.0


def wire_bytes_from_trace(
    tracer: Tracer, period: int
) -> Tuple[List[Optional[float]], List[Optional[str]]]:
    """Mean ``wire_bytes`` (and the wire tag) of the recorded
    ``collective-group`` spans per cycle phase.  First-dispatch spans
    are NOT excluded — byte counts are exact regardless of compile
    pollution; only durations need the ``first`` filter."""
    acc: Dict[int, List[float]] = {}
    tags: Dict[int, str] = {}
    for sp in tracer.spans("collective-group"):
        if sp.phase is None or not 0 <= sp.phase < period:
            continue
        wb = sp.args.get("wire_bytes")
        if wb is None:
            continue
        acc.setdefault(sp.phase, []).append(float(wb))
        tag = sp.args.get("precision")
        if tag is not None:
            tags[sp.phase] = tag
    measured = [
        (sum(acc[p]) / len(acc[p])) if acc.get(p) else None
        for p in range(period)
    ]
    return measured, [tags.get(p) for p in range(period)]


def link_wire_bytes_from_trace(
    tracer: Tracer, period: int
) -> List[Optional[Tuple[float, float]]]:
    """Mean (primary, secondary) wire bytes of the recorded
    ``collective-group`` spans per cycle phase (§14).  ``None`` for
    phases with no spans or spans from a runtime that predates the
    per-link attrs."""
    acc: Dict[int, List[Tuple[float, float]]] = {}
    for sp in tracer.spans("collective-group"):
        if sp.phase is None or not 0 <= sp.phase < period:
            continue
        wp = sp.args.get("wire_bytes_primary")
        ws = sp.args.get("wire_bytes_secondary")
        if wp is None or ws is None:
            continue
        acc.setdefault(sp.phase, []).append((float(wp), float(ws)))
    out: List[Optional[Tuple[float, float]]] = []
    for p in range(period):
        pairs = acc.get(p)
        if not pairs:
            out.append(None)
        else:
            out.append((
                sum(x for x, _ in pairs) / len(pairs),
                sum(y for _, y in pairs) / len(pairs),
            ))
    return out


def wire_bytes_report(
    tracer: Tracer,
    planned_per_phase: Sequence[int],
    planned_split: Optional[Sequence[Tuple[int, int]]] = None,
) -> WireBytesReport:
    """Compare a live trace's shipped bytes against the plan's pricing
    (``planned_per_phase`` — the runtime's per-phase wire-byte vector,
    ``DeftRuntime._wire_bytes_of_step``-shaped: one entry per cycle
    phase under the installed layout's precision).  Pass the runtime's
    ``wire_bytes_split_per_phase`` as ``planned_split`` to also check
    the per-link (primary, secondary) attribution (§14)."""
    period = len(planned_per_phase)
    measured, tags = wire_bytes_from_trace(tracer, period)
    m_split = (
        tuple(link_wire_bytes_from_trace(tracer, period))
        if planned_split is not None else None
    )
    return WireBytesReport(
        period=period,
        planned_per_phase=tuple(int(b) for b in planned_per_phase),
        measured_per_phase=tuple(measured),
        precisions=tuple(tags),
        planned_split=(
            tuple((int(p), int(s)) for p, s in planned_split)
            if planned_split is not None else None
        ),
        measured_split=m_split,
    )
