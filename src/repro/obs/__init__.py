"""Unified observability layer (DESIGN.md §11).

Three pieces, consumed by every other subsystem:

* :mod:`repro.obs.trace` — ring-buffer span recorder with an injectable
  monotonic clock and Chrome-trace (Perfetto-loadable) export;
* :mod:`repro.obs.metrics` — counters/gauges registry with JSONL export
  and a schema-pinned summary;
* :mod:`repro.obs.attribution` — turns raw spans back into the paper's
  own metrics (measured coverage rate, per-bucket bubble time, knapsack
  capacity utilization, predicted-vs-actual divergence per bucket);
* :mod:`repro.obs.events` — the one formatter every event surface
  (swap log, replan events, elastic faults/migrations) prints through.
"""
from repro.obs.trace import ManualClock, Span, SPAN_KINDS, Tracer
from repro.obs.metrics import Metrics, METRICS_SCHEMA_VERSION, validate_summary
from repro.obs.attribution import (
    Attribution,
    WireBytesReport,
    attribute,
    attribute_trace,
    bucket_divergence,
    latest_phase_durations,
    measured_phase_durations_from_trace,
    phase_divergence,
    sim_metrics_from_spans,
    spans_from_sim,
    timeline_bubbles,
    link_wire_bytes_from_trace,
    wire_bytes_from_trace,
    wire_bytes_report,
)
from repro.obs.events import format_event

__all__ = [
    "Attribution",
    "ManualClock",
    "Metrics",
    "METRICS_SCHEMA_VERSION",
    "Span",
    "SPAN_KINDS",
    "Tracer",
    "WireBytesReport",
    "attribute",
    "attribute_trace",
    "bucket_divergence",
    "format_event",
    "latest_phase_durations",
    "measured_phase_durations_from_trace",
    "phase_divergence",
    "sim_metrics_from_spans",
    "spans_from_sim",
    "timeline_bubbles",
    "validate_summary",
    "link_wire_bytes_from_trace",
    "wire_bytes_from_trace",
    "wire_bytes_report",
]
