"""Ring-buffer span recorder with an injectable clock (DESIGN.md §11).

Every layer of the runtime emits *spans* — ``(kind, name, t0, t1)`` plus
a small attribute dict — into one :class:`Tracer`.  Two properties make
it fit this codebase:

* **injectable monotonic clock**, same pattern as ``elastic/health.py``:
  the tracer never *requires* wall time.  Tests drive a
  :class:`ManualClock` and the resulting trace (and its Chrome-JSON
  export) is bit-for-bit reproducible; production uses
  ``time.perf_counter``.
* **bounded ring**: spans live in a ``deque(maxlen=capacity)``.  The
  recorder is allocation-light and can stay attached for the whole run;
  when the ring wraps, the oldest spans fall off and ``dropped`` counts
  them.  Control-plane events (swaps, replans, faults) are rare, so a
  ring sized for a few thousand step spans retains the full
  control-plane history of any realistic window.

Span kinds are a closed vocabulary (:data:`SPAN_KINDS`) so the
attribution pass and the Chrome export can assign stable tracks.
Export follows the Chrome trace-event format — complete (``"ph": "X"``)
duration events plus instant (``"ph": "i"``) events, timestamps in
microseconds — which Perfetto loads directly.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from collections import deque
from typing import Callable, Deque, Dict, Iterable, List, Optional, Sequence, Tuple

#: Closed span-kind vocabulary.  ``step``/``phase`` are the per-step
#: timing backbone; ``collective-group`` mirrors the fused collectives a
#: dispatched phase contains; the rest are control-plane events.
SPAN_KINDS: Tuple[str, ...] = (
    "step",              # one full train-loop step (driver-measured)
    "phase",             # one DeftRuntime.step dispatch (runtime-measured)
    "collective-group",  # the collectives fused into a dispatched phase
    "update-apply",      # optimizer-update positions in the cycle
    "gather-skip",       # phases dispatched with the gather-reuse mask
    "swap-install",      # pending schedule installed at a cycle boundary
    "swap-compile",      # prepare_swap compile work (maybe background)
    "repack",            # cross-layout state movement
    "replan",            # adaptive controller replan solve
    "elastic",           # health detection / arm / migrate lifecycle
    # simulator-derived kinds (attribution closure + explorer export)
    "compute",           # simulated compute op (F/B)
    "collective",        # simulated collective transmission
)

#: Default Chrome-export track per kind (pid 0, one tid per track).
_TRACKS: Tuple[str, ...] = (
    "steps", "phases", "collectives", "control", "elastic",
    "sim-compute", "sim-link0", "sim-link1",
)
_KIND_TRACK: Dict[str, str] = {
    "step": "steps",
    "phase": "phases",
    "collective-group": "collectives",
    "update-apply": "phases",
    "gather-skip": "phases",
    "swap-install": "control",
    "swap-compile": "control",
    "repack": "control",
    "replan": "control",
    "elastic": "elastic",
    "compute": "sim-compute",
    "collective": "sim-link0",
}


@dataclasses.dataclass(frozen=True)
class Span:
    """One recorded interval (``t1 == t0`` for instant events)."""

    kind: str
    name: str
    t0: float
    t1: float
    step: Optional[int] = None
    phase: Optional[int] = None
    track: Optional[str] = None
    attrs: Tuple[Tuple[str, object], ...] = ()

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    @property
    def args(self) -> Dict[str, object]:
        """Attribute dict view (attrs are stored as sorted tuples so
        spans stay hashable and exports stay deterministic)."""
        return dict(self.attrs)


class ManualClock:
    """Deterministic injectable clock: ``advance()`` is the only way
    time passes.  Mirrors the HealthMonitor's replayable-clock model."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def _freeze_attrs(attrs: Dict[str, object]) -> Tuple[Tuple[str, object], ...]:
    return tuple(sorted(attrs.items()))


class Tracer:
    """Bounded span recorder.

    ``clock`` is any zero-arg callable returning monotonic seconds;
    default is ``time.perf_counter``.  All record paths also accept
    explicit ``t0``/``t1`` so callers that already timed something
    (e.g. the runtime's dispatch stopwatch) don't sample twice.
    """

    def __init__(
        self,
        capacity: int = 65536,
        clock: Optional[Callable[[], float]] = None,
    ):
        if capacity <= 0:
            raise ValueError("tracer capacity must be positive")
        self.capacity = capacity
        self.clock = clock if clock is not None else time.perf_counter
        self._spans: Deque[Span] = deque(maxlen=capacity)
        self.dropped = 0
        self.n_recorded = 0

    # ---- recording ------------------------------------------------------
    def now(self) -> float:
        return self.clock()

    def add(
        self,
        kind: str,
        name: str,
        t0: float,
        t1: float,
        *,
        step: Optional[int] = None,
        phase: Optional[int] = None,
        track: Optional[str] = None,
        **attrs: object,
    ) -> Span:
        """Record a completed interval with explicit bounds."""
        if kind not in SPAN_KINDS:
            raise ValueError(f"unknown span kind {kind!r}")
        span = Span(
            kind, name, float(t0), float(t1),
            step=step, phase=phase, track=track,
            attrs=_freeze_attrs(attrs),
        )
        if len(self._spans) == self.capacity:
            self.dropped += 1
        self._spans.append(span)
        self.n_recorded += 1
        return span

    def instant(
        self,
        kind: str,
        name: str,
        *,
        t: Optional[float] = None,
        step: Optional[int] = None,
        phase: Optional[int] = None,
        track: Optional[str] = None,
        **attrs: object,
    ) -> Span:
        """Record a zero-duration event (``"ph": "i"`` in the export)."""
        at = self.now() if t is None else float(t)
        return self.add(
            kind, name, at, at, step=step, phase=phase, track=track, **attrs
        )

    @contextlib.contextmanager
    def span(
        self,
        kind: str,
        name: str,
        *,
        step: Optional[int] = None,
        phase: Optional[int] = None,
        track: Optional[str] = None,
        **attrs: object,
    ):
        """Context manager that measures the enclosed block with the
        tracer's clock.  The span is recorded even if the block raises."""
        t0 = self.now()
        try:
            yield
        finally:
            self.add(
                kind, name, t0, self.now(),
                step=step, phase=phase, track=track, **attrs,
            )

    # ---- queries --------------------------------------------------------
    def spans(
        self, kind: Optional[object] = None
    ) -> List[Span]:
        """Spans in record order; ``kind`` filters by one kind (str) or
        several (any iterable of str)."""
        if kind is None:
            return list(self._spans)
        kinds = {kind} if isinstance(kind, str) else set(kind)
        return [s for s in self._spans if s.kind in kinds]

    def __len__(self) -> int:
        return len(self._spans)

    def clear(self) -> None:
        self._spans.clear()
        self.dropped = 0

    def stats(self) -> dict:
        by_kind: Dict[str, int] = {}
        for s in self._spans:
            by_kind[s.kind] = by_kind.get(s.kind, 0) + 1
        return {
            "capacity": self.capacity,
            "recorded": self.n_recorded,
            "retained": len(self._spans),
            "dropped": self.dropped,
            "by_kind": by_kind,
        }

    # ---- Chrome / Perfetto export ---------------------------------------
    def chrome_trace(self, extra: Optional[dict] = None) -> dict:
        """Chrome trace-event JSON object (``{"traceEvents": [...]}``).

        Seconds become microseconds; each logical track gets its own
        ``tid`` under ``pid`` 0 with a ``thread_name`` metadata event, so
        Perfetto renders steps / phases / collectives / control-plane /
        elastic lanes separately.  Deterministic for a deterministic
        clock: track ids follow the canonical :data:`_TRACKS` order (then
        first-use order for custom tracks) and attrs are pre-sorted.
        """
        tids: Dict[str, int] = {}

        def tid_of(track: str) -> int:
            if track not in tids:
                tids[track] = len(tids)
            return tids[track]

        used = {s.track or _KIND_TRACK.get(s.kind, "control")
                for s in self._spans}
        for t in _TRACKS:
            if t in used:
                tid_of(t)

        events: List[dict] = []
        for s in self._spans:
            track = s.track or _KIND_TRACK.get(s.kind, "control")
            ev: Dict[str, object] = {
                "name": s.name,
                "cat": s.kind,
                "pid": 0,
                "tid": tid_of(track),
                "ts": s.t0 * 1e6,
            }
            if s.t1 > s.t0:
                ev["ph"] = "X"
                ev["dur"] = (s.t1 - s.t0) * 1e6
            else:
                ev["ph"] = "i"
                ev["s"] = "t"  # instant scoped to its thread/track
            args: Dict[str, object] = dict(s.attrs)
            if s.step is not None:
                args["step"] = s.step
            if s.phase is not None:
                args["phase"] = s.phase
            if args:
                ev["args"] = args
            events.append(ev)
        meta = [
            {
                "name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
                "args": {"name": track},
            }
            for track, tid in sorted(tids.items(), key=lambda kv: kv[1])
        ]
        out = {
            "traceEvents": meta + events,
            "displayTimeUnit": "ms",
            "otherData": {"dropped_spans": self.dropped},
        }
        if extra:
            out["otherData"].update(extra)
        return out

    def export_chrome_trace(
        self, path: str, extra: Optional[dict] = None
    ) -> str:
        """Serialize :meth:`chrome_trace` to ``path``.  ``sort_keys``
        plus pre-sorted attrs make the bytes reproducible under an
        injected clock (the trace-replay bit-match test relies on it)."""
        payload = json.dumps(
            self.chrome_trace(extra), sort_keys=True, separators=(",", ":")
        )
        with open(path, "w") as f:
            f.write(payload)
        return payload
