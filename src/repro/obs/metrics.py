"""Counters/gauges registry with JSONL export (DESIGN.md §11).

Deliberately tiny: a :class:`Metrics` instance is a pair of flat dicts.
Counters only go up (``inc``); gauges hold the latest value (``set``).
Snapshots are appended to a JSONL file one schema-versioned line at a
time, so long runs stream their metric history without ever holding it
in memory, and the final :meth:`summary` is the schema-pinned payload
benchmarks and the launch driver print/persist.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

#: Bump when the summary/JSONL line layout changes shape.
METRICS_SCHEMA_VERSION = 1

#: Keys every summary / JSONL line carries, in this shape.
SUMMARY_KEYS = ("schema", "counters", "gauges")


class Metrics:
    """Flat counters + gauges with schema-pinned export."""

    def __init__(self) -> None:
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}

    # ---- recording ------------------------------------------------------
    def inc(self, name: str, by: float = 1) -> float:
        v = self._counters.get(name, 0) + by
        self._counters[name] = v
        return v

    def set(self, name: str, value: float) -> None:
        self._gauges[name] = float(value)

    # ---- queries --------------------------------------------------------
    def counter(self, name: str) -> float:
        return self._counters.get(name, 0)

    def gauge(self, name: str) -> Optional[float]:
        return self._gauges.get(name)

    def summary(self) -> dict:
        """Schema-pinned snapshot: exactly :data:`SUMMARY_KEYS`."""
        return {
            "schema": METRICS_SCHEMA_VERSION,
            "counters": dict(sorted(self._counters.items())),
            "gauges": dict(sorted(self._gauges.items())),
        }

    # ---- export ---------------------------------------------------------
    def export_jsonl(self, path: str, extra: Optional[dict] = None) -> str:
        """Append one summary line to ``path``; returns the line."""
        payload = self.summary()
        if extra:
            payload = {**payload, "extra": extra}
        line = json.dumps(payload, sort_keys=True, separators=(",", ":"))
        with open(path, "a") as f:
            f.write(line + "\n")
        return line


def validate_summary(payload: dict) -> None:
    """Raise ``ValueError`` unless ``payload`` is a valid summary."""
    missing = [k for k in SUMMARY_KEYS if k not in payload]
    if missing:
        raise ValueError(f"metrics summary missing keys: {missing}")
    if payload["schema"] != METRICS_SCHEMA_VERSION:
        raise ValueError(
            f"metrics schema {payload['schema']} != {METRICS_SCHEMA_VERSION}"
        )
    for k in ("counters", "gauges"):
        if not isinstance(payload[k], dict):
            raise ValueError(f"metrics summary {k!r} must be a mapping")
