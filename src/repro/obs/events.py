"""One formatter for every control-plane event surface (DESIGN.md §11).

Before this module each surface printed its own shape: the runtime's
``swap_log`` dicts, ``ReplanEvent.describe()``, ``FaultEvent.describe()``
and the elastic coordinator's migration dicts.  :func:`format_event`
accepts any of them (plus raw :class:`~repro.obs.trace.Span`\\ s) and
emits one aligned line ``<surface>  step NNNNN  <detail>``, so the
launch driver and ``schedule_explorer`` print replan, elastic, swap and
repack events uniformly.
"""
from __future__ import annotations

from typing import Optional

from repro.obs.trace import Span


def _step(ev: dict) -> str:
    s = ev.get("step")
    return f"step {s:5d}" if isinstance(s, int) else "step     -"


def _fmt_swap(ev: dict) -> str:
    kind = ev.get("event")
    if kind == "swap-compile-failed":
        retry = "retrying" if ev.get("retrying") else "giving up"
        return (f"swap     {_step(ev)}  compile-failed attempt "
                f"{ev.get('attempt', '?')} ({retry}): {ev.get('error')}")
    if kind == "swap-abandoned":
        sup = " superseded" if ev.get("superseded") else ""
        return (f"swap     {_step(ev)}  ABANDONED after "
                f"{ev.get('attempts', '?')} attempts "
                f"({ev.get('elapsed_s', 0.0):.2f}s){sup}: {ev.get('error')}")
    # swap install entry (no 'event' key)
    out = (f"swap     {_step(ev)}  installed period={ev.get('period')} "
           f"updates/period={ev.get('updates_per_period')} "
           f"buckets={ev.get('n_buckets')} shards={ev.get('shards')}")
    if ev.get("repack_s") is not None:
        out += f"  repack {ev['repack_s'] * 1e3:.0f} ms"
    return out


def _fmt_elastic(ev: dict) -> str:
    action = ev.get("action", "?")
    if action == "checkpoint-halt":
        return (f"elastic  {_step(ev)}  checkpoint-halt "
                f"(trigger {ev.get('trigger')}, detected at step "
                f"{ev.get('detected_step')}) -> {ev.get('checkpoint')}")
    out = (f"elastic  {_step(ev)}  {action} "
           f"{ev.get('old_shards')}->{ev.get('new_shards')} shards "
           f"(trigger {ev.get('trigger')}, detected at step "
           f"{ev.get('detected_step')})  period "
           f"{ev.get('old_period')}->{ev.get('new_period')}")
    if ev.get("migrate_s") is not None:
        out += f"  migrate {ev['migrate_s'] * 1e3:.0f} ms"
    if ev.get("repack_s") is not None:
        out += f"  repack {ev['repack_s'] * 1e3:.0f} ms"
    return out


def _fmt_span(sp: Span) -> str:
    step = f"step {sp.step:5d}" if sp.step is not None else "step     -"
    dur = f"  {sp.duration * 1e3:.2f} ms" if sp.t1 > sp.t0 else ""
    args = sp.args
    extras = " ".join(
        f"{k}={v}" for k, v in sorted(args.items()) if k not in ("detail",)
    )
    return (f"{sp.kind:<8s} {step}  {sp.name}{dur}"
            + (f"  [{extras}]" if extras else ""))


def format_event(ev: object) -> str:
    """Format any control-plane event object into one aligned line."""
    # late imports keep obs importable without the adapt/elastic stacks
    try:
        from repro.adapt.controller import ReplanEvent
    except Exception:                                 # pragma: no cover
        ReplanEvent = ()                              # type: ignore
    try:
        from repro.elastic.health import FaultEvent
    except Exception:                                 # pragma: no cover
        FaultEvent = ()                               # type: ignore

    if ReplanEvent and isinstance(ev, ReplanEvent):
        return f"adapt    {ev.describe()}"
    if FaultEvent and isinstance(ev, FaultEvent):
        return f"elastic  {ev.describe()}"
    if isinstance(ev, Span):
        return _fmt_span(ev)
    if isinstance(ev, dict):
        if "action" in ev:
            return _fmt_elastic(ev)
        return _fmt_swap(ev)
    return f"event    {ev!r}"
