"""Small shared utilities."""
from repro.util.flags import scan_unroll_enabled, unroll_scans

__all__ = ["unroll_scans", "scan_unroll_enabled"]
