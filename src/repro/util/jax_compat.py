"""Run the new-jax (>= 0.6) API surface this codebase uses on older jax.

The container pins jax 0.4.37, which predates several names the runtime
and tests rely on:

* ``jax.sharding.AxisType`` and the ``axis_types=`` kwarg of
  ``jax.make_mesh`` / ``jax.sharding.AbstractMesh``
* ``jax.set_mesh`` (context manager form)
* ``jax.sharding.get_abstract_mesh`` / ``use_abstract_mesh``
* ``jax.shard_map`` (top-level, with ``axis_names=``/``check_vma=``)

``install()`` backfills those names onto the jax namespace with thin
adapters over the 0.4.x equivalents (``Mesh`` context manager,
``jax.experimental.shard_map`` with ``auto=``/``check_rep=``).  On a jax
that already provides a name natively the shim leaves it untouched, so
the same code runs on both versions.  ``repro/__init__.py`` calls
``install()``, which makes every ``import repro.<anything>`` sufficient
to activate the shims — including for test modules and subprocess
scripts that touch ``jax.sharding.AxisType`` directly.
"""
from __future__ import annotations

import contextlib
import enum
import functools
import inspect
import threading

import jax

_state = threading.local()

# jaxlib < 0.5 hard-CHECKs in the SPMD partitioner (hlo_sharding_util /
# spmd_partitioner ``IsManualSubgroup``) when a *partial-manual*
# shard_map region (auto axes present) contains tiled psum_scatter /
# all_gather collectives on a real multi-device mesh; plain psum is
# fine.  Callers gate the hierarchical reduce-scatter -> all-gather
# secondary-link sync on this and fall back to a numerically identical
# all-reduce (the hierarchy is a perf shaping, not semantics).
_V = tuple(int(x) for x in jax.__version__.split(".")[:2])
HIERARCHICAL_COLLECTIVES_OK = _V >= (0, 5)

# Pallas bucket-update kernels (kernels/bucket_update).  The kernels
# themselves use only the post-0.4.31 BlockSpec convention, which the
# pinned 0.4.37 provides, so the kernel gate is a backend question
# (TPU vs CPU — resolved at dispatch in bucket_update/ops.py, composing
# with the same fallback philosophy as the collectives gate above).
# ``input_output_aliases`` *inside* a shard_map partial-manual region is
# the one piece old jaxlib mishandles (same SPMD-partitioner vintage as
# the hierarchical-collectives CHECK) — gate it; without the in-kernel
# alias the jit-level donation still reuses the buffers, only the XLA
# copy-elision hint is lost.
PALLAS_BUCKET_ALIAS_OK = _V >= (0, 5)


def _ambient_abstract():
    return getattr(_state, "abstract_mesh", None)


def _physical_mesh():
    """The mesh of an enclosing ``with mesh:`` / ``jax.set_mesh(mesh)``."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover - private-API drift
        return None


def install() -> None:
    """Idempotently backfill new-jax names onto the jax namespace."""
    if getattr(jax, "_repro_compat_installed", False):
        return
    jax._repro_compat_installed = True

    # ---- jax.sharding.AxisType ------------------------------------------
    if not hasattr(jax.sharding, "AxisType"):

        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    # ---- jax.make_mesh(..., axis_types=...) -----------------------------
    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _orig_make_mesh = jax.make_mesh

        @functools.wraps(_orig_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            del axis_types  # 0.4.x meshes are implicitly all-Auto
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    # ---- jax.sharding.AbstractMesh(sizes, names, axis_types=...) --------
    try:
        jax.sharding.AbstractMesh((1,), ("x",))
        new_style_abstract = True
    except Exception:
        new_style_abstract = False
    if not new_style_abstract:
        _OldAbstract = jax.sharding.AbstractMesh

        def AbstractMesh(axis_sizes, axis_names=None, *, axis_types=None):
            del axis_types
            if axis_names is None:  # old-style (('name', size), ...) call
                return _OldAbstract(tuple(axis_sizes))
            return _OldAbstract(tuple(zip(axis_names, axis_sizes)))

        jax.sharding.AbstractMesh = AbstractMesh

    # ---- ambient mesh: set_mesh / get_abstract_mesh / use_abstract_mesh -
    if not hasattr(jax.sharding, "get_abstract_mesh"):

        def get_abstract_mesh():
            return _ambient_abstract() or _physical_mesh()

        jax.sharding.get_abstract_mesh = get_abstract_mesh

    if not hasattr(jax.sharding, "use_abstract_mesh"):

        @contextlib.contextmanager
        def use_abstract_mesh(mesh):
            prev = _ambient_abstract()
            _state.abstract_mesh = mesh
            try:
                yield mesh
            finally:
                _state.abstract_mesh = prev

        jax.sharding.use_abstract_mesh = use_abstract_mesh

    if not hasattr(jax, "set_mesh"):

        @contextlib.contextmanager
        def set_mesh(mesh):
            # The Mesh context manager provides what set_mesh gives newer
            # jax: bare-PartitionSpec with_sharding_constraint resolution
            # and an ambient mesh for get_abstract_mesh().
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh

    # ---- jax.shard_map --------------------------------------------------
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(
            f,
            *,
            mesh=None,
            in_specs=None,
            out_specs=None,
            axis_names=None,
            check_vma=True,
        ):
            if mesh is None:
                mesh = jax.sharding.get_abstract_mesh()
            if axis_names is None:
                auto = frozenset()
            else:
                auto = frozenset(mesh.axis_names) - frozenset(axis_names)
            return _shard_map(
                f,
                mesh=mesh,
                in_specs=in_specs,
                out_specs=out_specs,
                check_rep=bool(check_vma),
                auto=auto,
            )

        jax.shard_map = shard_map
