"""Trace-time flags.

``unroll_scans``: while active, every internal ``lax.scan`` (layer stack,
chunked CE, blocked-attention kv/q loops) fully unrolls.  XLA's
cost_analysis counts a while-loop body ONCE regardless of trip count, so
the dry-run lowers two small unrolled variants under this flag to get
exact per-period costs and extrapolates to the full depth (see
launch/dryrun.py).  Sequence-length recurrences (RWKV) deliberately
ignore the flag — unrolling 4k+ steps is intractable and their per-token
state update is <3% of layer FLOPs (noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import contextlib
import contextvars

_unroll = contextvars.ContextVar("repro_unroll_scans", default=False)
_sharded_decode = contextvars.ContextVar("repro_sharded_decode", default=False)


def scan_unroll_enabled() -> bool:
    return _unroll.get()


@contextlib.contextmanager
def unroll_scans(on: bool = True):
    tok = _unroll.set(on)
    try:
        yield
    finally:
        _unroll.reset(tok)


def sharded_decode_enabled() -> bool:
    return _sharded_decode.get()


@contextlib.contextmanager
def sharded_decode(on: bool = True):
    """Beyond-paper §Perf optimization: decode attention over a sequence-
    sharded KV cache runs as an explicit shard_map distributed softmax
    (partial max/sum psums over 'model') instead of letting the SPMD
    partitioner all-gather the cache (72 GiB/step at qwen3-4b decode_32k)."""
    tok = _sharded_decode.set(on)
    try:
        yield
    finally:
        _sharded_decode.reset(tok)
