"""Fault-tolerant elastic control plane (DESIGN.md §10).

The adaptive loop (repro.adapt) re-plans when the hardware gets
*slower*; this layer re-plans when the hardware gets *smaller*: per-shard
health monitoring detects stragglers and dead/preempted devices, an
:class:`ElasticController` prices the surviving mesh through the same
calibrated ``LeafTimeModel`` / :meth:`~repro.core.deft.Planner.plan`
(candidate grid) / Preserver path, and the :class:`ElasticCoordinator` executes the
cycle-boundary ``repack_state`` scale-down (and symmetric scale-up) with
zero restart.  Every recovery path replays deterministically through
:class:`FaultScenario`.
"""
from repro.elastic.controller import (
    ElasticConfig,
    ElasticController,
    ElasticPlan,
)
from repro.elastic.coordinator import (
    ElasticCoordinator,
    ElasticHalt,
    fold_accum_rows,
    migrate_state,
)
from repro.elastic.faults import (
    BandwidthCollapse,
    CapacityReturn,
    DeviceDrop,
    FaultScenario,
    KillMidCheckpoint,
    PreemptionNotice,
    ShardObservation,
    StragglerSlowdown,
    truncate_checkpoint,
)
from repro.elastic.health import FaultEvent, HealthConfig, HealthMonitor

__all__ = [
    "BandwidthCollapse",
    "CapacityReturn",
    "DeviceDrop",
    "ElasticConfig",
    "ElasticController",
    "ElasticCoordinator",
    "ElasticHalt",
    "ElasticPlan",
    "FaultEvent",
    "FaultScenario",
    "HealthConfig",
    "HealthMonitor",
    "KillMidCheckpoint",
    "PreemptionNotice",
    "ShardObservation",
    "StragglerSlowdown",
    "fold_accum_rows",
    "migrate_state",
    "truncate_checkpoint",
]
