"""Elastic replanning: price a proposed mesh, gate it, choose the engine.

On a detected fault the coordinator asks this controller for an
:class:`ElasticPlan`: "the job now has ``n_shards`` data-parallel shards
— what schedule (and which engine) should it run?".  The answer reuses
the adaptive stack end-to-end:

* a :class:`~repro.train.bucketing.LeafTimeModel` **per candidate mesh
  width** (``model_for(n)``) re-prices every bucket under the surviving
  hardware — the ring allreduce factor changes with ``n`` and the
  per-device batch grows as the global batch stays constant;
* the current partition (and, optionally, a
  :class:`~repro.adapt.repartition.Repartitioner` grid over the new
  width) competes through
  :meth:`repro.core.deft.Planner.plan` (candidate grid), every candidate
  **Preserver-gated** exactly like an adaptive repartition;
* cumulative calibrated drift scales (:meth:`set_calibration`) carry
  over from the adaptive controller, so a mesh change planned mid-drift
  prices candidates at the world as measured, not as modeled.

The degradation ladder lives here too (DESIGN.md §10): ``n_shards >=
min_sharded_shards`` keeps the sharded flat engine (scale-down /
scale-up), smaller-but-positive falls back to the replicated flat engine
(``sharded=False`` — a 1-shard ZeRO layout would shard nothing and the
replicated engine skips the gather machinery entirely), and ``n_shards
<= 0`` yields ``checkpoint-halt`` (nothing left to run on — emergency
checkpoint + clean resume is the only move).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Tuple

from repro.core.bucket import BucketTimes
from repro.core.deft import Planner, PlanRequest
from repro.core.preserver import PreserverVerdict, WalkParams
from repro.core.scheduler import DeftSchedule, SchedulerConfig
from repro.train.bucketing import LeafTimeModel


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Elastic-plan knobs (DESIGN.md §10)."""

    # below this many surviving shards the sharded flat engine degrades
    # to the replicated flat engine
    min_sharded_shards: int = 2
    # Preserver feedback loop (mirrors AdaptConfig)
    eps: float = 0.01
    max_retries: int = 10
    capacity_growth: float = 1.2
    # survival moves get no switch hysteresis: the old mesh is GONE, so
    # "keep the current plan" is not on the table (contrast
    # RepartitionConfig.min_gain for voluntary repartitions)
    min_gain: float = 0.0
    # optional repartition grid per candidate mesh (empty = keep the
    # installed partition, only re-solve the schedule)
    repartition_factors: Tuple[float, ...] = ()
    base_partition_elems: int = 0


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """One gated mesh-change decision, executable by the coordinator."""

    step: int
    trigger: str          # 'dead' | 'straggler' | 'preemption' | 'scale-up'
    action: str           # 'scale-down' | 'scale-up' |
    #                     # 'fallback-replicated' | 'checkpoint-halt'
    n_shards: int         # surviving data-parallel width (0 = none)
    sharded: bool         # engine: sharded flat (True) or replicated flat
    bucket_of: Tuple[int, ...] = ()
    n_buckets: int = 0
    schedule: Optional[DeftSchedule] = None
    scheduler_cfg: Optional[SchedulerConfig] = None
    verdict: Optional[PreserverVerdict] = None
    times: Optional[BucketTimes] = None
    candidate_solves: Tuple = ()
    plan_s: float = 0.0

    def describe(self) -> str:
        if self.action == "checkpoint-halt":
            return (f"step {self.step:5d}  {self.trigger:<10s} -> "
                    f"checkpoint-halt (no survivors)")
        return (
            f"step {self.step:5d}  {self.trigger:<10s} -> {self.action} "
            f"to {self.n_shards} shard(s) "
            f"[{'sharded' if self.sharded else 'replicated'} engine]  "
            f"period={self.schedule.period} "
            f"k-seq={self.schedule.batch_size_sequence}  "
            f"preserver ratio={self.verdict.ratio:.4f} "
            f"ok={self.verdict.ok}  ({self.plan_s * 1e3:.0f} ms)"
        )


class ElasticController:
    """Owns the installed partition + walk and prices mesh changes.

    ``model_for(n)`` returns the :class:`LeafTimeModel` of this job at
    data-parallel width ``n`` (the coordinator builds it from the arch
    config + hardware model; memoized here — fault handling must not
    re-derive timing atoms on every proposal).
    """

    def __init__(
        self,
        model_for: Callable[[int], LeafTimeModel],
        bucket_of: Tuple[int, ...],
        n_buckets: int,
        *,
        walk: Optional[WalkParams] = None,
        scheduler_cfg: Optional[SchedulerConfig] = None,
        cfg: Optional[ElasticConfig] = None,
    ):
        self.cfg = cfg or ElasticConfig()
        self._model_for = model_for
        self._models: Dict[int, LeafTimeModel] = {}
        self.bucket_of = tuple(bucket_of)
        self.n_buckets = n_buckets
        self.walk = walk or WalkParams(
            s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256
        )
        self.scheduler_cfg = scheduler_cfg or SchedulerConfig()
        # all repack solves route through the unified Planner facade
        self.planner = Planner()
        self._comp_scale = 1.0
        self._comm_scale = 1.0
        self.plans: list = []

    # ---- calibration hand-off -------------------------------------------
    def set_calibration(self, comp_scale: float, comm_scale: float) -> None:
        """Adopt the adaptive controller's cumulative calibrated drift so
        survival plans are priced at measured, not modeled, hardware."""
        self._comp_scale = comp_scale
        self._comm_scale = comm_scale

    def _model(self, n_shards: int) -> LeafTimeModel:
        if n_shards not in self._models:
            self._models[n_shards] = self._model_for(n_shards)
        return self._models[n_shards]

    # ---- planning --------------------------------------------------------
    def propose(self, step: int, n_shards: int, trigger: str) -> ElasticPlan:
        """Plan the move to ``n_shards`` surviving shards.  Always
        returns a plan — worst case ``checkpoint-halt``.  The schedule
        is Preserver-gated through the capacity feedback retries; like
        the capacity feedback loop, an exhausted retry budget yields the
        best-effort schedule with ``verdict.ok=False`` recorded."""
        t0 = time.perf_counter()
        if n_shards <= 0:
            plan = ElasticPlan(
                step=step, trigger=trigger, action="checkpoint-halt",
                n_shards=0, sharded=False,
            )
            self.plans.append(plan)
            return plan
        sharded = n_shards >= self.cfg.min_sharded_shards
        model = self._model(n_shards)
        pairs = [(
            "current",
            model.bucket_times(
                self.bucket_of, self.n_buckets,
                comp_scale=self._comp_scale, comm_scale=self._comm_scale,
            ),
        )]
        cands = {"current": (self.bucket_of, self.n_buckets)}
        if self.cfg.repartition_factors and self.cfg.base_partition_elems:
            from repro.adapt.repartition import (
                RepartitionConfig,
                Repartitioner,
            )

            rp = Repartitioner(model, RepartitionConfig(
                base_partition_elems=self.cfg.base_partition_elems,
                factors=self.cfg.repartition_factors,
                min_gain=self.cfg.min_gain,
            ))
            for c in rp.candidates(
                self.bucket_of, self.n_buckets,
                comp_scale=self._comp_scale, comm_scale=self._comm_scale,
            ):
                if c.tag == "current":
                    continue
                cands[c.tag] = (c.bucket_of, c.n_buckets)
                pairs.append((c.tag, rp.times_for(
                    c,
                    comp_scale=self._comp_scale,
                    comm_scale=self._comm_scale,
                )))
        res = self.planner.plan(PlanRequest(
            candidates=tuple(pairs),
            walk=self.walk,
            baseline_tag="current",
            min_gain=self.cfg.min_gain,
            heterogeneous=self.scheduler_cfg.heterogeneous,
            mu=self.scheduler_cfg.mu,
            eps=self.cfg.eps,
            max_retries=self.cfg.max_retries,
            capacity_growth=self.cfg.capacity_growth,
        ))
        solves = res.candidates
        best = next(s for s in solves if s.tag == res.winner_tag)
        bucket_of, n_buckets = cands[best.tag]
        if trigger == "scale-up":
            action = "scale-up"
        elif sharded:
            action = "scale-down"
        else:
            action = "fallback-replicated"
        plan = ElasticPlan(
            step=step, trigger=trigger, action=action,
            n_shards=n_shards, sharded=sharded,
            bucket_of=tuple(bucket_of), n_buckets=n_buckets,
            schedule=best.schedule, scheduler_cfg=best.scheduler_cfg,
            verdict=best.verdict, times=best.times,
            candidate_solves=solves,
            plan_s=time.perf_counter() - t0,
        )
        self.plans.append(plan)
        return plan

    def adopt(self, plan: ElasticPlan) -> None:
        """The coordinator executed ``plan`` — its partition becomes the
        installed one future proposals price 'current' against."""
        if plan.action == "checkpoint-halt":
            return
        self.bucket_of = tuple(plan.bucket_of)
        self.n_buckets = plan.n_buckets
        self.scheduler_cfg = plan.scheduler_cfg
