"""Deterministic fault injection (DESIGN.md §10).

A :class:`FaultScenario` is a frozen, pure-Python transform from a
*base* per-step observation (whole-job wall seconds + collective
seconds, typically from :class:`repro.adapt.scenario.SyntheticTelemetrySource`
or a constant) to the per-shard observation the
:class:`~repro.elastic.health.HealthMonitor` would have seen under the
injected faults.  Because it is a pure function of the step index, every
recovery path replays bit-for-bit — the chaos tests and
``benchmarks/elastic_bench.py`` drive the identical scenario objects.

Fault types:

* :class:`DeviceDrop` — shards vanish at a step: no heartbeat, ever
  (until a :class:`CapacityReturn` brings them back).
* :class:`StragglerSlowdown` — one shard's wall time multiplies by
  ``factor`` over a step window.
* :class:`BandwidthCollapse` — every shard's collective time multiplies
  by ``comm_scale`` (uniform: a *drift*, not a device fault).
* :class:`PreemptionNotice` — the explicit advance warning a cluster
  manager sends; surfaces in the observation so the driver can forward
  it to :meth:`HealthMonitor.notice_preemption`.
* :class:`CapacityReturn` — previously dropped/preempted shards come
  back (the scale-up trigger).
* :class:`KillMidCheckpoint` — the writer dies mid-save at a step;
  :func:`truncate_checkpoint` applies the damage to the newest
  checkpoint file so resume tests exercise the atomicity guarantees.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class DeviceDrop:
    """``shards`` produce no heartbeat from ``step`` on."""

    step: int
    shards: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class StragglerSlowdown:
    """``shard`` runs ``factor``x slower over [step, end_step)
    (``end_step=0`` = forever)."""

    step: int
    shard: int
    factor: float
    end_step: int = 0

    def active(self, step: int) -> bool:
        return step >= self.step and (
            self.end_step == 0 or step < self.end_step
        )


@dataclasses.dataclass(frozen=True)
class BandwidthCollapse:
    """Every shard's collective time multiplies by ``comm_scale`` over
    [step, end_step) — uniform, so the monitor must NOT call it a
    straggler; it surfaces as an informational ``bandwidth`` event."""

    step: int
    comm_scale: float
    end_step: int = 0

    def active(self, step: int) -> bool:
        return step >= self.step and (
            self.end_step == 0 or step < self.end_step
        )


@dataclasses.dataclass(frozen=True)
class PreemptionNotice:
    """The cluster manager announces ``shards`` will be reclaimed."""

    step: int
    shards: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class CapacityReturn:
    """``shards`` (previously dropped or preempted) become usable again."""

    step: int
    shards: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class KillMidCheckpoint:
    """The process dies mid-checkpoint-write at ``step``, leaving
    ``keep_bytes`` of the npz on disk (see :func:`truncate_checkpoint`)."""

    step: int
    keep_bytes: int = 96


@dataclasses.dataclass(frozen=True)
class ShardObservation:
    """What the driver would have measured at one step under the
    scenario — feed ``walls``/``collectives`` to
    :meth:`HealthMonitor.observe`, forward ``notices`` to
    :meth:`notice_preemption` and ``returned`` to the coordinator's
    capacity input."""

    walls: Tuple[Optional[float], ...]
    collectives: Tuple[Optional[float], ...]
    notices: Tuple[int, ...]
    returned: Tuple[int, ...]
    kill_checkpoint: Optional[KillMidCheckpoint]
    comm_scale: float


@dataclasses.dataclass(frozen=True)
class FaultScenario:
    """A replayable fault timeline over ``n_shards`` data-parallel
    shards.  :meth:`observe` is a pure function of the step index and
    the base observation — no clocks, no randomness."""

    n_shards: int
    events: Tuple = ()

    def _of_type(self, t):
        return [e for e in self.events if isinstance(e, t)]

    def dead_at(self, step: int) -> frozenset:
        """Shards with no heartbeat at ``step``: dropped or preempted,
        minus later capacity returns (chronological; the latest event
        for a shard wins)."""
        timeline = []
        for e in self._of_type(DeviceDrop) + self._of_type(PreemptionNotice):
            timeline.append((e.step, "gone", e.shards))
        for e in self._of_type(CapacityReturn):
            timeline.append((e.step, "back", e.shards))
        dead: set = set()
        for at, kind, shards in sorted(timeline, key=lambda x: (x[0], x[1])):
            if at > step:
                continue
            if kind == "gone":
                dead.update(shards)
            else:
                dead.difference_update(shards)
        return frozenset(dead)

    def comm_scale_at(self, step: int) -> float:
        scale = 1.0
        for e in self._of_type(BandwidthCollapse):
            if e.active(step):
                scale *= e.comm_scale
        return scale

    def straggler_factor(self, step: int, shard: int) -> float:
        f = 1.0
        for e in self._of_type(StragglerSlowdown):
            if e.shard == shard and e.active(step):
                f *= e.factor
        return f

    def observe(
        self,
        step: int,
        base_wall: float,
        base_collective: float = 0.0,
    ) -> ShardObservation:
        """Per-shard observation at ``step`` given the healthy-cluster
        base wall/collective seconds.  A dropped shard observes ``None``
        (missed heartbeat); a straggler's wall multiplies; a bandwidth
        collapse adds the extra collective seconds to every live shard's
        wall (a collective is on the critical path of the step)."""
        dead = self.dead_at(step)
        comm_scale = self.comm_scale_at(step)
        extra_comm = base_collective * (comm_scale - 1.0)
        walls = []
        colls = []
        for i in range(self.n_shards):
            if i in dead:
                walls.append(None)
                colls.append(None)
                continue
            walls.append(
                base_wall * self.straggler_factor(step, i) + extra_comm
            )
            colls.append(base_collective * comm_scale)
        notices = tuple(
            s for e in self._of_type(PreemptionNotice)
            if e.step == step for s in e.shards
        )
        returned = tuple(
            s for e in self._of_type(CapacityReturn)
            if e.step == step for s in e.shards
        )
        kill = next(
            (e for e in self._of_type(KillMidCheckpoint) if e.step == step),
            None,
        )
        return ShardObservation(
            walls=tuple(walls),
            collectives=tuple(colls),
            notices=notices,
            returned=returned,
            kill_checkpoint=kill,
            comm_scale=comm_scale,
        )


def truncate_checkpoint(
    directory: str,
    step: int,
    keep_bytes: int = 96,
    *,
    name: str = "ckpt",
) -> str:
    """Apply :class:`KillMidCheckpoint` damage: truncate the step's npz
    to ``keep_bytes`` (a crash mid-write leaves a torn file).  Returns
    the damaged path.  ``checkpoint.latest_step`` must skip the step
    afterwards — that is the atomicity regression test."""
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    size = os.path.getsize(path)
    with open(path, "rb+") as f:
        f.truncate(min(keep_bytes, size))
    return path
