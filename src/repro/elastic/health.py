"""Failure detection for the elastic control plane (DESIGN.md §10).

The adaptive loop (adapt/) answers "is the *plan* still right for the
hardware"; this module answers "is the *hardware* still there".  The
:class:`HealthMonitor` consumes one per-shard observation per training
step — wall seconds (or ``None`` for a missed heartbeat) plus optional
collective-phase seconds — and emits :class:`FaultEvent`\\ s under three
configurable policies:

* **absolute timeout** (dead/preempted device): a shard silent for
  longer than ``max(timeout_min_s, timeout_factor x median step EMA)``
  is declared ``dead``.  The clock is injected, never sampled, so fault
  scenarios replay bit-for-bit.
* **relative EWMA** (straggler): a shard whose step-time EMA exceeds
  ``straggler_ratio x`` the median of its live peers for
  ``straggler_patience`` consecutive observations is a ``straggler``;
  dropping back under ``recovered_ratio`` for ``recovered_patience``
  observations emits ``recovered``.
* **explicit preemption notice** (:meth:`notice_preemption`): cluster
  managers say goodbye before killing; the notice marks the shard
  ``preempted`` immediately — no timeout wait.

A *uniform* slowdown (every shard's collective EMA rising together) is
deliberately NOT a fault: that is bandwidth drift, the adaptive
replanner's job, and the monitor reports it as an informational
``bandwidth`` event exactly once per excursion so the caller can route
it there.  The straggler policy is ratio-against-median, so it stays
quiet under uniform degradation by construction.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.adapt.telemetry import ShardTelemetry, TelemetryConfig
from repro.obs.trace import Tracer


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Detection thresholds (DESIGN.md §10 documents the choices)."""

    # per-shard EWMA smoothing (ShardTelemetry)
    ema_alpha: float = 0.25
    warmup_steps: int = 3
    # straggler policy: shard EMA vs median of live peers
    straggler_ratio: float = 1.75
    straggler_patience: int = 3
    recovered_ratio: float = 1.2
    recovered_patience: int = 3
    # dead-device policy: absolute heartbeat timeout
    timeout_factor: float = 8.0      # x median step EMA
    timeout_min_s: float = 0.0       # absolute floor (0 = purely relative)
    # uniform collective-latency drift reported as 'bandwidth' (info only)
    bandwidth_ratio: float = 1.75


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One detected health transition."""

    step: int
    kind: str        # 'dead' | 'straggler' | 'preemption' | 'recovered'
    #                # | 'bandwidth'
    shard: int       # -1 for shard-less events (bandwidth)
    metric: float = 0.0   # the ratio / silence seconds that triggered it
    detail: str = ""

    def describe(self) -> str:
        who = f"shard {self.shard}" if self.shard >= 0 else "all shards"
        return (f"step {self.step:5d}  {self.kind:<10s} {who} "
                f"(metric {self.metric:.2f}){' ' + self.detail if self.detail else ''}")


class HealthMonitor:
    """Per-shard health state machine over :class:`ShardTelemetry`.

    Shard status: ``healthy`` -> ``straggler`` (recoverable) -> back, or
    ``healthy``/``straggler`` -> ``dead``/``preempted`` (terminal until
    :meth:`reset`, which the coordinator calls after a mesh change).
    Every transition fires exactly one :class:`FaultEvent`.
    """

    def __init__(
        self,
        n_shards: int,
        cfg: Optional[HealthConfig] = None,
        tracer: Optional[Tracer] = None,
    ):
        self.cfg = cfg or HealthConfig()
        # detection events mirror into the shared trace (DESIGN.md §11);
        # timestamps come from the TRACER's clock so one trace stays in
        # one clock domain — the monitor's replay clock rides as an attr
        self.tracer = tracer
        self.telemetry = ShardTelemetry(
            n_shards,
            TelemetryConfig(
                ema_alpha=self.cfg.ema_alpha,
                warmup_steps=self.cfg.warmup_steps,
            ),
        )
        self.events: List[FaultEvent] = []
        self.reset(n_shards)

    def _emit(self, ev: FaultEvent) -> None:
        if self.tracer is not None:
            self.tracer.instant(
                "elastic", f"detect-{ev.kind}", step=ev.step,
                shard=ev.shard, metric=ev.metric,
                monitor_clock=self._clock,
            )

    # ---- lifecycle ------------------------------------------------------
    def reset(self, n_shards: int, now: Optional[float] = None) -> None:
        """Re-arm for a new shard set (after an elastic mesh change).
        The event trail survives; all telemetry and status are fresh.

        The clock is CONTINUOUS across resets (``now`` overrides it),
        and every shard gets a liveness stamp at the reset instant — so
        a shard that never heartbeats after the mesh change (e.g. a
        returnee that fails to actually come back) accumulates silence
        from the reset and is declared dead, instead of being skipped
        forever on ``last_seen is None``."""
        self.n_shards = n_shards
        self.telemetry.rebase(n_shards)
        self.status: List[str] = ["healthy"] * n_shards
        self._slow_streak = [0] * n_shards
        self._ok_streak = [0] * n_shards
        self._clock = now if now is not None else getattr(self, "_clock", 0.0)
        for i in range(n_shards):
            self.telemetry.heartbeat(i, self._clock)
        self._bandwidth_flagged = False
        self._coll_baseline: Optional[float] = None

    # ---- explicit inputs ------------------------------------------------
    def notice_preemption(
        self, step: int, shard: int, detail: str = ""
    ) -> Optional[FaultEvent]:
        """Cluster-manager preemption notice: ``shard`` will die soon.
        Marks it terminally unhealthy NOW (no timeout wait).  Returns the
        event, or None if the shard was already dead/preempted."""
        if self.status[shard] in ("dead", "preempted"):
            return None
        self.status[shard] = "preempted"
        ev = FaultEvent(step, "preemption", shard, detail=detail)
        self.events.append(ev)
        self._emit(ev)
        return ev

    # ---- the per-step hook ----------------------------------------------
    def observe(
        self,
        step: int,
        walls: Sequence[Optional[float]],
        collectives: Optional[Sequence[Optional[float]]] = None,
        now: Optional[float] = None,
    ) -> List[FaultEvent]:
        """Feed one step's per-shard observations; returns the fault
        events this step triggered (usually none).

        ``walls[i]`` is shard ``i``'s step wall seconds, or ``None`` for
        a missed heartbeat.  ``now`` is the monotonic clock; when omitted
        the monitor advances an internal clock by the slowest observed
        wall (the step's critical path), which keeps synthetic replays
        free of real timestamps."""
        if len(walls) != self.n_shards:
            raise ValueError(
                f"expected {self.n_shards} shard observations, got {len(walls)}"
            )
        live_walls = [w for w in walls if w is not None]
        if now is None:
            self._clock += max(live_walls, default=0.0)
            now = self._clock
        else:
            self._clock = now
        for i, w in enumerate(walls):
            if w is None:
                continue
            c = collectives[i] if collectives is not None else None
            self.telemetry.record(i, w, collective_s=c, now=now)

        out: List[FaultEvent] = []
        alive = self.alive_shards()
        med = self.telemetry.median_step_time(alive)

        # -- absolute-timeout policy: dead devices ------------------------
        timeout = self.cfg.timeout_min_s
        if med is not None:
            timeout = max(timeout, self.cfg.timeout_factor * med)
        if timeout > 0:
            for i in alive:
                seen = self.telemetry.last_seen(i)
                if seen is None:       # unreachable: reset() stamps all
                    continue
                silence = now - seen
                if silence > timeout:
                    self.status[i] = "dead"
                    out.append(FaultEvent(
                        step, "dead", i, metric=silence,
                        detail=f"silent {silence:.2f}s > timeout {timeout:.2f}s",
                    ))

        # -- relative EWMA policy: stragglers -----------------------------
        alive = self.alive_shards()
        med = self.telemetry.median_step_time(alive)
        if med is not None and med > 0 and len(alive) >= 2:
            for i in alive:
                t = self.telemetry.step_time(i)
                if t is None:
                    continue
                ratio = t / med
                if self.status[i] == "healthy":
                    if ratio > self.cfg.straggler_ratio:
                        self._slow_streak[i] += 1
                        if self._slow_streak[i] >= self.cfg.straggler_patience:
                            self.status[i] = "straggler"
                            self._ok_streak[i] = 0
                            out.append(FaultEvent(
                                step, "straggler", i, metric=ratio,
                                detail=f"{ratio:.2f}x median",
                            ))
                    else:
                        self._slow_streak[i] = 0
                elif self.status[i] == "straggler":
                    if ratio < self.cfg.recovered_ratio:
                        self._ok_streak[i] += 1
                        if self._ok_streak[i] >= self.cfg.recovered_patience:
                            self.status[i] = "healthy"
                            self._slow_streak[i] = 0
                            out.append(FaultEvent(
                                step, "recovered", i, metric=ratio,
                            ))
                    else:
                        self._ok_streak[i] = 0

        # -- uniform collective drift: informational ----------------------
        coll = self.telemetry.median_collective_time(self.alive_shards())
        if coll is not None:
            if self._coll_baseline is None:
                self._coll_baseline = coll
            ratio = coll / max(self._coll_baseline, 1e-12)
            if ratio > self.cfg.bandwidth_ratio and not self._bandwidth_flagged:
                self._bandwidth_flagged = True
                out.append(FaultEvent(
                    step, "bandwidth", -1, metric=ratio,
                    detail="uniform collective-latency drift — route to "
                           "the adaptive replanner, not a mesh change",
                ))
            elif ratio <= self.cfg.recovered_ratio:
                self._bandwidth_flagged = False

        self.events.extend(out)
        for ev in out:
            self._emit(ev)
        return out

    # ---- queries --------------------------------------------------------
    def alive_shards(self) -> List[int]:
        """Shards still usable for collectives (healthy or straggling —
        a straggler is slow, not gone)."""
        return [
            i for i, s in enumerate(self.status)
            if s in ("healthy", "straggler")
        ]

    def healthy_shards(self) -> List[int]:
        return [i for i, s in enumerate(self.status) if s == "healthy"]

    def stats(self) -> dict:
        return {
            "n_shards": self.n_shards,
            "status": list(self.status),
            "events": [dataclasses.asdict(e) for e in self.events],
            "step_ema": [
                self.telemetry.step_time(i) for i in range(self.n_shards)
            ],
            "collective_ema": [
                self.telemetry.collective_time(i)
                for i in range(self.n_shards)
            ],
        }
