"""Elastic execution: drive a DeftRuntime across mesh changes (DESIGN.md §10).

The :class:`ElasticCoordinator` wraps a flat-state :class:`DeftRuntime`
and owns the fault-to-recovery pipeline:

    observe (per-shard walls) -> HealthMonitor -> FaultEvent
        -> ElasticController.propose (Preserver-gated plan)
        -> armed until the next cycle boundary
        -> migrate: fold accumulator rows -> device_put onto the
           survivor mesh -> ``repack_state`` -> ``reset_cycle`` -> new
           runtime dispatches — ZERO restart.

Shard identity: observations are indexed by **origin shard id** — the
data-parallel rows of the mesh the coordinator was constructed with.
After a 4->2 scale-down the surviving origin rows keep their ids, so a
:class:`~repro.elastic.faults.FaultScenario` scripted against the
original mesh replays unchanged across migrations; the coordinator
translates to current-mesh positions internally.

Accumulator folding: ``cur``/``fut`` rows carry per-device gradient
sums whose consumer divides by ``n_dp * k`` after a psum.  A mesh change
preserves the GLOBAL batch (per-device batch resizes), so rows fold as

    scale-down (n -> n'):  row'_j = (n'/n) * sum_{i : i mod n' == j} row_i
    scale-up   (n -> n'):  row'_j = (n'/n) * row_j   (j < n, else 0)

which keeps ``psum(rows') / n'`` identical to ``psum(rows) / n`` — the
in-flight delayed gradients survive the migration bit-for-bit in their
update semantics.  The repack itself only remaps the trailing (element)
axis; the fold is the one device-axis operation, done eagerly before the
transfer.

What a real deployment adds: this in-process harness migrates live
buffers — the "dead" devices still answer reads.  On real hardware a
dead shard's ZeRO spans are gone; production pairs this control flow
with the emergency-checkpoint path (or redundant sharding) to re-source
lost spans.  The control-plane logic — detection, pricing, gating,
cycle-boundary repack — is exactly what this module tests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import (
    save as save_ckpt,
    save_layout_descriptor,
    schedule_digest,
)
from repro.elastic.controller import ElasticController, ElasticPlan
from repro.elastic.health import FaultEvent, HealthMonitor
from repro.launch.mesh import make_elastic_mesh
from repro.obs.trace import Tracer
from repro.train.bucketing import (
    build_bucket_layout,
    build_layout_transition,
)

P = jax.sharding.PartitionSpec


class ElasticHalt(RuntimeError):
    """Raised by :meth:`ElasticCoordinator.step` when the degradation
    ladder bottoms out (no survivors / preempted out): the emergency
    checkpoint is on disk and the driver should exit cleanly; a later
    ``--resume`` continues from it."""

    def __init__(self, step: int, checkpoint_path: str):
        self.step = step
        self.checkpoint_path = checkpoint_path
        super().__init__(
            f"elastic halt at step {step}"
            + (f" (checkpoint: {checkpoint_path})"
               if checkpoint_path else " (no checkpoint dir configured)")
        )


def fold_accum_rows(rows: jax.Array, n_new: int) -> jax.Array:
    """Fold a ``(n_old, size)`` accumulator stack to ``n_new`` device
    rows, preserving ``psum(rows)/n`` (the global-mean gradient the
    delayed update consumes) under a constant global batch."""
    n_old = int(rows.shape[0])
    if n_new == n_old:
        return rows
    scale = n_new / n_old
    if n_new < n_old:
        seg = jnp.arange(n_old) % n_new
        out = jax.ops.segment_sum(rows, seg, num_segments=n_new)
    else:
        pad = jnp.zeros((n_new - n_old,) + rows.shape[1:], rows.dtype)
        out = jnp.concatenate([rows, pad], axis=0)
    return out * scale


def migrate_state(old_rt, new_rt, state) -> Any:
    """Move a flat train state from ``old_rt``'s mesh/layout onto
    ``new_rt``'s: fold the accumulator device rows, materialize onto the
    new device set (the one unavoidable full-state transfer of a
    device-set change), then ``repack_state`` into the new layout with
    its committed shardings.  Consumes ``state``."""
    from jax.sharding import NamedSharding

    state = dict(state)
    # the gather cache is layout- and mesh-bound and derived; drop it —
    # the post-migration cycle starts at position 0, which re-gathers
    state.pop("pgather", None)
    n_old, n_new = old_rt.accum_devices, new_rt.accum_devices
    if n_old != n_new:
        state["cur"] = tuple(fold_accum_rows(b, n_new) for b in state["cur"])
        state["fut"] = tuple(fold_accum_rows(b, n_new) for b in state["fut"])
    state = jax.device_put(state, NamedSharding(new_rt.mesh, P()))
    tr = build_layout_transition(old_rt.layout, new_rt.layout)
    with jax.set_mesh(new_rt.mesh):
        return new_rt.repack_state(state, tr)


class ElasticCoordinator:
    """Fault-tolerant wrapper around a flat-state :class:`DeftRuntime`.

    The driver loop calls :meth:`step` in place of ``runtime.step`` and
    :meth:`observe` with per-origin-shard walls each step; everything
    else — detection, planning, cycle-boundary migration, the
    degradation ladder — happens inside.  ``self.runtime`` is always the
    currently dispatching runtime.
    """

    def __init__(
        self,
        runtime,
        controller: ElasticController,
        monitor: HealthMonitor,
        *,
        params_abs,
        batch_spec=None,
        checkpoint_dir: str = "",
        mesh_for: Optional[Callable] = None,
        compile_on_migrate: bool = True,
        tracer: Optional[Tracer] = None,
    ):
        if not runtime.flat_state:
            raise ValueError(
                "elastic execution needs a flat-state runtime — the "
                "migration path repacks flat buffers (DESIGN.md §10)"
            )
        mesh = runtime.mesh
        if "pod" in mesh.axis_names:
            raise ValueError(
                "elastic execution supports (data, model) meshes; fold "
                "the pod axis into data before wrapping"
            )
        self.runtime = runtime
        self.controller = controller
        self.monitor = monitor
        self.params_abs = params_abs
        self.batch_spec = batch_spec
        self.checkpoint_dir = checkpoint_dir
        self._mesh_for = mesh_for or make_elastic_mesh
        self.compile_on_migrate = compile_on_migrate
        # origin shard id -> that data row's devices (model columns)
        devs = mesh.devices
        self._rows: Tuple[Tuple[Any, ...], ...] = tuple(
            tuple(devs[i, :]) for i in range(devs.shape[0])
        )
        self.n_origin = len(self._rows)
        # origin ids currently IN the mesh, mesh order, and the spare
        # pool capacity returns draw from.  An armed (not yet executed)
        # plan's membership is always `(members | returning) - spares`:
        # faulted members sit in BOTH `members` and `spares` until the
        # plan executes; capacity returnees sit in `returning` until
        # they land in `members`.  Every mutation re-arms the plan from
        # that one invariant, so cascading faults, straggler recoveries
        # and capacity returns compose instead of clobbering each other.
        self.members: List[int] = list(range(self.n_origin))
        self.spares: List[int] = []
        self._returning: List[int] = []
        # origin id -> the fault kind that planned it out (cleared when
        # the shard is restored or drawn back from the spare pool)
        self._out_reason: Dict[int, str] = {}
        self._pending: Optional[ElasticPlan] = None
        self._pending_members: List[int] = []
        self._halt: Optional[ElasticPlan] = None
        self.log: List[Dict[str, Any]] = []
        self.fault_events: List[FaultEvent] = []
        # detection -> arm -> migrate lifecycle mirrors into one trace
        # (DESIGN.md §11): default to the runtime's tracer so elastic
        # events land next to the step/phase spans they interrupt.
        # Compare against None, never truthiness — an empty Tracer has
        # __len__ == 0 and would be silently replaced by a private one
        if tracer is None:
            tracer = getattr(runtime, "tracer", None)
        self.tracer = tracer if tracer is not None else Tracer(capacity=1024)
        if self.monitor.tracer is None:
            self.monitor.tracer = self.tracer
        if monitor.n_shards != len(self.members):
            monitor.reset(len(self.members))

    # ---- observations ---------------------------------------------------
    def observe(
        self,
        step: int,
        walls: Sequence[Optional[float]],
        collectives: Optional[Sequence[Optional[float]]] = None,
        now: Optional[float] = None,
    ) -> List[FaultEvent]:
        """Feed one step's per-ORIGIN-shard observations (length
        ``n_origin``; entries for shards not currently in the mesh are
        ignored).  Returns the fault events raised, after any replanning
        they triggered."""
        if len(walls) != self.n_origin:
            raise ValueError(
                f"expected {self.n_origin} origin-shard observations, "
                f"got {len(walls)}"
            )
        cur_walls = [walls[o] for o in self.members]
        cur_colls = (
            [collectives[o] for o in self.members]
            if collectives is not None else None
        )
        events = self.monitor.observe(step, cur_walls, cur_colls, now=now)
        self._handle(step, events)
        return events

    def notice_preemption(
        self, step: int, shards: Sequence[int]
    ) -> List[FaultEvent]:
        """Explicit preemption notice for origin ``shards`` — no timeout
        wait; the scale-down (or halt) is planned immediately."""
        events = []
        for o in shards:
            if o not in self.members:
                continue
            ev = self.monitor.notice_preemption(
                step, self.members.index(o)
            )
            if ev is not None:
                events.append(ev)
        self._handle(step, events)
        return events

    def notice_capacity(self, step: int, shards: Sequence[int]) -> None:
        """Origin ``shards`` became available again.  A shard whose
        removal is still armed (in ``spares`` AND ``members``) is simply
        restored — its removal cancels; a shard already migrated out
        joins ``returning`` and the symmetric scale-up arms.  Either way
        the plan is re-armed from the membership invariant, MERGING with
        (never clobbering) any armed fault plan."""
        fresh = [o for o in shards if o in self.spares]
        if not fresh:
            return
        trigger = "scale-up"
        for o in fresh:
            self.spares.remove(o)
            self._out_reason.pop(o, None)
            if o not in self.members:
                self._returning.append(o)
        if not any(o in self._returning for o in fresh):
            # pure cancellation of armed removals: if removals for OTHER
            # shards remain armed, keep their fault trigger on the plan
            trigger = self._remaining_trigger() or trigger
        self._rearm(step, trigger)

    # ---- fault handling -------------------------------------------------
    def _handle(self, step: int, events: List[FaultEvent]) -> None:
        self.fault_events.extend(events)
        lost: List[Tuple[int, str]] = []
        restored = False
        for ev in events:
            if ev.kind in ("dead", "preemption", "straggler"):
                o = self.members[ev.shard]
                # a shard already planned out (armed earlier this cycle
                # window) must not be re-lost: it is in `spares`, and
                # counting it again would double-book the removal
                if o not in self.spares and all(o != p for p, _ in lost):
                    lost.append((o, ev.kind))
            # 'bandwidth' is informational here: uniform drift is the
            # adaptive replanner's job
            elif ev.kind == "recovered":
                o = self.members[ev.shard]
                # a straggler that recovers before its armed removal
                # executes is restored: out of the spare pool, removal
                # cancelled (dead/preempted shards never emit 'recovered')
                if o in self.spares and self._out_reason.get(o) == "straggler":
                    self.spares.remove(o)
                    self._out_reason.pop(o, None)
                    restored = True
        if not lost and not restored:
            return
        # shards planned out of the mesh move to the spare pool the
        # moment the plan arms — capacity returns can bring them back
        for o, kind in lost:
            self.spares.append(o)
            self._out_reason[o] = kind
        trigger = lost[-1][1] if lost else (self._remaining_trigger()
                                            or "scale-up")
        self._rearm(step, trigger)

    def _remaining_trigger(self) -> Optional[str]:
        """Fault kind of the latest still-armed removal, if any."""
        out = [o for o in self.members if o in self.spares]
        return self._out_reason.get(out[-1]) if out else None

    def _rearm(self, step: int, trigger: str) -> None:
        """Recompute the armed plan from the membership invariant
        ``(members | returning) - spares``; a target identical to the
        current membership disarms (nothing left to migrate)."""
        target = sorted(
            (set(self.members) | set(self._returning)) - set(self.spares)
        )
        if target == sorted(self.members):
            if self._pending is not None:
                self.tracer.instant(
                    "elastic", "disarm", step=step, trigger=trigger,
                )
            self._pending = None
            self._pending_members = []
            return
        plan = self.controller.propose(step, len(target), trigger)
        if plan.action == "checkpoint-halt":
            self._halt = plan
            self._pending = None
            self._pending_members = []
            self.tracer.instant(
                "elastic", "arm-checkpoint-halt", step=step,
                trigger=trigger, detected_step=plan.step,
            )
            return
        self._pending = plan
        self._pending_members = target
        self.tracer.instant(
            "elastic", f"arm-{plan.action}", step=step, trigger=trigger,
            detected_step=plan.step, new_shards=plan.n_shards,
            new_period=plan.schedule.period if plan.schedule else None,
        )

    # ---- migration ------------------------------------------------------
    def maybe_migrate(self, i: int, state):
        """Execute an armed plan if ``i`` is a cycle boundary (or halt
        immediately).  Returns the (possibly migrated) state; afterwards
        ``self.runtime`` dispatches it."""
        if self._halt is not None:
            self._do_halt(i, state)
        if self._pending is None:
            return state
        if self.runtime.phase_in_cycle(i) != 0:
            return state
        plan, self._pending = self._pending, None
        return self._execute(i, state, plan)

    def step(self, i: int, state, batch):
        """Drop-in for ``DeftRuntime.step`` with elastic handling."""
        state = self.maybe_migrate(i, state)
        return self.runtime.step(i, state, batch)

    def _do_halt(self, i: int, state) -> None:
        plan, self._halt = self._halt, None
        path = ""
        if self.checkpoint_dir:
            path = self.emergency_checkpoint(i, state)
        self.log.append({
            "step": i, "action": "checkpoint-halt",
            "detected_step": plan.step, "trigger": plan.trigger,
            "checkpoint": path,
        })
        self.tracer.instant(
            "elastic", "checkpoint-halt", step=i, trigger=plan.trigger,
            detected_step=plan.step, checkpoint=path,
        )
        raise ElasticHalt(i, path)

    def emergency_checkpoint(self, step: int, state) -> str:
        """Checkpoint NOW (tree form + layout/schedule sidecar), atomic
        — the clean-resume half of the unsurvivable-fault path."""
        rt = self.runtime
        path = save_ckpt(self.checkpoint_dir, step, rt.state_to_tree(state))
        save_layout_descriptor(
            self.checkpoint_dir, step, rt.layout,
            next_phase=rt.phase_in_cycle(step),
            digest=schedule_digest(rt.schedule),
        )
        return path

    def _execute(self, i: int, state, plan: ElasticPlan):
        t_mig = time.perf_counter()
        tr0 = self.tracer.now()
        old_rt = self.runtime
        members = sorted(self._pending_members)
        assert len(members) == plan.n_shards, (members, plan)
        assert len(set(members)) == len(members), members
        # a plan must never re-seat a shard still in the spare pool — a
        # cascading fault or capacity return that mutated the pool after
        # this plan armed would have re-armed it (see _rearm)
        assert set(members).isdisjoint(self.spares), (members, self.spares)
        rows = [self._rows[o] for o in members]
        new_mesh = self._mesh_for(rows)
        new_layout = build_bucket_layout(
            self.params_abs, plan.bucket_of, plan.n_buckets,
            shard_count=plan.n_shards if plan.sharded else 1,
        )
        old_pol = getattr(old_rt.layout, "precision", None)
        if old_pol is not None:
            # §13: the wire/master policy migrates with the state.  A
            # changed bucket count invalidates per-bucket wire choices,
            # so those reset to f32 (uniform policies survive); the
            # resident master dtype always carries — the migration must
            # not change the memory envelope mid-flight.
            from repro.core.precision import PrecisionPolicy

            if plan.n_buckets == old_rt.layout.n_buckets:
                new_layout = new_layout.with_precision(old_pol)
            else:
                wires = set(old_pol.wire)
                uni = wires.pop() if len(wires) == 1 else "f32"
                new_layout = new_layout.with_precision(
                    PrecisionPolicy.uniform(plan.n_buckets, uni,
                                            old_pol.master)
                )
        new_rt = old_rt.spawn(
            mesh=new_mesh, schedule=plan.schedule, layout=new_layout,
            fsdp=plan.sharded,
        )
        t0 = time.perf_counter()
        state = migrate_state(old_rt, new_rt, state)
        jax.block_until_ready(jax.tree_util.tree_leaves(state))
        repack_s = time.perf_counter() - t0
        compile_s = None
        if self.compile_on_migrate and self.batch_spec is not None:
            t0 = time.perf_counter()
            new_rt.compile(state, self.batch_spec)
            compile_s = time.perf_counter() - t0
        new_rt.reset_cycle(i)
        self.log.append({
            "step": i, "action": plan.action, "trigger": plan.trigger,
            "detected_step": plan.step,
            "old_shards": len(self.members), "new_shards": plan.n_shards,
            "old_period": old_rt.period, "new_period": new_rt.period,
            "sharded": plan.sharded,
            "preserver_ok": bool(plan.verdict and plan.verdict.ok),
            "preserver_ratio": plan.verdict.ratio if plan.verdict else None,
            "n_buckets": (old_rt.layout.n_buckets, new_layout.n_buckets),
            "repack_s": repack_s, "compile_s": compile_s,
            "migrate_s": time.perf_counter() - t_mig,
            "members": tuple(members),
        })
        self.tracer.add(
            "elastic", f"migrate-{plan.action}", tr0, self.tracer.now(),
            step=i, trigger=plan.trigger, detected_step=plan.step,
            old_shards=len(self.members), new_shards=plan.n_shards,
            old_period=old_rt.period, new_period=new_rt.period,
            repack_s=repack_s, compile_s=compile_s,
        )
        self.members = members
        self._returning = [o for o in self._returning if o not in members]
        self._pending_members = []
        self.runtime = new_rt
        self.monitor.reset(len(members))
        self.controller.adopt(plan)
        return state

    # ---- reporting ------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return {
            "n_origin": self.n_origin,
            "members": tuple(self.members),
            "spares": tuple(self.spares),
            "returning": tuple(self._returning),
            "migrations": list(self.log),
            "fault_events": [
                dataclasses.asdict(e) for e in self.fault_events
            ],
            "pending": self._pending is not None,
        }
