"""Per-link ring-chain collectives (DESIGN.md §14).

The planner assigns RS/AG items to a secondary link (``PhaseSpec.
secondary``, ``AgItem.link``); these collectives make that assignment
*executable*: a bucket routed to link ``l`` runs its reduce-scatter /
all-gather as ``ppermute`` rounds over that link's device-order chain
(``launch.mesh.ring_chain``, DeAR-style ring reordering) instead of the
single mesh axis every collective otherwise shares.  Distinct chains map
neighbor hops onto distinct physical cable sets on a multi-NIC fabric —
the chain is visible in the jaxpr as the ``ppermute`` permutation, which
is how tests verify the secondary traffic really left the primary ring.

Bitwise parity contract
-----------------------
Training must be bit-identical whichever link a bucket rides (the
Preserver gate reasons about schedule noise, not link noise).  A classic
ring reduce-scatter accumulates partial sums in *chain* order, which is
NOT the order XLA's ``psum``/``psum_scatter`` reduce in (ascending device
order on this backend — asserted by tests/test_chain_parity.py), so its
floats drift by rounding.  Instead:

* ``chain_reduce_scatter`` ships **raw per-source chunks** over ``n - 1``
  jump-``s`` permutations of the chain (round ``s`` sends each device's
  chunk for the device ``s`` chain-hops ahead — one chunk per device per
  round, the same total volume as a ring RS) and reduces locally in
  canonical ascending-device order.  The deferred reduction is what buys
  bitwise equality with ``psum_scatter``.
* ``chain_all_gather`` is a genuine store-and-forward ring relay on the
  chain permutation — pure data movement, trivially exact.
* ``chain_all_reduce`` composes the two (zero-padding non-divisible
  buffers; padding never mixes into real lanes), matching ``psum``.

All three take the chain as a static tuple of *axis indices* (positions
along the named mesh axis), so a distinct chain compiles to a distinct
executable — exactly like any other ``PhaseSpec`` dimension.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def chain_perm(chain: Sequence[int], jump: int = 1) -> Tuple[Tuple[int, int], ...]:
    """The ``ppermute`` permutation moving data ``jump`` hops forward
    along ``chain`` (source, destination) — ``jump=1`` is the ring."""
    n = len(chain)
    return tuple(
        (chain[p], chain[(p + jump) % n]) for p in range(n)
    )


def _chain_tables(chain: Sequence[int]):
    """(position-of-device, device-at-position) lookup arrays."""
    n = len(chain)
    pos_of = [0] * n
    for p, d in enumerate(chain):
        pos_of[d] = p
    return jnp.asarray(pos_of), jnp.asarray(list(chain))


def chain_reduce_scatter(x: jax.Array, axis: str,
                         chain: Sequence[int]) -> jax.Array:
    """Reduce-scatter ``x`` over ``axis`` along ``chain``; bitwise-equal
    to ``jax.lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)``.

    ``x`` is the full (replicated-shape) per-device buffer; the leading
    dimension must divide by ``len(chain)``.  Device ``d`` (axis index)
    returns the fully reduced ``d``-th chunk.  ``n - 1`` ppermute rounds,
    one chunk per device per round; the reduction itself happens locally
    in ascending device order after all raw chunks land."""
    n = len(chain)
    if n == 1:
        return x
    if x.shape[0] % n:
        raise ValueError(
            f"chain_reduce_scatter: leading dim {x.shape[0]} not divisible "
            f"by chain length {n}"
        )
    chunk = x.shape[0] // n
    xt = x.reshape((n, chunk) + x.shape[1:])
    posv, chainv = _chain_tables(chain)
    ax = jax.lax.axis_index(axis)
    mypos = posv[ax]
    contrib = jnp.zeros_like(xt)
    contrib = contrib.at[ax].set(xt[ax])
    for s in range(1, n):
        dest = chainv[(mypos + s) % n]
        sent = jax.lax.ppermute(xt[dest], axis, chain_perm(chain, jump=s))
        src = chainv[(mypos - s) % n]
        contrib = contrib.at[src].set(sent)
    acc = contrib[0]
    for d in range(1, n):
        acc = acc + contrib[d]
    return acc


def chain_all_gather(x: jax.Array, axis: str,
                     chain: Sequence[int]) -> jax.Array:
    """All-gather per-device shards over ``axis`` along ``chain``;
    bitwise-equal to ``jax.lax.all_gather(x, axis, axis=0, tiled=True)``.

    Store-and-forward ring relay: each round every device forwards the
    chunk it received last round along the chain ring — after ``n - 1``
    rounds every shard visited every device.  Pure movement, no
    arithmetic."""
    n = len(chain)
    if n == 1:
        return x
    posv, chainv = _chain_tables(chain)
    ax = jax.lax.axis_index(axis)
    mypos = posv[ax]
    perm = chain_perm(chain, jump=1)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    out = out.at[ax].set(x)
    cur = x
    for s in range(1, n):
        cur = jax.lax.ppermute(cur, axis, perm)
        out = out.at[chainv[(mypos - s) % n]].set(cur)
    return out.reshape((n * x.shape[0],) + x.shape[1:])


def chain_all_reduce(x: jax.Array, axis: str,
                     chain: Sequence[int]) -> jax.Array:
    """All-reduce over ``axis`` along ``chain``; bitwise-equal to
    ``jax.lax.psum(x, axis)`` (ascending-device reduction order).

    Composes reduce-scatter + all-gather the way a ring all-reduce does;
    arbitrary shapes are flattened and zero-padded to a chain multiple
    (padding lanes never mix with real lanes and are dropped after the
    gather)."""
    n = len(chain)
    if n == 1:
        return x
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = chain_reduce_scatter(flat, axis, chain)
    full = chain_all_gather(shard, axis, chain)
    if pad:
        full = full[: x.size]
    return full.reshape(x.shape)
