"""Lazy per-bucket parameter streaming for the decoupled engine.

The sharded flat engine re-materializes full parameter buffers from the
ZeRO shards at phase start — one up-front all-gather *burst* covering
every bucket before the first forward block runs.  The decoupled
schedule (DESIGN.md §12) splits that burst into one all-gather per
bucket, issued at the *first forward use* of any leaf the bucket holds:
the gather for the embedding bucket lands before block 0, the gather
for a tail bucket only once forward reaches it, so AG traffic streams
against forward compute exactly like the planner's deadline items.

Mechanically this is a trace-order trick, not a runtime dispatcher: the
parameter "tree" handed to ``loss_fn`` is a lazy view over the bucket
buffers.  Plain indexing (``params["embed"]["table"]``,
``params["prefix"][i]``) walks lazy containers; touching a leaf triggers
its bucket's materialization (``get_full(b)``, typically cache-or-
all-gather plus the zeros-trick offset), and since jaxpr equation order
is Python trace order, each bucket's all-gather lands in the jaxpr right
before the first block that consumes it.  The containers are registered
as pytree nodes whose flatten *fully materializes* the subtree, so any
JAX consumption boundary — ``jax.checkpoint`` block args, ``lax.scan``
xs over the stacked layers — densifies exactly the subtree it needs at
exactly the point it needs it.

Leaf extraction mirrors :func:`repro.train.bucketing.unflatten_buckets`
(same ``lax.slice`` + reshape on the same offsets), so a streamed leaf
is bit-identical to the fused engine's view of the same buffer.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import numpy as np

from repro.train.bucketing import BucketLayout


class _BucketLoader:
    """Shared per-trace materialization state: leaf index -> array view,
    memoized so repeated access (e.g. tied embeddings read again by the
    LM head) reuses the traced slice instead of re-slicing."""

    __slots__ = ("layout", "get_full", "_leaves")

    def __init__(self, layout: BucketLayout, get_full: Callable):
        self.layout = layout
        self.get_full = get_full
        self._leaves: Dict[int, jax.Array] = {}

    def leaf(self, i: int) -> jax.Array:
        hit = self._leaves.get(i)
        if hit is not None:
            return hit
        b = self.layout.bucket_of_leaf[i]
        full = self.get_full(b)
        pos = self.layout.leaves[b].index(i)
        off = self.layout.offsets[b][pos]
        shape = self.layout.shapes[i]
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        val = jax.lax.slice(full, (off,), (off + n,)).reshape(shape)
        self._leaves[i] = val
        return val


def _resolve(node, loader: _BucketLoader):
    """One lazy step: containers stay lazy, a leaf index materializes."""
    if isinstance(node, dict):
        return LazyDict(node, loader)
    if isinstance(node, (tuple, list)):
        return LazyList(node, loader)
    return loader.leaf(node)


def _deep(node, loader: _BucketLoader):
    """Full materialization of a subtree (plain dicts/tuples of arrays)."""
    if isinstance(node, dict):
        return {k: _deep(v, loader) for k, v in node.items()}
    if isinstance(node, (tuple, list)):
        return tuple(_deep(v, loader) for v in node)
    return loader.leaf(node)


class LazyDict:
    """Dict-shaped lazy view; ``[]`` resolves one level lazily."""

    __slots__ = ("_node", "_loader")

    def __init__(self, node, loader):
        self._node = node
        self._loader = loader

    def __getitem__(self, key):
        return _resolve(self._node[key], self._loader)

    def __contains__(self, key):
        return key in self._node

    def __len__(self):
        return len(self._node)

    def __iter__(self):
        return iter(self._node)

    def keys(self):
        return self._node.keys()

    def get(self, key, default=None):
        if key not in self._node:
            return default
        return self[key]


class LazyList:
    """Tuple-shaped lazy view; ``[i]``/iteration resolve lazily."""

    __slots__ = ("_node", "_loader")

    def __init__(self, node, loader):
        self._node = node
        self._loader = loader

    def __getitem__(self, i):
        if isinstance(i, slice):
            return LazyList(tuple(self._node[i]), self._loader)
        return _resolve(self._node[i], self._loader)

    def __len__(self):
        return len(self._node)

    def __iter__(self):
        return (_resolve(v, self._loader) for v in self._node)


def _dict_flatten(d: LazyDict):
    keys = tuple(sorted(d._node))
    return tuple(_deep(d._node[k], d._loader) for k in keys), keys


def _dict_unflatten(keys, children):
    return dict(zip(keys, children))


def _list_flatten(t: LazyList):
    return tuple(_deep(v, t._loader) for v in t._node), None


def _list_unflatten(_, children):
    return tuple(children)


# Flatten materializes: a lazy container crossing any JAX API boundary
# (checkpoint args, scan xs, tree.map) densifies to plain pytrees there.
jax.tree_util.register_pytree_node(LazyDict, _dict_flatten, _dict_unflatten)
jax.tree_util.register_pytree_node(LazyList, _list_flatten, _list_unflatten)


def lazy_param_tree(treedef, layout: BucketLayout, get_full: Callable):
    """Lazy parameter-tree view over per-bucket flat buffers.

    ``treedef`` is the parameter tree's ``tree_flatten`` treedef,
    ``get_full(b)`` returns bucket ``b``'s full flat buffer (called at
    most once per bucket per trace; its equations land at the first
    leaf access, which is what streams the all-gathers into forward).
    """
    index_tree = jax.tree_util.tree_unflatten(
        treedef, list(range(layout.n_leaves))
    )
    return _resolve(index_tree, _BucketLoader(layout, get_full))
