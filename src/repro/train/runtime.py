"""DeftRuntime: the production DeFT execution engine.

Replaces the ad-hoc per-phase step-fn list of ``train/steps.py`` with a
runtime that owns the whole compiled-phase lifecycle (see DESIGN.md):

* **Bucket-fused collectives** — gradients are packed per bucket into one
  contiguous f32 buffer using the static :class:`BucketLayout` (offsets /
  sizes precomputed at plan time), so each phase issues exactly ONE
  ``psum`` (or one hierarchical reduce-scatter chain on the secondary
  link) per *synced bucket* instead of one per parameter leaf.  The
  ``cur``/``fut`` gradient-generation accumulators are per-bucket flat
  buffers; accumulate / zero / rotate act on whole buffers and the
  leaf tree is only reassembled in update phases.
* **Buffer donation** — every phase executable (and the DDP baseline via
  :func:`make_ddp_step`) donates the train state, so params, optimizer
  moments and both accumulators update in place instead of being copied
  each step.
* **AOT phase cache** — phases are deduped by ``PhaseSpec`` signature and
  lowered + compiled ahead of the first step; ``step(i)`` dispatches the
  cached executable for ``i % period`` and the runtime exposes compile /
  dispatch timing stats.
* **Flat-resident state** (default, DESIGN.md §8) — params and optimizer
  moments live as per-bucket flat f32 buffers for the whole period, not
  as trees: the forward unflattens with static slice/reshape views, and
  update phases apply the optimizer with ONE fused bucket-update kernel
  per bucket (Pallas on TPU, lax fallback elsewhere — see
  ``kernels/bucket_update``) instead of per-leaf ``apply_updates`` over
  hundreds of tiny tensors.  The tree form exists only at checkpoint /
  eval boundaries (:meth:`DeftRuntime.params_tree` /
  :meth:`DeftRuntime.state_to_tree`).

The per-leaf path in ``train/steps.py`` is kept as the semantic
reference (tests prove flat == fused-tree == per-leaf == the gradient-
accumulation reference) and as the benchmark baseline; the PR-1
tree-state fused path remains available via ``flat_state=False``.
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.scheduler import DeftSchedule, PhaseSpec
from repro.kernels.bucket_update import (
    BucketSegments,
    apply_bucket_updates,
    build_segments,
    init_flat_opt_state,
)
from repro.models.model import init_params, loss_fn
from repro.optim.optimizers import OptimizerSpec, apply_updates, init_opt_state
from repro.sharding import (
    logical_rules,
    rules_deft_manual_dp,
    rules_deft_rs_manual_pod,
)
from repro.train.bucketing import (
    BucketLayout,
    flatten_buckets,
    unflatten_buckets,
)
from repro.train.steps import (
    TrainState,
    _batch_specs,
    _dp_sizes,
    _state_specs,
    _sync_primary,
    _sync_secondary,
    ddp_train_step,
)


def init_fused_accumulators(
    layout: BucketLayout, accum_devices: int
) -> Dict[str, Tuple[jax.Array, ...]]:
    """Per-bucket flat f32 accumulators with a leading device axis."""
    zeros = lambda: tuple(
        jnp.zeros((accum_devices, s), jnp.float32) for s in layout.buf_sizes
    )
    return {"cur": zeros(), "fut": zeros()}


# ---------------------------------------------------------------------------
# Shared per-bucket routing (identical for tree-state and flat-state paths)
# ---------------------------------------------------------------------------
def _route_and_sync(phase: PhaseSpec, g_flat, cur, fut, sync):
    """DeFT generation bookkeeping on per-bucket flat buffers.

    Returns (gen, new_fut, cur_synced): the merged fresh generation (or
    None when not rotating), the next future accumulator, and the older
    generation with this phase's scheduled collectives applied.
    """
    if phase.rotate:
        # fresh generation merges with the future accumulator (Cases 3/4)
        gen = [g + f for g, f in zip(g_flat, fut)]
        gen = [
            sync(x, b) if phase.route_new[b] == "sync" else x
            for b, x in enumerate(gen)
        ]
        new_fut = [jnp.zeros_like(f) for f in fut]
    else:
        # Cases 1/2: fresh gradients accumulate locally
        gen = None
        new_fut = [f + g for f, g in zip(fut, g_flat)]

    # older generation buckets scheduled this phase (fwd Case 1 + bwd 2/3)
    cur_synced = [
        sync(c, b) if phase.sync_cur[b] else c for b, c in enumerate(cur)
    ]
    return gen, new_fut, cur_synced


def _fused_metrics(loss, parts, phase: PhaseSpec, dp_axes, n_dp: int):
    """Loss and aux parts ride ONE fused psum, stacked to a vector."""
    part_keys = sorted(parts)
    stacked = jnp.stack([loss] + [parts[k] for k in part_keys])
    stacked = jax.lax.psum(stacked, dp_axes) / n_dp
    return {
        "loss": stacked[0],
        **{k: stacked[1 + j] for j, k in enumerate(part_keys)},
        "updated": jnp.asarray(phase.do_update),
        "k": jnp.asarray(phase.update_k, jnp.int32),
    }


def _cast_compute(params, compute_dtype):
    """Mixed-precision boundary of the flat engines: the f32 master
    buffers are cast to the compute dtype at the static slice/reshape
    views, so the forward/backward runs in (e.g.) bf16 while the
    optimizer state stays full-precision (DESIGN.md §8)."""
    if compute_dtype is None or compute_dtype == jnp.float32:
        return params
    return jax.tree.map(lambda x: x.astype(compute_dtype), params)


# ---------------------------------------------------------------------------
# Fused DeFT phase body
# ---------------------------------------------------------------------------
def _deft_body_fused(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    dp_axes: Tuple[str, ...],
    dp_sizes: Dict[str, int],
    rules: Dict,
    remat: bool,
    loss_chunk: int = 0,
    unroll: bool = False,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One DeFT phase over per-bucket flat buffers, inside shard_map.

    ``cur``/``fut`` arrive with the leading device axis stripped to 1 by
    the manual mapping; we work on index [0] and re-add it on return.
    Every tensor this body syncs is a whole bucket buffer — there is no
    per-leaf collective and no tree flatten/unflatten outside the update
    branch.
    """
    n_dp = 1
    for a in dp_axes:
        n_dp *= dp_sizes[a]
    params, opt = state["params"], state["opt"]
    with logical_rules(rules):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=remat,
                              loss_chunk=loss_chunk, unroll=unroll),
            has_aux=True,
        )(params)

    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    g_flat = flatten_buckets(layout, g_leaves)         # one buffer per bucket
    cur = [c[0] for c in state["cur"]]
    fut = [f[0] for f in state["fut"]]

    def sync(x: jax.Array, b: int) -> jax.Array:
        if phase.secondary[b]:
            return _sync_secondary(x, dp_axes, dp_sizes)
        return _sync_primary(x, dp_axes)

    gen, new_fut, cur_synced = _route_and_sync(phase, g_flat, cur, fut, sync)

    if phase.do_update:
        src = cur_synced if phase.update_source == "cur" else gen
        grad_tree = jax.tree_util.tree_unflatten(
            treedef, unflatten_buckets(layout, src)
        )
        scale = 1.0 / (n_dp * phase.update_k)
        params, opt = apply_updates(opt_spec, params, grad_tree, opt,
                                    grad_scale=scale)
        if phase.update_source == "cur":
            new_cur = gen if gen is not None else [
                jnp.zeros_like(c) for c in cur_synced
            ]
        else:
            new_cur = [jnp.zeros_like(c) for c in cur_synced]
    elif phase.rotate:
        new_cur = gen
    else:
        new_cur = cur_synced

    metrics = _fused_metrics(loss, parts, phase, dp_axes, n_dp)
    new_state = {
        "params": params,
        "opt": opt,
        "cur": tuple(c[None] for c in new_cur),
        "fut": tuple(f[None] for f in new_fut),
    }
    return new_state, metrics


# ---------------------------------------------------------------------------
# Flat-resident DeFT phase body (params/opt as per-bucket flat buffers)
# ---------------------------------------------------------------------------
def _deft_body_flat(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    segments: BucketSegments,
    treedef,
    dp_axes: Tuple[str, ...],
    dp_sizes: Dict[str, int],
    rules: Dict,
    remat: bool,
    loss_chunk: int = 0,
    unroll: bool = False,
    update_impl: Optional[str] = None,
    compute_dtype=None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One DeFT phase with params and optimizer moments resident as
    per-bucket flat f32 buffers (DESIGN.md §8).

    The forward reads params through static slice/reshape views of the
    buffers (no per-leaf copies survive fusion); the update phase applies
    the optimizer with one fused bucket-update kernel per bucket and the
    accumulator zeroing rides the same launch.  No per-leaf O(num_params)
    op sequence exists anywhere in the steady-state step.
    """
    n_dp = 1
    for a in dp_axes:
        n_dp *= dp_sizes[a]
    pbuf, opt = state["pbuf"], state["opt"]
    params = jax.tree_util.tree_unflatten(
        treedef, unflatten_buckets(layout, pbuf)
    )
    params = _cast_compute(params, compute_dtype)
    with logical_rules(rules):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=remat,
                              loss_chunk=loss_chunk, unroll=unroll),
            has_aux=True,
        )(params)

    g_flat = flatten_buckets(layout, jax.tree_util.tree_leaves(grads))
    cur = [c[0] for c in state["cur"]]
    fut = [f[0] for f in state["fut"]]

    def sync(x: jax.Array, b: int) -> jax.Array:
        if phase.secondary[b]:
            return _sync_secondary(x, dp_axes, dp_sizes)
        return _sync_primary(x, dp_axes)

    gen, new_fut, cur_synced = _route_and_sync(phase, g_flat, cur, fut, sync)

    if phase.do_update:
        src = cur_synced if phase.update_source == "cur" else gen
        # the consumed accumulator is replaced by the fresh generation
        # (rotate) or comes back zeroed fused from the update launch
        zero_grads = (phase.update_source == "new") or (gen is None)
        scale = 1.0 / (n_dp * phase.update_k)
        pbuf, opt, zeroed = apply_bucket_updates(
            opt_spec, segments, pbuf, src, opt,
            grad_scale=scale, zero_grads=zero_grads, impl=update_impl,
        )
        if phase.update_source == "cur" and gen is not None:
            new_cur = gen
        else:
            new_cur = list(zeroed)
    elif phase.rotate:
        new_cur = gen
    else:
        new_cur = cur_synced

    metrics = _fused_metrics(loss, parts, phase, dp_axes, n_dp)
    new_state = {
        "pbuf": tuple(pbuf),
        "opt": opt,
        "cur": tuple(c[None] for c in new_cur),
        "fut": tuple(f[None] for f in new_fut),
    }
    return new_state, metrics


# ---------------------------------------------------------------------------
# Sharded flat-resident DeFT phase body (FSDP/RS engine, DESIGN.md §8)
# ---------------------------------------------------------------------------
def _deft_body_flat_rs(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    segments: BucketSegments,
    treedef,
    dp_axes: Tuple[str, ...],
    shard_axis: str,
    dp_sizes: Dict[str, int],
    rules: Dict,
    remat: bool,
    loss_chunk: int = 0,
    unroll: bool = False,
    update_impl: Optional[str] = None,
    compute_dtype=None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One DeFT phase with params and optimizer moments SHARDED over
    ``shard_axis``: each device holds one contiguous 1/N span of every
    flat bucket buffer (``layout.shard_sizes``), ZeRO-style.

    * the forward all-gathers the updated param shards into full flat
      buffers and reads the tree through the usual static views;
    * scheduled syncs are hierarchical by construction — reduce-scatter
      over ``shard_axis`` into shard-local buffers, all-reduce over the
      outer (pod/DCN) axes, all-gather back ONLY when the synced buffer
      must be stored full (a later phase consumes it).  A bucket synced
      and consumed in the same phase feeds its shard-local reduction
      straight to the update kernel with no trailing all-gather;
    * the fused bucket-update kernels run on the shard-local p/m/v spans
      (segment maps sliced per shard, clip norm psum'd across shards),
      so optimizer state stays 1/N-resident for the whole run.

    ``cur``/``fut`` stay full-length per-device accumulators: an
    unsynchronized generation holds contributions to EVERY span, which a
    later reduce-scatter folds into the owning shard.
    """
    n_dp = 1
    for a in dp_axes:
        n_dp *= dp_sizes[a]
    outer_axes = tuple(a for a in dp_axes if a != shard_axis)
    shard_id = jax.lax.axis_index(shard_axis)
    spans = layout.shard_sizes

    pbuf_sh, opt = state["pbuf"], state["opt"]
    # ZeRO forward: re-materialize full param buffers from the shards.
    # Mixed precision casts each span down BEFORE the gather — the cast
    # is elementwise so the params are bit-identical, and the param
    # all-gather (the engine's dominant per-phase comm term) moves half
    # the bytes in bf16 instead of shipping f32 and casting after.
    if compute_dtype is not None and compute_dtype != jnp.float32:
        gather_src = [s.astype(compute_dtype) for s in pbuf_sh]
    else:
        gather_src = pbuf_sh
    pbuf = [
        jax.lax.all_gather(s, shard_axis, axis=0, tiled=True)
        for s in gather_src
    ]
    params = jax.tree_util.tree_unflatten(
        treedef, unflatten_buckets(layout, pbuf)
    )
    with logical_rules(rules):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=remat,
                              loss_chunk=loss_chunk, unroll=unroll),
            has_aux=True,
        )(params)

    g_flat = flatten_buckets(layout, jax.tree_util.tree_leaves(grads))
    cur = [c[0] for c in state["cur"]]
    fut = [f[0] for f in state["fut"]]

    def rs_shard(x: jax.Array) -> jax.Array:
        """Shard-local half of the hierarchical sync: reduce-scatter over
        the fast shard axis, all-reduce across the outer axes."""
        y = jax.lax.psum_scatter(
            x, shard_axis, scatter_dimension=0, tiled=True
        )
        if outer_axes:
            y = jax.lax.psum(y, outer_axes)
        return y

    def gather(y: jax.Array) -> jax.Array:
        return jax.lax.all_gather(y, shard_axis, axis=0, tiled=True)

    def slice_shard(x: jax.Array, b: int) -> jax.Array:
        """This device's span of an already-summed full buffer."""
        return jax.lax.dynamic_slice(x, (shard_id * spans[b],), (spans[b],))

    # --- routing: same generation bookkeeping as _route_and_sync, but
    # the shard-local reduction is kept alongside so the update path can
    # consume it without paying the all-gather --------------------------
    consumed_new = phase.do_update and phase.update_source == "new"
    consumed_cur = phase.do_update and phase.update_source == "cur"
    nb = layout.n_buckets
    gen_sh: List[Optional[jax.Array]] = [None] * nb
    cur_sh: List[Optional[jax.Array]] = [None] * nb
    if phase.rotate:
        gen_pre = [g + f for g, f in zip(g_flat, fut)]
        gen = []
        for b, x in enumerate(gen_pre):
            if phase.route_new[b] == "sync":
                gen_sh[b] = rs_shard(x)
                # stored full only when this generation survives the
                # phase (it becomes new_cur); a consumed one stays 1/N
                gen.append(x if consumed_new else gather(gen_sh[b]))
            else:
                gen.append(x)
        new_fut = [jnp.zeros_like(f) for f in fut]
    else:
        gen = None
        new_fut = [f + g for f, g in zip(fut, g_flat)]
    cur_synced = []
    for b, c in enumerate(cur):
        if phase.sync_cur[b]:
            cur_sh[b] = rs_shard(c)
            cur_synced.append(c if consumed_cur else gather(cur_sh[b]))
        else:
            cur_synced.append(c)

    if phase.do_update:
        src = cur_synced if consumed_cur else gen
        src_shards = cur_sh if consumed_cur else gen_sh
        # shard-local merged gradient: the fresh reduce-scatter result
        # where this phase synced the bucket, else this device's span of
        # the stored (already-summed) accumulator
        src_sh = [
            src_shards[b] if src_shards[b] is not None
            else slice_shard(src[b], b)
            for b in range(nb)
        ]
        scale = 1.0 / (n_dp * phase.update_k)
        pbuf_sh, opt, _ = apply_bucket_updates(
            opt_spec, segments, pbuf_sh, src_sh, opt,
            grad_scale=scale, zero_grads=False, impl=update_impl,
            shard_id=shard_id,
            norm_psum=lambda t: jax.lax.psum(t, shard_axis),
        )
        pbuf_sh = list(pbuf_sh)
        if consumed_cur and gen is not None:
            new_cur = gen
        else:
            new_cur = [jnp.zeros_like(c) for c in cur_synced]
    elif phase.rotate:
        new_cur = gen
    else:
        new_cur = cur_synced

    metrics = _fused_metrics(loss, parts, phase, dp_axes, n_dp)
    new_state = {
        "pbuf": tuple(pbuf_sh),
        "opt": opt,
        "cur": tuple(c[None] for c in new_cur),
        "fut": tuple(f[None] for f in new_fut),
    }
    return new_state, metrics


# ---------------------------------------------------------------------------
# shard_map wrappers (fused variants of steps.deft_phase_step / _rs_)
# ---------------------------------------------------------------------------
# steps._state_specs is layout-agnostic (params/opt replicated, cur/fut
# split on the leading device axis) and works unchanged on the fused
# tuple-shaped accumulators.
_fused_state_specs = _state_specs

_METRIC_SPECS = {"loss": P(), "ce": P(), "aux": P(), "updated": P(), "k": P()}


def _flat_state_specs(state: TrainState, dp_axes: Tuple[str, ...]):
    """Manual-axis specs for the flat-resident state: param buffers and
    optimizer moments replicated over DP, accumulators split on their
    leading device axis."""
    rep = jax.tree.map(
        lambda _: P(), {"pbuf": state["pbuf"], "opt": state["opt"]}
    )
    acc = jax.tree.map(
        lambda _: P(dp_axes if len(dp_axes) > 1 else dp_axes[0]),
        {"cur": state["cur"], "fut": state["fut"]},
    )
    return {**rep, **acc}


def _flat_rs_state_specs(
    state: TrainState, dp_axes: Tuple[str, ...], shard_axis: str
):
    """Manual-axis specs for the SHARDED flat-resident state: param and
    moment buffers split over the shard axis (each device holds one
    contiguous span), the step counter replicated, accumulators split on
    their leading device axis as usual."""
    shard = jax.tree.map(
        lambda x: P() if x.ndim == 0 else P(shard_axis),
        {"pbuf": state["pbuf"], "opt": state["opt"]},
    )
    acc = jax.tree.map(
        lambda _: P(dp_axes if len(dp_axes) > 1 else dp_axes[0]),
        {"cur": state["cur"], "fut": state["fut"]},
    )
    return {**shard, **acc}


def _shard_phase(body, specs_fn, state, batch, mesh, dp_axes):
    """The one shard_map invocation every phase wrapper shares (state
    specs from ``specs_fn``, batch split over DP, fused metric specs)."""
    in_specs = (specs_fn(state, dp_axes), _batch_specs(batch, dp_axes))
    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(specs_fn(state, dp_axes), _METRIC_SPECS),
        axis_names=set(dp_axes),
        check_vma=False,
    )(state, batch)


def deft_phase_step_flat(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    segments: BucketSegments,
    treedef,
    mesh,
    multi_pod: bool = False,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
    update_impl: Optional[str] = None,
    compute_dtype=None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """Flat-resident DeFT phase with explicit DP (params replicated)."""
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    body = functools.partial(
        _deft_body_flat,
        cfg=cfg,
        opt_spec=opt_spec,
        phase=phase,
        layout=layout,
        segments=segments,
        treedef=treedef,
        dp_axes=dp_axes,
        dp_sizes=_dp_sizes(mesh, dp_axes),
        rules=rules_deft_manual_dp(),
        remat=remat,
        loss_chunk=loss_chunk,
        unroll=unroll,
        update_impl=update_impl,
        compute_dtype=compute_dtype,
    )
    return _shard_phase(body, _flat_state_specs, state, batch, mesh, dp_axes)


def deft_rs_phase_step_flat(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    segments: BucketSegments,
    treedef,
    mesh,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
    update_impl: Optional[str] = None,
    compute_dtype=None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """Sharded flat-resident DeFT phase (the FSDP/RS engine): manual over
    every DP axis, param/moment buffers split 1/N over the innermost
    ('data') axis, hierarchical RS -> pod all-reduce -> AG syncs.

    Unlike the tree-state RS path (manual over 'pod' only, FSDP left to
    XLA), the whole DP hierarchy is explicit here, so the engine also
    runs on single-pod meshes — 'pod' is simply absent from the sync.

    Old-jaxlib caveat (composes with DESIGN.md §6): the tiled
    psum_scatter/all_gather chain partitions correctly inside a
    partial-manual region only when the auto (model) axis is size 1 on
    jaxlib < 0.5; real TP + this engine needs jax >= 0.5 — the same
    constraint the tree RS path already has.
    """
    shard_axis = "data"
    assert shard_axis in mesh.axis_names, "sharded flat engine needs 'data'"
    dp_axes = (
        ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    )
    body = functools.partial(
        _deft_body_flat_rs,
        cfg=cfg,
        opt_spec=opt_spec,
        phase=phase,
        layout=layout,
        segments=segments,
        treedef=treedef,
        dp_axes=dp_axes,
        shard_axis=shard_axis,
        dp_sizes=_dp_sizes(mesh, dp_axes),
        rules=rules_deft_manual_dp(),
        remat=remat,
        loss_chunk=loss_chunk,
        unroll=unroll,
        update_impl=update_impl,
        compute_dtype=compute_dtype,
    )
    specs_fn = lambda s, axes: _flat_rs_state_specs(s, axes, shard_axis)
    return _shard_phase(body, specs_fn, state, batch, mesh, dp_axes)


def deft_phase_step_fused(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    mesh,
    multi_pod: bool = False,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """Fused DeFT phase with explicit DP (params replicated over DP)."""
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    body = functools.partial(
        _deft_body_fused,
        cfg=cfg,
        opt_spec=opt_spec,
        phase=phase,
        layout=layout,
        dp_axes=dp_axes,
        dp_sizes=_dp_sizes(mesh, dp_axes),
        rules=rules_deft_manual_dp(),
        remat=remat,
        loss_chunk=loss_chunk,
        unroll=unroll,
    )
    return _shard_phase(body, _fused_state_specs, state, batch, mesh, dp_axes)


def deft_rs_phase_step_fused(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    mesh,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """Fused DeFT hierarchical path (FSDP archs): manual over 'pod' only."""
    assert "pod" in mesh.axis_names, "DeFT-RS needs the multi-pod mesh"
    dp_axes = ("pod",)
    body = functools.partial(
        _deft_body_fused,
        cfg=cfg,
        opt_spec=opt_spec,
        phase=phase,
        layout=layout,
        dp_axes=dp_axes,
        dp_sizes=_dp_sizes(mesh, dp_axes),
        rules=rules_deft_rs_manual_pod(),
        remat=remat,
        loss_chunk=loss_chunk,
        unroll=unroll,
    )
    return _shard_phase(body, _fused_state_specs, state, batch, mesh, dp_axes)


# ---------------------------------------------------------------------------
# Collective accounting (static, from the phase spec)
# ---------------------------------------------------------------------------
def phase_collectives(phase: PhaseSpec) -> Dict[str, int]:
    """Collectives one fused phase issues, by construction: one primary
    psum per primary-synced bucket, one reduce-scatter chain per
    secondary-synced bucket, plus the single fused metrics psum.

    On the sharded flat engine every sync is one hierarchical chain
    (these counts still bound the per-bucket syncs), plus one param
    all-gather per bucket for the ZeRO forward — see DESIGN.md §8."""
    n = len(phase.route_new)
    synced = [
        (phase.route_new[b] == "sync" and phase.rotate) or phase.sync_cur[b]
        for b in range(n)
    ]
    primary = sum(1 for b in range(n) if synced[b] and not phase.secondary[b])
    secondary = sum(1 for b in range(n) if synced[b] and phase.secondary[b])
    return {"primary": primary, "secondary": secondary, "metrics": 1}


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PhaseStats:
    """Per-unique-phase lifecycle stats."""

    lower_s: float = 0.0
    compile_s: float = 0.0
    dispatches: int = 0
    dispatch_s: float = 0.0


def _abstractify(x):
    """Shape/dtype/sharding snapshot of a (possibly soon-donated) array;
    passes ShapeDtypeStructs and non-array leaves through unchanged."""
    if isinstance(x, jax.ShapeDtypeStruct) or not hasattr(x, "dtype"):
        return x
    sharding = getattr(x, "sharding", None)
    try:
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sharding)
    except TypeError:  # older jax: no sharding kwarg
        return jax.ShapeDtypeStruct(x.shape, x.dtype)


class _PhaseEntry:
    """One unique PhaseSpec's executable lifecycle: the donated jitted
    callable, its AOT-compiled executable (once built) and stats.  Entries
    live in the runtime's *persistent* phase cache — a replanned schedule
    that reuses a PhaseSpec reuses its compiled executable verbatim."""

    __slots__ = ("spec", "jitted", "compiled", "stats")

    def __init__(self, spec: PhaseSpec, jitted: Callable):
        self.spec = spec
        self.jitted = jitted
        self.compiled: Optional[Callable] = None
        self.stats = PhaseStats()


class DeftRuntime:
    """Owns the per-phase executables of one (evolving) DeFT schedule.

    Lifecycle (DESIGN.md §5/§7):

    1. construction dedupes ``schedule.phases`` by spec signature and
       builds one donated jitted callable per *unique* phase;
    2. :meth:`compile` lowers + compiles each unique phase ahead of time
       against concrete (or abstract) state/batch, recording timings;
    3. :meth:`step` dispatches the step's cycle phase through the AOT
       cache (falling back to the jitted callable if :meth:`compile` was
       skipped — first dispatch then pays the compile);
    4. :meth:`prepare_swap` stages a replanned schedule: unseen phases
       are lowered + compiled (optionally on a background thread while
       training continues), previously-seen phases are reused from the
       persistent cache, and the new schedule is installed atomically at
       the next cycle boundary — the donated train state carries across
       untouched because a replan over the same :class:`BucketLayout`
       leaves every buffer shape and sharding unchanged.

    All phase executables donate the train state: callers MUST treat the
    state passed to :meth:`step` as consumed and continue with the
    returned one.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        opt_spec: OptimizerSpec,
        schedule: DeftSchedule,
        layout: BucketLayout,
        mesh,
        *,
        multi_pod: bool = False,
        fsdp: bool = False,
        remat: bool = True,
        loss_chunk: int = 0,
        unroll: bool = False,
        donate: bool = True,
        flat_state: Optional[bool] = None,
        update_impl: Optional[str] = None,
        compute_dtype=None,
    ):
        self.cfg = cfg
        self.opt_spec = opt_spec
        self.layout = layout
        self.mesh = mesh
        self.fsdp = fsdp
        self.multi_pod = multi_pod
        self.donate = donate
        self._remat = remat
        self._loss_chunk = loss_chunk
        self._unroll = unroll
        # flat-resident state (DESIGN.md §8): the default everywhere.
        # On the FSDP/RS path the flat engine SHARDS the param/moment
        # buffers 1/N over 'data' (shard-aware BucketLayout) instead of
        # replicating them, so the memory-bound archs keep their ZeRO
        # residency and still get the fused bucket-update kernels.
        self.flat_state = True if flat_state is None else flat_state
        self.update_impl = update_impl
        # mixed precision (flat engines only): forward/backward in
        # compute_dtype against the f32 master buffers
        self.compute_dtype = compute_dtype
        self._treedef = None
        self._segments: Optional[BucketSegments] = None
        shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        if self.flat_state:
            params_abs = jax.eval_shape(
                lambda: init_params(jax.random.PRNGKey(0), cfg)
            )
            leaves, self._treedef = jax.tree_util.tree_flatten(params_abs)
            assert tuple(tuple(l.shape) for l in leaves) == layout.shapes, (
                "BucketLayout does not match this config's parameter tree"
            )
            self._segments = build_segments(layout, opt_spec)
        if self.flat_state and fsdp:
            n_shards = int(shape["data"])
            if layout.shards != n_shards:
                raise ValueError(
                    f"sharded flat engine: BucketLayout was built with "
                    f"shard_count={layout.shards} but the mesh 'data' axis "
                    f"is {n_shards}-way — build the layout with "
                    f"build_bucket_layout(..., shard_count={n_shards})"
                )
        if fsdp:
            # tree state: manual over 'pod' only (FSDP left to XLA);
            # sharded flat state: the whole DP hierarchy is explicit
            if self.flat_state:
                self.dp_axes: Tuple[str, ...] = (
                    ("pod", "data") if "pod" in mesh.axis_names
                    else ("data",)
                )
            else:
                self.dp_axes = ("pod",)
        else:
            self.dp_axes = ("pod", "data") if multi_pod else ("data",)
        self.accum_devices = 1
        for a in self.dp_axes:
            self.accum_devices *= int(shape[a])

        # persistent phase cache: PhaseSpec -> executable entry.  Survives
        # hot-swaps; schedules only reference into it.
        self._entries: Dict[PhaseSpec, _PhaseEntry] = {}
        # hot-swap state
        self._cycle_base = 0               # step at which the cycle restarts
        self._pending: Optional[DeftSchedule] = None
        self._swap_gen = 0                 # stale background builds don't publish
        self._swap_thread: Optional[threading.Thread] = None
        self.replans = 0                   # schedules staged via prepare_swap
        self.hot_swaps = 0                 # schedules actually installed
        self.swap_log: List[Dict[str, Any]] = []
        self.last_phase = 0                # cycle phase of the last dispatch
        self._install(schedule)

    # ---- schedule installation ------------------------------------------
    def _make_jitted(self, phase: PhaseSpec) -> Callable:
        if self.flat_state:
            step_impl = (
                deft_rs_phase_step_flat if self.fsdp
                else deft_phase_step_flat
            )
        else:
            step_impl = (
                deft_rs_phase_step_fused if self.fsdp
                else deft_phase_step_fused
            )
        kw = dict(
            cfg=self.cfg,
            opt_spec=self.opt_spec,
            phase=phase,
            layout=self.layout,
            mesh=self.mesh,
            remat=self._remat,
            loss_chunk=self._loss_chunk,
            unroll=self._unroll,
        )
        if self.flat_state:
            kw.update(
                segments=self._segments,
                treedef=self._treedef,
                update_impl=self.update_impl,
                compute_dtype=self.compute_dtype,
            )
        if not self.fsdp:
            kw["multi_pod"] = self.multi_pod
        return jax.jit(
            functools.partial(step_impl, **kw),
            donate_argnums=(0,) if self.donate else (),
        )

    def _ensure_entries(
        self, schedule: DeftSchedule
    ) -> Tuple[List[_PhaseEntry], int]:
        """Create cache entries for the schedule's unseen PhaseSpecs.
        Returns (entries needing compile, number reused from cache)."""
        fresh: List[_PhaseEntry] = []
        reused = 0
        for phase in schedule.phases:
            if phase in self._entries:
                reused += 1
                continue
            entry = _PhaseEntry(phase, self._make_jitted(phase))
            self._entries[phase] = entry
            fresh.append(entry)
        return fresh, reused

    def _install(self, schedule: DeftSchedule) -> None:
        self._ensure_entries(schedule)
        self.schedule = schedule
        self._unique: List[PhaseSpec] = []
        index_of: Dict[PhaseSpec, int] = {}
        for phase in schedule.phases:
            if phase not in index_of:
                index_of[phase] = len(self._unique)
                self._unique.append(phase)
        self.phase_of_step: Tuple[int, ...] = tuple(
            index_of[p] for p in schedule.phases
        )

    # ---- state ----------------------------------------------------------
    @property
    def period(self) -> int:
        return self.schedule.period

    @property
    def n_unique_phases(self) -> int:
        return len(self._unique)

    @property
    def n_cached_phases(self) -> int:
        """Unique phases ever compiled/jitted, across all installed
        schedules (the persistent cache's size)."""
        return len(self._entries)

    def phase_in_cycle(self, i: int) -> int:
        """Cycle phase step ``i`` will dispatch.  Correct across swaps:
        a staged schedule installs exactly at a boundary, where both the
        old and the new cycle agree the phase is 0."""
        return (i - self._cycle_base) % self.period

    def phase_executable(self, offset: int) -> Callable:
        """The donated executable behind cycle phase ``offset`` — the
        AOT-compiled one when :meth:`compile` ran, else the jitted
        callable.  Public handle for benchmarks/tools that dispatch one
        phase directly without the :meth:`step` bookkeeping."""
        entry = self._entries[self._unique[self.phase_of_step[offset]]]
        return entry.compiled if entry.compiled is not None else entry.jitted

    def init_state(self, key, dtype=jnp.float32) -> TrainState:
        """Fresh train state, committed to the shardings the phase
        executables expect — params/opt replicated, accumulators split on
        their leading device axis.  Committed placement is what lets XLA
        alias the donated input buffers (an uncommitted array would be
        resharded at dispatch and could not be updated in place).

        Flat-state runtimes return ``{pbuf, opt, cur, fut}`` — params
        and moments as per-bucket flat f32 buffers (the master copy; see
        :meth:`params_tree` / :meth:`state_to_tree` for the checkpoint /
        eval boundary).  On the sharded FSDP/RS engine the buffers are
        committed split over 'data' (each device holds its span), so
        optimizer state is 1/N-resident from step 0.

        A non-f32 ``dtype`` on a flat runtime selects the *initialization
        rounding* of the mixed-precision path: params are drawn at
        ``dtype`` (matching the tree-path init bit-for-bit) and promoted
        into the f32 master; the runtime must have been built with
        ``compute_dtype=dtype`` so the forward casts back down at the
        buffer views."""
        from jax.sharding import NamedSharding

        if self.flat_state and dtype != jnp.float32 \
                and dtype != self.compute_dtype:
            raise ValueError(
                f"flat_state keeps an f32 master copy; init dtype={dtype} "
                f"needs the runtime built with compute_dtype={dtype} so "
                f"the forward runs at that precision (got "
                f"compute_dtype={self.compute_dtype}) — or use "
                f"flat_state=False for non-f32 resident params "
                f"(DESIGN.md §8)"
            )
        params = init_params(key, self.cfg, dtype=dtype)
        dp = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        rep = NamedSharding(self.mesh, P())
        split = NamedSharding(self.mesh, P(dp))
        acc = init_fused_accumulators(self.layout, self.accum_devices)
        if self.flat_state:
            # flat f32 master copy — one buffer per bucket (flatten
            # promotes a low-precision init to f32)
            pbuf = tuple(
                flatten_buckets(self.layout, jax.tree_util.tree_leaves(params))
            )
            opt = init_flat_opt_state(self.opt_spec, self.layout.buf_sizes)
            # sharded engine: commit buffers split over 'data' so every
            # device materializes only its 1/N span
            buf = NamedSharding(self.mesh, P("data")) if self.fsdp else rep
            opt_shardings = jax.tree.map(
                lambda x: rep if x.ndim == 0 else buf, opt
            )
            return {
                "pbuf": jax.device_put(pbuf, buf),
                "opt": jax.tree.map(jax.device_put, opt, opt_shardings),
                "cur": jax.device_put(acc["cur"], split),
                "fut": jax.device_put(acc["fut"], split),
            }
        return {
            "params": jax.device_put(params, rep),
            "opt": jax.device_put(init_opt_state(self.opt_spec, params), rep),
            "cur": jax.device_put(acc["cur"], split),
            "fut": jax.device_put(acc["fut"], split),
        }

    # ---- checkpoint / eval boundary (tree <-> flat) ---------------------
    def params_tree(self, state: TrainState):
        """Parameter pytree view of a train state.  For flat-state
        runtimes this is THE unflatten boundary — steady-state steps
        never materialize the tree; call this only at checkpoint / eval
        / debug points."""
        if not self.flat_state:
            return state["params"]
        return jax.tree_util.tree_unflatten(
            self._treedef, unflatten_buckets(self.layout, state["pbuf"])
        )

    def state_to_tree(self, state: TrainState) -> TrainState:
        """Checkpoint-friendly tree form {params, opt{step,m[,v]}} of a
        train state (accumulators pass through unchanged)."""
        if not self.flat_state:
            return state
        unflat = lambda bufs: jax.tree_util.tree_unflatten(
            self._treedef, unflatten_buckets(self.layout, bufs)
        )
        opt: Dict[str, Any] = {"step": state["opt"]["step"],
                               "m": unflat(state["opt"]["m"])}
        if "v" in state["opt"]:
            opt["v"] = unflat(state["opt"]["v"])
        return {"params": self.params_tree(state), "opt": opt,
                "cur": state["cur"], "fut": state["fut"]}

    def tree_to_state(self, tree_state: TrainState) -> TrainState:
        """Inverse of :meth:`state_to_tree` — restore a checkpointed tree
        into the runtime's resident representation."""
        if not self.flat_state:
            return tree_state
        flat = lambda t: tuple(
            flatten_buckets(self.layout, jax.tree_util.tree_leaves(t))
        )
        opt: Dict[str, Any] = {"step": tree_state["opt"]["step"],
                               "m": flat(tree_state["opt"]["m"])}
        if "v" in tree_state["opt"]:
            opt["v"] = flat(tree_state["opt"]["v"])
        return {"pbuf": flat(tree_state["params"]), "opt": opt,
                "cur": tree_state["cur"], "fut": tree_state["fut"]}

    # ---- AOT phase cache ------------------------------------------------
    def _compile_entries(
        self, entries: Sequence[_PhaseEntry], state, batch
    ) -> Dict[str, float]:
        out: Dict[str, float] = {}
        with jax.set_mesh(self.mesh):
            for i, entry in enumerate(entries):
                if entry.compiled is not None:
                    continue
                t0 = time.perf_counter()
                lowered = entry.jitted.lower(state, batch)
                t1 = time.perf_counter()
                entry.compiled = lowered.compile()
                t2 = time.perf_counter()
                entry.stats.lower_s = t1 - t0
                entry.stats.compile_s = t2 - t1
                out[f"phase{i}"] = t2 - t0
        return out

    def compile(self, state: TrainState, batch) -> Dict[str, float]:
        """Lower + compile every unique phase of the installed schedule
        ahead of the first step.

        ``state``/``batch`` may be concrete arrays or ShapeDtypeStructs.
        Returns {phase_index: seconds} wall-clock compile times.
        """
        return self._compile_entries(
            [self._entries[p] for p in self._unique], state, batch
        )

    # ---- hot-swap -------------------------------------------------------
    def prepare_swap(
        self,
        schedule: DeftSchedule,
        state: TrainState,
        batch,
        *,
        background: bool = False,
    ) -> Dict[str, Any]:
        """Stage a replanned schedule for installation at the next cycle
        boundary.

        Unseen PhaseSpecs are lowered + compiled against the current
        state/batch shapes (``lower`` only reads avals — it never consumes
        the donated buffers); PhaseSpecs already in the persistent cache
        reuse their compiled executables.  With ``background=True`` the
        compile happens on a daemon thread while training keeps stepping
        the old schedule; the swap arms only once compilation finishes, so
        :meth:`step` never blocks on a half-built schedule.

        The swap itself (see :meth:`step`) is a pure Python pointer flip
        at ``(i - cycle_base) % period == 0``: the donated train state
        carries across untouched because every replan shares this
        runtime's :class:`BucketLayout` — params, opt moments and both
        per-bucket accumulator sets keep their shapes and shardings.
        """
        fresh, reused = self._ensure_entries(schedule)
        self.replans += 1
        info: Dict[str, Any] = {
            "new_phases": len(fresh),
            "reused_phases": reused,
            "background": background,
        }
        # snapshot avals NOW: the caller keeps training, and donation
        # deletes the concrete state buffers under the background thread
        state_abs = jax.tree.map(_abstractify, state)
        batch_abs = jax.tree.map(_abstractify, batch)
        self._swap_gen += 1
        gen = self._swap_gen
        self._pending = None   # a newer replan supersedes any armed one

        def _build() -> None:
            t0 = time.perf_counter()
            self._compile_entries(fresh, state_abs, batch_abs)
            info["compile_s"] = time.perf_counter() - t0
            # publish last — step() sees the schedule only fully compiled —
            # and only if no NEWER prepare_swap superseded this one (a slow
            # older compile must not overwrite a fresher staged schedule)
            if self._swap_gen == gen:
                self._pending = schedule

        if background:
            self._swap_thread = threading.Thread(
                target=_build, name="deft-swap-compile", daemon=True
            )
            self._swap_thread.start()
        else:
            _build()
        return info

    def swap_ready(self) -> bool:
        """A staged schedule is compiled and armed for the next cycle
        boundary."""
        return self._pending is not None

    def wait_swap_ready(self, timeout: Optional[float] = None) -> bool:
        """Block until a background prepare_swap finishes compiling."""
        if self._swap_thread is not None:
            self._swap_thread.join(timeout)
        return self.swap_ready()

    # ---- dispatch -------------------------------------------------------
    def step(
        self, i: int, state: TrainState, batch
    ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        """Run training step ``i`` (cycle phase ``(i - cycle_base) %
        period``).  Consumes ``state`` when donation is on.  If a staged
        schedule is armed and ``i`` lands on a cycle boundary, it is
        installed first and ``i`` becomes step 0 of the new cycle."""
        if self._pending is not None and (i - self._cycle_base) % self.period == 0:
            pending, self._pending = self._pending, None
            self._install(pending)
            self._cycle_base = i
            self.hot_swaps += 1
            self.swap_log.append(
                {"step": i, "period": pending.period,
                 "updates_per_period": pending.updates_per_period}
            )
        off = (i - self._cycle_base) % self.period
        self.last_phase = off
        entry = self._entries[self._unique[self.phase_of_step[off]]]
        t0 = time.perf_counter()
        if entry.compiled is not None:
            out = entry.compiled(state, batch)
        else:  # compile() skipped — trace under the mesh on first hit
            with jax.set_mesh(self.mesh):
                out = entry.jitted(state, batch)
        entry.stats.dispatches += 1
        entry.stats.dispatch_s += time.perf_counter() - t0
        return out

    # ---- reporting ------------------------------------------------------
    def collectives_per_phase(self) -> List[Dict[str, int]]:
        """Static per-schedule-phase collective counts (fused path)."""
        return [phase_collectives(p) for p in self.schedule.phases]

    def stats(self) -> Dict[str, Any]:
        entries = list(self._entries.values())
        per_phase = [dataclasses.asdict(e.stats) for e in entries]
        total_compile = sum(
            e.stats.lower_s + e.stats.compile_s for e in entries
        )
        total_dispatch = sum(e.stats.dispatch_s for e in entries)
        n = sum(e.stats.dispatches for e in entries)
        coll = self.collectives_per_phase()
        from repro.kernels.bucket_update import default_bucket_update_impl

        return {
            "period": self.period,
            "unique_phases": self.n_unique_phases,
            "cached_phases": self.n_cached_phases,
            "flat_state": self.flat_state,
            "sharded_state": bool(self.flat_state and self.fsdp),
            "shards": self.layout.shards,
            "compute_dtype": (
                jnp.dtype(self.compute_dtype).name
                if self.compute_dtype is not None else "float32"
            ),
            "update_impl": (
                (self.update_impl or default_bucket_update_impl())
                if self.flat_state else "per-leaf"
            ),
            "accum_devices": self.accum_devices,
            "n_buckets": self.layout.n_buckets,
            "n_leaves": self.layout.n_leaves,
            "compile_s_total": total_compile,
            "steps_dispatched": n,
            "dispatch_s_total": total_dispatch,
            # dispatch-wall throughput: what the benchmarks report without
            # re-deriving it from their own timers
            "steps_per_s": n / total_dispatch if total_dispatch > 0 else 0.0,
            "replans": self.replans,
            "hot_swaps": self.hot_swaps,
            "swap_log": list(self.swap_log),
            "collectives_per_phase": coll,
            "max_collectives_in_a_phase": max(
                (c["primary"] + c["secondary"] for c in coll), default=0
            ),
            "phases": per_phase,
        }


# ---------------------------------------------------------------------------
# Convenience constructors
# ---------------------------------------------------------------------------
def make_ddp_step(
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    *,
    fsdp: bool = False,
    multi_pod: bool = False,
    donate: bool = True,
    **kw,
) -> Callable:
    """Donated jitted DDP baseline step (params/opt update in place)."""
    return jax.jit(
        functools.partial(
            ddp_train_step, cfg=cfg, opt_spec=opt_spec,
            fsdp=fsdp, multi_pod=multi_pod, **kw,
        ),
        donate_argnums=(0,) if donate else (),
    )
