"""DeftRuntime: the production DeFT execution engine.

Replaces the ad-hoc per-phase step-fn list of ``train/steps.py`` with a
runtime that owns the whole compiled-phase lifecycle (see DESIGN.md):

* **Bucket-fused collectives** — gradients are packed per bucket into one
  contiguous f32 buffer using the static :class:`BucketLayout` (offsets /
  sizes precomputed at plan time), so each phase issues exactly ONE
  ``psum`` (or one hierarchical reduce-scatter chain on the secondary
  link) per *synced bucket* instead of one per parameter leaf.  The
  ``cur``/``fut`` gradient-generation accumulators are per-bucket flat
  buffers; accumulate / zero / rotate act on whole buffers and the
  leaf tree is only reassembled in update phases.
* **Buffer donation** — every phase executable (and the DDP baseline via
  :func:`make_ddp_step`) donates the train state, so params, optimizer
  moments and both accumulators update in place instead of being copied
  each step.
* **AOT phase cache** — phases are deduped by ``PhaseSpec`` signature and
  lowered + compiled ahead of the first step; ``step(i)`` dispatches the
  cached executable for ``i % period`` and the runtime exposes compile /
  dispatch timing stats.
* **Flat-resident state** (default, DESIGN.md §8) — params and optimizer
  moments live as per-bucket flat f32 buffers for the whole period, not
  as trees: the forward unflattens with static slice/reshape views, and
  update phases apply the optimizer with ONE fused bucket-update kernel
  per bucket (Pallas on TPU, lax fallback elsewhere — see
  ``kernels/bucket_update``) instead of per-leaf ``apply_updates`` over
  hundreds of tiny tensors.  The tree form exists only at checkpoint /
  eval boundaries (:meth:`DeftRuntime.params_tree` /
  :meth:`DeftRuntime.state_to_tree`).

The per-leaf path in ``train/steps.py`` is kept as the semantic
reference (tests prove flat == fused-tree == per-leaf == the gradient-
accumulation reference) and as the benchmark baseline; the PR-1
tree-state fused path remains available via ``flat_state=False``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.precision import WIRE_BYTES
from repro.core.scheduler import DeftSchedule, PhaseSpec
from repro.kernels.bucket_update import (
    BucketSegments,
    apply_bucket_updates,
    build_segments,
    init_flat_opt_state,
)
from repro.kernels.quantize import (
    cast_compute,
    dequantize_int8,
    quantize_dequantize_int8,
    quantize_int8,
    stochastic_round_bf16,
)
from repro.models.model import init_params, loss_fn
from repro.obs.trace import Tracer
from repro.optim.optimizers import OptimizerSpec, apply_updates, init_opt_state
from repro.sharding import (
    logical_rules,
    rules_deft_manual_dp,
    rules_deft_rs_manual_pod,
)
from repro.train.bucketing import (
    BucketLayout,
    LayoutTransition,
    build_layout_transition,
    flatten_buckets,
    repack_buffers,
    unflatten_buckets,
)
from repro.train.chains import chain_all_gather, chain_reduce_scatter
from repro.train.streaming import lazy_param_tree
from repro.train.steps import (
    TrainState,
    _batch_specs,
    _dp_sizes,
    _state_specs,
    _sync_primary,
    _sync_secondary,
    ddp_train_step,
)


def init_fused_accumulators(
    layout: BucketLayout, accum_devices: int
) -> Dict[str, Tuple[jax.Array, ...]]:
    """Per-bucket flat f32 accumulators with a leading device axis."""
    zeros = lambda: tuple(
        jnp.zeros((accum_devices, s), jnp.float32) for s in layout.buf_sizes
    )
    return {"cur": zeros(), "fut": zeros()}


# ---------------------------------------------------------------------------
# Shared per-bucket routing (identical for tree-state and flat-state paths)
# ---------------------------------------------------------------------------
def _route_and_sync(phase: PhaseSpec, g_flat, cur, fut, sync):
    """DeFT generation bookkeeping on per-bucket flat buffers.

    Returns (gen, new_fut, cur_synced): the merged fresh generation (or
    None when not rotating), the next future accumulator, and the older
    generation with this phase's scheduled collectives applied.
    """
    if phase.rotate:
        # fresh generation merges with the future accumulator (Cases 3/4)
        gen = [g + f for g, f in zip(g_flat, fut)]
        gen = [
            sync(x, b) if phase.route_new[b] == "sync" else x
            for b, x in enumerate(gen)
        ]
        new_fut = [jnp.zeros_like(f) for f in fut]
    else:
        # Cases 1/2: fresh gradients accumulate locally
        gen = None
        new_fut = [f + g for f, g in zip(fut, g_flat)]

    # older generation buckets scheduled this phase (fwd Case 1 + bwd 2/3)
    cur_synced = [
        sync(c, b) if phase.sync_cur[b] else c for b, c in enumerate(cur)
    ]
    return gen, new_fut, cur_synced


def _fused_metrics(loss, parts, phase: PhaseSpec, dp_axes, n_dp: int):
    """Loss and aux parts ride ONE fused psum, stacked to a vector."""
    part_keys = sorted(parts)
    stacked = jnp.stack([loss] + [parts[k] for k in part_keys])
    stacked = jax.lax.psum(stacked, dp_axes) / n_dp
    return {
        "loss": stacked[0],
        **{k: stacked[1 + j] for j, k in enumerate(part_keys)},
        "updated": jnp.asarray(phase.do_update),
        "k": jnp.asarray(phase.update_k, jnp.int32),
    }


def _cast_compute(params, compute_dtype):
    """Mixed-precision boundary of the flat engines: the master buffers
    are cast to the compute dtype at the static slice/reshape views, so
    the forward/backward runs in (e.g.) bf16 while the optimizer state
    stays full-precision (DESIGN.md §8).  Routed through the ONE cast
    site in kernels/quantize/ops.py (DESIGN.md §13) — both directions:
    a bf16sr resident master upcasts through the same call."""
    if compute_dtype is None:
        return params
    return jax.tree.map(lambda x: cast_compute(x, compute_dtype), params)


# ---------------------------------------------------------------------------
# Wire-precision edges (DESIGN.md §13)
# ---------------------------------------------------------------------------
def _layout_wire(layout: BucketLayout) -> Tuple[str, ...]:
    """Per-bucket wire dtype names; all-f32 when the layout carries no
    :class:`PrecisionPolicy`."""
    if layout.precision is None:
        return ("f32",) * layout.n_buckets
    return tuple(layout.precision.wire)


def _wire_sync(x: jax.Array, wire: str, collective) -> jax.Array:
    """Run a gradient-sum ``collective`` at a bucket's wire precision.

    * ``bf16`` genuinely halves the wire bytes: the reduction runs on
      bf16 values and the result is promoted back to f32 for routing and
      the optimizer.
    * ``int8`` projects the local contribution onto the blockwise int8
      grid and runs the sum in f32 — an int8 ring sum would overflow at
      the first hop, so this is value-exact emulation of the quantized
      wire (the knapsack and obs account the int8 representation's
      bytes; DESIGN.md §13).
    """
    if wire == "bf16":
        return collective(x.astype(jnp.bfloat16)).astype(jnp.float32)
    if wire == "int8":
        return collective(quantize_dequantize_int8(x.astype(jnp.float32)))
    return collective(x)


def _wire_gather(
    span: jax.Array, wire: str, gather, fwd_dtype
) -> jax.Array:
    """One param all-gather at a bucket's wire precision (the AG edge of
    the sharded flat engine).  ``fwd_dtype`` is what the forward reads
    (compute dtype, f32 by default): the gathered buffer is decoded back
    to it, so the wire dtype is invisible downstream.  int8 genuinely
    gathers int8 values plus the per-row f32 scales and dequantizes."""
    if wire == "int8":
        q, s = quantize_int8(span.astype(jnp.float32))
        full = dequantize_int8(gather(q), gather(s))
        return cast_compute(full, fwd_dtype)
    if wire == "bf16":
        return cast_compute(
            gather(cast_compute(span, jnp.bfloat16)), fwd_dtype
        )
    return gather(cast_compute(span, fwd_dtype))


# ---------------------------------------------------------------------------
# Fused DeFT phase body
# ---------------------------------------------------------------------------
def _deft_body_fused(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    dp_axes: Tuple[str, ...],
    dp_sizes: Dict[str, int],
    rules: Dict,
    remat: bool,
    loss_chunk: int = 0,
    unroll: bool = False,
    secondary_chain: Optional[Tuple[int, ...]] = None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One DeFT phase over per-bucket flat buffers, inside shard_map.

    ``cur``/``fut`` arrive with the leading device axis stripped to 1 by
    the manual mapping; we work on index [0] and re-add it on return.
    Every tensor this body syncs is a whole bucket buffer — there is no
    per-leaf collective and no tree flatten/unflatten outside the update
    branch.  With ``secondary_chain`` the secondary-assigned buckets run
    their all-reduce over that device-order ring chain (DESIGN.md §14)
    instead of the shared mesh axis.
    """
    n_dp = 1
    for a in dp_axes:
        n_dp *= dp_sizes[a]
    params, opt = state["params"], state["opt"]
    with logical_rules(rules):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=remat,
                              loss_chunk=loss_chunk, unroll=unroll),
            has_aux=True,
        )(params)

    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    g_flat = flatten_buckets(layout, g_leaves)         # one buffer per bucket
    cur = [c[0] for c in state["cur"]]
    fut = [f[0] for f in state["fut"]]

    def sync(x: jax.Array, b: int) -> jax.Array:
        if phase.secondary[b]:
            return _sync_secondary(x, dp_axes, dp_sizes,
                                   chain=secondary_chain)
        return _sync_primary(x, dp_axes)

    gen, new_fut, cur_synced = _route_and_sync(phase, g_flat, cur, fut, sync)

    if phase.do_update:
        src = cur_synced if phase.update_source == "cur" else gen
        grad_tree = jax.tree_util.tree_unflatten(
            treedef, unflatten_buckets(layout, src)
        )
        scale = 1.0 / (n_dp * phase.update_k)
        params, opt = apply_updates(opt_spec, params, grad_tree, opt,
                                    grad_scale=scale)
        if phase.update_source == "cur":
            new_cur = gen if gen is not None else [
                jnp.zeros_like(c) for c in cur_synced
            ]
        else:
            new_cur = [jnp.zeros_like(c) for c in cur_synced]
    elif phase.rotate:
        new_cur = gen
    else:
        new_cur = cur_synced

    metrics = _fused_metrics(loss, parts, phase, dp_axes, n_dp)
    new_state = {
        "params": params,
        "opt": opt,
        "cur": tuple(c[None] for c in new_cur),
        "fut": tuple(f[None] for f in new_fut),
    }
    return new_state, metrics


# ---------------------------------------------------------------------------
# Flat-resident DeFT phase body (params/opt as per-bucket flat buffers)
# ---------------------------------------------------------------------------
def _deft_body_flat(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    segments: BucketSegments,
    treedef,
    dp_axes: Tuple[str, ...],
    dp_sizes: Dict[str, int],
    rules: Dict,
    remat: bool,
    loss_chunk: int = 0,
    unroll: bool = False,
    update_impl: Optional[str] = None,
    compute_dtype=None,
    master_dtype: Optional[str] = None,
    secondary_chain: Optional[Tuple[int, ...]] = None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One DeFT phase with params and optimizer moments resident as
    per-bucket flat f32 buffers (DESIGN.md §8).

    The forward reads params through static slice/reshape views of the
    buffers (no per-leaf copies survive fusion); the update phase applies
    the optimizer with one fused bucket-update kernel per bucket and the
    accumulator zeroing rides the same launch.  No per-leaf O(num_params)
    op sequence exists anywhere in the steady-state step.
    """
    n_dp = 1
    for a in dp_axes:
        n_dp *= dp_sizes[a]
    pbuf, opt = state["pbuf"], state["opt"]
    params = jax.tree_util.tree_unflatten(
        treedef, unflatten_buckets(layout, pbuf)
    )
    params = _cast_compute(params, compute_dtype)
    with logical_rules(rules):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=remat,
                              loss_chunk=loss_chunk, unroll=unroll),
            has_aux=True,
        )(params)

    g_flat = flatten_buckets(layout, jax.tree_util.tree_leaves(grads))
    cur = [c[0] for c in state["cur"]]
    fut = [f[0] for f in state["fut"]]
    wire = _layout_wire(layout)

    def sync(x: jax.Array, b: int) -> jax.Array:
        if phase.secondary[b]:
            coll = lambda y: _sync_secondary(y, dp_axes, dp_sizes,
                                             chain=secondary_chain)
        else:
            coll = lambda y: _sync_primary(y, dp_axes)
        return _wire_sync(x, wire[b], coll)

    gen, new_fut, cur_synced = _route_and_sync(phase, g_flat, cur, fut, sync)

    if phase.do_update:
        src = cur_synced if phase.update_source == "cur" else gen
        # the consumed accumulator is replaced by the fresh generation
        # (rotate) or comes back zeroed fused from the update launch
        zero_grads = (phase.update_source == "new") or (gen is None)
        scale = 1.0 / (n_dp * phase.update_k)
        pbuf, opt, zeroed = apply_bucket_updates(
            opt_spec, segments, pbuf, src, opt,
            grad_scale=scale, zero_grads=zero_grads, impl=update_impl,
            master_dtype=master_dtype,
        )
        if phase.update_source == "cur" and gen is not None:
            new_cur = gen
        else:
            new_cur = list(zeroed)
    elif phase.rotate:
        new_cur = gen
    else:
        new_cur = cur_synced

    metrics = _fused_metrics(loss, parts, phase, dp_axes, n_dp)
    new_state = {
        "pbuf": tuple(pbuf),
        "opt": opt,
        "cur": tuple(c[None] for c in new_cur),
        "fut": tuple(f[None] for f in new_fut),
    }
    return new_state, metrics


# ---------------------------------------------------------------------------
# Sharded flat-resident DeFT phase body (FSDP/RS engine, DESIGN.md §8)
# ---------------------------------------------------------------------------
def _deft_body_flat_rs(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    segments: BucketSegments,
    treedef,
    dp_axes: Tuple[str, ...],
    shard_axis: str,
    dp_sizes: Dict[str, int],
    rules: Dict,
    remat: bool,
    loss_chunk: int = 0,
    unroll: bool = False,
    update_impl: Optional[str] = None,
    compute_dtype=None,
    master_dtype: Optional[str] = None,
    gather_reuse: Optional[Tuple[bool, ...]] = None,
    decoupled: bool = False,
    secondary_chain: Optional[Tuple[int, ...]] = None,
    ag_links: Optional[Tuple[bool, ...]] = None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One DeFT phase with params and optimizer moments SHARDED over
    ``shard_axis``: each device holds one contiguous 1/N span of every
    flat bucket buffer (``layout.shard_sizes``), ZeRO-style.

    * the forward all-gathers the updated param shards into full flat
      buffers and reads the tree through the usual static views;
      with ``gather_reuse[b]`` set (the gather-skip path, DESIGN.md §9)
      bucket ``b``'s gather is skipped and the full buffer is read from
      the ``pgather`` cache the previous phase stored — valid exactly
      when no update touched the params since that stored gather, a
      per-bucket generation tag that is STATIC per cycle position
      (updates are scheduled, not data-dependent), so the skip costs
      zero runtime bookkeeping;
    * with ``decoupled`` (DESIGN.md §12) the gathers are not issued as
      one up-front burst: each bucket's all-gather is traced at its
      first forward leaf access via the lazy param view, streaming AG
      traffic against forward compute (composes with ``gather_reuse`` —
      a skipped bucket reads the cache and emits no AG at all);
    * scheduled syncs are hierarchical by construction — reduce-scatter
      over ``shard_axis`` into shard-local buffers, all-reduce over the
      outer (pod/DCN) axes, all-gather back ONLY when the synced buffer
      must be stored full (a later phase consumes it).  A bucket synced
      and consumed in the same phase feeds its shard-local reduction
      straight to the update kernel with no trailing all-gather;
    * the fused bucket-update kernels run on the shard-local p/m/v spans
      (segment maps sliced per shard, clip norm psum'd across shards),
      so optimizer state stays 1/N-resident for the whole run.

    ``cur``/``fut`` stay full-length per-device accumulators: an
    unsynchronized generation holds contributions to EVERY span, which a
    later reduce-scatter folds into the owning shard.

    With ``secondary_chain`` (DESIGN.md §14) the per-link plan becomes
    executable: a bucket the scheduler assigned to the secondary link
    (``phase.secondary[b]``) runs its shard-axis reduce-scatter and any
    trailing all-gather over that device-order ring chain; a bucket whose
    streamed param AG was placed on the secondary link
    (``ag_links[b]``, from ``AgItem.link``) gathers over the chain too.
    The outer pod all-reduce is untouched — chain collectives are
    bitwise-equal to the single-axis ones they replace (train/chains.py),
    so routing never perturbs training.
    """
    n_dp = 1
    for a in dp_axes:
        n_dp *= dp_sizes[a]
    outer_axes = tuple(a for a in dp_axes if a != shard_axis)
    shard_id = jax.lax.axis_index(shard_axis)
    spans = layout.shard_sizes

    pbuf_sh, opt = state["pbuf"], state["opt"]
    # ZeRO forward: re-materialize full param buffers from the shards.
    # Mixed precision casts each span down BEFORE the gather — the cast
    # is elementwise so the params are bit-identical, and the param
    # all-gather (the engine's dominant per-phase comm term) moves half
    # the bytes in bf16 instead of shipping f32 and casting after.
    # Buckets flagged in ``gather_reuse`` skip the collective entirely
    # and read the previous phase's stored gather (bit-identical: params
    # did not change in between, by the static schedule).  With a
    # precision policy on the layout, each bucket's gather runs at its
    # wire dtype (bf16 half-width; int8 values + per-row scales) and is
    # decoded back to the forward dtype after the collective (§13).
    wire = _layout_wire(layout)
    fwd_dtype = compute_dtype if compute_dtype is not None else jnp.float32
    chained = lambda b: (
        secondary_chain is not None and ag_links is not None and ag_links[b]
    )
    ag_ = lambda x: jax.lax.all_gather(x, shard_axis, axis=0, tiled=True)
    ag_chain = lambda x: chain_all_gather(x, shard_axis, secondary_chain)
    gather_bucket = lambda b: _wire_gather(
        pbuf_sh[b], wire[b], ag_chain if chained(b) else ag_, fwd_dtype
    )
    cache = state.get("pgather")
    reuse = gather_reuse if (cache is not None and gather_reuse) \
        else (False,) * layout.n_buckets
    nb_ = layout.n_buckets
    if decoupled:
        # Decoupled AG streaming (DESIGN.md §12): no up-front gather
        # burst.  Params are a lazy per-bucket view — bucket ``b``'s
        # all-gather is traced at the FIRST forward leaf access, so the
        # jaxpr interleaves one AG per bucket with the forward blocks
        # that consume it (matching the planner's deadline items).
        # Gradients come from the zeros trick: differentiate w.r.t. a
        # full-size zero buffer added onto each gathered bucket — the
        # transpose of the disjoint leaf slices scatter-adds every leaf
        # cotangent into its span, i.e. ``flatten_buckets`` of the leaf
        # grads, bit-for-bit (cast commutes with concat elementwise),
        # without ever differentiating through the collective.
        zbufs = tuple(
            jnp.zeros((s,), fwd_dtype) for s in layout.buf_sizes
        )

        def run(z):
            gathered: Dict[int, jax.Array] = {}
            full: Dict[int, jax.Array] = {}

            def full_buf(b: int) -> jax.Array:
                if b not in full:
                    g = cache[b] if reuse[b] else gather_bucket(b)
                    gathered[b] = g
                    full[b] = g + z[b]
                return full[b]

            params = lazy_param_tree(treedef, layout, full_buf)
            loss, parts = loss_fn(params, cfg, batch, remat=remat,
                                  loss_chunk=loss_chunk, unroll=unroll)
            # a bucket the forward never read still needs its gather
            # for the pgather cache; its z-gradient stays zero
            for b in range(nb_):
                full_buf(b)
            return loss, (parts, tuple(gathered[b] for b in range(nb_)))

        with logical_rules(rules):
            (loss, (parts, pbuf_t)), gz = jax.value_and_grad(
                run, has_aux=True
            )(zbufs)
        pbuf = list(pbuf_t)
        g_flat = [g.astype(jnp.float32) for g in gz]
    else:
        pbuf = [
            cache[b] if reuse[b] else gather_bucket(b)
            for b in range(nb_)
        ]
        params = jax.tree_util.tree_unflatten(
            treedef, unflatten_buckets(layout, pbuf)
        )
        with logical_rules(rules):
            (loss, parts), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch, remat=remat,
                                  loss_chunk=loss_chunk, unroll=unroll),
                has_aux=True,
            )(params)

        g_flat = flatten_buckets(layout, jax.tree_util.tree_leaves(grads))
    cur = [c[0] for c in state["cur"]]
    fut = [f[0] for f in state["fut"]]

    def rs_shard(x: jax.Array, b: int) -> jax.Array:
        """Shard-local half of the hierarchical sync: reduce-scatter over
        the fast shard axis, all-reduce across the outer axes — run at
        bucket ``b``'s wire precision (§13).  A secondary-assigned bucket
        rides the secondary link's ring chain when one is configured
        (§14); the outer pod all-reduce stays on its own fabric either
        way, so the chain never has to split a joint-axis reduction."""
        on_chain = secondary_chain is not None and phase.secondary[b]

        def coll(v: jax.Array) -> jax.Array:
            if on_chain:
                y = chain_reduce_scatter(v, shard_axis, secondary_chain)
            else:
                y = jax.lax.psum_scatter(
                    v, shard_axis, scatter_dimension=0, tiled=True
                )
            if outer_axes:
                y = jax.lax.psum(y, outer_axes)
            return y

        return _wire_sync(x, wire[b], coll)

    def gather(y: jax.Array, b: int) -> jax.Array:
        """Trailing all-gather of a synced-and-stored bucket — on the
        same link its reduce-scatter used."""
        if secondary_chain is not None and phase.secondary[b]:
            return chain_all_gather(y, shard_axis, secondary_chain)
        return jax.lax.all_gather(y, shard_axis, axis=0, tiled=True)

    def slice_shard(x: jax.Array, b: int) -> jax.Array:
        """This device's span of an already-summed full buffer."""
        return jax.lax.dynamic_slice(x, (shard_id * spans[b],), (spans[b],))

    # --- routing: same generation bookkeeping as _route_and_sync, but
    # the shard-local reduction is kept alongside so the update path can
    # consume it without paying the all-gather --------------------------
    consumed_new = phase.do_update and phase.update_source == "new"
    consumed_cur = phase.do_update and phase.update_source == "cur"
    nb = layout.n_buckets
    gen_sh: List[Optional[jax.Array]] = [None] * nb
    cur_sh: List[Optional[jax.Array]] = [None] * nb
    if phase.rotate:
        gen_pre = [g + f for g, f in zip(g_flat, fut)]
        gen = []
        for b, x in enumerate(gen_pre):
            if phase.route_new[b] == "sync":
                gen_sh[b] = rs_shard(x, b)
                # stored full only when this generation survives the
                # phase (it becomes new_cur); a consumed one stays 1/N
                gen.append(x if consumed_new else gather(gen_sh[b], b))
            else:
                gen.append(x)
        new_fut = [jnp.zeros_like(f) for f in fut]
    else:
        gen = None
        new_fut = [f + g for f, g in zip(fut, g_flat)]
    cur_synced = []
    for b, c in enumerate(cur):
        if phase.sync_cur[b]:
            cur_sh[b] = rs_shard(c, b)
            cur_synced.append(c if consumed_cur else gather(cur_sh[b], b))
        else:
            cur_synced.append(c)

    if phase.do_update:
        src = cur_synced if consumed_cur else gen
        src_shards = cur_sh if consumed_cur else gen_sh
        # shard-local merged gradient: the fresh reduce-scatter result
        # where this phase synced the bucket, else this device's span of
        # the stored (already-summed) accumulator
        src_sh = [
            src_shards[b] if src_shards[b] is not None
            else slice_shard(src[b], b)
            for b in range(nb)
        ]
        scale = 1.0 / (n_dp * phase.update_k)
        pbuf_sh, opt, _ = apply_bucket_updates(
            opt_spec, segments, pbuf_sh, src_sh, opt,
            grad_scale=scale, zero_grads=False, impl=update_impl,
            shard_id=shard_id,
            norm_psum=lambda t: jax.lax.psum(t, shard_axis),
            master_dtype=master_dtype,
        )
        pbuf_sh = list(pbuf_sh)
        if consumed_cur and gen is not None:
            new_cur = gen
        else:
            new_cur = [jnp.zeros_like(c) for c in cur_synced]
    elif phase.rotate:
        new_cur = gen
    else:
        new_cur = cur_synced

    metrics = _fused_metrics(loss, parts, phase, dp_axes, n_dp)
    new_state = {
        "pbuf": tuple(pbuf_sh),
        "opt": opt,
        "cur": tuple(c[None] for c in new_cur),
        "fut": tuple(f[None] for f in new_fut),
    }
    if cache is not None:
        # store this phase's gathered buffers for the next phase's skip
        # decision (stale after an update — the static reuse mask never
        # reads a stale entry)
        new_state["pgather"] = tuple(pbuf)
    return new_state, metrics


# ---------------------------------------------------------------------------
# shard_map wrappers (fused variants of steps.deft_phase_step / _rs_)
# ---------------------------------------------------------------------------
# steps._state_specs is layout-agnostic (params/opt replicated, cur/fut
# split on the leading device axis) and works unchanged on the fused
# tuple-shaped accumulators.
_fused_state_specs = _state_specs

_METRIC_SPECS = {"loss": P(), "ce": P(), "aux": P(), "updated": P(), "k": P()}


def _flat_state_specs(state: TrainState, dp_axes: Tuple[str, ...]):
    """Manual-axis specs for the flat-resident state: param buffers and
    optimizer moments replicated over DP, accumulators split on their
    leading device axis."""
    rep = jax.tree.map(
        lambda _: P(), {"pbuf": state["pbuf"], "opt": state["opt"]}
    )
    acc = jax.tree.map(
        lambda _: P(dp_axes if len(dp_axes) > 1 else dp_axes[0]),
        {"cur": state["cur"], "fut": state["fut"]},
    )
    return {**rep, **acc}


def _flat_rs_state_specs(
    state: TrainState, dp_axes: Tuple[str, ...], shard_axis: str
):
    """Manual-axis specs for the SHARDED flat-resident state: param and
    moment buffers split over the shard axis (each device holds one
    contiguous span), the step counter replicated, accumulators split on
    their leading device axis as usual."""
    shard = jax.tree.map(
        lambda x: P() if x.ndim == 0 else P(shard_axis),
        {"pbuf": state["pbuf"], "opt": state["opt"]},
    )
    acc = jax.tree.map(
        lambda _: P(dp_axes if len(dp_axes) > 1 else dp_axes[0]),
        {"cur": state["cur"], "fut": state["fut"]},
    )
    out = {**shard, **acc}
    if "pgather" in state:
        # the gather cache holds full (post-all-gather) buffers — the
        # same value on every device, i.e. replicated
        out["pgather"] = jax.tree.map(lambda _: P(), state["pgather"])
    return out


def _shard_phase(body, specs_fn, state, batch, mesh, dp_axes):
    """The one shard_map invocation every phase wrapper shares (state
    specs from ``specs_fn``, batch split over DP, fused metric specs)."""
    in_specs = (specs_fn(state, dp_axes), _batch_specs(batch, dp_axes))
    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(specs_fn(state, dp_axes), _METRIC_SPECS),
        axis_names=set(dp_axes),
        check_vma=False,
    )(state, batch)


def deft_phase_step_flat(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    segments: BucketSegments,
    treedef,
    mesh,
    multi_pod: bool = False,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
    update_impl: Optional[str] = None,
    compute_dtype=None,
    master_dtype: Optional[str] = None,
    secondary_chain: Optional[Tuple[int, ...]] = None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """Flat-resident DeFT phase with explicit DP (params replicated)."""
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    body = functools.partial(
        _deft_body_flat,
        cfg=cfg,
        opt_spec=opt_spec,
        phase=phase,
        layout=layout,
        segments=segments,
        treedef=treedef,
        dp_axes=dp_axes,
        dp_sizes=_dp_sizes(mesh, dp_axes),
        rules=rules_deft_manual_dp(),
        remat=remat,
        loss_chunk=loss_chunk,
        unroll=unroll,
        update_impl=update_impl,
        compute_dtype=compute_dtype,
        master_dtype=master_dtype,
        secondary_chain=secondary_chain,
    )
    return _shard_phase(body, _flat_state_specs, state, batch, mesh, dp_axes)


def deft_rs_phase_step_flat(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    segments: BucketSegments,
    treedef,
    mesh,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
    update_impl: Optional[str] = None,
    compute_dtype=None,
    master_dtype: Optional[str] = None,
    gather_reuse: Optional[Tuple[bool, ...]] = None,
    decoupled: bool = False,
    secondary_chain: Optional[Tuple[int, ...]] = None,
    ag_links: Optional[Tuple[bool, ...]] = None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """Sharded flat-resident DeFT phase (the FSDP/RS engine): manual over
    every DP axis, param/moment buffers split 1/N over the innermost
    ('data') axis, hierarchical RS -> pod all-reduce -> AG syncs.

    Unlike the tree-state RS path (manual over 'pod' only, FSDP left to
    XLA), the whole DP hierarchy is explicit here, so the engine also
    runs on single-pod meshes — 'pod' is simply absent from the sync.

    Old-jaxlib caveat (composes with DESIGN.md §6): the tiled
    psum_scatter/all_gather chain partitions correctly inside a
    partial-manual region only when the auto (model) axis is size 1 on
    jaxlib < 0.5; real TP + this engine needs jax >= 0.5 — the same
    constraint the tree RS path already has.
    """
    shard_axis = "data"
    assert shard_axis in mesh.axis_names, "sharded flat engine needs 'data'"
    dp_axes = (
        ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    )
    body = functools.partial(
        _deft_body_flat_rs,
        cfg=cfg,
        opt_spec=opt_spec,
        phase=phase,
        layout=layout,
        segments=segments,
        treedef=treedef,
        dp_axes=dp_axes,
        shard_axis=shard_axis,
        dp_sizes=_dp_sizes(mesh, dp_axes),
        rules=rules_deft_manual_dp(),
        remat=remat,
        loss_chunk=loss_chunk,
        unroll=unroll,
        update_impl=update_impl,
        compute_dtype=compute_dtype,
        master_dtype=master_dtype,
        gather_reuse=gather_reuse,
        decoupled=decoupled,
        secondary_chain=secondary_chain,
        ag_links=ag_links,
    )
    specs_fn = lambda s, axes: _flat_rs_state_specs(s, axes, shard_axis)
    return _shard_phase(body, specs_fn, state, batch, mesh, dp_axes)


def deft_phase_step_fused(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    mesh,
    multi_pod: bool = False,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
    secondary_chain: Optional[Tuple[int, ...]] = None,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """Fused DeFT phase with explicit DP (params replicated over DP)."""
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    body = functools.partial(
        _deft_body_fused,
        cfg=cfg,
        opt_spec=opt_spec,
        phase=phase,
        layout=layout,
        dp_axes=dp_axes,
        dp_sizes=_dp_sizes(mesh, dp_axes),
        rules=rules_deft_manual_dp(),
        remat=remat,
        loss_chunk=loss_chunk,
        unroll=unroll,
        secondary_chain=secondary_chain,
    )
    return _shard_phase(body, _fused_state_specs, state, batch, mesh, dp_axes)


def deft_rs_phase_step_fused(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    layout: BucketLayout,
    mesh,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """Fused DeFT hierarchical path (FSDP archs): manual over 'pod' only."""
    assert "pod" in mesh.axis_names, "DeFT-RS needs the multi-pod mesh"
    dp_axes = ("pod",)
    body = functools.partial(
        _deft_body_fused,
        cfg=cfg,
        opt_spec=opt_spec,
        phase=phase,
        layout=layout,
        dp_axes=dp_axes,
        dp_sizes=_dp_sizes(mesh, dp_axes),
        rules=rules_deft_rs_manual_pod(),
        remat=remat,
        loss_chunk=loss_chunk,
        unroll=unroll,
    )
    return _shard_phase(body, _fused_state_specs, state, batch, mesh, dp_axes)


# ---------------------------------------------------------------------------
# Collective accounting (static, from the phase spec)
# ---------------------------------------------------------------------------
def phase_collectives(phase: PhaseSpec) -> Dict[str, int]:
    """Collectives one fused phase issues, by construction: one primary
    psum per primary-synced bucket, one reduce-scatter chain per
    secondary-synced bucket, plus the single fused metrics psum.

    On the sharded flat engine every sync is one hierarchical chain
    (these counts still bound the per-bucket syncs), plus one param
    all-gather per bucket for the ZeRO forward — see DESIGN.md §8."""
    n = len(phase.route_new)
    synced = [
        (phase.route_new[b] == "sync" and phase.rotate) or phase.sync_cur[b]
        for b in range(n)
    ]
    primary = sum(1 for b in range(n) if synced[b] and not phase.secondary[b])
    secondary = sum(1 for b in range(n) if synced[b] and phase.secondary[b])
    return {"primary": primary, "secondary": secondary, "metrics": 1}


# ---------------------------------------------------------------------------
# The runtime
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PhaseStats:
    """Per-unique-phase lifecycle stats."""

    lower_s: float = 0.0
    compile_s: float = 0.0
    dispatches: int = 0
    dispatch_s: float = 0.0


def _abstractify(x):
    """Shape/dtype/sharding snapshot of a (possibly soon-donated) array;
    passes ShapeDtypeStructs and non-array leaves through unchanged."""
    if isinstance(x, jax.ShapeDtypeStruct) or not hasattr(x, "dtype"):
        return x
    sharding = getattr(x, "sharding", None)
    try:
        return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sharding)
    except TypeError:  # older jax: no sharding kwarg
        return jax.ShapeDtypeStruct(x.shape, x.dtype)


class _PhaseEntry:
    """One unique (layout, PhaseSpec, gather-mask) executable lifecycle:
    the donated jitted callable, its AOT-compiled executable (once built)
    and stats.  Entries live in the runtime's *persistent* phase cache —
    a replanned schedule that reuses a PhaseSpec under the same layout
    reuses its compiled executable verbatim, including across layout
    swaps that later return to a previously-seen layout."""

    __slots__ = ("spec", "jitted", "compiled", "stats")

    def __init__(self, spec: PhaseSpec, jitted: Callable):
        self.spec = spec
        self.jitted = jitted
        self.compiled: Optional[Callable] = None
        self.stats = PhaseStats()


@dataclasses.dataclass
class _PendingSwap:
    """A fully-compiled staged schedule, armed for the next cycle
    boundary.  ``layout`` is None for the classic same-layout hot-swap;
    otherwise ``repack`` is the AOT-compiled single-pass gather/scatter
    that re-flattens the donated train state from the installed layout
    into ``layout`` (DESIGN.md §9)."""

    schedule: DeftSchedule
    layout: Optional[BucketLayout] = None
    segments: Optional[BucketSegments] = None
    transition: Optional[LayoutTransition] = None
    repack: Optional[Callable] = None


_UNSET: Any = object()


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Engine configuration of a :class:`DeftRuntime` — every knob that
    used to be a loose ``DeftRuntime(...)`` kwarg, as one frozen value.

    Known-illegal combinations raise at CONSTRUCTION (``validate``), not
    deep inside phase dispatch: ``gather_skip``/``decoupled`` need the
    sharded flat engine, mixed precision needs flat-resident buffers.
    :meth:`DeftRuntime.spawn` derives sibling runtimes via
    :meth:`replace`, so elastic/degraded-mode dispatch composes overrides
    on a validated base instead of re-threading ten kwargs.

    ``flat_state``/``gather_skip`` keep their tri-state semantics: None
    means "resolve the default" (flat state on; gather skip on for the
    sharded flat engine when the schedule has a reusable position).
    ``decoupled`` (DESIGN.md §12) selects the streamed-AG forward on the
    sharded flat engine: per-bucket all-gathers traced at first forward
    use instead of the up-front ZeRO gather burst.
    """

    multi_pod: bool = False
    fsdp: bool = False
    remat: bool = True
    loss_chunk: int = 0
    unroll: bool = False
    donate: bool = True
    flat_state: Optional[bool] = None
    update_impl: Optional[str] = None
    compute_dtype: Any = None
    gather_skip: Optional[bool] = None
    decoupled: bool = False
    # resident master dtype (DESIGN.md §13): None/'f32' keeps the f32
    # master buffers; 'bf16sr' stores them bf16 and writes updates back
    # through seeded stochastic rounding (flat engines only)
    master_dtype: Optional[str] = None
    # secondary-link device-order ring chain (DESIGN.md §14): a
    # permutation of the 'data'-axis positions (launch.mesh.ring_chain).
    # None (default) keeps every collective on the mesh axis — the
    # pre-§14 behavior bit-for-bit.  When set, secondary-assigned
    # RS/AG items execute as ppermute chains over this ordering.
    secondary_chain: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.secondary_chain is not None:
            # normalize (lists hash-break the frozen config) before
            # validate sees it
            object.__setattr__(
                self, "secondary_chain",
                tuple(int(p) for p in self.secondary_chain),
            )
        self.validate()

    @property
    def resolved_flat_state(self) -> bool:
        return True if self.flat_state is None else bool(self.flat_state)

    @property
    def sharded_flat(self) -> bool:
        """The FSDP/RS engine: flat buffers sharded 1/N over 'data'."""
        return bool(self.fsdp and self.resolved_flat_state)

    def validate(self) -> None:
        if self.loss_chunk < 0:
            raise ValueError(f"loss_chunk={self.loss_chunk} must be >= 0")
        if self.gather_skip and not self.sharded_flat:
            raise ValueError(
                "gather_skip only applies to the sharded flat engine "
                "(fsdp=True, flat_state=True) — the other engines never "
                "all-gather params"
            )
        if self.decoupled and not self.sharded_flat:
            raise ValueError(
                "decoupled AG streaming only applies to the sharded flat "
                "engine (fsdp=True, flat_state=True) — the other engines "
                "have no per-bucket param all-gather to stream "
                "(DESIGN.md §12)"
            )
        if self.compute_dtype is not None and self.flat_state is False:
            raise ValueError(
                "compute_dtype (mixed precision) needs the flat engine: "
                "tree-state params are resident at their init dtype — "
                "drop flat_state=False or drop compute_dtype (DESIGN.md §8)"
            )
        if self.update_impl is not None and self.flat_state is False:
            raise ValueError(
                "update_impl selects a fused bucket-update kernel — only "
                "the flat engine runs those; flat_state=False applies "
                "per-leaf updates"
            )
        if self.master_dtype not in (None, "f32", "bf16sr"):
            raise ValueError(
                f"master_dtype={self.master_dtype!r}: expected None, "
                f"'f32' or 'bf16sr'"
            )
        if self.master_dtype == "bf16sr" and self.flat_state is False:
            raise ValueError(
                "master_dtype='bf16sr' needs the flat engine: the "
                "stochastic-rounding write-back rides the fused "
                "bucket-update kernels (DESIGN.md §13)"
            )
        if self.secondary_chain is not None:
            chain = self.secondary_chain
            if sorted(chain) != list(range(len(chain))):
                raise ValueError(
                    f"secondary_chain={chain} is not a permutation of "
                    f"0..{len(chain) - 1} — build it with "
                    f"launch.mesh.ring_chain"
                )
            if self.fsdp and self.flat_state is False:
                raise ValueError(
                    "secondary_chain needs a 'data'-axis sync to reroute; "
                    "the tree-state RS engine is manual over 'pod' only "
                    "(DESIGN.md §14) — use the flat engines"
                )
            if self.multi_pod and not self.sharded_flat:
                raise ValueError(
                    "secondary_chain on a multi-pod mesh needs the "
                    "sharded flat engine: its shard-axis reduce-scatter "
                    "is separate from the pod all-reduce, so the chain "
                    "swaps in bitwise-exactly.  The replicated engines "
                    "sync with ONE joint ('pod','data') psum whose "
                    "reduction order a per-axis chain cannot reproduce "
                    "(DESIGN.md §14)"
                )

    @property
    def resolved_master(self) -> str:
        return self.master_dtype or "f32"

    def replace(self, **overrides) -> "RuntimeConfig":
        """A new validated config with ``overrides`` applied."""
        return dataclasses.replace(self, **overrides)


class DeftRuntime:
    """Owns the per-phase executables of one (evolving) DeFT schedule.

    Lifecycle (DESIGN.md §5/§7):

    1. construction dedupes ``schedule.phases`` by spec signature and
       builds one donated jitted callable per *unique* phase;
    2. :meth:`compile` lowers + compiles each unique phase ahead of time
       against concrete (or abstract) state/batch, recording timings;
    3. :meth:`step` dispatches the step's cycle phase through the AOT
       cache (falling back to the jitted callable if :meth:`compile` was
       skipped — first dispatch then pays the compile);
    4. :meth:`prepare_swap` stages a replanned schedule: unseen phases
       are lowered + compiled (optionally on a background thread while
       training continues), previously-seen phases are reused from the
       persistent cache, and the new schedule is installed atomically at
       the next cycle boundary.  Over the same :class:`BucketLayout` the
       donated train state carries across untouched (every buffer keeps
       its shape and sharding); with ``layout=`` the state is re-packed
       through a compiled :class:`LayoutTransition` at that boundary
       (DESIGN.md §9), so a replan may change the bucket partition or
       the shard count mid-run with no restart.

    All phase executables donate the train state: callers MUST treat the
    state passed to :meth:`step` as consumed and continue with the
    returned one.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        opt_spec: OptimizerSpec,
        schedule: DeftSchedule,
        layout: BucketLayout,
        mesh,
        *,
        config: Optional[RuntimeConfig] = None,
        tracer: Optional[Tracer] = None,
        ag_plan: Any = None,
        multi_pod: Any = _UNSET,
        fsdp: Any = _UNSET,
        remat: Any = _UNSET,
        loss_chunk: Any = _UNSET,
        unroll: Any = _UNSET,
        donate: Any = _UNSET,
        flat_state: Any = _UNSET,
        update_impl: Any = _UNSET,
        compute_dtype: Any = _UNSET,
        gather_skip: Any = _UNSET,
        decoupled: Any = _UNSET,
    ):
        # engine knobs arrive either as one validated RuntimeConfig or as
        # the legacy loose kwargs (kept working; they build the config) —
        # mixing the two is ambiguous and refused
        legacy = {
            k: v
            for k, v in dict(
                multi_pod=multi_pod, fsdp=fsdp, remat=remat,
                loss_chunk=loss_chunk, unroll=unroll, donate=donate,
                flat_state=flat_state, update_impl=update_impl,
                compute_dtype=compute_dtype, gather_skip=gather_skip,
                decoupled=decoupled,
            ).items()
            if v is not _UNSET
        }
        if config is None:
            config = RuntimeConfig(**legacy)
        elif legacy:
            raise ValueError(
                f"pass engine knobs through config=RuntimeConfig(...) OR "
                f"as legacy kwargs, not both (got config= and "
                f"{sorted(legacy)})"
            )
        self.config = config
        self.cfg = cfg
        self.opt_spec = opt_spec
        self.layout = layout
        self.mesh = mesh
        self.fsdp = config.fsdp
        self.multi_pod = config.multi_pod
        self.donate = config.donate
        self._remat = config.remat
        self._loss_chunk = config.loss_chunk
        self._unroll = config.unroll
        # flat-resident state (DESIGN.md §8): the default everywhere.
        # On the FSDP/RS path the flat engine SHARDS the param/moment
        # buffers 1/N over 'data' (shard-aware BucketLayout) instead of
        # replicating them, so the memory-bound archs keep their ZeRO
        # residency and still get the fused bucket-update kernels.
        self.flat_state = config.resolved_flat_state
        self.update_impl = config.update_impl
        # mixed precision (flat engines only): forward/backward in
        # compute_dtype against the f32 master buffers
        self.compute_dtype = config.compute_dtype
        # precision as a layout dimension (DESIGN.md §13): per-bucket
        # wire dtypes ride layout.precision; the resident-master dtype is
        # config-owned and must agree with what the layout declares
        lp_master = (
            layout.precision.master if layout.precision is not None else None
        )
        if (config.master_dtype is not None and lp_master is not None
                and config.master_dtype != lp_master):
            raise ValueError(
                f"master dtype disagreement: config.master_dtype="
                f"{config.master_dtype!r} but the layout's precision "
                f"policy says {lp_master!r}"
            )
        self.master_dtype = config.master_dtype or lp_master or "f32"
        self._master_jdtype = (
            jnp.bfloat16 if self.master_dtype == "bf16sr" else jnp.float32
        )
        quantized = layout.precision is not None and (
            not layout.precision.all_f32
        )
        if (quantized or self.master_dtype != "f32") \
                and not config.resolved_flat_state:
            raise ValueError(
                "a non-f32 PrecisionPolicy needs the flat engines: the "
                "tree-state path has no per-bucket wire edges "
                "(DESIGN.md §13) — drop flat_state=False"
            )
        self._validate_precision_layout(layout)
        # decoupled AG streaming (DESIGN.md §12): per-bucket forward
        # all-gathers at first use instead of the up-front ZeRO burst
        self.decoupled = config.decoupled
        self._treedef = None
        self._segments: Optional[BucketSegments] = None
        shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        # secondary-link ring chain (DESIGN.md §14): a permutation of the
        # 'data'-axis positions; the AG-link plan says which streamed
        # param gathers ride it (gradient syncs follow phase.secondary)
        if config.secondary_chain is not None:
            n_data = int(shape.get("data", 0))
            if len(config.secondary_chain) != n_data:
                raise ValueError(
                    f"secondary_chain covers "
                    f"{len(config.secondary_chain)} positions but the "
                    f"mesh 'data' axis is {n_data}-way — build it with "
                    f"launch.mesh.ring_chain({n_data}, link)"
                )
        self._ag_plan = ag_plan
        if self.flat_state:
            params_abs = jax.eval_shape(
                lambda: init_params(jax.random.PRNGKey(0), cfg)
            )
            leaves, self._treedef = jax.tree_util.tree_flatten(params_abs)
            assert tuple(tuple(l.shape) for l in leaves) == layout.shapes, (
                "BucketLayout does not match this config's parameter tree"
            )
            self._segments = build_segments(layout, opt_spec)
        if self.flat_state and self.fsdp:
            n_shards = int(shape["data"])
            if layout.shards != n_shards:
                raise ValueError(
                    f"sharded flat engine: BucketLayout was built with "
                    f"shard_count={layout.shards} but the mesh 'data' axis "
                    f"is {n_shards}-way — build the layout with "
                    f"build_bucket_layout(..., shard_count={n_shards})"
                )
        if self.fsdp:
            # tree state: manual over 'pod' only (FSDP left to XLA);
            # sharded flat state: the whole DP hierarchy is explicit
            if self.flat_state:
                self.dp_axes: Tuple[str, ...] = (
                    ("pod", "data") if "pod" in mesh.axis_names
                    else ("data",)
                )
            else:
                self.dp_axes = ("pod",)
        else:
            self.dp_axes = ("pod", "data") if self.multi_pod else ("data",)
        self.accum_devices = 1
        for a in self.dp_axes:
            self.accum_devices *= int(shape[a])
        # ZeRO gather skip (DESIGN.md §9): default ON for the sharded
        # flat engine — phases not preceded by an update reuse the
        # previous phase's stored param gather instead of re-all-gathering.
        # The cache rides the donated state, so it is only worth carrying
        # when the installed schedule actually HAS a reusable position;
        # otherwise every phase would haul an unread (hence undonatable)
        # full-param cache through each step for nothing.
        # illegal combinations already refused by RuntimeConfig.validate
        self._gather_skip = bool(
            config.gather_skip if config.gather_skip is not None
            else (self.fsdp and self.flat_state
                  and self._schedule_has_reuse(schedule))
        )

        # persistent phase cache: (layout, PhaseSpec, gather-mask) ->
        # executable entry.  Survives hot-swaps — including layout
        # changes; schedules only reference into it.
        self._entries: Dict[Tuple, _PhaseEntry] = {}
        # jitted repack callables, keyed per transition (repack_state)
        self._repack_cache: Dict[LayoutTransition, Callable] = {}
        # hot-swap state
        self._cycle_base = 0               # step at which the cycle restarts
        self._pending: Optional[_PendingSwap] = None
        self._swap_gen = 0                 # stale background builds don't publish
        self._swap_thread: Optional[threading.Thread] = None
        self.replans = 0                   # schedules staged via prepare_swap
        self.hot_swaps = 0                 # schedules actually installed
        self.layout_swaps = 0              # hot-swaps that re-packed state
        self.swap_failures = 0             # background compile attempts failed
        self.last_swap_error: Optional[str] = None
        # observability (DESIGN.md §11): control-plane events (swaps,
        # repacks, compile failures) always record into the tracer — the
        # legacy ``swap_log`` dicts are reconstructed from those events —
        # but per-step phase/collective spans are only emitted when a
        # tracer was explicitly attached, keeping the untraced hot path
        # free of span bookkeeping.
        self.tracer = tracer if tracer is not None else Tracer(capacity=8192)
        self.trace_steps = tracer is not None
        self.last_phase = 0                # cycle phase of the last dispatch
        self.last_dispatch_first = False   # last dispatch was an entry's first
        self._install(schedule)

    # ---- precision (DESIGN.md §13) --------------------------------------
    def _validate_precision_layout(self, layout: BucketLayout) -> None:
        """Refuse a layout whose precision policy this runtime cannot
        execute: int8 wire needs 128-lane-aligned buffers (and shard
        spans, on the sharded engine) for the blockwise quantize grid,
        and the layout's master dtype must match the runtime's."""
        p = layout.precision
        if p is not None and p.master != self.master_dtype \
                and not (p.master == "f32" and self.master_dtype == "f32"):
            raise ValueError(
                f"layout precision master {p.master!r} != runtime "
                f"master_dtype {self.master_dtype!r}"
            )
        if p is None:
            return
        for b, w in enumerate(p.wire):
            if w != "int8":
                continue
            if layout.buf_sizes[b] % 128 != 0:
                raise ValueError(
                    f"int8 wire on bucket {b}: buffer size "
                    f"{layout.buf_sizes[b]} is not a 128-lane multiple"
                )
            if self.flat_state and self.fsdp \
                    and layout.shard_sizes[b] % 128 != 0:
                raise ValueError(
                    f"int8 wire on bucket {b}: shard span "
                    f"{layout.shard_sizes[b]} is not a 128-lane multiple"
                )

    def _wire_bytes_split_of_phase(
        self, phase: PhaseSpec
    ) -> Tuple[int, int]:
        """Planned (primary, secondary) wire bytes of one phase's
        scheduled gradient syncs under the installed layout's precision
        policy (int8 counts the quantized values plus 4 bytes per
        128-lane row of scales).  The per-link split follows
        ``phase.secondary`` — what the obs layer's per-link attribution
        audits each link's measured traffic against (DESIGN.md §14)."""
        wire = _layout_wire(self.layout)
        primary = secondary = 0
        for b in range(len(phase.route_new)):
            synced = (
                (phase.route_new[b] == "sync" and phase.rotate)
                or phase.sync_cur[b]
            )
            if not synced:
                continue
            n = self.layout.buf_sizes[b]
            if wire[b] == "int8":
                bts = n + 4 * (n // 128)
            else:
                bts = n * WIRE_BYTES[wire[b]]
            if phase.secondary[b]:
                secondary += bts
            else:
                primary += bts
        return primary, secondary

    def _wire_bytes_of_phase(self, phase: PhaseSpec) -> int:
        """Total planned wire bytes of one phase (both links)."""
        return sum(self._wire_bytes_split_of_phase(phase))

    # ---- schedule installation ------------------------------------------
    @staticmethod
    def _schedule_has_reuse(schedule: DeftSchedule) -> bool:
        """True when at least one cycle position can skip its param
        gather (a phase whose predecessor did not update)."""
        return any(
            not schedule.phases[t - 1].do_update
            for t in range(1, schedule.period)
        )

    def _gather_reuse_masks(
        self, schedule: DeftSchedule
    ) -> List[Optional[Tuple[bool, ...]]]:
        """Per cycle position, the per-bucket gather-skip mask of the
        sharded flat engine (None when the skip is off).  A bucket's
        stored gather is valid iff no update touched its params since
        the previous phase stored it — with the fused whole-state update
        that is simply "the previous phase did not update"; position 0
        always gathers (a swap or a fresh/restored cycle lands there
        with an unwarmed cache)."""
        if not self._gather_skip:
            return [None] * schedule.period
        masks: List[Optional[Tuple[bool, ...]]] = []
        for t, ph in enumerate(schedule.phases):
            nb = len(ph.route_new)
            fresh = t == 0 or schedule.phases[t - 1].do_update
            masks.append(((not fresh),) * nb)
        return masks

    def _ag_link_masks(
        self, schedule: DeftSchedule
    ) -> List[Optional[Tuple[bool, ...]]]:
        """Per cycle position, the per-bucket secondary-AG mask of the
        sharded flat engine (DESIGN.md §14): True where the streamed
        param all-gather was planned onto the secondary link
        (``AgItem.link >= 1``), so the executable routes that bucket's
        gather over the configured ring chain.  All-None without an AG
        plan or a chain — the pre-§14 executables, byte-for-byte."""
        if (self._ag_plan is None
                or self.config.secondary_chain is None
                or not (self.fsdp and self.flat_state)):
            return [None] * schedule.period
        per_phase: Dict[int, Dict[int, bool]] = {}
        for item in self._ag_plan.items:
            d = per_phase.setdefault(item.phase, {})
            d[item.bucket] = d.get(item.bucket, False) or item.link >= 1
        masks: List[Optional[Tuple[bool, ...]]] = []
        for t, ph in enumerate(schedule.phases):
            nb = len(ph.route_new)
            hot = per_phase.get(t)
            if not hot or not any(hot.values()):
                masks.append(None)
                continue
            masks.append(tuple(
                bool(hot.get(b, False)) for b in range(nb)
            ))
        return masks

    def _schedule_keys(
        self,
        schedule: DeftSchedule,
        layout: Optional[BucketLayout] = None,
    ) -> List[Tuple]:
        """Entry-cache keys, one per cycle position: the executable
        identity is (layout, PhaseSpec, gather-skip mask, AG-link
        mask)."""
        layout = layout or self.layout
        masks = self._gather_reuse_masks(schedule)
        ag_masks = self._ag_link_masks(schedule)
        return [
            (layout, ph, masks[t], ag_masks[t])
            for t, ph in enumerate(schedule.phases)
        ]

    def _make_jitted(
        self,
        phase: PhaseSpec,
        layout: BucketLayout,
        segments: Optional[BucketSegments],
        gather_reuse: Optional[Tuple[bool, ...]],
        ag_links: Optional[Tuple[bool, ...]] = None,
    ) -> Callable:
        if self.flat_state:
            step_impl = (
                deft_rs_phase_step_flat if self.fsdp
                else deft_phase_step_flat
            )
        else:
            step_impl = (
                deft_rs_phase_step_fused if self.fsdp
                else deft_phase_step_fused
            )
        kw = dict(
            cfg=self.cfg,
            opt_spec=self.opt_spec,
            phase=phase,
            layout=layout,
            mesh=self.mesh,
            remat=self._remat,
            loss_chunk=self._loss_chunk,
            unroll=self._unroll,
        )
        if self.flat_state:
            kw.update(
                segments=segments,
                treedef=self._treedef,
                update_impl=self.update_impl,
                compute_dtype=self.compute_dtype,
                master_dtype=(
                    self.master_dtype if self.master_dtype != "f32"
                    else None
                ),
            )
        if self.flat_state and self.fsdp:
            kw["gather_reuse"] = gather_reuse
            kw["decoupled"] = self.decoupled
        chain = self.config.secondary_chain
        if chain is not None:
            # validate() refused the one engine that cannot take it (the
            # tree-state RS path), so every reachable step_impl accepts it
            kw["secondary_chain"] = chain
            if self.flat_state and self.fsdp:
                kw["ag_links"] = ag_links
        if not self.fsdp:
            kw["multi_pod"] = self.multi_pod
        return jax.jit(
            functools.partial(step_impl, **kw),
            donate_argnums=(0,) if self.donate else (),
        )

    def _ensure_entries(
        self,
        schedule: DeftSchedule,
        layout: Optional[BucketLayout] = None,
        segments: Optional[BucketSegments] = None,
    ) -> Tuple[List[_PhaseEntry], int]:
        """Create cache entries for the schedule's unseen executables
        under ``layout`` (default: the installed one).  Returns (entries
        needing compile, number reused from cache)."""
        layout = layout or self.layout
        segments = segments if segments is not None else self._segments
        fresh: List[_PhaseEntry] = []
        reused = 0
        for key in self._schedule_keys(schedule, layout):
            if key in self._entries:
                reused += 1
                continue
            _, phase, mask, ag_mask = key
            entry = _PhaseEntry(
                phase,
                self._make_jitted(phase, layout, segments, mask, ag_mask),
            )
            self._entries[key] = entry
            fresh.append(entry)
        return fresh, reused

    def _install(self, schedule: DeftSchedule) -> None:
        self._ensure_entries(schedule)
        self.schedule = schedule
        self._unique: List[Tuple] = []
        # entry objects resolved ONCE here: hashing a full BucketLayout
        # (thousands of nested ints) on every step() dispatch would put
        # tens of microseconds of pure-Python work on the hot path
        self._unique_entries: List[_PhaseEntry] = []
        index_of: Dict[Tuple, int] = {}
        keys = self._schedule_keys(schedule)
        for key in keys:
            if key not in index_of:
                index_of[key] = len(self._unique)
                self._unique.append(key)
                self._unique_entries.append(self._entries[key])
        self.phase_of_step: Tuple[int, ...] = tuple(
            index_of[key] for key in keys
        )
        # static per-cycle-position span attributes (DESIGN.md §11):
        # resolved at install so the traced dispatch path stays cheap
        masks = self._gather_reuse_masks(schedule)
        self._reuse_of_step: Tuple[bool, ...] = tuple(
            m is not None and any(m) for m in masks
        )
        self._coll_of_step: Tuple[Dict[str, int], ...] = tuple(
            phase_collectives(ph) for ph in schedule.phases
        )
        # planned wire bytes per cycle position under the installed
        # layout's precision policy (§13) — the obs layer's measured-vs-
        # planned bytes attribution reads these off the spans; split
        # per link (§14) so each link's traffic audits separately
        self._wire_bytes_split_of_step: Tuple[Tuple[int, int], ...] = tuple(
            self._wire_bytes_split_of_phase(ph) for ph in schedule.phases
        )
        self._wire_bytes_of_step: Tuple[int, ...] = tuple(
            p + s for p, s in self._wire_bytes_split_of_step
        )

    # ---- state ----------------------------------------------------------
    @property
    def period(self) -> int:
        return self.schedule.period

    @property
    def wire_bytes_per_phase(self) -> Tuple[int, ...]:
        """Planned bytes on the wire per cycle phase under the installed
        layout's precision (what ``obs.wire_bytes_report`` audits the
        trace against)."""
        return self._wire_bytes_of_step

    @property
    def wire_bytes_split_per_phase(self) -> Tuple[Tuple[int, int], ...]:
        """Planned (primary, secondary) wire bytes per cycle phase (§14)
        — the per-link audit vector ``obs.wire_bytes_report`` takes as
        ``planned_split``."""
        return self._wire_bytes_split_of_step

    @property
    def n_unique_phases(self) -> int:
        return len(self._unique)

    @property
    def n_cached_phases(self) -> int:
        """Unique phases ever compiled/jitted, across all installed
        schedules (the persistent cache's size)."""
        return len(self._entries)

    def reset_cycle(self, step: int) -> None:
        """Restart the schedule cycle at ``step``: a restored run begins
        a fresh cycle there (position 0, which always re-gathers), so
        resuming at an arbitrary global step keeps phase bookkeeping
        aligned."""
        self._cycle_base = step

    def phase_in_cycle(self, i: int) -> int:
        """Cycle phase step ``i`` will dispatch.  Correct across swaps:
        a staged schedule installs exactly at a boundary, where both the
        old and the new cycle agree the phase is 0."""
        return (i - self._cycle_base) % self.period

    def phase_executable(self, offset: int) -> Callable:
        """The donated executable behind cycle phase ``offset`` — the
        AOT-compiled one when :meth:`compile` ran, else the jitted
        callable.  Public handle for benchmarks/tools that dispatch one
        phase directly without the :meth:`step` bookkeeping."""
        entry = self._unique_entries[self.phase_of_step[offset]]
        return entry.compiled if entry.compiled is not None else entry.jitted

    @property
    def swap_log(self) -> List[Dict[str, Any]]:
        """Compat shim (DESIGN.md §11): the legacy swap-log dict list,
        reconstructed from the trace events that replaced it.  Install
        entries come from ``swap-install`` events; compile failures from
        the ``swap-compile`` events carrying an ``event`` attr (the
        successful-compile *span* has none and is not part of the log)."""
        out: List[Dict[str, Any]] = []
        for sp in self.tracer.spans(("swap-install", "swap-compile")):
            args = sp.args
            if sp.kind == "swap-install" or "event" in args:
                out.append({"step": sp.step, **args})
        return out

    def init_state(self, key, dtype=jnp.float32) -> TrainState:
        """Fresh train state, committed to the shardings the phase
        executables expect — params/opt replicated, accumulators split on
        their leading device axis.  Committed placement is what lets XLA
        alias the donated input buffers (an uncommitted array would be
        resharded at dispatch and could not be updated in place).

        Flat-state runtimes return ``{pbuf, opt, cur, fut}`` — params
        and moments as per-bucket flat f32 buffers (the master copy; see
        :meth:`params_tree` / :meth:`state_to_tree` for the checkpoint /
        eval boundary).  On the sharded FSDP/RS engine the buffers are
        committed split over 'data' (each device holds its span), so
        optimizer state is 1/N-resident from step 0.

        A non-f32 ``dtype`` on a flat runtime selects the *initialization
        rounding* of the mixed-precision path: params are drawn at
        ``dtype`` (matching the tree-path init bit-for-bit) and promoted
        into the f32 master; the runtime must have been built with
        ``compute_dtype=dtype`` so the forward casts back down at the
        buffer views."""
        from jax.sharding import NamedSharding

        if self.flat_state and dtype != jnp.float32 \
                and dtype != self.compute_dtype:
            raise ValueError(
                f"flat_state keeps an f32 master copy; init dtype={dtype} "
                f"needs the runtime built with compute_dtype={dtype} so "
                f"the forward runs at that precision (got "
                f"compute_dtype={self.compute_dtype}) — or use "
                f"flat_state=False for non-f32 resident params "
                f"(DESIGN.md §8)"
            )
        params = init_params(key, self.cfg, dtype=dtype)
        dp = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        rep = NamedSharding(self.mesh, P())
        split = NamedSharding(self.mesh, P(dp))
        acc = init_fused_accumulators(self.layout, self.accum_devices)
        if self.flat_state:
            # flat master copy — one buffer per bucket (flatten promotes
            # a low-precision init to f32; a bf16sr master rounds back
            # down through the seeded stochastic-rounding kernel)
            pbuf = tuple(
                flatten_buckets(self.layout, jax.tree_util.tree_leaves(params))
            )
            if self.master_dtype == "bf16sr":
                pbuf = tuple(
                    self._round_master(p, b) for b, p in enumerate(pbuf)
                )
            opt = init_flat_opt_state(self.opt_spec, self.layout.buf_sizes)
            # sharded engine: commit buffers split over 'data' so every
            # device materializes only its 1/N span
            buf = NamedSharding(self.mesh, P("data")) if self.fsdp else rep
            opt_shardings = jax.tree.map(
                lambda x: rep if x.ndim == 0 else buf, opt
            )
            state = {
                "pbuf": jax.device_put(pbuf, buf),
                "opt": jax.tree.map(jax.device_put, opt, opt_shardings),
                "cur": jax.device_put(acc["cur"], split),
                "fut": jax.device_put(acc["fut"], split),
            }
            if self._gather_skip:
                state["pgather"] = jax.device_put(
                    self._init_pgather(self.layout), rep
                )
            return state
        return {
            "params": jax.device_put(params, rep),
            "opt": jax.device_put(init_opt_state(self.opt_spec, params), rep),
            "cur": jax.device_put(acc["cur"], split),
            "fut": jax.device_put(acc["fut"], split),
        }

    def _round_master(self, buf: jax.Array, b: int) -> jax.Array:
        """One flat f32 buffer rounded into the bf16sr resident master
        (seeded, deterministic); nearest rounding for buffers the 128-
        lane kernels cannot tile."""
        if buf.shape[0] % 128 == 0:
            return stochastic_round_bf16(buf, jnp.uint32(b + 1))
        return buf.astype(jnp.bfloat16)

    def _init_pgather(self, layout: BucketLayout) -> Tuple[jax.Array, ...]:
        """Cold gather cache for ``layout``: zeros in the compute dtype.
        Safe because cycle position 0 (where every fresh/restored/swapped
        cycle starts) always re-gathers — the cache is never read before
        a phase stored it."""
        dt = self.compute_dtype or jnp.float32
        return tuple(jnp.zeros((s,), dt) for s in layout.buf_sizes)

    # ---- checkpoint / eval boundary (tree <-> flat) ---------------------
    def params_tree(self, state: TrainState):
        """Parameter pytree view of a train state.  For flat-state
        runtimes this is THE unflatten boundary — steady-state steps
        never materialize the tree; call this only at checkpoint / eval
        / debug points."""
        if not self.flat_state:
            return state["params"]
        return jax.tree_util.tree_unflatten(
            self._treedef, unflatten_buckets(self.layout, state["pbuf"])
        )

    def state_to_tree(self, state: TrainState) -> TrainState:
        """Checkpoint-friendly tree form {params, opt{step,m[,v]}} of a
        train state.  Params and moments become layout-agnostic pytrees;
        the ``cur``/``fut`` accumulators (and the ``pgather`` cache of
        the gather-skip engine) stay per-bucket flat buffers BOUND TO
        this runtime's layout — :meth:`tree_to_state` routes them through
        a :class:`LayoutTransition` when restoring under a different
        layout (``src_layout``)."""
        if not self.flat_state:
            return state
        unflat = lambda bufs: jax.tree_util.tree_unflatten(
            self._treedef, unflatten_buckets(self.layout, bufs)
        )
        opt: Dict[str, Any] = {"step": state["opt"]["step"],
                               "m": unflat(state["opt"]["m"])}
        if "v" in state["opt"]:
            opt["v"] = unflat(state["opt"]["v"])
        out = {"params": self.params_tree(state), "opt": opt,
               "cur": state["cur"], "fut": state["fut"]}
        if "pgather" in state:
            # the gather cache is part of a mid-cycle resume's state: a
            # reuse-phase position would otherwise read a cold cache
            out["pgather"] = state["pgather"]
        return out

    def tree_to_state(
        self,
        tree_state: TrainState,
        src_layout: Optional[BucketLayout] = None,
    ) -> TrainState:
        """Inverse of :meth:`state_to_tree` — restore a checkpointed tree
        into the runtime's resident representation.

        ``src_layout`` names the :class:`BucketLayout` the checkpoint was
        written under; when it differs from this runtime's layout the
        flat accumulators are routed through the
        :class:`LayoutTransition` span remap (params/moments are
        layout-agnostic trees and simply re-flatten), so a run can be
        resumed under a different partition or shard count than it was
        saved with.  A cross-layout restore resets the gather cache —
        the restored run starts a fresh cycle at position 0, which
        always re-gathers."""
        cur, fut = tree_state["cur"], tree_state["fut"]
        cross = src_layout is not None and src_layout != self.layout
        if cross:
            tr = build_layout_transition(src_layout, self.layout)
            cur = tuple(repack_buffers(tr, cur))
            fut = tuple(repack_buffers(tr, fut))
        if not self.flat_state:
            return {**tree_state, "cur": cur, "fut": fut}
        flat = lambda t: tuple(
            flatten_buckets(self.layout, jax.tree_util.tree_leaves(t))
        )
        opt: Dict[str, Any] = {"step": tree_state["opt"]["step"],
                               "m": flat(tree_state["opt"]["m"])}
        if "v" in tree_state["opt"]:
            opt["v"] = flat(tree_state["opt"]["v"])
        pbuf = flat(tree_state["params"])
        if self.master_dtype == "bf16sr":
            # checkpointed bf16 values promote exactly through flatten;
            # the plain downcast restores them bit-for-bit
            pbuf = tuple(p.astype(jnp.bfloat16) for p in pbuf)
        out = {"pbuf": pbuf, "opt": opt, "cur": cur, "fut": fut}
        if self._gather_skip:
            if not cross and "pgather" in tree_state:
                out["pgather"] = tree_state["pgather"]
            else:
                out["pgather"] = self._init_pgather(self.layout)
        return out

    def checkpoint_struct(
        self,
        src_layout: Optional[BucketLayout] = None,
        *,
        with_pgather: Optional[bool] = None,
    ) -> TrainState:
        """ShapeDtypeStruct pytree of :meth:`state_to_tree` output as
        written under ``src_layout`` (default: this runtime's layout) —
        the ``like`` argument :func:`repro.checkpoint.checkpoint.restore`
        needs to verify shapes of a checkpoint possibly written under a
        DIFFERENT layout before :meth:`tree_to_state` re-packs it.

        ``with_pgather`` says whether the checkpoint carries the
        gather-skip cache; the default reads it only for a same-layout
        restore on a gather-skip runtime (a cross-layout restore resets
        the cache anyway, so the saved one — if any — is left unread)."""
        if not self.flat_state:
            raise ValueError("checkpoint_struct needs a flat-state runtime")
        lay = src_layout or self.layout
        cross = lay != self.layout
        if with_pgather is None:
            with_pgather = self._gather_skip and not cross
        tree = lambda dt: jax.tree_util.tree_unflatten(
            self._treedef,
            [jax.ShapeDtypeStruct(s, dt) for s in lay.shapes],
        )
        opt: Dict[str, Any] = {
            "step": jax.ShapeDtypeStruct((), jnp.int32),
            "m": tree(jnp.float32),
        }
        if self.opt_spec.name == "adamw":
            opt["v"] = tree(jnp.float32)
        acc = lambda: tuple(
            jax.ShapeDtypeStruct((self.accum_devices, n), jnp.float32)
            for n in lay.buf_sizes
        )
        out = {"params": tree(self._master_jdtype), "opt": opt,
               "cur": acc(), "fut": acc()}
        if with_pgather:
            dt = self.compute_dtype or jnp.float32
            out["pgather"] = tuple(
                jax.ShapeDtypeStruct((n,), dt) for n in lay.buf_sizes
            )
        return out

    # ---- AOT phase cache ------------------------------------------------
    def _compile_entries(
        self, entries: Sequence[_PhaseEntry], state, batch
    ) -> Dict[str, float]:
        out: Dict[str, float] = {}
        with jax.set_mesh(self.mesh):
            for i, entry in enumerate(entries):
                if entry.compiled is not None:
                    continue
                t0 = time.perf_counter()
                lowered = entry.jitted.lower(state, batch)
                t1 = time.perf_counter()
                entry.compiled = lowered.compile()
                t2 = time.perf_counter()
                entry.stats.lower_s = t1 - t0
                entry.stats.compile_s = t2 - t1
                out[f"phase{i}"] = t2 - t0
        return out

    def compile(self, state: TrainState, batch) -> Dict[str, float]:
        """Lower + compile every unique phase of the installed schedule
        ahead of the first step.

        ``state``/``batch`` may be concrete arrays or ShapeDtypeStructs.
        Returns {phase_index: seconds} wall-clock compile times.
        """
        return self._compile_entries(self._unique_entries, state, batch)

    # ---- layout re-pack -------------------------------------------------
    @staticmethod
    @contextlib.contextmanager
    def _partial_donation_ok():
        """A repack between different bucket counts cannot alias every
        donated src buffer into a dst buffer (the allocation sizes
        changed — that is the point); XLA's partial-donation warning is
        expected there, not a lost optimization, so it is silenced for
        the repack compile only."""
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable"
            )
            yield

    def _state_placement(self, rep, buf, split):
        """(replicated, buffer, accumulator) sharding choices shared by
        repack outputs and swap-state avals."""
        return {
            "rep": rep,
            "buf": buf if (self.flat_state and self.fsdp) else rep,
            "split": split,
        }

    def _repack_jitted(self, transition: LayoutTransition) -> Callable:
        """Donated jitted single-pass gather/scatter applying a
        :class:`LayoutTransition` to a whole train state: params/moment
        buffers and both accumulator stacks re-flatten span-by-span;
        byte-identical buckets pass through so XLA aliases their donated
        buffers instead of copying.  Output shardings re-commit the
        dst-layout placement (on the sharded engine a shard-count change
        is just a different split of the same global buffers)."""
        hit = self._repack_cache.get(transition)
        if hit is not None:
            return hit
        from jax.sharding import NamedSharding

        dst = transition.dst
        dp = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        place = self._state_placement(
            NamedSharding(self.mesh, P()),
            NamedSharding(self.mesh, P("data")),
            NamedSharding(self.mesh, P(dp)),
        )
        rep, buf, split = place["rep"], place["buf"], place["split"]
        flat_state = self.flat_state
        gather_skip = self._gather_skip
        compute_dtype = self.compute_dtype or jnp.float32
        adam = self.opt_spec.name == "adamw"

        def fn(state):
            out: Dict[str, Any] = {}
            if flat_state:
                out["pbuf"] = tuple(repack_buffers(transition, state["pbuf"]))
                opt: Dict[str, Any] = {
                    "step": state["opt"]["step"],
                    "m": tuple(repack_buffers(transition, state["opt"]["m"])),
                }
                if "v" in state["opt"]:
                    opt["v"] = tuple(
                        repack_buffers(transition, state["opt"]["v"])
                    )
                out["opt"] = opt
            else:
                out["params"] = state["params"]
                out["opt"] = state["opt"]
            out["cur"] = tuple(repack_buffers(transition, state["cur"]))
            out["fut"] = tuple(repack_buffers(transition, state["fut"]))
            if gather_skip:
                # the gather cache is layout-bound and derived: reset cold
                # (post-swap cycle position 0 always re-gathers)
                out["pgather"] = tuple(
                    jnp.zeros((n,), compute_dtype) for n in dst.buf_sizes
                )
            return out

        out_sh: Dict[str, Any] = {"cur": split, "fut": split}
        if flat_state:
            out_sh["pbuf"] = buf
            opt_sh: Dict[str, Any] = {"step": rep, "m": buf}
            if adam:
                opt_sh["v"] = buf
            out_sh["opt"] = opt_sh
        else:
            out_sh["params"] = rep
            out_sh["opt"] = rep
        if gather_skip:
            out_sh["pgather"] = rep
        jitted = jax.jit(
            fn,
            donate_argnums=(0,) if self.donate else (),
            out_shardings=out_sh,
        )
        self._repack_cache[transition] = jitted
        return jitted

    def repack_state(
        self, state: TrainState, transition: LayoutTransition
    ) -> TrainState:
        """Re-flatten a train state between two bucket layouts in ONE
        jitted gather/scatter pass (DESIGN.md §9).  Pure data movement —
        the returned state is bit-identical to flatten(unflatten(state))
        under the dst layout.  Consumes ``state`` when donation is on.
        Normally driven by the staged swap in :meth:`step`; public for
        cross-layout checkpoint restores, tests and benchmarks."""
        if transition.dst.shapes != self.layout.shapes:
            raise ValueError(
                "transition targets a different parameter tree than this "
                "runtime's layout"
            )
        with self._partial_donation_ok():
            tr0 = self.tracer.now()
            out = self._repack_jitted(transition)(state)
            self.tracer.add(
                "repack", "repack-state", tr0, self.tracer.now(),
                moved_elems=transition.moved_elems,
                n_buckets=transition.dst.n_buckets,
            )
            return out

    def _swap_state_struct(self, state_abs, layout: BucketLayout):
        """Abstract post-repack train state under ``layout`` — what the
        staged schedule's fresh phases are compiled against while the old
        cycle keeps training on the old layout."""
        from jax.sharding import NamedSharding

        dp = self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]
        place = self._state_placement(
            NamedSharding(self.mesh, P()),
            NamedSharding(self.mesh, P("data")),
            NamedSharding(self.mesh, P(dp)),
        )
        rep, buf, split = place["rep"], place["buf"], place["split"]

        def sds(shape, dtype, sharding):
            try:
                return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)
            except TypeError:   # older jax: no sharding kwarg
                return jax.ShapeDtypeStruct(shape, dtype)

        out = dict(state_abs)
        out["cur"] = tuple(
            sds((self.accum_devices, n), jnp.float32, split)
            for n in layout.buf_sizes
        )
        out["fut"] = tuple(
            sds((self.accum_devices, n), jnp.float32, split)
            for n in layout.buf_sizes
        )
        if self.flat_state:
            bufs = lambda dt: tuple(
                sds((n,), dt, buf) for n in layout.buf_sizes
            )
            out["pbuf"] = bufs(self._master_jdtype)
            opt: Dict[str, Any] = {"step": state_abs["opt"]["step"],
                                   "m": bufs(jnp.float32)}
            if "v" in state_abs["opt"]:
                opt["v"] = bufs(jnp.float32)
            out["opt"] = opt
        if self._gather_skip:
            dt = self.compute_dtype or jnp.float32
            out["pgather"] = tuple(
                sds((n,), dt, rep) for n in layout.buf_sizes
            )
        return out

    # ---- hot-swap -------------------------------------------------------
    def prepare_swap(
        self,
        schedule: DeftSchedule,
        state: TrainState,
        batch,
        *,
        background: bool = False,
        layout: Optional[BucketLayout] = None,
        ag_plan: Any = _UNSET,
        retries: int = 2,
        retry_backoff_s: float = 0.05,
    ) -> Dict[str, Any]:
        """Stage a replanned schedule for installation at the next cycle
        boundary.

        Unseen executables are lowered + compiled against the current
        state/batch shapes (``lower`` only reads avals — it never consumes
        the donated buffers); executables already in the persistent cache
        are reused.  With ``background=True`` the compile happens on a
        daemon thread while training keeps stepping the old schedule; the
        swap arms only once compilation finishes, so :meth:`step` never
        blocks on a half-built schedule.

        A compile failure NEVER silently strands the staged swap: the
        exception is recorded in ``swap_log`` (``event:
        'swap-compile-failed'``) and counted in ``swap_failures``, then
        the build retries up to ``retries`` times with linear backoff
        (``retry_backoff_s * attempt``; already-compiled phases are not
        recompiled).  When the budget is exhausted the swap is abandoned
        — training keeps stepping the installed schedule and a later
        :meth:`prepare_swap` starts clean (DESIGN.md §10).

        With ``layout`` (a different :class:`BucketLayout` over the SAME
        parameter tree — a new bucket partition and/or shard count) the
        swap becomes a layout-changing one (DESIGN.md §9): a
        :class:`LayoutTransition` is compiled alongside, the staged
        phases compile against the POST-repack state avals and segment
        maps of the new layout, and :meth:`step` runs the single-pass
        re-pack at the cycle boundary before dispatching phase 0 of the
        new schedule — an adaptive repartition needs no restart and no
        checkpoint round-trip.

        For a same-layout swap the install is a pure Python pointer flip
        at ``(i - cycle_base) % period == 0``: the donated train state
        carries across untouched because every buffer keeps its shape
        and sharding.
        """
        # a replanned AG stream (DESIGN.md §14) re-derives the per-bucket
        # secondary-AG masks for the staged executables; _UNSET keeps the
        # current plan.  Takes effect immediately for key derivation —
        # the installed schedule's entries were resolved at install and
        # never re-keyed, so running dispatch is unaffected.
        if ag_plan is not _UNSET:
            self._ag_plan = ag_plan
        new_layout: Optional[BucketLayout] = None
        transition: Optional[LayoutTransition] = None
        new_segments: Optional[BucketSegments] = None
        if layout is not None and layout != self.layout:
            # a hot-swap may change per-bucket WIRE precision (it is just
            # a new layout identity; an all-identical repack aliases the
            # state across) but never the resident master dtype — that
            # would need a state-wide cast, not a repack
            self._validate_precision_layout(layout)
            if self.flat_state and self.fsdp:
                shape = dict(zip(self.mesh.axis_names,
                                 self.mesh.devices.shape))
                if layout.shards != int(shape["data"]):
                    raise ValueError(
                        f"layout swap on the sharded engine: proposed "
                        f"layout has shard_count={layout.shards} but the "
                        f"mesh 'data' axis is {shape['data']}-way"
                    )
            new_layout = layout
            transition = build_layout_transition(self.layout, new_layout)
            if self.flat_state:
                new_segments = build_segments(new_layout, self.opt_spec)
        fresh, reused = self._ensure_entries(
            schedule, new_layout, new_segments
        )
        self.replans += 1
        info: Dict[str, Any] = {
            "new_phases": len(fresh),
            "reused_phases": reused,
            "background": background,
            "layout_change": new_layout is not None,
        }
        if new_layout is not None:
            info["n_buckets"] = (self.layout.n_buckets, new_layout.n_buckets)
            info["shards"] = (self.layout.shards, new_layout.shards)
            info["moved_elems"] = transition.moved_elems
        # snapshot avals NOW: the caller keeps training, and donation
        # deletes the concrete state buffers under the background thread
        state_abs = jax.tree.map(_abstractify, state)
        batch_abs = jax.tree.map(_abstractify, batch)
        if new_layout is not None:
            compile_state_abs = self._swap_state_struct(state_abs, new_layout)
        else:
            compile_state_abs = state_abs
        self._swap_gen += 1
        gen = self._swap_gen
        self._pending = None   # a newer replan supersedes any armed one

        def _build() -> None:
            t0 = time.perf_counter()
            tr0 = self.tracer.now()
            attempt = 0
            while True:
                try:
                    self._compile_entries(fresh, compile_state_abs, batch_abs)
                    repack = None
                    if transition is not None:
                        # AOT-compile the repack pass too: the
                        # cycle-boundary install must not pay a
                        # trace+compile on the hot path
                        with jax.set_mesh(self.mesh), \
                                self._partial_donation_ok():
                            repack = self._repack_jitted(transition).lower(
                                state_abs
                            ).compile()
                    break
                except Exception as e:   # noqa: BLE001 — surfaced, retried
                    attempt += 1
                    self.swap_failures += 1
                    err = f"{type(e).__name__}: {e}"
                    self.last_swap_error = err
                    retrying = attempt <= retries and self._swap_gen == gen
                    # failures SURFACE in the trace (and through the
                    # swap_log shim) — a background-thread exception must
                    # never silently strand a staged swap
                    self.tracer.instant(
                        "swap-compile", "swap-compile-failed",
                        step=None, event="swap-compile-failed",
                        error=err, attempt=attempt, retrying=retrying,
                    )
                    if not retrying:
                        # abandoned; old schedule keeps running.  Close
                        # the books so callers reading `info` can tell
                        # an abandoned build from one that never started
                        elapsed = time.perf_counter() - t0
                        info["compile_s"] = elapsed
                        info["compile_attempts"] = attempt
                        info["abandoned"] = True
                        self.tracer.instant(
                            "swap-compile", "swap-abandoned",
                            step=None, event="swap-abandoned",
                            error=err, attempts=attempt,
                            elapsed_s=elapsed,
                            superseded=self._swap_gen != gen,
                        )
                        return
                    time.sleep(retry_backoff_s * attempt)
            info["compile_s"] = time.perf_counter() - t0
            info["compile_attempts"] = attempt + 1
            self.tracer.add(
                "swap-compile", "swap-compile", tr0, self.tracer.now(),
                new_phases=len(fresh), reused_phases=reused,
                background=background,
                layout_change=new_layout is not None,
                attempts=attempt + 1,
            )
            # publish last — step() sees the schedule only fully compiled —
            # and only if no NEWER prepare_swap superseded this one (a slow
            # older compile must not overwrite a fresher staged schedule)
            if self._swap_gen == gen:
                self._pending = _PendingSwap(
                    schedule=schedule,
                    layout=new_layout,
                    segments=new_segments,
                    transition=transition,
                    repack=repack,
                )

        if background:
            self._swap_thread = threading.Thread(
                target=_build, name="deft-swap-compile", daemon=True
            )
            self._swap_thread.start()
        else:
            _build()
        return info

    def swap_ready(self) -> bool:
        """A staged schedule is compiled and armed for the next cycle
        boundary."""
        return self._pending is not None

    def wait_swap_ready(self, timeout: Optional[float] = None) -> bool:
        """Block until a background prepare_swap finishes compiling."""
        if self._swap_thread is not None:
            self._swap_thread.join(timeout)
        return self.swap_ready()

    # ---- elastic / degraded-mode dispatch -------------------------------
    def spawn(
        self,
        *,
        mesh=None,
        schedule: Optional[DeftSchedule] = None,
        layout: Optional[BucketLayout] = None,
        fsdp: Optional[bool] = None,
        gather_skip: Optional[bool] = None,
        donate: Optional[bool] = None,
        decoupled: Optional[bool] = None,
        config: Optional[RuntimeConfig] = None,
        tracer: Optional[Tracer] = None,
    ) -> "DeftRuntime":
        """Sibling runtime: same arch/optimizer/engine config, overriding
        mesh, schedule, layout and/or engine.  The elastic control plane
        builds these for mesh scale-down/up and for the
        sharded->replicated degraded-mode fallback (DESIGN.md §10);
        state moves over via :func:`repro.elastic.coordinator.migrate_state`.
        The phase cache is NOT shared — executables are mesh-bound.

        Overrides compose through :meth:`RuntimeConfig.replace` on this
        runtime's config (so an illegal combination is refused before any
        compile); pass ``config=`` for a full replacement instead of
        per-knob overrides."""
        new_mesh = self.mesh if mesh is None else mesh
        if config is None:
            fsdp_r = self.fsdp if fsdp is None else fsdp
            dec_r = self.decoupled if decoupled is None else decoupled
            if decoupled is None and not (fsdp_r and self.flat_state):
                # an inherited decoupled flag dies with the RS engine
                # (degraded-mode replicated fallback has no param AG)
                dec_r = False
            config = self.config.replace(
                multi_pod=(self.multi_pod if mesh is None
                           else "pod" in new_mesh.axis_names),
                fsdp=fsdp_r,
                donate=self.donate if donate is None else donate,
                decoupled=dec_r,
                # the sibling re-resolves its gather-skip default against
                # its own schedule unless explicitly pinned
                gather_skip=gather_skip,
            )
        elif any(v is not None for v in (fsdp, gather_skip, donate,
                                         decoupled)):
            raise ValueError(
                "spawn: pass config= OR per-knob overrides, not both"
            )
        return DeftRuntime(
            self.cfg,
            self.opt_spec,
            self.schedule if schedule is None else schedule,
            self.layout if layout is None else layout,
            new_mesh,
            config=config,
            ag_plan=self._ag_plan,
            # the sibling inherits the event stream by default: one trace
            # spans an elastic migration end to end
            tracer=(tracer if tracer is not None
                    else (self.tracer if self.trace_steps else None)),
        )

    # ---- dispatch -------------------------------------------------------
    def step(
        self, i: int, state: TrainState, batch
    ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        """Run training step ``i`` (cycle phase ``(i - cycle_base) %
        period``).  Consumes ``state`` when donation is on.  If a staged
        schedule is armed and ``i`` lands on a cycle boundary, it is
        installed first and ``i`` becomes step 0 of the new cycle; a
        layout-changing swap additionally re-packs the donated state
        through the staged transition before dispatching (the one-time
        repack cost is recorded in ``swap_log``)."""
        if self._pending is not None and (i - self._cycle_base) % self.period == 0:
            pending, self._pending = self._pending, None
            repack_s = None
            if pending.layout is not None:
                t0 = time.perf_counter()
                tr0 = self.tracer.now()
                state = pending.repack(state)
                jax.block_until_ready(jax.tree_util.tree_leaves(state))
                repack_s = time.perf_counter() - t0
                self.tracer.add(
                    "repack", "swap-repack", tr0, self.tracer.now(),
                    step=i, moved_elems=pending.transition.moved_elems,
                    n_buckets=pending.layout.n_buckets,
                )
                self.layout = pending.layout
                self._segments = pending.segments
                self.layout_swaps += 1
            self._install(pending.schedule)
            self._cycle_base = i
            self.hot_swaps += 1
            self.tracer.instant(
                "swap-install", "swap-install",
                step=i, period=pending.schedule.period,
                updates_per_period=pending.schedule.updates_per_period,
                n_buckets=self.layout.n_buckets,
                shards=self.layout.shards,
                repack_s=repack_s,
                precision=(
                    self.layout.precision.describe()
                    if self.layout.precision is not None else "f32"
                ),
            )
        off = (i - self._cycle_base) % self.period
        self.last_phase = off
        entry = self._unique_entries[self.phase_of_step[off]]
        # an entry's first dispatch carries residual lazy work (jit
        # trace+compile on the fallback branch, executable warm-up even
        # when AOT-compiled) — tag it so telemetry can skip it (§11)
        first = entry.stats.dispatches == 0
        self.last_dispatch_first = first
        tracing = self.trace_steps
        clock = self.tracer.now if tracing else time.perf_counter
        t0 = clock()
        if entry.compiled is not None:
            out = entry.compiled(state, batch)
        else:  # compile() skipped — trace under the mesh on first hit
            with jax.set_mesh(self.mesh):
                out = entry.jitted(state, batch)
        t1 = clock()
        entry.stats.dispatches += 1
        entry.stats.dispatch_s += t1 - t0
        if tracing:
            spec = entry.spec
            self.tracer.add(
                "phase", f"phase{off}", t0, t1, step=i, phase=off,
                first=first, update=spec.do_update,
            )
            coll = self._coll_of_step[off]
            wire = (
                self.layout.precision.describe()
                if self.layout.precision is not None else "f32"
            )
            wb_p, wb_s = self._wire_bytes_split_of_step[off]
            self.tracer.add(
                "collective-group", f"collectives@{off}", t0, t1,
                step=i, phase=off,
                primary=coll["primary"], secondary=coll["secondary"],
                wire_bytes=self._wire_bytes_of_step[off],
                wire_bytes_primary=wb_p, wire_bytes_secondary=wb_s,
                precision=wire,
            )
            if spec.do_update:
                self.tracer.instant(
                    "update-apply", f"update-k{spec.update_k}",
                    t=t1, step=i, phase=off, k=spec.update_k,
                    source=spec.update_source,
                )
            if self._reuse_of_step[off]:
                self.tracer.instant(
                    "gather-skip", "gather-skip", t=t0, step=i, phase=off,
                )
        return out

    # ---- reporting ------------------------------------------------------
    def collectives_per_phase(self) -> List[Dict[str, int]]:
        """Static per-schedule-phase collective counts (fused path)."""
        return [phase_collectives(p) for p in self.schedule.phases]

    def stats(self) -> Dict[str, Any]:
        entries = list(self._entries.values())
        per_phase = [dataclasses.asdict(e.stats) for e in entries]
        total_compile = sum(
            e.stats.lower_s + e.stats.compile_s for e in entries
        )
        total_dispatch = sum(e.stats.dispatch_s for e in entries)
        n = sum(e.stats.dispatches for e in entries)
        coll = self.collectives_per_phase()
        from repro.kernels.bucket_update import default_bucket_update_impl

        return {
            "period": self.period,
            "unique_phases": self.n_unique_phases,
            "cached_phases": self.n_cached_phases,
            "flat_state": self.flat_state,
            "sharded_state": bool(self.flat_state and self.fsdp),
            "shards": self.layout.shards,
            "compute_dtype": (
                jnp.dtype(self.compute_dtype).name
                if self.compute_dtype is not None else "float32"
            ),
            "update_impl": (
                (self.update_impl or default_bucket_update_impl())
                if self.flat_state else "per-leaf"
            ),
            "wire_precision": (
                self.layout.precision.describe()
                if self.layout.precision is not None else "f32"
            ),
            "master_dtype": self.master_dtype,
            "planned_wire_bytes_per_cycle": sum(self._wire_bytes_of_step),
            "accum_devices": self.accum_devices,
            "n_buckets": self.layout.n_buckets,
            "n_leaves": self.layout.n_leaves,
            "compile_s_total": total_compile,
            "steps_dispatched": n,
            "dispatch_s_total": total_dispatch,
            # dispatch-wall throughput: what the benchmarks report without
            # re-deriving it from their own timers
            "steps_per_s": n / total_dispatch if total_dispatch > 0 else 0.0,
            "replans": self.replans,
            "hot_swaps": self.hot_swaps,
            "layout_swaps": self.layout_swaps,
            "swap_failures": self.swap_failures,
            "last_swap_error": self.last_swap_error,
            "gather_skip": self._gather_skip,
            "decoupled": self.decoupled,
            "swap_log": list(self.swap_log),
            "trace": self.tracer.stats(),
            "collectives_per_phase": coll,
            "max_collectives_in_a_phase": max(
                (c["primary"] + c["secondary"] for c in coll), default=0
            ),
            "phases": per_phase,
        }


# ---------------------------------------------------------------------------
# Convenience constructors
# ---------------------------------------------------------------------------
def make_ddp_step(
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    *,
    fsdp: bool = False,
    multi_pod: bool = False,
    donate: bool = True,
    **kw,
) -> Callable:
    """Donated jitted DDP baseline step (params/opt update in place)."""
    return jax.jit(
        functools.partial(
            ddp_train_step, cfg=cfg, opt_spec=opt_spec,
            fsdp=fsdp, multi_pod=multi_pod, **kw,
        ),
        donate_argnums=(0,) if donate else (),
    )
