"""Gradient bucketing over actual parameter-tree leaves.

The analytical profiler (core/profiler.py) buckets by *layer* for the
paper-figure studies; the JAX train step needs buckets over the real
pytree leaves (scan-stacked weights), ordered input->output the way DDP's
reverse-registration order would see them:

    embed -> encoder -> prefix blocks -> stack (pattern positions) ->
    tail blocks -> final_norm -> head

One stacked leaf covers every period of that weight, so leaf-bucket
counts land in the paper's "< 20 items" knapsack regime naturally.
``assign_buckets`` greedily fills buckets to ``partition_elems``;
``leaf_bucket_times`` derives each bucket's fwd/bwd/comm seconds from the
same HardwareModel the Solver uses, with MoE leaves weighted by their
active fraction (top-k / n_experts).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.bucket import BucketTimes
from repro.core.profiler import HardwareModel

_GROUP_ORDER = {
    "embed": 0,
    "encoder": 1,
    "prefix": 2,
    "stack": 3,
    "tail": 4,
    "final_norm": 5,
    "head": 6,
}


def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        else:
            keys.append(str(p))
    return tuple(keys)


def ordered_leaf_indices(params) -> List[int]:
    """Indices into tree_flatten(params) leaf order, re-ordered to model
    input->output traversal."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    keyed = []
    for i, (path, leaf) in enumerate(flat):
        keys = _path_keys(path)
        group = _GROUP_ORDER.get(keys[0], 9)
        sub = 0
        if keys[0] in ("prefix", "stack", "tail") and len(keys) > 1:
            try:
                sub = int(keys[1])
            except ValueError:
                sub = 0
        keyed.append((group, sub, i))
    keyed.sort(key=lambda t: (t[0], t[1]))
    return [i for (_, _, i) in keyed]


def leaf_active_fraction(cfg: ArchConfig, keys: Tuple[str, ...]) -> float:
    """Fraction of a leaf's elements doing matmul work per token (MoE
    routed experts: top-k of E)."""
    if cfg.moe and "experts" in keys and keys[-1] in ("gate", "up", "down"):
        return cfg.moe.experts_per_token / cfg.moe.n_experts
    return 1.0


def assign_buckets(
    params,
    cfg: ArchConfig,
    partition_elems: int = 50_000_000,
) -> Tuple[Tuple[int, ...], int]:
    """Greedy fill in model order.  Returns (bucket_of_leaf aligned with
    tree_flatten leaf order, n_buckets); bucket 0 is input-most."""
    leaves = jax.tree_util.tree_flatten(params)[0]
    order = ordered_leaf_indices(params)
    bucket_of = [0] * len(leaves)
    b, acc = 0, 0
    for idx in order:
        n = int(np.prod(leaves[idx].shape))
        bucket_of[idx] = b
        acc += n
        if acc >= partition_elems:
            b += 1
            acc = 0
    n_buckets = b + (1 if acc > 0 else 0)
    n_buckets = max(n_buckets, 1)
    # if the last bucket ended exactly on a boundary, b overshoots by one
    n_buckets = max(set(bucket_of)) + 1
    return tuple(bucket_of), n_buckets


def leaf_bucket_times(
    params,
    cfg: ArchConfig,
    bucket_of_leaf: Sequence[int],
    n_buckets: int,
    hw: HardwareModel,
    seq_len: int,
    per_device_batch: int,
) -> BucketTimes:
    """Analytical fwd/bwd/comm seconds per leaf-bucket."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    tokens = per_device_batch * seq_len
    fwd = [0.0] * n_buckets
    comm_elems = [0] * n_buckets
    for i, (path, leaf) in enumerate(flat):
        keys = _path_keys(path)
        b = bucket_of_leaf[i]
        elems = int(np.prod(leaf.shape))
        active = leaf_active_fraction(cfg, keys)
        flops = 2.0 * elems * active * tokens if leaf.ndim >= 2 else 0.0
        fwd[b] += hw.compute_time(flops)
        comm_elems[b] += elems
    bwd = [2.0 * f for f in fwd]
    comm = [hw.allreduce_time(e) for e in comm_elems]
    return BucketTimes(tuple(fwd), tuple(bwd), tuple(comm))
