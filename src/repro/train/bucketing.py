"""Gradient bucketing over actual parameter-tree leaves.

The analytical profiler (core/profiler.py) buckets by *layer* for the
paper-figure studies; the JAX train step needs buckets over the real
pytree leaves (scan-stacked weights), ordered input->output the way DDP's
reverse-registration order would see them:

    embed -> encoder -> prefix blocks -> stack (pattern positions) ->
    tail blocks -> final_norm -> head

One stacked leaf covers every period of that weight, so leaf-bucket
counts land in the paper's "< 20 items" knapsack regime naturally.
``assign_buckets`` greedily fills buckets to ``partition_elems``;
``leaf_bucket_times`` derives each bucket's fwd/bwd/comm seconds from the
same HardwareModel the Solver uses, with MoE leaves weighted by their
active fraction (top-k / n_experts).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.bucket import BucketTimes
from repro.core.precision import PrecisionPolicy
from repro.core.profiler import HardwareModel

_GROUP_ORDER = {
    "embed": 0,
    "encoder": 1,
    "prefix": 2,
    "stack": 3,
    "tail": 4,
    "final_norm": 5,
    "head": 6,
}


def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        else:
            keys.append(str(p))
    return tuple(keys)


def ordered_leaf_indices(params) -> List[int]:
    """Indices into tree_flatten(params) leaf order, re-ordered to model
    input->output traversal."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    keyed = []
    for i, (path, leaf) in enumerate(flat):
        keys = _path_keys(path)
        group = _GROUP_ORDER.get(keys[0], 9)
        sub = 0
        if keys[0] in ("prefix", "stack", "tail") and len(keys) > 1:
            try:
                sub = int(keys[1])
            except ValueError:
                sub = 0
        keyed.append((group, sub, i))
    keyed.sort(key=lambda t: (t[0], t[1]))
    return [i for (_, _, i) in keyed]


def leaf_active_fraction(cfg: ArchConfig, keys: Tuple[str, ...]) -> float:
    """Fraction of a leaf's elements doing matmul work per token (MoE
    routed experts: top-k of E)."""
    if cfg.moe and "experts" in keys and keys[-1] in ("gate", "up", "down"):
        return cfg.moe.experts_per_token / cfg.moe.n_experts
    return 1.0


def greedy_fill_partition(
    order: Sequence[int],
    elems: Sequence[int],
    partition_elems: int,
) -> Tuple[Tuple[int, ...], int]:
    """THE greedy model-order fill: walk ``order``, open a new bucket
    whenever the running element count reaches ``partition_elems``.
    Shared by :func:`assign_buckets` (params tree) and
    :meth:`LeafTimeModel.partition` (frozen atoms) so the online
    repartitioner's candidate grid can never drift from the partitions
    the real layouts are built with."""
    bucket_of = [0] * len(elems)
    b, acc = 0, 0
    for idx in order:
        bucket_of[idx] = b
        acc += elems[idx]
        if acc >= partition_elems:
            b += 1
            acc = 0
    # if the last bucket ended exactly on a boundary, b overshoots by one
    n_buckets = max(set(bucket_of)) + 1
    return tuple(bucket_of), n_buckets


def assign_buckets(
    params,
    cfg: ArchConfig,
    partition_elems: int = 50_000_000,
) -> Tuple[Tuple[int, ...], int]:
    """Greedy fill in model order.  Returns (bucket_of_leaf aligned with
    tree_flatten leaf order, n_buckets); bucket 0 is input-most."""
    leaves = jax.tree_util.tree_flatten(params)[0]
    return greedy_fill_partition(
        ordered_leaf_indices(params),
        [int(np.prod(l.shape)) for l in leaves],
        partition_elems,
    )


# ---------------------------------------------------------------------------
# Static leaf -> flat-buffer layout (fused-bucket collectives)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Static mapping between parameter-tree leaves and per-bucket flat
    f32 buffers (DESIGN.md §Fused buffers).

    Each bucket owns one contiguous buffer holding every leaf assigned to
    it, in ``tree_flatten`` leaf order.  All offsets/sizes are Python ints
    computed once at plan time, so flatten/unflatten trace to static
    concatenate/slice/reshape ops and each bucket syncs as ONE collective.

    bucket_of_leaf: leaf index (tree_flatten order) -> bucket id.
    n_buckets:      number of buckets (== number of flat buffers).
    leaves:         per bucket, the leaf indices it holds (ascending).
    offsets:        per bucket, the start offset of each leaf's span.
    sizes:          per bucket, total element count of its *valid* span.
    shapes:         per leaf (tree_flatten order), the original shape.
    padded_sizes:   per bucket, the allocated buffer length — ``sizes``
                    rounded up to ``pad_multiple`` so the buffer reshapes
                    to (rows, 128) lanes for the Pallas bucket-update
                    kernels (DESIGN.md §8).  The tail [size, padded) is
                    always zero: flatten pads zeros, collectives reduce
                    zeros, and the update kernels mask it.  Empty tuple
                    means "no padding" (legacy hand-built layouts).
    shards:         shard count of the sharded flat engine (DESIGN.md
                    §8, sharded layout): every allocated buffer length is
                    additionally a multiple of ``shards * pad_multiple``,
                    so the buffer splits into ``shards`` equal contiguous
                    spans and every span is itself a lane-aligned kernel
                    operand.  1 (the default) is the replicated engine.
    precision:      per-bucket wire precision policy (DESIGN.md §13);
                    ``None`` means all-f32.  Part of layout identity on
                    purpose: the runtime's phase cache keys on the
                    layout, so a precision change is a cycle-boundary
                    layout swap — while :func:`build_layout_transition`
                    ignores it, making the precision-only repack a pure
                    aliasing pass (zero data movement).
    """

    bucket_of_leaf: Tuple[int, ...]
    n_buckets: int
    leaves: Tuple[Tuple[int, ...], ...]
    offsets: Tuple[Tuple[int, ...], ...]
    sizes: Tuple[int, ...]
    shapes: Tuple[Tuple[int, ...], ...]
    padded_sizes: Tuple[int, ...] = ()
    shards: int = 1
    precision: Optional[PrecisionPolicy] = None

    def __post_init__(self):
        if self.precision is not None:
            self.precision.validate(self.n_buckets)

    @property
    def n_leaves(self) -> int:
        return len(self.bucket_of_leaf)

    def wire(self, b: int) -> str:
        """Wire dtype name of bucket ``b`` ("f32" without a policy)."""
        return "f32" if self.precision is None else self.precision.wire[b]

    @property
    def master_dtype(self) -> str:
        return "f32" if self.precision is None else self.precision.master

    def with_precision(
        self, precision: Optional[PrecisionPolicy]
    ) -> "BucketLayout":
        """Same partition/sharding, different precision policy — the
        layout a precision-only hot-swap targets."""
        return dataclasses.replace(self, precision=precision)

    @property
    def total_elems(self) -> int:
        return sum(self.sizes)

    @property
    def buf_sizes(self) -> Tuple[int, ...]:
        """Allocated per-bucket buffer lengths (padded when available)."""
        return self.padded_sizes or self.sizes

    @property
    def shard_sizes(self) -> Tuple[int, ...]:
        """Per bucket, the length of one device's contiguous shard span
        (``buf_sizes[b] // shards``; a lane multiple by construction).
        Shard ``s`` of bucket ``b`` covers the global index range
        ``[s * shard_sizes[b], (s + 1) * shard_sizes[b])``."""
        return tuple(n // self.shards for n in self.buf_sizes)


# One f32 lane row: the bucket-update kernels reshape buffers to
# (rows, PAD_MULTIPLE) tiles (kernels/bucket_update/kernel.py re-checks
# the two constants agree on every trace, so they cannot drift apart
# silently).
PAD_MULTIPLE = 128


def build_bucket_layout(
    params,
    bucket_of_leaf: Sequence[int],
    n_buckets: int,
    *,
    pad_multiple: int = PAD_MULTIPLE,
    shard_count: int = 1,
    precision: Optional[PrecisionPolicy] = None,
) -> BucketLayout:
    """Precompute the per-bucket flat-buffer layout for a parameter tree.

    ``shard_count > 1`` builds the shard-aware layout of the sharded flat
    engine (DESIGN.md §8): every buffer is padded to a multiple of
    ``shard_count * pad_multiple`` so it splits into ``shard_count``
    equal, lane-aligned spans — each span a valid kernel operand and a
    valid tiled reduce-scatter / all-gather shard.
    """
    if pad_multiple <= 0 or pad_multiple % PAD_MULTIPLE:
        raise ValueError(
            f"pad_multiple={pad_multiple} must be a positive multiple of "
            f"{PAD_MULTIPLE} (the bucket-update kernels' lane width) — a "
            f"smaller value would only fail deep inside the flat engine's "
            f"first update-phase compile"
        )
    if shard_count < 1:
        raise ValueError(f"shard_count={shard_count} must be >= 1")
    unit = pad_multiple * shard_count
    flat = jax.tree_util.tree_flatten(params)[0]
    assert len(flat) == len(bucket_of_leaf)
    shapes = tuple(tuple(l.shape) for l in flat)
    leaves: List[List[int]] = [[] for _ in range(n_buckets)]
    for i, b in enumerate(bucket_of_leaf):
        leaves[b].append(i)
    offsets: List[Tuple[int, ...]] = []
    sizes: List[int] = []
    padded: List[int] = []
    for b in range(n_buckets):
        offs, acc = [], 0
        for i in leaves[b]:
            offs.append(acc)
            acc += int(np.prod(shapes[i], dtype=np.int64)) if shapes[i] else 1
        offsets.append(tuple(offs))
        sizes.append(acc)
        # sharded layouts allocate one unit even for an empty bucket so
        # every shard span is a non-empty kernel / collective operand
        if acc:
            padded.append(-(-acc // unit) * unit)
        else:
            padded.append(unit if shard_count > 1 else 0)
    return BucketLayout(
        bucket_of_leaf=tuple(bucket_of_leaf),
        n_buckets=n_buckets,
        leaves=tuple(tuple(g) for g in leaves),
        offsets=tuple(offsets),
        sizes=tuple(sizes),
        shapes=shapes,
        padded_sizes=tuple(padded),
        shards=shard_count,
        precision=precision,
    )


def flatten_buckets(layout: BucketLayout, leaf_vals) -> List[jax.Array]:
    """Pack leaf values (tree_flatten order) into per-bucket flat f32
    buffers, zero-padded to the layout's allocated length.  Traced:
    static concatenation, no data-dependent shapes."""
    out = []
    buf_sizes = layout.buf_sizes
    for b in range(layout.n_buckets):
        parts = [
            leaf_vals[i].astype(jnp.float32).reshape(-1)
            for i in layout.leaves[b]
        ]
        pad = buf_sizes[b] - layout.sizes[b]
        if pad:
            parts.append(jnp.zeros((pad,), jnp.float32))
        out.append(
            parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        )
    return out


def unflatten_buckets(layout: BucketLayout, flats) -> List[jax.Array]:
    """Inverse of :func:`flatten_buckets`: per-bucket flat buffers back to
    leaf values (tree_flatten order, f32)."""
    leaf_vals: List[jax.Array] = [None] * layout.n_leaves  # type: ignore
    for b in range(layout.n_buckets):
        flat = flats[b]
        for i, off in zip(layout.leaves[b], layout.offsets[b]):
            shape = layout.shapes[i]
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            leaf_vals[i] = jax.lax.slice(flat, (off,), (off + n,)).reshape(shape)
    assert all(v is not None for v in leaf_vals)
    return leaf_vals


def leaf_bucket_times(
    params,
    cfg: ArchConfig,
    bucket_of_leaf: Sequence[int],
    n_buckets: int,
    hw: HardwareModel,
    seq_len: int,
    per_device_batch: int,
) -> BucketTimes:
    """Analytical fwd/bwd/comm seconds per leaf-bucket."""
    model = build_leaf_time_model(params, cfg, hw, seq_len, per_device_batch)
    return model.bucket_times(bucket_of_leaf, n_buckets)


# ---------------------------------------------------------------------------
# Per-leaf time model (repartitioning input)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LeafTimeModel:
    """Per-leaf timing atoms from which bucket times for ANY partition of
    the same parameter tree can be regenerated.

    ``leaf_bucket_times`` bakes the partition into its output; the online
    repartitioning path (adapt/repartition.py) instead needs "what would
    this OTHER partition's BucketTimes be under the calibrated hardware"
    — so the per-leaf fwd seconds and element counts are frozen once (a
    pure-Python tuple dataclass; jax is only touched at construction) and
    every candidate partition re-aggregates them.

    ``comm_scale`` folds in the uniform coverage-rate rescale the train
    driver applies (build_schedule's synthetic-CR knob), so regenerated
    times stay comparable with the times the installed plan was solved
    from.  ``bucket_times(..., comp_scale=, comm_scale=)`` additionally
    applies calibration scales on top (adapt/calibrate.py semantics).
    """

    order: Tuple[int, ...]       # model-order traversal of flat leaf idx
    fwd_s: Tuple[float, ...]     # per leaf (flat idx), analytic fwd seconds
    elems: Tuple[int, ...]       # per leaf (flat idx), element count
    hw: HardwareModel
    comm_scale: float = 1.0      # uniform CR rescale folded into comm

    @property
    def n_leaves(self) -> int:
        return len(self.fwd_s)

    def with_comm_scale(self, scale: float) -> "LeafTimeModel":
        return dataclasses.replace(self, comm_scale=scale)

    def with_coverage_rate(
        self,
        bucket_of_leaf: Sequence[int],
        n_buckets: int,
        coverage_rate: float,
    ) -> "LeafTimeModel":
        """Fold the synthetic-CR rescale into the model so that
        ``bucket_times(bucket_of_leaf, n_buckets)`` hits ``coverage_rate``
        — the ONE place the rescale math lives, keeping candidate pricing
        commensurable with the times the installed plan was solved from
        (see :func:`coverage_rescale`)."""
        t = self.bucket_times(bucket_of_leaf, n_buckets)
        return self.with_comm_scale(
            self.comm_scale * coverage_rescale(t, coverage_rate)
        )

    def partition(
        self, partition_elems: int
    ) -> Tuple[Tuple[int, ...], int]:
        """Greedy model-order fill at ``partition_elems`` — literally
        :func:`assign_buckets`' walk (shared via
        :func:`greedy_fill_partition`), without the params tree."""
        return greedy_fill_partition(self.order, self.elems,
                                     partition_elems)

    def bucket_times(
        self,
        bucket_of_leaf: Sequence[int],
        n_buckets: int,
        *,
        comp_scale: float = 1.0,
        comm_scale: float = 1.0,
        precision: Optional[PrecisionPolicy] = None,
    ) -> BucketTimes:
        """BucketTimes of an arbitrary partition of this tree, optionally
        under calibrated effective scales.  ``precision`` prices each
        bucket's comm at its policy wire width (§13) — the latency term
        inside ``allreduce_time`` stays fixed."""
        if precision is not None:
            precision.validate(n_buckets)
        fwd = [0.0] * n_buckets
        comm_elems = [0] * n_buckets
        for i, b in enumerate(bucket_of_leaf):
            fwd[b] += self.fwd_s[i]
            comm_elems[b] += self.elems[i]
        fwd = [f * comp_scale for f in fwd]
        bwd = [2.0 * f for f in fwd]
        c_scale = self.comm_scale * comm_scale
        comm = [
            self.hw.allreduce_time(
                e,
                bytes_per_elem=(
                    None if precision is None
                    else precision.wire_bytes_per_elem(b)
                ),
            ) * c_scale
            for b, e in enumerate(comm_elems)
        ]
        return BucketTimes(tuple(fwd), tuple(bwd), tuple(comm))


def build_leaf_time_model(
    params,
    cfg: ArchConfig,
    hw: HardwareModel,
    seq_len: int,
    per_device_batch: int,
) -> LeafTimeModel:
    """Freeze the per-leaf timing atoms of a parameter tree (shapes only —
    an ``eval_shape`` tree works)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    tokens = per_device_batch * seq_len
    fwd_s: List[float] = []
    elems: List[int] = []
    for path, leaf in flat:
        keys = _path_keys(path)
        n = int(np.prod(leaf.shape, dtype=np.int64)) if leaf.shape else 1
        active = leaf_active_fraction(cfg, keys)
        flops = 2.0 * n * active * tokens if len(leaf.shape) >= 2 else 0.0
        fwd_s.append(hw.compute_time(flops))
        elems.append(n)
    return LeafTimeModel(
        order=tuple(ordered_leaf_indices(params)),
        fwd_s=tuple(fwd_s),
        elems=tuple(elems),
        hw=hw,
    )


def coverage_rescale(times: BucketTimes, coverage_rate: float) -> float:
    """The uniform comm multiplier that pins ``times`` to a target
    coverage rate — shared by the train driver's synthetic-CR knob, the
    repartitioning leaf model and the examples, so the copies cannot
    drift apart and silently bias candidate pricing."""
    return (
        coverage_rate
        * (times.fwd_total + times.bwd_total)
        / max(times.comm_total, 1e-12)
    )


# ---------------------------------------------------------------------------
# Layout transitions (cycle-boundary re-pack between two BucketLayouts)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SpanCopy:
    """One contiguous copy of a layout transition: ``length`` elements
    from offset ``src_off`` of src bucket ``src_bucket`` land at offset
    ``dst_off`` of the dst bucket this copy belongs to."""

    src_bucket: int
    src_off: int
    dst_off: int
    length: int


@dataclasses.dataclass(frozen=True)
class LayoutTransition:
    """Static per-leaf span remap between two :class:`BucketLayout`\\ s of
    the SAME parameter tree (DESIGN.md §9).

    Built once at replan time (pure Python over the two layouts' offset
    tables), consumed by :func:`repack_buffers` as a traced gather: every
    dst buffer is a static concatenation of slices of src buffers plus a
    zero tail.  Adjacent leaves contiguous in both layouts merge into one
    :class:`SpanCopy`, so a transition that only changes the shard count
    (identical partition, different padding unit) compiles to one slice
    per bucket.

    ``identical[b]`` marks dst buckets whose allocated buffer is
    byte-identical to one src buffer (same single full-range copy, same
    padded length): :func:`repack_buffers` passes those through untouched,
    which lets XLA alias the donated src buffer instead of copying it.
    """

    src: BucketLayout
    dst: BucketLayout
    copies: Tuple[Tuple[SpanCopy, ...], ...]   # per dst bucket
    identical: Tuple[bool, ...]                # per dst bucket

    @property
    def moved_elems(self) -> int:
        """Valid elements actually gathered (identical buckets excluded)."""
        return sum(
            c.length
            for b, spans in enumerate(self.copies)
            if not self.identical[b]
            for c in spans
        )

    def reverse(self) -> "LayoutTransition":
        return build_layout_transition(self.dst, self.src)


def build_layout_transition(
    src: BucketLayout, dst: BucketLayout
) -> LayoutTransition:
    """Compile the static span remap ``src`` -> ``dst``.

    Both layouts must cover the same leaf set (identical ``shapes``);
    everything else — bucket count, leaf->bucket assignment, padding,
    shard count — may differ.
    """
    if src.shapes != dst.shapes:
        raise ValueError(
            f"layout transition needs the same parameter tree on both "
            f"sides: src has {len(src.shapes)} leaves, dst "
            f"{len(dst.shapes)} (or shapes differ)"
        )
    # leaf idx -> (src bucket, src offset)
    src_pos: Dict[int, Tuple[int, int]] = {}
    for b in range(src.n_buckets):
        for i, off in zip(src.leaves[b], src.offsets[b]):
            src_pos[i] = (b, off)
    copies: List[Tuple[SpanCopy, ...]] = []
    identical: List[bool] = []
    for b in range(dst.n_buckets):
        spans: List[SpanCopy] = []
        run: Optional[List[int]] = None   # [src_bucket, src_off, dst_off, len]
        for i, d_off in zip(dst.leaves[b], dst.offsets[b]):
            sb, s_off = src_pos[i]
            shape = dst.shapes[i]
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            if (
                run is not None
                and run[0] == sb
                and run[1] + run[3] == s_off
                and run[2] + run[3] == d_off
            ):
                run[3] += n
            else:
                if run is not None:
                    spans.append(SpanCopy(*run))
                run = [sb, s_off, d_off, n]
        if run is not None:
            spans.append(SpanCopy(*run))
        copies.append(tuple(spans))
        identical.append(
            len(spans) == 1
            and spans[0].src_off == 0
            and spans[0].dst_off == 0
            and spans[0].length == dst.sizes[b]
            and src.sizes[spans[0].src_bucket] == dst.sizes[b]
            and src.buf_sizes[spans[0].src_bucket] == dst.buf_sizes[b]
        )
    return LayoutTransition(
        src=src, dst=dst, copies=tuple(copies), identical=tuple(identical)
    )


def repack_buffers(
    transition: LayoutTransition, src_bufs: Sequence[jax.Array]
) -> List[jax.Array]:
    """Apply a layout transition to per-bucket buffers: the single traced
    gather pass of :meth:`DeftRuntime.repack_state`.

    Buffers are remapped along their LAST axis (1-D param/moment buffers
    and ``(accum_devices, size)`` accumulator stacks both work); leading
    axes pass through.  Byte-identical buckets are returned as the src
    array itself so a donating jit can alias instead of copying; the
    padded dst tail is zero by construction (src valid spans are copied,
    src tails — zero by the flat engines' invariant — are never read).
    """
    dst = transition.dst
    out: List[jax.Array] = []
    for b in range(dst.n_buckets):
        if transition.identical[b]:
            out.append(src_bufs[transition.copies[b][0].src_bucket])
            continue
        lead = src_bufs[0].shape[:-1]
        # pad fills match the src dtype — an f32 zero concatenated into
        # e.g. a bf16 buffer would silently promote the whole dst buffer
        dtype = src_bufs[0].dtype
        parts: List[jax.Array] = []
        cursor = 0
        for c in transition.copies[b]:
            if c.dst_off > cursor:   # cannot happen (offsets are dense)
                parts.append(
                    jnp.zeros(lead + (c.dst_off - cursor,), dtype)
                )
            parts.append(
                jax.lax.slice_in_dim(
                    src_bufs[c.src_bucket], c.src_off, c.src_off + c.length,
                    axis=len(lead),
                )
            )
            cursor = c.dst_off + c.length
        pad = dst.buf_sizes[b] - cursor
        if pad:
            parts.append(jnp.zeros(lead + (pad,), dtype))
        out.append(
            parts[0] if len(parts) == 1
            else jnp.concatenate(parts, axis=len(lead))
        )
    return out
