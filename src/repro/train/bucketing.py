"""Gradient bucketing over actual parameter-tree leaves.

The analytical profiler (core/profiler.py) buckets by *layer* for the
paper-figure studies; the JAX train step needs buckets over the real
pytree leaves (scan-stacked weights), ordered input->output the way DDP's
reverse-registration order would see them:

    embed -> encoder -> prefix blocks -> stack (pattern positions) ->
    tail blocks -> final_norm -> head

One stacked leaf covers every period of that weight, so leaf-bucket
counts land in the paper's "< 20 items" knapsack regime naturally.
``assign_buckets`` greedily fills buckets to ``partition_elems``;
``leaf_bucket_times`` derives each bucket's fwd/bwd/comm seconds from the
same HardwareModel the Solver uses, with MoE leaves weighted by their
active fraction (top-k / n_experts).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.bucket import BucketTimes
from repro.core.profiler import HardwareModel

_GROUP_ORDER = {
    "embed": 0,
    "encoder": 1,
    "prefix": 2,
    "stack": 3,
    "tail": 4,
    "final_norm": 5,
    "head": 6,
}


def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        else:
            keys.append(str(p))
    return tuple(keys)


def ordered_leaf_indices(params) -> List[int]:
    """Indices into tree_flatten(params) leaf order, re-ordered to model
    input->output traversal."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    keyed = []
    for i, (path, leaf) in enumerate(flat):
        keys = _path_keys(path)
        group = _GROUP_ORDER.get(keys[0], 9)
        sub = 0
        if keys[0] in ("prefix", "stack", "tail") and len(keys) > 1:
            try:
                sub = int(keys[1])
            except ValueError:
                sub = 0
        keyed.append((group, sub, i))
    keyed.sort(key=lambda t: (t[0], t[1]))
    return [i for (_, _, i) in keyed]


def leaf_active_fraction(cfg: ArchConfig, keys: Tuple[str, ...]) -> float:
    """Fraction of a leaf's elements doing matmul work per token (MoE
    routed experts: top-k of E)."""
    if cfg.moe and "experts" in keys and keys[-1] in ("gate", "up", "down"):
        return cfg.moe.experts_per_token / cfg.moe.n_experts
    return 1.0


def assign_buckets(
    params,
    cfg: ArchConfig,
    partition_elems: int = 50_000_000,
) -> Tuple[Tuple[int, ...], int]:
    """Greedy fill in model order.  Returns (bucket_of_leaf aligned with
    tree_flatten leaf order, n_buckets); bucket 0 is input-most."""
    leaves = jax.tree_util.tree_flatten(params)[0]
    order = ordered_leaf_indices(params)
    bucket_of = [0] * len(leaves)
    b, acc = 0, 0
    for idx in order:
        n = int(np.prod(leaves[idx].shape))
        bucket_of[idx] = b
        acc += n
        if acc >= partition_elems:
            b += 1
            acc = 0
    n_buckets = b + (1 if acc > 0 else 0)
    n_buckets = max(n_buckets, 1)
    # if the last bucket ended exactly on a boundary, b overshoots by one
    n_buckets = max(set(bucket_of)) + 1
    return tuple(bucket_of), n_buckets


# ---------------------------------------------------------------------------
# Static leaf -> flat-buffer layout (fused-bucket collectives)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Static mapping between parameter-tree leaves and per-bucket flat
    f32 buffers (DESIGN.md §Fused buffers).

    Each bucket owns one contiguous buffer holding every leaf assigned to
    it, in ``tree_flatten`` leaf order.  All offsets/sizes are Python ints
    computed once at plan time, so flatten/unflatten trace to static
    concatenate/slice/reshape ops and each bucket syncs as ONE collective.

    bucket_of_leaf: leaf index (tree_flatten order) -> bucket id.
    n_buckets:      number of buckets (== number of flat buffers).
    leaves:         per bucket, the leaf indices it holds (ascending).
    offsets:        per bucket, the start offset of each leaf's span.
    sizes:          per bucket, total element count of its *valid* span.
    shapes:         per leaf (tree_flatten order), the original shape.
    padded_sizes:   per bucket, the allocated buffer length — ``sizes``
                    rounded up to ``pad_multiple`` so the buffer reshapes
                    to (rows, 128) lanes for the Pallas bucket-update
                    kernels (DESIGN.md §8).  The tail [size, padded) is
                    always zero: flatten pads zeros, collectives reduce
                    zeros, and the update kernels mask it.  Empty tuple
                    means "no padding" (legacy hand-built layouts).
    shards:         shard count of the sharded flat engine (DESIGN.md
                    §8, sharded layout): every allocated buffer length is
                    additionally a multiple of ``shards * pad_multiple``,
                    so the buffer splits into ``shards`` equal contiguous
                    spans and every span is itself a lane-aligned kernel
                    operand.  1 (the default) is the replicated engine.
    """

    bucket_of_leaf: Tuple[int, ...]
    n_buckets: int
    leaves: Tuple[Tuple[int, ...], ...]
    offsets: Tuple[Tuple[int, ...], ...]
    sizes: Tuple[int, ...]
    shapes: Tuple[Tuple[int, ...], ...]
    padded_sizes: Tuple[int, ...] = ()
    shards: int = 1

    @property
    def n_leaves(self) -> int:
        return len(self.bucket_of_leaf)

    @property
    def total_elems(self) -> int:
        return sum(self.sizes)

    @property
    def buf_sizes(self) -> Tuple[int, ...]:
        """Allocated per-bucket buffer lengths (padded when available)."""
        return self.padded_sizes or self.sizes

    @property
    def shard_sizes(self) -> Tuple[int, ...]:
        """Per bucket, the length of one device's contiguous shard span
        (``buf_sizes[b] // shards``; a lane multiple by construction).
        Shard ``s`` of bucket ``b`` covers the global index range
        ``[s * shard_sizes[b], (s + 1) * shard_sizes[b])``."""
        return tuple(n // self.shards for n in self.buf_sizes)


# One f32 lane row: the bucket-update kernels reshape buffers to
# (rows, PAD_MULTIPLE) tiles (kernels/bucket_update/kernel.py re-checks
# the two constants agree on every trace, so they cannot drift apart
# silently).
PAD_MULTIPLE = 128


def build_bucket_layout(
    params,
    bucket_of_leaf: Sequence[int],
    n_buckets: int,
    *,
    pad_multiple: int = PAD_MULTIPLE,
    shard_count: int = 1,
) -> BucketLayout:
    """Precompute the per-bucket flat-buffer layout for a parameter tree.

    ``shard_count > 1`` builds the shard-aware layout of the sharded flat
    engine (DESIGN.md §8): every buffer is padded to a multiple of
    ``shard_count * pad_multiple`` so it splits into ``shard_count``
    equal, lane-aligned spans — each span a valid kernel operand and a
    valid tiled reduce-scatter / all-gather shard.
    """
    if pad_multiple <= 0 or pad_multiple % PAD_MULTIPLE:
        raise ValueError(
            f"pad_multiple={pad_multiple} must be a positive multiple of "
            f"{PAD_MULTIPLE} (the bucket-update kernels' lane width) — a "
            f"smaller value would only fail deep inside the flat engine's "
            f"first update-phase compile"
        )
    if shard_count < 1:
        raise ValueError(f"shard_count={shard_count} must be >= 1")
    unit = pad_multiple * shard_count
    flat = jax.tree_util.tree_flatten(params)[0]
    assert len(flat) == len(bucket_of_leaf)
    shapes = tuple(tuple(l.shape) for l in flat)
    leaves: List[List[int]] = [[] for _ in range(n_buckets)]
    for i, b in enumerate(bucket_of_leaf):
        leaves[b].append(i)
    offsets: List[Tuple[int, ...]] = []
    sizes: List[int] = []
    padded: List[int] = []
    for b in range(n_buckets):
        offs, acc = [], 0
        for i in leaves[b]:
            offs.append(acc)
            acc += int(np.prod(shapes[i], dtype=np.int64)) if shapes[i] else 1
        offsets.append(tuple(offs))
        sizes.append(acc)
        # sharded layouts allocate one unit even for an empty bucket so
        # every shard span is a non-empty kernel / collective operand
        if acc:
            padded.append(-(-acc // unit) * unit)
        else:
            padded.append(unit if shard_count > 1 else 0)
    return BucketLayout(
        bucket_of_leaf=tuple(bucket_of_leaf),
        n_buckets=n_buckets,
        leaves=tuple(tuple(g) for g in leaves),
        offsets=tuple(offsets),
        sizes=tuple(sizes),
        shapes=shapes,
        padded_sizes=tuple(padded),
        shards=shard_count,
    )


def flatten_buckets(layout: BucketLayout, leaf_vals) -> List[jax.Array]:
    """Pack leaf values (tree_flatten order) into per-bucket flat f32
    buffers, zero-padded to the layout's allocated length.  Traced:
    static concatenation, no data-dependent shapes."""
    out = []
    buf_sizes = layout.buf_sizes
    for b in range(layout.n_buckets):
        parts = [
            leaf_vals[i].astype(jnp.float32).reshape(-1)
            for i in layout.leaves[b]
        ]
        pad = buf_sizes[b] - layout.sizes[b]
        if pad:
            parts.append(jnp.zeros((pad,), jnp.float32))
        out.append(
            parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        )
    return out


def unflatten_buckets(layout: BucketLayout, flats) -> List[jax.Array]:
    """Inverse of :func:`flatten_buckets`: per-bucket flat buffers back to
    leaf values (tree_flatten order, f32)."""
    leaf_vals: List[jax.Array] = [None] * layout.n_leaves  # type: ignore
    for b in range(layout.n_buckets):
        flat = flats[b]
        for i, off in zip(layout.leaves[b], layout.offsets[b]):
            shape = layout.shapes[i]
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            leaf_vals[i] = jax.lax.slice(flat, (off,), (off + n,)).reshape(shape)
    assert all(v is not None for v in leaf_vals)
    return leaf_vals


def leaf_bucket_times(
    params,
    cfg: ArchConfig,
    bucket_of_leaf: Sequence[int],
    n_buckets: int,
    hw: HardwareModel,
    seq_len: int,
    per_device_batch: int,
) -> BucketTimes:
    """Analytical fwd/bwd/comm seconds per leaf-bucket."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    tokens = per_device_batch * seq_len
    fwd = [0.0] * n_buckets
    comm_elems = [0] * n_buckets
    for i, (path, leaf) in enumerate(flat):
        keys = _path_keys(path)
        b = bucket_of_leaf[i]
        elems = int(np.prod(leaf.shape))
        active = leaf_active_fraction(cfg, keys)
        flops = 2.0 * elems * active * tokens if leaf.ndim >= 2 else 0.0
        fwd[b] += hw.compute_time(flops)
        comm_elems[b] += elems
    bwd = [2.0 * f for f in fwd]
    comm = [hw.allreduce_time(e) for e in comm_elems]
    return BucketTimes(tuple(fwd), tuple(bwd), tuple(comm))
