"""Training substrate: gradient bucketing over real parameter leaves, the
fused-bucket DeftRuntime execution engine, and the legacy per-leaf
compiled train steps (baseline DDP and DeFT per-phase executables)."""
from repro.train.bucketing import (
    BucketLayout,
    assign_buckets,
    build_bucket_layout,
    flatten_buckets,
    leaf_bucket_times,
    ordered_leaf_indices,
    unflatten_buckets,
)
from repro.train.runtime import (
    DeftRuntime,
    deft_phase_step_flat,
    deft_phase_step_fused,
    deft_rs_phase_step_flat,
    deft_rs_phase_step_fused,
    init_fused_accumulators,
    make_ddp_step,
    phase_collectives,
)
from repro.train.steps import (
    TrainState,
    ddp_train_step,
    deft_phase_step,
    deft_rs_phase_step,
    init_train_state,
    make_deft_step_fns,
)

__all__ = [
    "BucketLayout",
    "assign_buckets",
    "build_bucket_layout",
    "flatten_buckets",
    "unflatten_buckets",
    "leaf_bucket_times",
    "ordered_leaf_indices",
    "TrainState",
    "init_train_state",
    "init_fused_accumulators",
    "ddp_train_step",
    "deft_phase_step",
    "deft_rs_phase_step",
    "deft_phase_step_fused",
    "deft_rs_phase_step_fused",
    "deft_phase_step_flat",
    "deft_rs_phase_step_flat",
    "make_deft_step_fns",
    "make_ddp_step",
    "phase_collectives",
    "DeftRuntime",
]
