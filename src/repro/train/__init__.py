"""Training substrate: gradient bucketing over real parameter leaves and
the compiled train steps (baseline DDP and DeFT per-phase executables)."""
from repro.train.bucketing import (
    assign_buckets,
    leaf_bucket_times,
    ordered_leaf_indices,
)
from repro.train.steps import (
    TrainState,
    ddp_train_step,
    deft_phase_step,
    deft_rs_phase_step,
    init_train_state,
    make_deft_step_fns,
)

__all__ = [
    "assign_buckets",
    "leaf_bucket_times",
    "ordered_leaf_indices",
    "TrainState",
    "init_train_state",
    "ddp_train_step",
    "deft_phase_step",
    "deft_rs_phase_step",
    "make_deft_step_fns",
]
