"""Compiled train steps: DDP baseline and DeFT per-phase executables.

The paper's runtime scheduler reorders NCCL launches under PyTorch eager
execution.  Under XLA there is no runtime launch order to reorder — the
compiler owns the intra-step schedule — so the *semantically meaningful*
part of a DeFT schedule is realized structurally in the compiled graph:

* each :class:`~repro.core.scheduler.PhaseSpec` of the periodic schedule
  becomes ONE jitted executable whose HLO contains an all-reduce for
  exactly the buckets that phase synchronizes — masked-out buckets have
  *no collective at all* and accumulate in device-local buffers;
* parameter updates fire only in phases with ``do_update`` (delayed
  updates), consuming the merged (k-batch) gradient with the gradient-
  accumulation scaling ``1/(n_dp * k)``;
* buckets assigned to the paper's *secondary link* (gloo/second NIC)
  synchronize via a hierarchical reduce-scatter -> (pod all-reduce) ->
  all-gather, exercising the slower DCN/host path concurrently with the
  primary ICI ring (see DESIGN.md §3 for the link-mapping adaptation).

Distribution modes
------------------
``ddp_train_step``      pjit auto-sharding; batch over ('pod','data'),
                        tensors over 'model'; XLA inserts one all-reduce
                        per gradient — the WFBP / PyTorch-DDP baseline.
``deft_phase_step``     ``jax.shard_map`` manual over the DP axes with
                        params replicated across them ('model' stays
                        auto); per-bucket explicit ``psum`` under the
                        phase masks.  Used by the non-FSDP archs.
``deft_rs_phase_step``  manual over 'pod' only: params/optimizer FSDP-
                        sharded over 'data' (XLA keeps the intra-pod
                        reduce-scatter every step); DeFT masks the
                        *inter-pod* gradient psums — the slow-link
                        schedule on a multi-pod mesh.  Used by the three
                        FSDP archs (deepseek-v2-236b, llama4-maverick,
                        llama-3.2-vision-90b) whose params cannot
                        replicate across DP.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.scheduler import DeftSchedule, PhaseSpec
from repro.models.model import init_params, loss_fn
from repro.optim.optimizers import OptimizerSpec, apply_updates, init_opt_state
from repro.sharding import (
    logical_rules,
    rules_deft_manual_dp,
    rules_deft_rs_manual_pod,
    rules_pjit,
)

# TrainState is a plain dict pytree (checkpoint-friendly):
#   params, opt, and (DeFT only) cur/fut gradient accumulators with a
#   leading device axis (size n_dp for manual-DP, n_pod for the RS path).
TrainState = Dict[str, Any]


def init_train_state(
    key,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    *,
    deft: bool = False,
    accum_devices: int = 1,
    dtype=jnp.float32,
    layout=None,
) -> TrainState:
    """Fresh train state.

    ``deft=True`` adds the cur/fut gradient-generation accumulators:
    per-bucket flat f32 buffers when a :class:`~repro.train.bucketing.
    BucketLayout` is given (the fused runtime layout, DESIGN.md §Fused
    buffers), else one buffer per parameter leaf (the legacy per-leaf
    path kept as semantic reference)."""
    params = init_params(key, cfg, dtype=dtype)
    state: TrainState = {"params": params, "opt": init_opt_state(opt_spec, params)}
    if deft and layout is not None:
        from repro.train.runtime import init_fused_accumulators

        state.update(init_fused_accumulators(layout, accum_devices))
    elif deft:
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros((accum_devices,) + p.shape, jnp.float32), params
        )
        state["cur"] = zeros()
        state["fut"] = zeros()
    return state


# ---------------------------------------------------------------------------
# Baseline: pjit DDP (WFBP semantics — every bucket syncs, update every step)
# ---------------------------------------------------------------------------
def ddp_train_step(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    multi_pod: bool = False,
    fsdp: bool = False,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
    layout: str = "tp",
    microbatch: int = 0,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """WFBP/DDP baseline step.

    ``microbatch = M > 1`` splits the global batch into M sequential
    micro-batches accumulated in f32 under lax.scan — activation memory
    drops ~M-fold for one extra f32 gradient buffer (beyond-paper §Perf
    lever for the memory-bound giants; the gradient all-reduce still
    happens once per step, so DeFT's scheduling domain is unchanged)."""
    with logical_rules(rules_pjit(multi_pod, fsdp, layout)):
        if microbatch and microbatch > 1:
            m = microbatch

            def to_micro(x):
                return x.reshape((m, x.shape[0] // m) + x.shape[1:])

            mb = jax.tree.map(to_micro, batch)

            def micro(carry, bslice):
                gsum, lsum = carry
                (l, parts), g = jax.value_and_grad(
                    lambda p: loss_fn(p, cfg, bslice, remat=remat,
                                      loss_chunk=loss_chunk, unroll=unroll),
                    has_aux=True,
                )(state["params"])
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (gsum, lsum + l), parts

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
            )
            (grads, loss), parts = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32)), mb
            )
            loss = loss / m
            parts = jax.tree.map(lambda x: jnp.mean(x), parts)
            grads = jax.tree.map(lambda g: g / m, grads)
        else:
            (loss, parts), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, batch, remat=remat,
                                  loss_chunk=loss_chunk, unroll=unroll),
                has_aux=True,
            )(state["params"])
        grads = _anchor_grad_shardings(grads, cfg, multi_pod, layout)
    params, opt = apply_updates(opt_spec, state["params"], grads, state["opt"])
    metrics = {"loss": loss, **parts, "updated": jnp.ones((), jnp.bool_)}
    return {"params": params, "opt": opt}, metrics


def _anchor_grad_shardings(grads, cfg, multi_pod: bool, layout: str):
    """Pin every weight gradient to its parameter's sharding.

    Without this anchor the SPMD partitioner is free to compute dW by
    all-gathering the (global-batch!) f32 activation/cotangent pair and
    doing the contraction locally — observed on gemma2-2b train_4k as
    54 GiB of f32[256,4096,2304] all-gathers per step.  Constraining dW
    to the weight's sharding forces the local-contraction + psum form
    (the WFBP gradient all-reduce the paper schedules).  See
    EXPERIMENTS.md §Perf."""
    from repro.sharding.specs import param_rules, spec_tree

    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return grads
    specs = spec_tree(grads, param_rules(cfg.name, multi_pod, layout), mesh)
    return jax.tree.map(
        lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, specs
    )


# ---------------------------------------------------------------------------
# DeFT phase step (shared body)
# ---------------------------------------------------------------------------
def _sync_primary(x: jax.Array, dp_axes: Tuple[str, ...]) -> jax.Array:
    return jax.lax.psum(x, dp_axes)


def _sync_secondary(
    x: jax.Array, dp_axes: Tuple[str, ...], dp_sizes: Dict[str, int],
    chain: Optional[Tuple[int, ...]] = None,
) -> jax.Array:
    """Slow-link sync for secondary-assigned buckets.

    With ``chain`` (the secondary link's device-order permutation from
    ``launch.mesh.ring_chain``) and a single DP axis, the all-reduce runs
    as ppermute rounds along that chain (``train.chains``) — genuinely
    distinct wires from the primary axis, bitwise-equal to ``psum``.
    Multi-axis DP keeps the chain off: splitting the joint psum into
    per-axis stages changes the float reduction grouping, and bitwise
    parity with the single-collective path is the contract.

    Without a chain: hierarchical reduce-scatter over the innermost DP
    axis, all-reduce over the outer (pod/DCN) axes, then all-gather.
    Falls back to a plain psum when the leading dim does not tile, or
    when the installed jaxlib cannot partition tiled collectives inside a
    partial-manual region (see jax_compat.HIERARCHICAL_COLLECTIVES_OK —
    the all-reduce is numerically identical, only the link shaping is
    lost)."""
    from repro.util.jax_compat import HIERARCHICAL_COLLECTIVES_OK

    fast = dp_axes[-1]
    size = dp_sizes[fast]
    if chain is not None and len(dp_axes) == 1 and len(chain) == size:
        from repro.train.chains import chain_all_reduce

        return chain_all_reduce(x, fast, chain)
    if (HIERARCHICAL_COLLECTIVES_OK and x.ndim >= 1
            and x.shape[0] % size == 0 and x.shape[0] >= size):
        y = jax.lax.psum_scatter(x, fast, scatter_dimension=0, tiled=True)
        if len(dp_axes) > 1:
            y = jax.lax.psum(y, dp_axes[:-1])
        return jax.lax.all_gather(y, fast, axis=0, tiled=True)
    return jax.lax.psum(x, dp_axes)


def _zeros_like_tree(tree):
    return jax.tree.map(lambda x: jnp.zeros_like(x), tree)


def _deft_body(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    bucket_of_leaf: Sequence[int],
    dp_axes: Tuple[str, ...],
    dp_sizes: Dict[str, int],
    rules: Dict,
    remat: bool,
    loss_chunk: int = 0,
    unroll: bool = False,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """One DeFT phase, executed inside a shard_map manual over dp_axes.

    cur/fut arrive with their leading device axis already stripped to 1 by
    the manual mapping; we work on index [0] and re-add the axis on return.
    """
    n_dp = 1
    for a in dp_axes:
        n_dp *= dp_sizes[a]
    params, opt = state["params"], state["opt"]
    with logical_rules(rules):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, remat=remat,
                              loss_chunk=loss_chunk, unroll=unroll),
            has_aux=True,
        )(params)

    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    cur_leaves = [c[0] for c in jax.tree_util.tree_flatten(state["cur"])[0]]
    fut_leaves = [f[0] for f in jax.tree_util.tree_flatten(state["fut"])[0]]
    assert len(g_leaves) == len(bucket_of_leaf)

    def sync(x: jax.Array, b: int) -> jax.Array:
        if phase.secondary[b]:
            return _sync_secondary(x, dp_axes, dp_sizes)
        return _sync_primary(x, dp_axes)

    if phase.rotate:
        # fresh generation merges with the future accumulator (Cases 3/4)
        gen = [g.astype(jnp.float32) + f for g, f in zip(g_leaves, fut_leaves)]
        gen = [
            sync(x, bucket_of_leaf[i]) if phase.route_new[bucket_of_leaf[i]] == "sync" else x
            for i, x in enumerate(gen)
        ]
        new_fut = [jnp.zeros_like(f) for f in fut_leaves]
    else:
        # Cases 1/2: fresh gradients accumulate locally
        gen = None
        new_fut = [f + g.astype(jnp.float32) for f, g in zip(fut_leaves, g_leaves)]

    # older generation buckets scheduled this phase (fwd Case 1 + bwd Case 2/3)
    cur_synced = [
        sync(c, bucket_of_leaf[i]) if phase.sync_cur[bucket_of_leaf[i]] else c
        for i, c in enumerate(cur_leaves)
    ]

    updated = jnp.asarray(phase.do_update)
    if phase.do_update:
        src = cur_synced if phase.update_source == "cur" else gen
        grad_tree = jax.tree_util.tree_unflatten(treedef, src)
        scale = 1.0 / (n_dp * phase.update_k)
        params, opt = apply_updates(opt_spec, params, grad_tree, opt, grad_scale=scale)
        if phase.update_source == "cur":
            # the consumed generation is replaced by the fresh one (rotate)
            # or — in a forced-liveness non-rotate phase — left empty until
            # the next Case-4 rotation fills it from the future accumulator
            new_cur = gen if gen is not None else [
                jnp.zeros_like(c) for c in cur_synced
            ]
        else:
            new_cur = [jnp.zeros_like(c) for c in cur_synced]
    elif phase.rotate:
        # Case 4 with leftovers: the (empty) current generation is replaced
        new_cur = gen
    else:
        new_cur = cur_synced

    mean_loss = jax.lax.psum(loss, dp_axes) / n_dp
    metrics = {
        "loss": mean_loss,
        **{k: jax.lax.psum(v, dp_axes) / n_dp for k, v in parts.items()},
        "updated": updated,
        "k": jnp.asarray(phase.update_k, jnp.int32),
    }
    new_state = {
        "params": params,
        "opt": opt,
        "cur": jax.tree_util.tree_unflatten(treedef, [c[None] for c in new_cur]),
        "fut": jax.tree_util.tree_unflatten(treedef, [f[None] for f in new_fut]),
    }
    return new_state, metrics


# ---------------------------------------------------------------------------
# shard_map wrappers
# ---------------------------------------------------------------------------
def _dp_sizes(mesh, dp_axes: Tuple[str, ...]) -> Dict[str, int]:
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return {a: int(shape[a]) for a in dp_axes}


def _state_specs(state: TrainState, dp_axes: Tuple[str, ...]):
    """Manual-axis in/out specs: params/opt replicated over dp, accumulators
    split on their leading device axis."""
    rep = jax.tree.map(lambda _: P(), {"params": state["params"], "opt": state["opt"]})
    acc = jax.tree.map(
        lambda _: P(dp_axes if len(dp_axes) > 1 else dp_axes[0]),
        {"cur": state["cur"], "fut": state["fut"]},
    )
    return {**rep, **acc}


def _batch_specs(batch: Dict[str, jax.Array], dp_axes: Tuple[str, ...]):
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    return jax.tree.map(lambda x: P(*((dp,) + (None,) * (x.ndim - 1))), batch)


def deft_phase_step(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    bucket_of_leaf: Sequence[int],
    mesh,
    multi_pod: bool = False,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """DeFT phase with explicit DP (params replicated over DP axes)."""
    dp_axes = ("pod", "data") if multi_pod else ("data",)
    dp_sizes = _dp_sizes(mesh, dp_axes)
    body = functools.partial(
        _deft_body,
        cfg=cfg,
        opt_spec=opt_spec,
        phase=phase,
        bucket_of_leaf=tuple(bucket_of_leaf),
        dp_axes=dp_axes,
        dp_sizes=dp_sizes,
        rules=rules_deft_manual_dp(),
        remat=remat,
        loss_chunk=loss_chunk,
        unroll=unroll,
    )
    in_specs = (_state_specs(state, dp_axes), _batch_specs(batch, dp_axes))
    out_state_specs = _state_specs(state, dp_axes)
    out_metric_specs = {
        "loss": P(), "ce": P(), "aux": P(), "updated": P(), "k": P()
    }
    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(out_state_specs, out_metric_specs),
        axis_names=set(dp_axes),
        check_vma=False,
    )(state, batch)


def deft_rs_phase_step(
    state: TrainState,
    batch: Dict[str, jax.Array],
    *,
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    phase: PhaseSpec,
    bucket_of_leaf: Sequence[int],
    mesh,
    remat: bool = True,
    loss_chunk: int = 0,
    unroll: bool = False,
) -> Tuple[TrainState, Dict[str, jax.Array]]:
    """DeFT hierarchical path for FSDP archs: manual over 'pod' only.

    Params and optimizer state stay FSDP-sharded over 'data' (auto — XLA
    keeps the intra-pod reduce-scatter every step); the phase masks gate
    the *inter-pod* psums, i.e. DeFT schedules the slow DCN link.  Only
    meaningful on the multi-pod mesh.
    """
    assert "pod" in mesh.axis_names, "DeFT-RS needs the multi-pod mesh"
    dp_axes = ("pod",)
    dp_sizes = _dp_sizes(mesh, dp_axes)
    body = functools.partial(
        _deft_body,
        cfg=cfg,
        opt_spec=opt_spec,
        phase=phase,
        bucket_of_leaf=tuple(bucket_of_leaf),
        dp_axes=dp_axes,
        dp_sizes=dp_sizes,
        rules=rules_deft_rs_manual_pod(),
        remat=remat,
        loss_chunk=loss_chunk,
        unroll=unroll,
    )
    in_specs = (_state_specs(state, dp_axes), _batch_specs(batch, dp_axes))
    out_metric_specs = {
        "loss": P(), "ce": P(), "aux": P(), "updated": P(), "k": P()
    }
    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(_state_specs(state, dp_axes), out_metric_specs),
        axis_names=set(dp_axes),
        check_vma=False,
    )(state, batch)


# ---------------------------------------------------------------------------
# Per-schedule step-function factory
# ---------------------------------------------------------------------------
def make_deft_step_fns(
    cfg: ArchConfig,
    opt_spec: OptimizerSpec,
    schedule: DeftSchedule,
    bucket_of_leaf: Sequence[int],
    mesh,
    *,
    multi_pod: bool = False,
    fsdp: bool = False,
    remat: bool = True,
    loss_chunk: int = 0,
) -> List[Callable]:
    """LEGACY per-leaf path: one jitted executable per distinct phase,
    one psum per parameter leaf, tree-shaped accumulators, no donation.

    Kept as the semantic reference and benchmark baseline; production
    code uses :class:`repro.train.runtime.DeftRuntime` (bucket-fused
    collectives, donated buffers, AOT phase cache)."""
    step_impl = deft_rs_phase_step if fsdp else deft_phase_step
    fns: List[Callable] = []
    seen: Dict[PhaseSpec, Callable] = {}
    for phase in schedule.phases:
        if phase not in seen:
            kw = dict(
                cfg=cfg,
                opt_spec=opt_spec,
                phase=phase,
                bucket_of_leaf=tuple(bucket_of_leaf),
                mesh=mesh,
                remat=remat,
                loss_chunk=loss_chunk,
            )
            if not fsdp:
                kw["multi_pod"] = multi_pod
            seen[phase] = jax.jit(functools.partial(step_impl, **kw))
        fns.append(seen[phase])
    return fns
