"""Parameter / optimizer / batch PartitionSpecs per architecture and mode.

Every parameter leaf gets *logical* axis names from its key path (the
naming convention of repro.models); ``spec_tree`` resolves them through a
rule table against a concrete mesh, silently dropping any axis whose
dimension does not divide the mesh axis product (e.g. 36 heads over a
16-way 'model' axis -> replicated heads, sharded FFN; seamless's 256206
vocab -> replicated embedding).  Divisibility-driven fallback keeps every
(arch x mesh) combination compiling without per-arch special cases, and
the dropped axes are visible in the roofline discussion.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding import AxisVal


# --------------------------------------------------------------------------
# Logical axes per parameter leaf
# --------------------------------------------------------------------------
def _base_axes(path: Tuple[str, ...], ndim: int) -> Tuple[Optional[str], ...]:
    """Logical dim names for a leaf, from its path (innermost name +
    context), EXCLUDING any stacked leading period dim."""
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    in_ffn = "ffn" in path or "shared" in path
    axes: Tuple[Optional[str], ...]

    if name == "table":
        axes = ("vocab", "embed")
    elif parent == "head" and name == "w":
        axes = ("embed", "vocab")
    elif parent == "experts" and name in ("gate", "up"):  # MoE [E, d, de]
        axes = ("experts", "embed", None)
    elif parent == "experts" and name == "down":
        axes = ("experts", None, "embed")
    elif name in ("gate", "up"):
        axes = ("embed", "ff")
    elif name == "down":
        axes = ("ff", "embed")
    elif name == "router":
        axes = ("embed", None)
    elif name == "wq":
        axes = ("embed", "heads")
    elif name in ("wk", "wv") and in_ffn:             # rwkv channel-mix
        axes = ("embed", "ff") if name == "wk" else ("ff", "embed")
    elif name in ("wk", "wv"):
        axes = ("embed", "kv")
    elif name in ("wr", "wg"):                         # rwkv projections
        axes = ("embed", "heads")
    elif name == "wo":
        axes = ("heads", "embed")
    elif name in ("wx", "wgate"):                      # rglru in-projections
        axes = ("embed", "lru")
    elif name in ("wdq", "wdkv"):                      # MLA down-projections
        axes = ("embed", None)
    elif name in ("wuq", "wuk", "wuv"):                # MLA up-projections
        axes = (None, "heads")
    elif name == "conv_w":
        axes = (None, "lru")
    elif name in ("conv_b", "a_param"):
        axes = ("lru",)
    elif name in ("w_rgate", "w_igate"):
        axes = ("heads", None, None)
    elif name == "ddlerp_a":
        axes = ("embed", None)
    elif name == "ddlerp_b":
        axes = (None, None, "embed")
    elif name == "w_lora_a":
        axes = ("embed", None)
    elif name == "w_lora_b":
        axes = (None, "embed")
    elif name == "u":
        axes = ("heads", None)
    elif name == "mu_base":
        axes = (None, "embed")
    elif name == "w0":
        axes = ("embed",)
    else:
        axes = tuple([None] * ndim)  # norms, gates, scalars

    # stacked scan leaves carry a leading period dim
    if len(axes) == ndim - 1:
        axes = (None,) + axes
    if len(axes) != ndim:
        axes = tuple([None] * ndim)
    return axes


def _mesh_axis_size(mesh, axis: AxisVal) -> int:
    if axis is None:
        return 1
    names = (axis,) if isinstance(axis, str) else axis
    # mesh.shape works for both Mesh and AbstractMesh
    shape = dict(mesh.shape)
    return int(np.prod([shape[n] for n in names]))


def leaf_spec(
    path: Tuple[str, ...],
    shape: Tuple[int, ...],
    rules: Dict[str, AxisVal],
    mesh,
) -> P:
    axes = _base_axes(path, len(shape))
    out = []
    for dim, name in zip(shape, axes):
        mapped = rules.get(name) if name else None
        if mapped is not None and dim % _mesh_axis_size(mesh, mapped) != 0:
            mapped = None  # divisibility fallback -> replicate this dim
        out.append(mapped)
    return P(*out)


def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        else:
            keys.append(str(p))
    return tuple(keys)


def spec_tree(tree, rules: Dict[str, AxisVal], mesh):
    """PartitionSpec pytree for a parameter (or optimizer-state) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: leaf_spec(
            _path_keys(path), tuple(leaf.shape), rules, mesh
        ),
        tree,
    )


def sharding_tree(tree, rules: Dict[str, AxisVal], mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), spec_tree(tree, rules, mesh)
    )


# --------------------------------------------------------------------------
# Per-arch distribution policy
# --------------------------------------------------------------------------
# Archs whose parameters cannot replicate across the DP axis on a 16 GB
# v5e chip (bf16 params / 16-way TP > ~4 GB) use FSDP ('embed' dim sharded
# over 'data').  These rules drive the pjit baseline and the legacy
# tree-state DeFT-RS path (explicit psums over 'pod' only, weight FSDP
# left to XLA); the production engine for FSDP archs is the SHARDED
# flat-state runtime (DESIGN.md §8), which realizes the same 1/N
# residency by splitting the flat bucket buffers over 'data' explicitly
# instead of through these per-leaf specs.
FSDP_ARCHS = frozenset(
    {"deepseek-v2-236b", "llama4-maverick-400b-a17b", "llama-3.2-vision-90b"}
)


def needs_fsdp(arch_name: str) -> bool:
    return arch_name.split("-smoke")[0] in FSDP_ARCHS


def param_rules(
    arch_name: str, multi_pod: bool, layout: str = "tp"
) -> Dict[str, AxisVal]:
    """Rules used for *parameter storage* shardings (pjit boundary).

    layout='tp'  — tensor-parallel over 'model' (default; FSDP over 'data'
                   for the three giant archs).
    layout='dp'  — pure data parallelism: weights fully replicated, batch
                   over every mesh axis.  A beyond-paper optimization for
                   small archs whose TP activation all-reduces dominate
                   the collective term (see EXPERIMENTS.md §Perf); also
                   the layout closest to the paper's own DP-only setting.
    """
    if layout == "dp":
        assert not needs_fsdp(arch_name), "dp layout cannot replicate >90B"
        return {k: None for k in
                ("embed", "heads", "kv", "ff", "vocab", "experts", "lru")}
    fsdp = needs_fsdp(arch_name)
    return {
        "embed": ("data",) if fsdp else None,
        "heads": "model",
        "kv": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "lru": "model",
    }


def batch_axes(multi_pod: bool, layout: str = "tp") -> Tuple[str, ...]:
    if layout == "dp":
        return ("pod", "data", "model") if multi_pod else ("data", "model")
    return ("pod", "data") if multi_pod else ("data",)
