"""Logical-axis sharding (MaxText-style rules).

Model code annotates tensors with *logical* dimension names
(``constrain(x, ("batch", "seq", "embed"))``); a rule table maps logical
names to mesh axes.  When no rules are active (CPU unit tests) the
annotations are no-ops, so the same model code runs everywhere.

Rules differ per train-step mode:

* ``pjit`` baseline — batch over ('pod','data'), tensor dims over 'model';
  optionally FSDP: weight input-feature dims over 'data'.
* DeFT explicit-DP (shard_map manual over ('pod','data')) — batch is
  already local inside the manual region, so the 'batch' rule must be
  dropped there; tensor dims stay on the auto 'model' axis.
* DeFT-RS hierarchical (shard_map manual over 'pod') — batch over 'data',
  weights FSDP over 'data', explicit psum over 'pod'.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

AxisVal = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def _current_rules() -> Optional[Dict[str, AxisVal]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def logical_rules(rules: Optional[Dict[str, AxisVal]]):
    """Activate a logical->mesh axis mapping for model code in scope."""
    prev = _current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def _axis_prod(mesh_shape: Dict[str, int], axis: AxisVal) -> int:
    if axis is None:
        return 1
    names = (axis,) if isinstance(axis, str) else axis
    return 1 if not names else int(
        __import__("math").prod(mesh_shape.get(n, 1) for n in names)
    )


def spec_for(names: Sequence[Optional[str]], shape=None) -> P:
    """PartitionSpec for a tuple of logical dim names under active rules.
    Axes whose dimension does not divide the mesh axis product are dropped
    (replicated) — e.g. 36 heads over a 16-way 'model' axis."""
    rules = _current_rules() or {}
    mesh = jax.sharding.get_abstract_mesh()
    mesh_shape = dict(getattr(mesh, "shape", {}) or {})
    out = []
    for i, n in enumerate(names):
        axis = rules.get(n) if n else None
        if axis is not None and shape is not None:
            if shape[i] % _axis_prod(mesh_shape, axis) != 0:
                axis = None
        out.append(axis)
    return P(*out)


def constrain(x: jax.Array, names: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint iff rules are active; else identity."""
    rules = _current_rules()
    if rules is None:
        return x
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    assert len(names) == x.ndim, (names, x.shape)
    return jax.lax.with_sharding_constraint(x, spec_for(names, x.shape))


# ---------------------------------------------------------------------------
# Canonical rule tables
# ---------------------------------------------------------------------------
def rules_pjit(
    multi_pod: bool, fsdp: bool, layout: str = "tp"
) -> Dict[str, AxisVal]:
    """Baseline pjit train/serve step (XLA inserts every collective)."""
    if layout == "dp":
        batch = ("pod", "data", "model") if multi_pod else ("data", "model")
        return {"batch": batch, "embed": None, "heads": None, "kv": None,
                "ff": None, "vocab": None, "experts": None, "lru": None,
                "seq": None, "modal": None}
    batch = ("pod", "data") if multi_pod else ("data",)
    del fsdp  # FSDP shards *weights* (see specs.param_rules); activations
    #           keep 'embed' replicated to avoid batch/data double-mapping.
    return {
        "batch": batch,
        "embed": None,
        "heads": "model",
        "kv": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "lru": "model",
        "seq": None,
        "modal": None,
    }


def rules_deft_manual_dp() -> Dict[str, AxisVal]:
    """Inside shard_map manual over ('pod','data'): batch dims are local."""
    return {
        "batch": None,
        "embed": None,
        "heads": "model",
        "kv": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "lru": "model",
        "seq": None,
        "modal": None,
    }


def rules_deft_rs_manual_pod() -> Dict[str, AxisVal]:
    """Inside shard_map manual over ('pod',): data axis still auto (FSDP +
    batch sharding handled by XLA); pod-axis collectives are explicit."""
    return {
        "batch": ("data",),
        "embed": None,   # weight FSDP comes from specs.param_rules, not here
        "heads": "model",
        "kv": "model",
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "lru": "model",
        "seq": None,
        "modal": None,
    }
