"""DeftRuntime perf benchmark: fused-bucket runtime vs the seed per-leaf
implementation, plus solver planning time with/without memoization.

Emits machine-readable ``BENCH_runtime.json`` (steps/s, compile time,
solver planning time, collectives-per-phase) so the perf trajectory is
tracked across PRs.  Two train-loop scenarios, each in its own
subprocess:

* ``smoke`` — the smoke DeFT train loop exactly as ``repro.launch.train
  --smoke --scheduler deft`` runs it on this host (single device).  The
  fused runtime wins on graph leanness (per-bucket buffers instead of
  per-leaf accumulator ops) and buffer donation (params/opt/accumulators
  update in place instead of being copied every step).
* ``dp4`` — 4 forced host devices so the per-bucket vs per-leaf gradient
  collectives are real inter-device operations.

The solver benchmark runs in-process on a paper-scale bucket profile
(comm times in the 10..300 ms range — the regime the production planner
faces; microsecond toy instances make the DP trivially cheap and would
understate the memoization win).
"""
from __future__ import annotations

import json
import os
import random
import subprocess
import sys
import time

_STEPS = int(os.environ.get("BENCH_RUNTIME_STEPS", "30"))
_OUT = os.environ.get("BENCH_RUNTIME_OUT", "BENCH_runtime.json")


def _paired_min_of_reps(engines, *, warmup, chunk, reps):
    """Paired interleaved min-of-reps over engine step callables.

    ``engines`` maps name -> ``[fn, state]`` with ``fn(i, state) ->
    (state, metrics)``; states advance in place (donated engines must
    keep stepping their own returned state).  Every rep times one
    ``chunk``-step block per engine back to back, so an ambient load
    spike on a shared host hits every engine of that rep instead of
    whichever happened to run second; the per-engine minimum over reps
    is the reported average step time.  Callers align ``chunk`` to the
    schedule period — a fixed-length window would rotate through the
    cycle and the min would pick the cheapest phase mix rather than a
    steady-state period.  Returns ({name: best_avg_step_s},
    {name: warmup_wall_s}, {name: steps_run})."""
    import jax

    steps_done = {k: 0 for k in engines}

    def run_chunk(name, n):
        fn, state = engines[name]
        i0 = steps_done[name]
        t0 = time.perf_counter()
        for i in range(i0, i0 + n):
            state, m = fn(i, state)
        jax.block_until_ready(m["loss"])
        engines[name][1] = state
        steps_done[name] = i0 + n
        return (time.perf_counter() - t0) / n

    warmup_s = {}
    for name in engines:
        t0 = time.perf_counter()
        run_chunk(name, warmup)
        warmup_s[name] = time.perf_counter() - t0
    best = {k: float("inf") for k in engines}
    for _ in range(reps):
        for name in engines:
            best[name] = min(best[name], run_chunk(name, chunk))
    return best, warmup_s, steps_done


def _paper_tree(n_leaves: int = 256, leaf_elems: int = 8192):
    """Synthetic paper-regime parameter tree — a few hundred tensors,
    as in the paper's DNN/LLM profiles."""
    import jax

    key = jax.random.PRNGKey(1)
    tree = {
        f"l{i:03d}": jax.random.normal(jax.random.fold_in(key, i),
                                       (leaf_elems,))
        for i in range(n_leaves)
    }
    return tree


def _timed_apply_pair(f_flat, flat_args, f_leaf, leaf_args,
                      *, reps: int = 9, n: int = 20):
    """Paired interleaved min-of-reps over the two jitted apply fns.
    Returns (ms_flat, ms_leaf)."""
    import jax

    def timed(f, args):
        t0 = time.perf_counter()
        for _ in range(n):
            out = f(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / n

    jax.block_until_ready(f_flat(*flat_args))     # compile outside timing
    jax.block_until_ready(f_leaf(*leaf_args))
    ms_flat = ms_leaf = float("inf")
    for _ in range(reps):
        ms_flat = min(ms_flat, timed(f_flat, flat_args) * 1e3)
        ms_leaf = min(ms_leaf, timed(f_leaf, leaf_args) * 1e3)
    return ms_flat, ms_leaf


def _inner(devices: int) -> dict:
    if devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}"
        )
    import jax

    import repro  # noqa: F401  (jax compat shims)
    from repro.configs import get_config, reduce_for_smoke
    from repro.core.bucket import BucketTimes
    from repro.core.deft import solve_schedule
    from repro.core.profiler import HardwareModel
    from repro.core.scheduler import SchedulerConfig
    from repro.data.pipeline import make_batch
    from repro.optim.optimizers import adamw
    from repro.train import (
        DeftRuntime,
        assign_buckets,
        build_bucket_layout,
        init_train_state,
        leaf_bucket_times,
        make_deft_step_fns,
    )

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    dp = jax.device_count()
    mesh = jax.make_mesh((dp, 1), ("data", "model"))
    B, S = max(4, dp), 32

    probe = init_train_state(key, cfg, opt)
    bucket_of, nb = assign_buckets(probe["params"], cfg,
                                   partition_elems=150_000)
    times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb,
                              HardwareModel(dp_degree=max(dp, 2)), S,
                              max(B // dp, 1))
    scale = 1.8 * (times.fwd_total + times.bwd_total) / max(
        times.comm_total, 1e-12
    )
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    sched = solve_schedule(times, SchedulerConfig())
    layout = build_bucket_layout(probe["params"], bucket_of, nb)
    batch = make_batch(cfg, 0, 0, B, S)

    # the phase whose executable applies the (delayed) optimizer update —
    # the update-path comparison times this one phase across engines
    upd = next(i for i, ph in enumerate(sched.phases) if ph.do_update)

    with mesh:
        # ---- build every engine up front, then time them INTERLEAVED:
        # a load spike on a shared CPU host hits all engines of the rep,
        # not whichever happened to run second (same paired min-of-reps
        # harness as _bench_update_path — whole-phase wall times used to
        # be single-shot and load-noisy enough to invert orderings) -----
        t0 = time.perf_counter()
        fns = make_deft_step_fns(cfg, opt, sched, bucket_of, mesh)
        state_l = init_train_state(key, cfg, opt, deft=True,
                                   accum_devices=dp)
        legacy_build = time.perf_counter() - t0

        rt_tree = DeftRuntime(cfg, opt, sched, layout, mesh,
                              flat_state=False)
        state_t = rt_tree.init_state(key)
        rt_tree.compile(state_t, batch)

        t0 = time.perf_counter()
        rt = DeftRuntime(cfg, opt, sched, layout, mesh)
        state_f = rt.init_state(key)
        compile_s = sum(rt.compile(state_f, batch).values())
        fused_build = time.perf_counter() - t0

        engines = {
            "legacy": [lambda i, s: fns[i % sched.period](s, batch),
                       state_l],
            "tree":   [lambda i, s: rt_tree.step(i, s, batch), state_t],
            "flat":   [lambda i, s: rt.step(i, s, batch), state_f],
        }
        chunk = sched.period                 # period-aligned windows
        reps = max(_STEPS // chunk, 1)
        best, warmup_s, steps_done = _paired_min_of_reps(
            engines, warmup=sched.period, chunk=chunk, reps=reps
        )
        sps_legacy = 1.0 / best["legacy"]
        sps_tree = 1.0 / best["tree"]
        sps_fused = 1.0 / best["flat"]
        # comparable wall totals: build (the fused engine pays its AOT
        # compile there) + warmup (where the legacy path pays its lazy
        # first-dispatch compiles) + the timed steady-state steps
        timed = reps * chunk
        legacy_wall = (legacy_build + warmup_s["legacy"]
                       + timed * best["legacy"])
        fused_wall = fused_build + warmup_s["flat"] + timed * best["flat"]

        # ---- isolated update phase, same interleaved harness ----------
        phase_engines = {
            "legacy": [lambda i, s: fns[upd](s, batch),
                       engines["legacy"][1]],
            "tree": [lambda i, s: rt_tree.phase_executable(upd)(s, batch),
                     engines["tree"][1]],
            "flat": [lambda i, s: rt.phase_executable(upd)(s, batch),
                     engines["flat"][1]],
        }
        ph_best, _, _ = _paired_min_of_reps(
            phase_engines, warmup=2, chunk=chunk, reps=reps
        )
        upd_s_legacy = ph_best["legacy"]
        upd_s_tree = ph_best["tree"]
        upd_s_flat = ph_best["flat"]

    coll = rt.collectives_per_phase()
    per_leaf = [
        sum(
            len(layout.leaves[b]) for b in range(nb)
            if (ph.route_new[b] == "sync" and ph.rotate) or ph.sync_cur[b]
        )
        for ph in sched.phases
    ]
    return {
        "host_devices": dp,
        "model": {"name": cfg.name, "params": int(cfg.total_params()),
                  "n_leaves": layout.n_leaves, "n_buckets": nb},
        "schedule": {"period": sched.period,
                     "updates_per_period": sched.updates_per_period},
        "engine": {"flat_state": rt.flat_state,
                   "update_impl": rt.stats()["update_impl"]},
        "timing": "paired-interleaved-min-of-reps",
        "steps_timed": reps * chunk,
        "steps_per_s_fused": sps_fused,
        "steps_per_s_fused_tree": sps_tree,
        "steps_per_s_legacy": sps_legacy,
        "speedup_fused_vs_legacy": sps_fused / sps_legacy,
        "compile_s_fused_aot": compile_s,
        "wall_s_fused_total": fused_wall,
        "wall_s_legacy_total": legacy_wall,
        # wall time of the do_update phase across the three update paths:
        # flat fused-kernel engine vs PR-1 tree-state (per-leaf
        # apply_updates on fused buffers) vs the seed per-leaf step
        "update_phase_ms_flat": upd_s_flat * 1e3,
        "update_phase_ms_tree": upd_s_tree * 1e3,
        "update_phase_ms_legacy_per_leaf": upd_s_legacy * 1e3,
        "update_phase_speedup_flat_vs_per_leaf": upd_s_legacy / upd_s_flat,
        "update_phase_speedup_flat_vs_tree": upd_s_tree / upd_s_flat,
        "collectives_per_phase_fused": [
            c["primary"] + c["secondary"] for c in coll
        ],
        "collectives_per_phase_legacy_per_leaf": per_leaf,
    }


def _inner_fsdp() -> dict:
    """fsdp_flat scenario: the sharded flat-state engine (PR 4) on 4
    forced host devices — mesh (pod=2, data=2), param/moment buffers
    1/2-resident over 'data' — against the replicated flat engine on the
    same mesh, plus the ISOLATED sharded update-path comparison at the
    paper-regime leaf count (the stable signal; whole-phase CPU wall
    times stay load-noisy even interleaved).

    The per-leaf comparison is ZeRO-honest: the per-leaf reference
    updates the same 1/N-sized state, one op sequence per leaf — exactly
    what the tree-state RS path pays per shard — vs one fused kernel per
    bucket span."""
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp

    import repro  # noqa: F401
    from repro.configs import get_config, reduce_for_smoke
    from repro.core.bucket import BucketTimes
    from repro.core.deft import solve_schedule
    from repro.core.profiler import HardwareModel
    from repro.core.scheduler import SchedulerConfig
    from repro.data.pipeline import make_batch
    from repro.kernels.bucket_update import (
        apply_bucket_updates,
        build_segments,
        init_flat_opt_state,
    )
    from repro.optim.optimizers import adamw, apply_updates, init_opt_state
    from repro.train import (
        DeftRuntime,
        assign_buckets,
        build_bucket_layout,
        flatten_buckets,
        init_train_state,
        leaf_bucket_times,
    )

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    mesh = jax.make_mesh((2, 2, 1), ("pod", "data", "model"))
    B, S = 8, 32

    probe = init_train_state(key, cfg, opt)
    bucket_of, nb = assign_buckets(probe["params"], cfg,
                                   partition_elems=150_000)
    times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb,
                              HardwareModel(dp_degree=4), S, 2)
    scale = 1.8 * (times.fwd_total + times.bwd_total) / max(
        times.comm_total, 1e-12
    )
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    sched = solve_schedule(times, SchedulerConfig())
    lay_sh = build_bucket_layout(probe["params"], bucket_of, nb,
                                 shard_count=2)
    lay_rep = build_bucket_layout(probe["params"], bucket_of, nb)
    batch = make_batch(cfg, 0, 0, B, S)
    upd = next(i for i, ph in enumerate(sched.phases) if ph.do_update)

    with mesh:
        rt_sh = DeftRuntime(cfg, opt, sched, lay_sh, mesh, fsdp=True)
        state_sh = rt_sh.init_state(key)
        compile_s = sum(rt_sh.compile(state_sh, batch).values())
        rt_rep = DeftRuntime(cfg, opt, sched, lay_rep, mesh,
                             multi_pod=True)
        state_rep = rt_rep.init_state(key)
        rt_rep.compile(state_rep, batch)

        engines = {
            "sharded": [lambda i, s: rt_sh.step(i, s, batch), state_sh],
            "replicated": [lambda i, s: rt_rep.step(i, s, batch),
                           state_rep],
        }
        chunk = sched.period                 # period-aligned windows
        reps = max(_STEPS // chunk, 1)
        best, _, _ = _paired_min_of_reps(
            engines, warmup=sched.period, chunk=chunk, reps=reps
        )

        phase_engines = {
            "sharded": [lambda i, s: rt_sh.phase_executable(upd)(s, batch),
                        engines["sharded"][1]],
            "replicated": [
                lambda i, s: rt_rep.phase_executable(upd)(s, batch),
                engines["replicated"][1],
            ],
        }
        ph_best, _, _ = _paired_min_of_reps(
            phase_engines, warmup=2, chunk=chunk, reps=reps
        )

    # ---- isolated sharded update path, paper-regime leaf count --------
    n_leaves, leaf_elems, n_buckets, n_shards = 256, 8192, 8, 4
    tree = _paper_tree(n_leaves, leaf_elems)
    grads = jax.tree.map(lambda p: p * 0.01, tree)
    bo = tuple(i * n_buckets // n_leaves for i in range(n_leaves))
    lay = build_bucket_layout(tree, bo, n_buckets, shard_count=n_shards)
    seg = build_segments(lay, opt)
    spans = lay.shard_sizes
    shard = lambda bufs: tuple(
        x[: spans[b]] for b, x in enumerate(bufs)
    )
    pbuf = shard(flatten_buckets(lay, jax.tree.leaves(tree)))
    gbuf = shard(flatten_buckets(lay, jax.tree.leaves(grads)))
    opt_full = init_flat_opt_state(opt, lay.buf_sizes)
    opt_sh = {"step": opt_full["step"], "m": shard(opt_full["m"]),
              "v": shard(opt_full["v"])}
    # ZeRO per-leaf twin: the same 1/N elements as one shard per leaf
    tree_sh = jax.tree.map(lambda x: x[: x.size // n_shards], tree)
    grads_sh = jax.tree.map(lambda x: x[: x.size // n_shards], grads)
    opt_leaf = init_opt_state(opt, tree_sh)

    sid = jnp.int32(0)
    f_flat = jax.jit(lambda p, g, o: apply_bucket_updates(
        opt, seg, p, g, o, grad_scale=0.1, shard_id=sid,
        norm_psum=lambda t: t)[:2])
    f_leaf = jax.jit(lambda p, g, o: apply_updates(
        opt, p, g, o, grad_scale=0.1))
    ms_flat, ms_leaf = _timed_apply_pair(
        f_flat, (pbuf, gbuf, opt_sh), f_leaf, (tree_sh, grads_sh, opt_leaf)
    )

    st = rt_sh.stats()
    return {
        "host_devices": jax.device_count(),
        "mesh": {"pod": 2, "data": 2, "model": 1},
        "model": {"name": cfg.name, "params": int(cfg.total_params()),
                  "n_leaves": lay_sh.n_leaves, "n_buckets": nb},
        "schedule": {"period": sched.period,
                     "updates_per_period": sched.updates_per_period},
        "engine": {"flat_state": True, "sharded_state": True,
                   "shards": lay_sh.shards,
                   "update_impl": st["update_impl"]},
        "timing": "paired-interleaved-min-of-reps",
        "steps_timed": reps * chunk,
        "compile_s_fused_aot": compile_s,
        "steps_per_s_sharded": 1.0 / best["sharded"],
        "steps_per_s_replicated_flat": 1.0 / best["replicated"],
        "update_phase_ms_sharded": ph_best["sharded"] * 1e3,
        "update_phase_ms_replicated_flat": ph_best["replicated"] * 1e3,
        "update_path_sharded": {
            "n_leaves": n_leaves,
            "n_buckets": n_buckets,
            "shard_count": n_shards,
            "total_elems": lay.total_elems,
            "apply_ms_flat_shard": ms_flat,
            "apply_ms_per_leaf_shard": ms_leaf,
            "speedup_flat_vs_per_leaf": ms_leaf / ms_flat,
        },
    }


def _inner_decoupled() -> dict:
    """decoupled scenario (DESIGN.md §12): the streamed-AG engine vs the
    fused-chain engine on 4 forced host devices, both running the SAME
    schedule (solved on the RS-side profile through the Planner) so the
    steps/s ratio isolates the all-gather placement.  Alongside the
    measured ratio:

    * simulated steady-state comparison — the fused plan prices the
      whole sync on backward capacity, the split plan prices the RS half
      there and streams the AG half against forward (coverage = the
      compute fraction of the simulated iteration; streaming can only
      add scheduling freedom, so decoupled >= fused);
    * the AG-burst static delta — bytes of full parameter buffers the
      fused engine gathers before the first forward block (every bucket
      at once) vs the decoupled peak (one bucket); the jaxpr census in
      tests/test_decoupled.py pins the same fact structurally.
    """
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax

    import repro  # noqa: F401
    from repro.configs import get_config, reduce_for_smoke
    from repro.core.bucket import BucketTimes
    from repro.core.deft import Planner, PlanRequest, ag_times, rs_times
    from repro.core.profiler import HardwareModel
    from repro.core.scheduler import DeftScheduler
    from repro.core.simulator import simulate_deft
    from repro.data.pipeline import make_batch
    from repro.optim.optimizers import adamw
    from repro.train import (
        DeftRuntime,
        RuntimeConfig,
        assign_buckets,
        build_bucket_layout,
        init_train_state,
        leaf_bucket_times,
    )

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    mesh = jax.make_mesh((2, 2, 1), ("pod", "data", "model"))
    B, S = 8, 32

    probe = init_train_state(key, cfg, opt)
    bucket_of, nb = assign_buckets(probe["params"], cfg,
                                   partition_elems=150_000)
    times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb,
                              HardwareModel(dp_degree=4), S, 2)
    scale = 1.8 * (times.fwd_total + times.bwd_total) / max(
        times.comm_total, 1e-12
    )
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    res = Planner().plan(PlanRequest(times=times, preserve=False,
                                     decoupled=True))
    sched, scfg, ag_plan = res.schedule, res.scheduler_cfg, res.ag_plan

    # ---- simulated steady state: both engines reduce-scatter on
    # backward capacity and all-gather around the forward; the fused
    # chain BURSTS the gathers before forward block 0 (WaitAll), the
    # decoupled plan STREAMS them against the forward window under
    # per-bucket deadlines — same wire bytes, different placement
    kw = dict(mu=scfg.mu, heterogeneous=scfg.heterogeneous)
    rs = rs_times(times)
    split = ag_times(times)
    plans_rs = DeftScheduler(rs, scfg).run(48)
    sim_d = simulate_deft(rs, plans_rs, ag_times=split,
                          ag_mode="streamed", **kw)
    sim_b = simulate_deft(rs, plans_rs, ag_times=split,
                          ag_mode="burst", **kw)

    lay = build_bucket_layout(probe["params"], bucket_of, nb,
                              shard_count=2)
    batch = make_batch(cfg, 0, 0, B, S)
    with mesh:
        rt_f = DeftRuntime(cfg, opt, sched, lay, mesh, fsdp=True)
        state_f = rt_f.init_state(key)
        rt_f.compile(state_f, batch)
        rt_d = DeftRuntime(cfg, opt, sched, lay, mesh,
                           config=RuntimeConfig(fsdp=True, decoupled=True))
        state_d = rt_d.init_state(key)
        compile_s = sum(rt_d.compile(state_d, batch).values())

        engines = {
            "fused": [lambda i, s: rt_f.step(i, s, batch), state_f],
            "decoupled": [lambda i, s: rt_d.step(i, s, batch), state_d],
        }
        chunk = sched.period                 # period-aligned windows
        # 3x the default step budget: the floor test pins the ratio of
        # two near-identical engines at >= 1.0, which needs tighter
        # min-of-reps convergence than the cross-engine scenarios
        reps = max(3 * _STEPS // chunk, 2)
        best, _, _ = _paired_min_of_reps(
            engines, warmup=sched.period, chunk=chunk, reps=reps
        )

    # static AG-burst accounting: full f32 param buffers gathered before
    # the first forward block — fused bursts every bucket, decoupled
    # peaks at its largest single bucket
    bytes_per = [s * 4 for s in lay.buf_sizes]
    burst_fused = sum(bytes_per)
    burst_dec = max(bytes_per)
    return {
        "host_devices": jax.device_count(),
        "mesh": {"pod": 2, "data": 2, "model": 1},
        "model": {"name": cfg.name, "params": int(cfg.total_params()),
                  "n_leaves": lay.n_leaves, "n_buckets": nb},
        "schedule": {"period": sched.period,
                     "updates_per_period": sched.updates_per_period},
        "engine": {"flat_state": True, "sharded_state": True,
                   "shards": lay.shards, "decoupled": True},
        "timing": "paired-interleaved-min-of-reps",
        "steps_timed": reps * chunk,
        "compile_s_decoupled_aot": compile_s,
        "steps_per_s_fused": 1.0 / best["fused"],
        "steps_per_s_decoupled": 1.0 / best["decoupled"],
        "steps_per_s_ratio_decoupled_vs_fused": (
            best["fused"] / best["decoupled"]
        ),
        "sim": {
            "iteration_time_fused_burst": sim_b.iteration_time,
            "iteration_time_decoupled_streamed": sim_d.iteration_time,
            "coverage_fused": 1.0 - sim_b.bubble_fraction,
            "coverage_decoupled": 1.0 - sim_d.bubble_fraction,
            "ag_stall_s_streamed": sim_d.ag_stall_s,
            "ag_plan_coverage": ag_plan.coverage,
            "ag_plan_items": len(ag_plan.items),
        },
        "ag_burst_bytes_fused": burst_fused,
        "ag_burst_bytes_decoupled_peak": burst_dec,
        "ag_burst_bytes_delta": burst_fused - burst_dec,
    }


def _inner_precision() -> dict:
    """precision scenario (DESIGN.md §13): planner-chosen mixed wire
    precision vs all-f32 under constrained bandwidth, on 4 forced host
    devices.  The comm profile is scaled so the f32 wire time is ~1.8x
    the compute window — the regime where the §13 ladder has headroom —
    and the Planner prices the full per-bucket ladder.  Reported
    side by side:

    * simulated steady state from the planner's own priced candidates —
      the adopted mixed policy's coverage must be >= the all-f32 row's
      (downgrading wire bytes can only relieve the comm capacity; the
      floor test pins this on the checked-in file);
    * measured steps/s of the SAME schedule executed with the f32
      layout vs the precision layout.  On CPU hosts the collectives are
      local memcpys while the quantize kernels are real work, so the
      measured ratio is reported, not floored — the wire-byte win needs
      a real interconnect to show up in wall time (the exact
      plan-vs-measured byte accounting is pinned by
      tests/test_precision.py::test_runtime_wire_bytes_match_plan).
    """
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax

    import repro  # noqa: F401
    from repro.configs import get_config, reduce_for_smoke
    from repro.core.bucket import BucketTimes
    from repro.core.deft import Planner, PlanRequest
    from repro.core.preserver import WalkParams
    from repro.core.profiler import HardwareModel
    from repro.data.pipeline import make_batch
    from repro.optim.optimizers import adamw
    from repro.train import (
        DeftRuntime,
        assign_buckets,
        build_bucket_layout,
        init_train_state,
        leaf_bucket_times,
    )

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    dp = jax.device_count()
    mesh = jax.make_mesh((dp, 1), ("data", "model"))
    B, S = max(4, dp), 32

    probe = init_train_state(key, cfg, opt)
    bucket_of, nb = assign_buckets(probe["params"], cfg,
                                   partition_elems=150_000)
    times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb,
                              HardwareModel(dp_degree=max(dp, 2)), S,
                              max(B // dp, 1))
    # constrained bandwidth: f32 wire time ~1.8x the compute window
    # (CR 1.8, same regime as the other scenarios) — compute cannot
    # cover the f32 wire, so the ladder has headroom.  The whole
    # profile is then scaled into the paper regime (compute ~100 ms per
    # iteration): the smoke model's microsecond comm times sit BELOW
    # the 20 us collective-latency floor, where the §13 pricing rightly
    # refuses to downgrade — bandwidth-dominated times are the regime
    # the policy is for.  Only the schedule structure feeds the
    # measured engines, so the time unit is free to choose.
    scale = 1.8 * (times.fwd_total + times.bwd_total) / max(
        times.comm_total, 1e-12
    )
    ms = 0.1 / max(times.fwd_total + times.bwd_total, 1e-12)
    times = BucketTimes(tuple(f * ms for f in times.fwd),
                        tuple(b * ms for b in times.bwd),
                        tuple(c * scale * ms for c in times.comm))
    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    res = Planner().plan(PlanRequest(times=times, walk=walk,
                                     wire_precision="auto"))
    sched = res.schedule
    f32 = next(s for s in res.precision_candidates
               if all(w == "f32" for w in s.policy.wire))
    mix = (next(s for s in res.precision_candidates
                if s.policy == res.precision)
           if res.precision is not None else f32)

    lay_f32 = build_bucket_layout(probe["params"], bucket_of, nb)
    lay_mix = (lay_f32.with_precision(res.precision)
               if res.precision is not None else lay_f32)
    batch = make_batch(cfg, 0, 0, B, S)
    with mesh:
        rt_f = DeftRuntime(cfg, opt, sched, lay_f32, mesh)
        state_f = rt_f.init_state(key)
        rt_f.compile(state_f, batch)
        rt_m = DeftRuntime(cfg, opt, sched, lay_mix, mesh)
        state_m = rt_m.init_state(key)
        compile_s = sum(rt_m.compile(state_m, batch).values())

        engines = {
            "f32": [lambda i, s: rt_f.step(i, s, batch), state_f],
            "mixed": [lambda i, s: rt_m.step(i, s, batch), state_m],
        }
        chunk = sched.period                 # period-aligned windows
        reps = max(_STEPS // chunk, 1)
        best, _, _ = _paired_min_of_reps(
            engines, warmup=sched.period, chunk=chunk, reps=reps
        )

    return {
        "host_devices": dp,
        "model": {"name": cfg.name, "params": int(cfg.total_params()),
                  "n_leaves": lay_f32.n_leaves, "n_buckets": nb},
        "schedule": {"period": sched.period,
                     "updates_per_period": sched.updates_per_period},
        "engine": {"flat_state": True,
                   "wire_precision": (res.precision.describe()
                                      if res.precision else "f32x%d" % nb),
                   "master_dtype": "f32"},
        "timing": "paired-interleaved-min-of-reps",
        "steps_timed": reps * chunk,
        "compile_s_mixed_aot": compile_s,
        "steps_per_s_f32": 1.0 / best["f32"],
        "steps_per_s_mixed": 1.0 / best["mixed"],
        "steps_per_s_ratio_mixed_vs_f32": best["f32"] / best["mixed"],
        "sim": {
            "iteration_time_f32": f32.iteration_time,
            "iteration_time_mixed": mix.iteration_time,
            "coverage_f32": f32.coverage,
            "coverage_mixed": mix.coverage,
            "wire_bytes_scale_mixed": mix.wire_bytes_scale,
            "gate_ok_mixed": bool(mix.verdict.ok),
            "ladder_candidates": len(res.precision_candidates),
        },
        "wire_bytes_per_cycle_f32": sum(rt_f.wire_bytes_per_phase),
        "wire_bytes_per_cycle_mixed": sum(rt_m.wire_bytes_per_phase),
    }


def _inner_two_link() -> dict:
    """two_link scenario (DESIGN.md §14): per-link ring-chain execution
    of the secondary RS/AG traffic vs the single-axis collectives, on 4
    forced host devices.  Reported side by side:

    * simulated steady state of the SAME profile solved with the
      secondary link priced (two-link knapsack) vs solved single-link —
      the secondary chain can only add communication capacity, so
      two-link coverage >= single-link (the floor test pins this on the
      checked-in file);
    * measured steps/s of the SAME schedule executed through the
      per-link chain collectives vs the single-axis originals.  Every
      synced bucket is forced onto the secondary link and every
      streamed AG item onto link 1 (maximal chain routing — the parity
      suite proves routing is bitwise-neutral), so the ratio isolates
      the chain's ppermute cost.  On CPU hosts the n-1 store-and-forward
      hops are real memcpys while XLA's fused collectives are one, so
      the ratio is reported, not floored — the chain wins only when the
      secondary link is real extra wire;
    * the per-link wire-byte audit: traced primary/secondary bytes per
      cycle must match the planned split exactly
      (``obs.wire_bytes_report`` with ``planned_split``).
    """
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses

    import jax

    import repro  # noqa: F401
    from repro.configs import get_config, reduce_for_smoke
    from repro.core.bucket import BucketTimes
    from repro.core.deft import Planner, PlanRequest, ag_times, rs_times
    from repro.core.profiler import HardwareModel
    from repro.core.scheduler import DeftScheduler
    from repro.core.simulator import simulate_deft
    from repro.data.pipeline import make_batch
    from repro.launch.mesh import ring_chain
    from repro.obs import Tracer, wire_bytes_report
    from repro.optim.optimizers import adamw
    from repro.train import (
        DeftRuntime,
        RuntimeConfig,
        assign_buckets,
        build_bucket_layout,
        init_train_state,
        leaf_bucket_times,
    )

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    mesh = jax.make_mesh((4, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    chain = ring_chain(4, 1)
    B, S = 8, 32

    probe = init_train_state(key, cfg, opt)
    bucket_of, nb = assign_buckets(probe["params"], cfg,
                                   partition_elems=150_000)
    times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb,
                              HardwareModel(dp_degree=4), S, 2)
    scale = 1.8 * (times.fwd_total + times.bwd_total) / max(
        times.comm_total, 1e-12
    )
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    res = Planner().plan(PlanRequest(times=times, preserve=False,
                                     decoupled=True))
    sched, scfg, ag_plan = res.schedule, res.scheduler_cfg, res.ag_plan

    # ---- simulated steady state: same RS-side profile solved with the
    # secondary link priced vs solved single-link — the two-link
    # knapsack sees strictly more capacity
    rs = rs_times(times)
    split = ag_times(times)
    scfg_one = dataclasses.replace(scfg, heterogeneous=False)
    plans_two = DeftScheduler(rs, scfg).run(48)
    plans_one = DeftScheduler(rs, scfg_one).run(48)
    sim_two = simulate_deft(rs, plans_two, mu=scfg.mu,
                            heterogeneous=True,
                            link_models=scfg.link_models,
                            ag_times=split, ag_mode="streamed")
    sim_one = simulate_deft(rs, plans_one, mu=scfg.mu,
                            heterogeneous=False,
                            ag_times=split, ag_mode="streamed")
    slots_planned = sum(sum(ph.secondary) for ph in sched.phases)
    ag_link1_planned = sum(1 for i in ag_plan.items if i.link == 1)

    # ---- forced maximal routing: every synced bucket on the secondary
    # link, every streamed AG item on link 1 — deterministic regardless
    # of what the knapsack picked for this profile, and bitwise-neutral
    # (tests/test_chain_parity.py), so the paired timing isolates the
    # chain collectives themselves
    phases = []
    for ph in sched.phases:
        sec = tuple(
            (ph.route_new[b] == "sync" and ph.rotate) or ph.sync_cur[b]
            for b in range(len(ph.route_new))
        )
        phases.append(dataclasses.replace(ph, secondary=sec))
    sched = dataclasses.replace(sched, phases=tuple(phases))
    slots_forced = sum(sum(ph.secondary) for ph in sched.phases)
    ag_plan = dataclasses.replace(
        ag_plan,
        items=tuple(dataclasses.replace(i, link=1) for i in ag_plan.items),
    )

    lay = build_bucket_layout(probe["params"], bucket_of, nb,
                              shard_count=4)
    batch = make_batch(cfg, 0, 0, B, S)
    base = RuntimeConfig(fsdp=True, decoupled=True)
    tracer = Tracer(capacity=1 << 16)
    with jax.set_mesh(mesh):
        rt_s = DeftRuntime(cfg, opt, sched, lay, mesh, config=base)
        state_s = rt_s.init_state(key)
        rt_s.compile(state_s, batch)
        rt_c = DeftRuntime(cfg, opt, sched, lay, mesh,
                           config=base.replace(secondary_chain=chain),
                           ag_plan=ag_plan, tracer=tracer)
        state_c = rt_c.init_state(key)
        compile_s = sum(rt_c.compile(state_c, batch).values())

        engines = {
            "single_axis": [lambda i, s: rt_s.step(i, s, batch), state_s],
            "chain": [lambda i, s: rt_c.step(i, s, batch), state_c],
        }
        chunk = sched.period                 # period-aligned windows
        reps = max(_STEPS // chunk, 1)
        best, _, _ = _paired_min_of_reps(
            engines, warmup=sched.period, chunk=chunk, reps=reps
        )

    # per-link wire-byte audit over the traced chain steps: totals AND
    # the primary/secondary split must match the plan exactly
    rep = wire_bytes_report(tracer, rt_c.wire_bytes_per_phase,
                            planned_split=rt_c.wire_bytes_split_per_phase)
    wire_split = rt_c.wire_bytes_split_per_phase
    return {
        "host_devices": jax.device_count(),
        "mesh": {"data": 4, "model": 1},
        "model": {"name": cfg.name, "params": int(cfg.total_params()),
                  "n_leaves": lay.n_leaves, "n_buckets": nb},
        "schedule": {"period": sched.period,
                     "updates_per_period": sched.updates_per_period,
                     "secondary_slots_planned": slots_planned,
                     "secondary_slots_forced": slots_forced,
                     "ag_items": len(ag_plan.items),
                     "ag_items_link1_planned": ag_link1_planned},
        "engine": {"flat_state": True, "sharded_state": True,
                   "shards": lay.shards, "decoupled": True,
                   "secondary_chain": list(chain)},
        "timing": "paired-interleaved-min-of-reps",
        "steps_timed": reps * chunk,
        "compile_s_chain_aot": compile_s,
        "steps_per_s_single_axis": 1.0 / best["single_axis"],
        "steps_per_s_chain": 1.0 / best["chain"],
        "steps_per_s_ratio_chain_vs_single_axis": (
            best["single_axis"] / best["chain"]
        ),
        "sim": {
            "mu": scfg.mu,
            "iteration_time_single_link": sim_one.iteration_time,
            "iteration_time_two_link": sim_two.iteration_time,
            "coverage_single_link": 1.0 - sim_one.bubble_fraction,
            "coverage_two_link": 1.0 - sim_two.bubble_fraction,
        },
        "wire_bytes_primary_per_cycle": sum(p for p, _ in wire_split),
        "wire_bytes_secondary_per_cycle": sum(s for _, s in wire_split),
        "wire_split_max_abs_error": rep.max_abs_split_error,
        "wire_split_ok": bool(rep.ok),
    }


def _bench_update_path() -> dict:
    """Isolated optimizer-apply wall time: fused flat bucket kernels
    (kernels/bucket_update) vs per-leaf apply_updates over the same
    values.  min-of-reps timing (robust to CPU load spikes — the
    whole-phase numbers in the scenario entries bury the update under
    fwd/bwd noise).  Two granularities:

    * ``smoke_config`` — the smoke model's real layout (few stacked
      leaves; memory-bound, so CPU parity is the expected result);
    * ``paper_leafcount`` — a few hundred tensors as in the paper's
      DNN/LLM profiles, where the per-tensor op overhead the engine
      removes (the MG-WFBP/DeAR motivation) actually shows.
    """
    import jax

    import repro  # noqa: F401
    from repro.configs import get_config, reduce_for_smoke
    from repro.kernels.bucket_update import (
        apply_bucket_updates,
        build_segments,
        init_flat_opt_state,
    )
    from repro.optim.optimizers import adamw, apply_updates, init_opt_state
    from repro.train import (
        assign_buckets,
        build_bucket_layout,
        flatten_buckets,
        init_train_state,
    )

    opt = adamw(1e-3)

    def measure(params, layout) -> dict:
        grads = jax.tree.map(lambda p: p * 0.01, params)
        seg = build_segments(layout, opt)
        pbuf = tuple(flatten_buckets(layout, jax.tree.leaves(params)))
        gbuf = tuple(flatten_buckets(layout, jax.tree.leaves(grads)))
        opt_f = init_flat_opt_state(opt, layout.buf_sizes)
        opt_l = init_opt_state(opt, params)
        f_flat = jax.jit(lambda p, g, o: apply_bucket_updates(
            opt, seg, p, g, o, grad_scale=0.1)[:2])
        f_leaf = jax.jit(lambda p, g, o: apply_updates(
            opt, p, g, o, grad_scale=0.1))
        ms_flat, ms_leaf = _timed_apply_pair(
            f_flat, (pbuf, gbuf, opt_f), f_leaf, (params, grads, opt_l)
        )
        return {
            "n_leaves": layout.n_leaves,
            "n_buckets": layout.n_buckets,
            "total_elems": layout.total_elems,
            "apply_ms_flat": ms_flat,
            "apply_ms_per_leaf": ms_leaf,
            "speedup_flat_vs_per_leaf": ms_leaf / ms_flat,
        }

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    probe = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    bucket_of, nb = assign_buckets(probe["params"], cfg,
                                   partition_elems=150_000)
    smoke = measure(probe["params"],
                    build_bucket_layout(probe["params"], bucket_of, nb))

    n_leaves, leaf_elems, n_buckets = 256, 8192, 8
    tree = _paper_tree(n_leaves, leaf_elems)
    bo = tuple(i * n_buckets // n_leaves for i in range(n_leaves))
    paper = measure(tree, build_bucket_layout(tree, bo, n_buckets))
    return {"smoke_config": smoke, "paper_leafcount": paper}


def _bench_repack(step_s_smoke: float) -> dict:
    """Cycle-boundary re-pack cost (DESIGN.md §9): the runtime's own
    jitted LayoutTransition pass between two partitions of the smoke
    model, alternated A->B->A (donated state stays live), min-of-reps —
    against the isolated flat update apply (the cheapest thing a phase
    does) and the smoke scenario's whole-step time (the amortization
    denominator: a repack happens once per adopted repartition, i.e.
    every O(100) steps at realistic replan cadence)."""
    import jax

    import repro  # noqa: F401
    from repro.configs import get_config, reduce_for_smoke
    from repro.core.deft import solve_schedule
    from repro.core.scheduler import SchedulerConfig
    from repro.kernels.bucket_update import (
        apply_bucket_updates,
        build_segments,
        init_flat_opt_state,
    )
    from repro.optim.optimizers import adamw
    from repro.train import (
        DeftRuntime,
        assign_buckets,
        build_bucket_layout,
        build_layout_transition,
        flatten_buckets,
        init_train_state,
        leaf_bucket_times,
    )
    from repro.core.profiler import HardwareModel

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    probe = init_train_state(key, cfg, opt)
    bo_a, nb_a = assign_buckets(probe["params"], cfg,
                                partition_elems=150_000)
    bo_b, nb_b = assign_buckets(probe["params"], cfg,
                                partition_elems=400_000)
    lay_a = build_bucket_layout(probe["params"], bo_a, nb_a)
    lay_b = build_bucket_layout(probe["params"], bo_b, nb_b)
    tr_ab = build_layout_transition(lay_a, lay_b)
    tr_ba = build_layout_transition(lay_b, lay_a)
    times = leaf_bucket_times(probe["params"], cfg, bo_a, nb_a,
                              HardwareModel(dp_degree=2), 32, 4)
    sched = solve_schedule(times, SchedulerConfig())
    with mesh:
        # construction only jits (no phase compiles): repack_state is
        # the runtime's real staged-swap executable
        rt = DeftRuntime(cfg, opt, sched, lay_a, mesh)
        state = rt.init_state(key)
        reps = 7
        best_ab = best_ba = float("inf")
        for _ in range(1 + reps):                 # first rep compiles
            t0 = time.perf_counter()
            state = rt.repack_state(state, tr_ab)
            jax.block_until_ready(jax.tree_util.tree_leaves(state))
            ab = time.perf_counter() - t0
            t0 = time.perf_counter()
            state = rt.repack_state(state, tr_ba)
            jax.block_until_ready(jax.tree_util.tree_leaves(state))
            ba = time.perf_counter() - t0
            if _ > 0:
                best_ab, best_ba = min(best_ab, ab), min(best_ba, ba)

        # isolated flat update apply under layout A (same harness as
        # _bench_update_path): the per-phase work a repack competes with
        grads = jax.tree.map(lambda p: p * 0.01, probe["params"])
        seg = build_segments(lay_a, opt)
        pbuf = tuple(flatten_buckets(lay_a, jax.tree.leaves(probe["params"])))
        gbuf = tuple(flatten_buckets(lay_a, jax.tree.leaves(grads)))
        opt_f = init_flat_opt_state(opt, lay_a.buf_sizes)
        f_flat = jax.jit(lambda p, g, o: apply_bucket_updates(
            opt, seg, p, g, o, grad_scale=0.1)[:2])
        jax.block_until_ready(f_flat(pbuf, gbuf, opt_f))
        apply_ms = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            out = f_flat(pbuf, gbuf, opt_f)
            jax.block_until_ready(out)
            apply_ms = min(apply_ms, (time.perf_counter() - t0) * 1e3)

    repack_ms = best_ab * 1e3
    return {
        "n_buckets_a": nb_a,
        "n_buckets_b": nb_b,
        "total_elems": lay_a.total_elems,
        "moved_elems_a_to_b": tr_ab.moved_elems,
        "repack_ms_a_to_b": repack_ms,
        "repack_ms_b_to_a": best_ba * 1e3,
        "update_phase_apply_ms": apply_ms,
        "repack_over_update_apply": repack_ms / max(apply_ms, 1e-9),
        "step_ms_smoke": step_s_smoke * 1e3,
        "amortized_overhead_at_replan_every_100_steps": (
            repack_ms / 1e3 / max(100.0 * step_s_smoke, 1e-12)
        ),
    }


def _bench_solver() -> dict:
    """Planning time of the two-stage Solver over the 96-iteration
    horizon, memoized vs unmemoized, on a paper-scale profile."""
    from repro.core.bucket import BucketTimes
    from repro.core.deft import solve_schedule
    from repro.core.knapsack import (
        clear_knapsack_caches,
        knapsack_cache_info,
        set_knapsack_memoization,
    )
    from repro.core.scheduler import SchedulerConfig

    rng = random.Random(0)
    n = 12
    fwd = tuple(rng.uniform(0.001, 0.05) for _ in range(n))
    bwd = tuple(2 * f for f in fwd)
    comm = tuple(rng.uniform(0.01, 0.3) for _ in range(n))
    times = BucketTimes(fwd, bwd, comm)
    scfg = SchedulerConfig()
    reps = 5

    prev = set_knapsack_memoization(False)
    t0 = time.perf_counter()
    for _ in range(reps):
        solve_schedule(times, scfg)
    plan_unmemo = (time.perf_counter() - t0) / reps

    set_knapsack_memoization(True)
    clear_knapsack_caches()
    t0 = time.perf_counter()
    for _ in range(reps):
        solve_schedule(times, scfg)
    plan_memo = (time.perf_counter() - t0) / reps
    cache = knapsack_cache_info()
    set_knapsack_memoization(prev)
    return {
        "n_buckets": n,
        "horizon_reps": reps,
        "plan_s_unmemoized": plan_unmemo,
        "plan_s_memoized": plan_memo,
        "speedup": plan_unmemo / max(plan_memo, 1e-12),
        "cache_hits": cache.hits,
        "cache_misses": cache.misses,
    }


def run() -> None:
    """Benchmark section entry point (benchmarks/run.py)."""
    t0 = time.time()
    results: dict = {
        "solver": _bench_solver(),
        "update_path": _bench_update_path(),
    }
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    for name, args in (("smoke", ["--inner", "1"]),
                       ("dp4", ["--inner", "4"]),
                       ("fsdp_flat", ["--inner-fsdp"]),
                       ("decoupled", ["--inner-decoupled"]),
                       ("precision", ["--inner-precision"]),
                       ("two_link", ["--inner-two-link"])):
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), *args],
            env=env, capture_output=True, text=True, timeout=1800,
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"runtime bench ({name}) failed:\n{proc.stderr[-2000:]}"
            )
        results[name] = json.loads(proc.stdout.splitlines()[-1])

    # repack rides in-process AFTER the scenarios: the smoke scenario's
    # steady-state step time is its amortization denominator
    results["repack"] = _bench_repack(
        1.0 / results["smoke"]["steps_per_s_fused"]
    )

    tmp = _OUT + ".tmp"
    json.dump(results, open(tmp, "w"), indent=1)
    os.replace(tmp, _OUT)

    for name in ("smoke", "dp4"):
        r = results[name]
        print(f"runtime_{name}_steps_per_s_fused,"
              f"{1e6 / r['steps_per_s_fused']:.0f},"
              f"{r['steps_per_s_fused']:.3f} steps/s")
        print(f"runtime_{name}_steps_per_s_legacy,"
              f"{1e6 / r['steps_per_s_legacy']:.0f},"
              f"{r['steps_per_s_legacy']:.3f} steps/s")
        print(f"runtime_{name}_speedup,{r['speedup_fused_vs_legacy']:.2f},"
              f"fused vs per-leaf on {r['host_devices']} device(s)")
        print(f"runtime_{name}_collectives_per_phase,"
              f"{max(r['collectives_per_phase_fused'])},"
              f"legacy per-leaf "
              f"{max(r['collectives_per_phase_legacy_per_leaf'])}")
        print(f"runtime_{name}_update_phase_ms,"
              f"{r['update_phase_ms_flat'] * 1e3:.0f},"
              f"flat {r['update_phase_ms_flat']:.1f}ms vs per-leaf "
              f"{r['update_phase_ms_legacy_per_leaf']:.1f}ms "
              f"({r['update_phase_speedup_flat_vs_per_leaf']:.2f}x) / "
              f"tree {r['update_phase_ms_tree']:.1f}ms "
              f"({r['update_phase_speedup_flat_vs_tree']:.2f}x)")
    fs = results["fsdp_flat"]
    us = fs["update_path_sharded"]
    print(f"runtime_fsdp_flat_steps_per_s,"
          f"{1e6 / fs['steps_per_s_sharded']:.0f},"
          f"sharded {fs['steps_per_s_sharded']:.3f} vs replicated-flat "
          f"{fs['steps_per_s_replicated_flat']:.3f} steps/s "
          f"(1/{fs['engine']['shards']} resident opt state)")
    print(f"update_path_sharded_apply_ms,{us['apply_ms_flat_shard'] * 1e3:.0f},"
          f"shard-fused {us['apply_ms_flat_shard']:.2f}ms vs ZeRO per-leaf "
          f"{us['apply_ms_per_leaf_shard']:.2f}ms "
          f"({us['speedup_flat_vs_per_leaf']:.2f}x, {us['n_leaves']} leaves "
          f"-> {us['n_buckets']} buckets, {us['shard_count']} shards)")
    dc = results["decoupled"]
    print(f"runtime_decoupled_steps_per_s,"
          f"{1e6 / dc['steps_per_s_decoupled']:.0f},"
          f"streamed-AG {dc['steps_per_s_decoupled']:.3f} vs fused-chain "
          f"{dc['steps_per_s_fused']:.3f} steps/s "
          f"({dc['steps_per_s_ratio_decoupled_vs_fused']:.2f}x)")
    print(f"runtime_decoupled_sim_coverage,"
          f"{dc['sim']['coverage_decoupled'] * 1e4:.0f},"
          f"decoupled {dc['sim']['coverage_decoupled']:.3f} vs fused "
          f"{dc['sim']['coverage_fused']:.3f} "
          f"(AG plan coverage {dc['sim']['ag_plan_coverage']:.3f})")
    print(f"runtime_decoupled_ag_burst_bytes_delta,"
          f"{dc['ag_burst_bytes_delta']},"
          f"fused bursts {dc['ag_burst_bytes_fused'] / 1e6:.1f}MB "
          f"pre-forward vs decoupled peak "
          f"{dc['ag_burst_bytes_decoupled_peak'] / 1e6:.1f}MB")
    pc = results["precision"]
    print(f"runtime_precision_sim_coverage,"
          f"{pc['sim']['coverage_mixed'] * 1e4:.0f},"
          f"mixed {pc['sim']['coverage_mixed']:.3f} vs f32 "
          f"{pc['sim']['coverage_f32']:.3f} "
          f"({pc['engine']['wire_precision']}, wire bytes "
          f"x{pc['sim']['wire_bytes_scale_mixed']:.2f})")
    print(f"runtime_precision_steps_per_s,"
          f"{1e6 / pc['steps_per_s_mixed']:.0f},"
          f"mixed {pc['steps_per_s_mixed']:.3f} vs f32 "
          f"{pc['steps_per_s_f32']:.3f} steps/s "
          f"({pc['steps_per_s_ratio_mixed_vs_f32']:.2f}x)")
    print(f"runtime_precision_wire_bytes_per_cycle,"
          f"{pc['wire_bytes_per_cycle_mixed']},"
          f"mixed {pc['wire_bytes_per_cycle_mixed'] / 1e6:.1f}MB vs f32 "
          f"{pc['wire_bytes_per_cycle_f32'] / 1e6:.1f}MB")
    tl = results["two_link"]
    print(f"runtime_two_link_sim_coverage,"
          f"{tl['sim']['coverage_two_link'] * 1e4:.0f},"
          f"two-link {tl['sim']['coverage_two_link']:.3f} vs single-link "
          f"{tl['sim']['coverage_single_link']:.3f} (mu {tl['sim']['mu']})")
    print(f"runtime_two_link_steps_per_s,"
          f"{1e6 / tl['steps_per_s_chain']:.0f},"
          f"chain {tl['steps_per_s_chain']:.3f} vs single-axis "
          f"{tl['steps_per_s_single_axis']:.3f} steps/s "
          f"({tl['steps_per_s_ratio_chain_vs_single_axis']:.2f}x)")
    print(f"runtime_two_link_wire_bytes_secondary,"
          f"{tl['wire_bytes_secondary_per_cycle']},"
          f"secondary {tl['wire_bytes_secondary_per_cycle'] / 1e6:.1f}MB vs "
          f"primary {tl['wire_bytes_primary_per_cycle'] / 1e6:.1f}MB per "
          f"cycle (split audit ok={tl['wire_split_ok']})")
    for gran, u in results["update_path"].items():
        print(f"update_path_{gran}_apply_ms,"
              f"{u['apply_ms_flat'] * 1e3:.0f},"
              f"flat {u['apply_ms_flat']:.2f}ms vs per-leaf "
              f"{u['apply_ms_per_leaf']:.2f}ms "
              f"({u['speedup_flat_vs_per_leaf']:.2f}x, "
              f"{u['n_leaves']} leaves -> {u['n_buckets']} buckets)")
    rp = results["repack"]
    print(f"repack_us,{rp['repack_ms_a_to_b'] * 1e3:.0f},"
          f"{rp['n_buckets_a']}->{rp['n_buckets_b']} buckets "
          f"{rp['repack_ms_a_to_b']:.1f}ms "
          f"(vs update apply {rp['update_phase_apply_ms']:.1f}ms; "
          f"{rp['amortized_overhead_at_replan_every_100_steps'] * 100:.2f}% "
          f"overhead at a replan every 100 steps)")
    s = results["solver"]
    print(f"solver_plan_us_memoized,{s['plan_s_memoized'] * 1e6:.0f},"
          f"{s['speedup']:.1f}x vs unmemoized "
          f"({s['plan_s_unmemoized'] * 1e3:.0f} ms)")
    print(f"# BENCH_runtime.json written in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--inner":
        json.dump(_inner(int(sys.argv[2])), sys.stdout)
        print()
    elif len(sys.argv) > 1 and sys.argv[1] == "--inner-fsdp":
        json.dump(_inner_fsdp(), sys.stdout)
        print()
    elif len(sys.argv) > 1 and sys.argv[1] == "--inner-decoupled":
        json.dump(_inner_decoupled(), sys.stdout)
        print()
    elif len(sys.argv) > 1 and sys.argv[1] == "--inner-precision":
        json.dump(_inner_precision(), sys.stdout)
        print()
    elif len(sys.argv) > 1 and sys.argv[1] == "--inner-two-link":
        json.dump(_inner_two_link(), sys.stdout)
        print()
    else:
        run()
