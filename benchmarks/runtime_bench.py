"""DeftRuntime perf benchmark: fused-bucket runtime vs the seed per-leaf
implementation, plus solver planning time with/without memoization.

Emits machine-readable ``BENCH_runtime.json`` (steps/s, compile time,
solver planning time, collectives-per-phase) so the perf trajectory is
tracked across PRs.  Two train-loop scenarios, each in its own
subprocess:

* ``smoke`` — the smoke DeFT train loop exactly as ``repro.launch.train
  --smoke --scheduler deft`` runs it on this host (single device).  The
  fused runtime wins on graph leanness (per-bucket buffers instead of
  per-leaf accumulator ops) and buffer donation (params/opt/accumulators
  update in place instead of being copied every step).
* ``dp4`` — 4 forced host devices so the per-bucket vs per-leaf gradient
  collectives are real inter-device operations.

The solver benchmark runs in-process on a paper-scale bucket profile
(comm times in the 10..300 ms range — the regime the production planner
faces; microsecond toy instances make the DP trivially cheap and would
understate the memoization win).
"""
from __future__ import annotations

import json
import os
import random
import subprocess
import sys
import time

_STEPS = int(os.environ.get("BENCH_RUNTIME_STEPS", "30"))
_OUT = os.environ.get("BENCH_RUNTIME_OUT", "BENCH_runtime.json")


def _inner(devices: int) -> dict:
    if devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}"
        )
    import jax

    import repro  # noqa: F401  (jax compat shims)
    from repro.configs import get_config, reduce_for_smoke
    from repro.core.bucket import BucketTimes
    from repro.core.deft import solve_schedule
    from repro.core.profiler import HardwareModel
    from repro.core.scheduler import SchedulerConfig
    from repro.data.pipeline import make_batch
    from repro.optim.optimizers import adamw
    from repro.train import (
        DeftRuntime,
        assign_buckets,
        build_bucket_layout,
        init_train_state,
        leaf_bucket_times,
        make_deft_step_fns,
    )

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    dp = jax.device_count()
    mesh = jax.make_mesh((dp, 1), ("data", "model"))
    B, S = max(4, dp), 32

    probe = init_train_state(key, cfg, opt)
    bucket_of, nb = assign_buckets(probe["params"], cfg,
                                   partition_elems=150_000)
    times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb,
                              HardwareModel(dp_degree=max(dp, 2)), S,
                              max(B // dp, 1))
    scale = 1.8 * (times.fwd_total + times.bwd_total) / max(
        times.comm_total, 1e-12
    )
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    sched = solve_schedule(times, SchedulerConfig())
    layout = build_bucket_layout(probe["params"], bucket_of, nb)
    batch = make_batch(cfg, 0, 0, B, S)

    def bench_loop(step_of, state, n):
        for i in range(sched.period):        # warmup one full period
            state, m = step_of(i, state, batch)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for i in range(n):
            state, m = step_of(i, state, batch)
        jax.block_until_ready(m["loss"])
        return n / (time.perf_counter() - t0)

    with mesh:
        # ---- seed implementation: per-leaf psums, tree accumulators,
        # no donation, compile-on-first-dispatch ------------------------
        t0 = time.perf_counter()
        fns = make_deft_step_fns(cfg, opt, sched, bucket_of, mesh)
        state_l = init_train_state(key, cfg, opt, deft=True,
                                   accum_devices=dp)
        sps_legacy = bench_loop(
            lambda i, s, b: fns[i % sched.period](s, b), state_l, _STEPS
        )
        legacy_wall = time.perf_counter() - t0

        # ---- fused runtime: bucket collectives + donation + AOT cache -
        t0 = time.perf_counter()
        rt = DeftRuntime(cfg, opt, sched, layout, mesh)
        state_f = rt.init_state(key)
        compile_s = sum(rt.compile(state_f, batch).values())
        sps_fused = bench_loop(rt.step, state_f, _STEPS)
        fused_wall = time.perf_counter() - t0

    coll = rt.collectives_per_phase()
    per_leaf = [
        sum(
            len(layout.leaves[b]) for b in range(nb)
            if (ph.route_new[b] == "sync" and ph.rotate) or ph.sync_cur[b]
        )
        for ph in sched.phases
    ]
    return {
        "host_devices": dp,
        "model": {"name": cfg.name, "params": int(cfg.total_params()),
                  "n_leaves": layout.n_leaves, "n_buckets": nb},
        "schedule": {"period": sched.period,
                     "updates_per_period": sched.updates_per_period},
        "steps_timed": _STEPS,
        "steps_per_s_fused": sps_fused,
        "steps_per_s_legacy": sps_legacy,
        "speedup_fused_vs_legacy": sps_fused / sps_legacy,
        "compile_s_fused_aot": compile_s,
        "wall_s_fused_total": fused_wall,
        "wall_s_legacy_total": legacy_wall,
        "collectives_per_phase_fused": [
            c["primary"] + c["secondary"] for c in coll
        ],
        "collectives_per_phase_legacy_per_leaf": per_leaf,
    }


def _bench_solver() -> dict:
    """Planning time of the two-stage Solver over the 96-iteration
    horizon, memoized vs unmemoized, on a paper-scale profile."""
    from repro.core.bucket import BucketTimes
    from repro.core.deft import solve_schedule
    from repro.core.knapsack import (
        clear_knapsack_caches,
        knapsack_cache_info,
        set_knapsack_memoization,
    )
    from repro.core.scheduler import SchedulerConfig

    rng = random.Random(0)
    n = 12
    fwd = tuple(rng.uniform(0.001, 0.05) for _ in range(n))
    bwd = tuple(2 * f for f in fwd)
    comm = tuple(rng.uniform(0.01, 0.3) for _ in range(n))
    times = BucketTimes(fwd, bwd, comm)
    scfg = SchedulerConfig()
    reps = 5

    prev = set_knapsack_memoization(False)
    t0 = time.perf_counter()
    for _ in range(reps):
        solve_schedule(times, scfg)
    plan_unmemo = (time.perf_counter() - t0) / reps

    set_knapsack_memoization(True)
    clear_knapsack_caches()
    t0 = time.perf_counter()
    for _ in range(reps):
        solve_schedule(times, scfg)
    plan_memo = (time.perf_counter() - t0) / reps
    cache = knapsack_cache_info()
    set_knapsack_memoization(prev)
    return {
        "n_buckets": n,
        "horizon_reps": reps,
        "plan_s_unmemoized": plan_unmemo,
        "plan_s_memoized": plan_memo,
        "speedup": plan_unmemo / max(plan_memo, 1e-12),
        "cache_hits": cache.hits,
        "cache_misses": cache.misses,
    }


def run() -> None:
    """Benchmark section entry point (benchmarks/run.py)."""
    t0 = time.time()
    results: dict = {"solver": _bench_solver()}
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    for name, devices in (("smoke", 1), ("dp4", 4)):
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--inner",
             str(devices)],
            env=env, capture_output=True, text=True, timeout=1800,
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"runtime bench ({name}) failed:\n{proc.stderr[-2000:]}"
            )
        results[name] = json.loads(proc.stdout.splitlines()[-1])

    tmp = _OUT + ".tmp"
    json.dump(results, open(tmp, "w"), indent=1)
    os.replace(tmp, _OUT)

    for name in ("smoke", "dp4"):
        r = results[name]
        print(f"runtime_{name}_steps_per_s_fused,"
              f"{1e6 / r['steps_per_s_fused']:.0f},"
              f"{r['steps_per_s_fused']:.3f} steps/s")
        print(f"runtime_{name}_steps_per_s_legacy,"
              f"{1e6 / r['steps_per_s_legacy']:.0f},"
              f"{r['steps_per_s_legacy']:.3f} steps/s")
        print(f"runtime_{name}_speedup,{r['speedup_fused_vs_legacy']:.2f},"
              f"fused vs per-leaf on {r['host_devices']} device(s)")
        print(f"runtime_{name}_collectives_per_phase,"
              f"{max(r['collectives_per_phase_fused'])},"
              f"legacy per-leaf "
              f"{max(r['collectives_per_phase_legacy_per_leaf'])}")
    s = results["solver"]
    print(f"solver_plan_us_memoized,{s['plan_s_memoized'] * 1e6:.0f},"
          f"{s['speedup']:.1f}x vs unmemoized "
          f"({s['plan_s_unmemoized'] * 1e3:.0f} ms)")
    print(f"# BENCH_runtime.json written in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--inner":
        json.dump(_inner(int(sys.argv[2])), sys.stdout)
        print()
    else:
        run()
