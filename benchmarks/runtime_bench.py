"""DeftRuntime perf benchmark: fused-bucket runtime vs the seed per-leaf
implementation, plus solver planning time with/without memoization.

Emits machine-readable ``BENCH_runtime.json`` (steps/s, compile time,
solver planning time, collectives-per-phase) so the perf trajectory is
tracked across PRs.  Two train-loop scenarios, each in its own
subprocess:

* ``smoke`` — the smoke DeFT train loop exactly as ``repro.launch.train
  --smoke --scheduler deft`` runs it on this host (single device).  The
  fused runtime wins on graph leanness (per-bucket buffers instead of
  per-leaf accumulator ops) and buffer donation (params/opt/accumulators
  update in place instead of being copied every step).
* ``dp4`` — 4 forced host devices so the per-bucket vs per-leaf gradient
  collectives are real inter-device operations.

The solver benchmark runs in-process on a paper-scale bucket profile
(comm times in the 10..300 ms range — the regime the production planner
faces; microsecond toy instances make the DP trivially cheap and would
understate the memoization win).
"""
from __future__ import annotations

import json
import os
import random
import subprocess
import sys
import time

_STEPS = int(os.environ.get("BENCH_RUNTIME_STEPS", "30"))
_OUT = os.environ.get("BENCH_RUNTIME_OUT", "BENCH_runtime.json")


def _inner(devices: int) -> dict:
    if devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}"
        )
    import jax

    import repro  # noqa: F401  (jax compat shims)
    from repro.configs import get_config, reduce_for_smoke
    from repro.core.bucket import BucketTimes
    from repro.core.deft import solve_schedule
    from repro.core.profiler import HardwareModel
    from repro.core.scheduler import SchedulerConfig
    from repro.data.pipeline import make_batch
    from repro.optim.optimizers import adamw
    from repro.train import (
        DeftRuntime,
        assign_buckets,
        build_bucket_layout,
        init_train_state,
        leaf_bucket_times,
        make_deft_step_fns,
    )

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    opt = adamw(1e-3)
    key = jax.random.PRNGKey(0)
    dp = jax.device_count()
    mesh = jax.make_mesh((dp, 1), ("data", "model"))
    B, S = max(4, dp), 32

    probe = init_train_state(key, cfg, opt)
    bucket_of, nb = assign_buckets(probe["params"], cfg,
                                   partition_elems=150_000)
    times = leaf_bucket_times(probe["params"], cfg, bucket_of, nb,
                              HardwareModel(dp_degree=max(dp, 2)), S,
                              max(B // dp, 1))
    scale = 1.8 * (times.fwd_total + times.bwd_total) / max(
        times.comm_total, 1e-12
    )
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    sched = solve_schedule(times, SchedulerConfig())
    layout = build_bucket_layout(probe["params"], bucket_of, nb)
    batch = make_batch(cfg, 0, 0, B, S)

    def bench_loop(step_of, state, n):
        for i in range(sched.period):        # warmup one full period
            state, m = step_of(i, state, batch)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for i in range(n):
            state, m = step_of(i, state, batch)
        jax.block_until_ready(m["loss"])
        return n / (time.perf_counter() - t0), state

    # the phase whose executable applies the (delayed) optimizer update —
    # the update-path comparison times this one phase across engines
    upd = next(i for i, ph in enumerate(sched.phases) if ph.do_update)

    def bench_phase(dispatch, state, n):
        for _ in range(2):                   # warmup (compile + caches)
            state, m = dispatch(state)
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(n):
            state, m = dispatch(state)
        jax.block_until_ready(m["loss"])
        return (time.perf_counter() - t0) / n

    def rt_phase_dispatch(rt):
        fn = rt.phase_executable(upd)
        return lambda s: fn(s, batch)

    with mesh:
        # ---- seed implementation: per-leaf psums, tree accumulators,
        # no donation, compile-on-first-dispatch ------------------------
        t0 = time.perf_counter()
        fns = make_deft_step_fns(cfg, opt, sched, bucket_of, mesh)
        state_l = init_train_state(key, cfg, opt, deft=True,
                                   accum_devices=dp)
        sps_legacy, state_l = bench_loop(
            lambda i, s, b: fns[i % sched.period](s, b), state_l, _STEPS
        )
        legacy_wall = time.perf_counter() - t0
        upd_s_legacy = bench_phase(
            lambda s: fns[upd](s, batch), state_l, _STEPS
        )

        # ---- PR-1 fused runtime, tree state: bucket collectives +
        # donation + AOT cache, but per-leaf apply_updates ---------------
        rt_tree = DeftRuntime(cfg, opt, sched, layout, mesh,
                              flat_state=False)
        state_t = rt_tree.init_state(key)
        rt_tree.compile(state_t, batch)
        sps_tree, state_t = bench_loop(rt_tree.step, state_t, _STEPS)
        upd_s_tree = bench_phase(rt_phase_dispatch(rt_tree), state_t, _STEPS)

        # ---- production engine: flat-resident state + fused
        # bucket-update kernels ------------------------------------------
        t0 = time.perf_counter()
        rt = DeftRuntime(cfg, opt, sched, layout, mesh)
        state_f = rt.init_state(key)
        compile_s = sum(rt.compile(state_f, batch).values())
        sps_fused, state_f = bench_loop(rt.step, state_f, _STEPS)
        fused_wall = time.perf_counter() - t0
        upd_s_flat = bench_phase(rt_phase_dispatch(rt), state_f, _STEPS)

    coll = rt.collectives_per_phase()
    per_leaf = [
        sum(
            len(layout.leaves[b]) for b in range(nb)
            if (ph.route_new[b] == "sync" and ph.rotate) or ph.sync_cur[b]
        )
        for ph in sched.phases
    ]
    return {
        "host_devices": dp,
        "model": {"name": cfg.name, "params": int(cfg.total_params()),
                  "n_leaves": layout.n_leaves, "n_buckets": nb},
        "schedule": {"period": sched.period,
                     "updates_per_period": sched.updates_per_period},
        "engine": {"flat_state": rt.flat_state,
                   "update_impl": rt.stats()["update_impl"]},
        "steps_timed": _STEPS,
        "steps_per_s_fused": sps_fused,
        "steps_per_s_fused_tree": sps_tree,
        "steps_per_s_legacy": sps_legacy,
        "speedup_fused_vs_legacy": sps_fused / sps_legacy,
        "compile_s_fused_aot": compile_s,
        "wall_s_fused_total": fused_wall,
        "wall_s_legacy_total": legacy_wall,
        # wall time of the do_update phase across the three update paths:
        # flat fused-kernel engine vs PR-1 tree-state (per-leaf
        # apply_updates on fused buffers) vs the seed per-leaf step
        "update_phase_ms_flat": upd_s_flat * 1e3,
        "update_phase_ms_tree": upd_s_tree * 1e3,
        "update_phase_ms_legacy_per_leaf": upd_s_legacy * 1e3,
        "update_phase_speedup_flat_vs_per_leaf": upd_s_legacy / upd_s_flat,
        "update_phase_speedup_flat_vs_tree": upd_s_tree / upd_s_flat,
        "collectives_per_phase_fused": [
            c["primary"] + c["secondary"] for c in coll
        ],
        "collectives_per_phase_legacy_per_leaf": per_leaf,
    }


def _bench_update_path() -> dict:
    """Isolated optimizer-apply wall time: fused flat bucket kernels
    (kernels/bucket_update) vs per-leaf apply_updates over the same
    values.  min-of-reps timing (robust to CPU load spikes — the
    whole-phase numbers in the scenario entries bury the update under
    fwd/bwd noise).  Two granularities:

    * ``smoke_config`` — the smoke model's real layout (few stacked
      leaves; memory-bound, so CPU parity is the expected result);
    * ``paper_leafcount`` — a few hundred tensors as in the paper's
      DNN/LLM profiles, where the per-tensor op overhead the engine
      removes (the MG-WFBP/DeAR motivation) actually shows.
    """
    import jax

    import repro  # noqa: F401
    from repro.configs import get_config, reduce_for_smoke
    from repro.kernels.bucket_update import (
        apply_bucket_updates,
        build_segments,
        init_flat_opt_state,
    )
    from repro.optim.optimizers import adamw, apply_updates, init_opt_state
    from repro.train import (
        assign_buckets,
        build_bucket_layout,
        flatten_buckets,
        init_train_state,
    )

    opt = adamw(1e-3)

    def measure(params, layout) -> dict:
        grads = jax.tree.map(lambda p: p * 0.01, params)
        seg = build_segments(layout, opt)
        pbuf = tuple(flatten_buckets(layout, jax.tree.leaves(params)))
        gbuf = tuple(flatten_buckets(layout, jax.tree.leaves(grads)))
        opt_f = init_flat_opt_state(opt, layout.buf_sizes)
        opt_l = init_opt_state(opt, params)
        f_flat = jax.jit(lambda p, g, o: apply_bucket_updates(
            opt, seg, p, g, o, grad_scale=0.1)[:2])
        f_leaf = jax.jit(lambda p, g, o: apply_updates(
            opt, p, g, o, grad_scale=0.1))

        def timed(f, *args, n=20):
            t0 = time.perf_counter()
            for _ in range(n):
                out = f(*args)
            jax.block_until_ready(out)
            return (time.perf_counter() - t0) / n

        # paired + interleaved min-of-reps: ambient load spikes on a
        # shared host hit both paths, not whichever ran second
        jax.block_until_ready(f_flat(pbuf, gbuf, opt_f))
        jax.block_until_ready(f_leaf(params, grads, opt_l))
        ms_flat = ms_leaf = float("inf")
        for _ in range(9):
            ms_flat = min(ms_flat, timed(f_flat, pbuf, gbuf, opt_f) * 1e3)
            ms_leaf = min(ms_leaf, timed(f_leaf, params, grads, opt_l) * 1e3)
        return {
            "n_leaves": layout.n_leaves,
            "n_buckets": layout.n_buckets,
            "total_elems": layout.total_elems,
            "apply_ms_flat": ms_flat,
            "apply_ms_per_leaf": ms_leaf,
            "speedup_flat_vs_per_leaf": ms_leaf / ms_flat,
        }

    cfg = reduce_for_smoke(get_config("qwen3-4b"))
    probe = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    bucket_of, nb = assign_buckets(probe["params"], cfg,
                                   partition_elems=150_000)
    smoke = measure(probe["params"],
                    build_bucket_layout(probe["params"], bucket_of, nb))

    n_leaves, leaf_elems, n_buckets = 256, 8192, 8
    key = jax.random.PRNGKey(1)
    tree = {
        f"l{i:03d}": jax.random.normal(jax.random.fold_in(key, i),
                                       (leaf_elems,))
        for i in range(n_leaves)
    }
    bo = tuple(i * n_buckets // n_leaves for i in range(n_leaves))
    paper = measure(tree, build_bucket_layout(tree, bo, n_buckets))
    return {"smoke_config": smoke, "paper_leafcount": paper}


def _bench_solver() -> dict:
    """Planning time of the two-stage Solver over the 96-iteration
    horizon, memoized vs unmemoized, on a paper-scale profile."""
    from repro.core.bucket import BucketTimes
    from repro.core.deft import solve_schedule
    from repro.core.knapsack import (
        clear_knapsack_caches,
        knapsack_cache_info,
        set_knapsack_memoization,
    )
    from repro.core.scheduler import SchedulerConfig

    rng = random.Random(0)
    n = 12
    fwd = tuple(rng.uniform(0.001, 0.05) for _ in range(n))
    bwd = tuple(2 * f for f in fwd)
    comm = tuple(rng.uniform(0.01, 0.3) for _ in range(n))
    times = BucketTimes(fwd, bwd, comm)
    scfg = SchedulerConfig()
    reps = 5

    prev = set_knapsack_memoization(False)
    t0 = time.perf_counter()
    for _ in range(reps):
        solve_schedule(times, scfg)
    plan_unmemo = (time.perf_counter() - t0) / reps

    set_knapsack_memoization(True)
    clear_knapsack_caches()
    t0 = time.perf_counter()
    for _ in range(reps):
        solve_schedule(times, scfg)
    plan_memo = (time.perf_counter() - t0) / reps
    cache = knapsack_cache_info()
    set_knapsack_memoization(prev)
    return {
        "n_buckets": n,
        "horizon_reps": reps,
        "plan_s_unmemoized": plan_unmemo,
        "plan_s_memoized": plan_memo,
        "speedup": plan_unmemo / max(plan_memo, 1e-12),
        "cache_hits": cache.hits,
        "cache_misses": cache.misses,
    }


def run() -> None:
    """Benchmark section entry point (benchmarks/run.py)."""
    t0 = time.time()
    results: dict = {
        "solver": _bench_solver(),
        "update_path": _bench_update_path(),
    }
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    for name, devices in (("smoke", 1), ("dp4", 4)):
        proc = subprocess.run(
            [sys.executable, os.path.abspath(__file__), "--inner",
             str(devices)],
            env=env, capture_output=True, text=True, timeout=1800,
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"runtime bench ({name}) failed:\n{proc.stderr[-2000:]}"
            )
        results[name] = json.loads(proc.stdout.splitlines()[-1])

    tmp = _OUT + ".tmp"
    json.dump(results, open(tmp, "w"), indent=1)
    os.replace(tmp, _OUT)

    for name in ("smoke", "dp4"):
        r = results[name]
        print(f"runtime_{name}_steps_per_s_fused,"
              f"{1e6 / r['steps_per_s_fused']:.0f},"
              f"{r['steps_per_s_fused']:.3f} steps/s")
        print(f"runtime_{name}_steps_per_s_legacy,"
              f"{1e6 / r['steps_per_s_legacy']:.0f},"
              f"{r['steps_per_s_legacy']:.3f} steps/s")
        print(f"runtime_{name}_speedup,{r['speedup_fused_vs_legacy']:.2f},"
              f"fused vs per-leaf on {r['host_devices']} device(s)")
        print(f"runtime_{name}_collectives_per_phase,"
              f"{max(r['collectives_per_phase_fused'])},"
              f"legacy per-leaf "
              f"{max(r['collectives_per_phase_legacy_per_leaf'])}")
        print(f"runtime_{name}_update_phase_ms,"
              f"{r['update_phase_ms_flat'] * 1e3:.0f},"
              f"flat {r['update_phase_ms_flat']:.1f}ms vs per-leaf "
              f"{r['update_phase_ms_legacy_per_leaf']:.1f}ms "
              f"({r['update_phase_speedup_flat_vs_per_leaf']:.2f}x) / "
              f"tree {r['update_phase_ms_tree']:.1f}ms "
              f"({r['update_phase_speedup_flat_vs_tree']:.2f}x)")
    for gran, u in results["update_path"].items():
        print(f"update_path_{gran}_apply_ms,"
              f"{u['apply_ms_flat'] * 1e3:.0f},"
              f"flat {u['apply_ms_flat']:.2f}ms vs per-leaf "
              f"{u['apply_ms_per_leaf']:.2f}ms "
              f"({u['speedup_flat_vs_per_leaf']:.2f}x, "
              f"{u['n_leaves']} leaves -> {u['n_buckets']} buckets)")
    s = results["solver"]
    print(f"solver_plan_us_memoized,{s['plan_s_memoized'] * 1e6:.0f},"
          f"{s['speedup']:.1f}x vs unmemoized "
          f"({s['plan_s_unmemoized'] * 1e3:.0f} ms)")
    print(f"# BENCH_runtime.json written in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--inner":
        json.dump(_inner(int(sys.argv[2])), sys.stdout)
        print()
    else:
        run()
