"""Render the EXPERIMENTS.md §Roofline markdown table from the dry-run
JSONs.

    PYTHONPATH=src:. python -m benchmarks.roofline_table [--update]

``--update`` splices the table into EXPERIMENTS.md at the
``<!-- ROOFLINE TABLE -->`` marker.
"""
from __future__ import annotations

import argparse
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN_DIR = ROOT / "experiments" / "dryrun"
SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def build_table() -> str:
    rows = []
    skips = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(p.read_text())
        if "skip" in d:
            skips.append(d)
            continue
        # hillclimb variants carry their --opt suffix in the filename
        stem = p.stem
        for token in stem.split("_"):
            if token.startswith(("sharded-decode", "dp-only", "microbatch")):
                d["mode"] = d["mode"] + "+" + token
        rows.append(d)
    rows.sort(key=lambda d: (d["mesh"], d["arch"], SHAPE_ORDER.get(d["shape"], 9),
                             d["mode"]))
    lines = [
        "| arch | shape | mode | mesh | t_compute (ms) | t_memory (ms) | "
        "t_collective (ms) | bound | useful (6ND/HLO) | mem/dev (GiB) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in rows:
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mode']} | {d['mesh']} | "
            f"{d['t_compute']*1e3:.2f} | {d['t_memory']*1e3:.2f} | "
            f"{d['t_collective']*1e3:.2f} | {d['dominant']} | "
            f"{d['useful_flops_ratio']:.3f} | "
            f"{d['bytes_per_device']/2**30:.2f} |"
        )
    seen = set()
    for d in skips:
        key = (d["arch"], d["shape"])
        if key in seen:
            continue
        seen.add(key)
        lines.append(f"| {d['arch']} | {d['shape']} | — | — | — | — | — | "
                     f"SKIP: {d['skip']} | — | — |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true")
    args = ap.parse_args()
    table = build_table()
    print(table)
    if args.update:
        exp = ROOT / "EXPERIMENTS.md"
        text = exp.read_text()
        marker = "<!-- ROOFLINE TABLE -->"
        assert marker in text
        pre = text.split(marker)[0]
        post = text.split(marker, 1)[1]
        # drop any previously spliced table (up to the next section header)
        tail = post.split("\n## ", 1)
        rest = ("\n## " + tail[1]) if len(tail) > 1 else ""
        exp.write_text(pre + marker + "\n\n" + table + "\n" + rest)
        print(f"\n(updated {exp})")


if __name__ == "__main__":
    main()
