"""Paper Fig. 14 analog: relative speedup vs worker count (4..32) per
scheduler.  Speedup = single-worker throughput x N / simulated iteration
time (the 'Linear' line is N)."""
from __future__ import annotations

from benchmarks.common import REGIMES, emit, hw_for, run_all_schedulers
from repro.configs import get_config
from repro.core.profiler import profile_arch


def run() -> None:
    regime = REGIMES[1]  # ResNet-like
    cfg = get_config(regime.arch)
    for dp in (4, 8, 16, 32):
        hw = hw_for(regime, dp=dp)
        prof = profile_arch(cfg, hw=hw, seq_len=regime.seq_len,
                            per_device_batch=1)
        compute = prof.times.fwd_total + prof.times.bwd_total
        results = run_all_schedulers(prof.times)
        for name, r in results.items():
            speedup = dp * compute / r.iteration_time
            emit(
                f"fig14/dp{dp}/{name}", r.iteration_time * 1e6,
                f"speedup={speedup:.1f}x linear={dp}x "
                f"efficiency={speedup/dp:.2f}",
            )


if __name__ == "__main__":
    run()
