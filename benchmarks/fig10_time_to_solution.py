"""Paper Fig. 10 analog: time-to-solution for the four schedulers in the
three regimes.

Wall-time per iteration comes from the discrete-event simulator; the loss
trajectory comes from the Preserver's Gaussian-walk model rolled out with
each scheduler's actual update pattern (DeFT applies fewer, k-merged
updates).  The product is a loss-vs-wall-clock curve — the shape of the
paper's Fig. 10 without a GPU cluster."""
from __future__ import annotations

from benchmarks.common import REGIMES, emit, profile_regime, run_all_schedulers
from repro.core.preserver import WalkParams, expected_next_state
from repro.core.scheduler import DeftScheduler, SchedulerConfig, extract_schedule

TARGET_FRACTION = 0.25   # "solution" = loss reduced to 25% of initial
HORIZON = 4000           # iterations simulated


def time_to_solution(iter_time: float, batch_mults, walk: WalkParams) -> float:
    """Roll the walk with one update per entry of the repeating
    ``batch_mults`` pattern; each pattern period costs ``period`` x
    iter_time wall seconds."""
    s = walk.s0
    target = walk.s0 * TARGET_FRACTION + walk.s_star
    t = 0.0
    it = 0
    while it < HORIZON:
        for k in batch_mults:
            s = expected_next_state(s, float(k), walk)
            it += k
            t = it * iter_time
            if s <= target:
                return t
    return float("inf")


def run() -> None:
    walk = WalkParams(s0=6.0, s_star=1.0, eta=0.02, mu=1.0, sigma=60.0,
                      batch=256)
    for regime in REGIMES:
        prof = profile_regime(regime)
        results = run_all_schedulers(prof.times)
        # update patterns: baselines update every iteration (k=1)
        plans = DeftScheduler(prof.times, SchedulerConfig()).run(48)
        sched = extract_schedule(plans, prof.times.n)
        patterns = {name: (1,) for name in results if name != "deft"}
        patterns["deft"] = sched.batch_size_sequence or (1,)
        tts = {}
        for name, r in results.items():
            tts[name] = time_to_solution(r.iteration_time, patterns[name],
                                         walk)
        base = tts["pytorch-ddp"]
        for name, r in results.items():
            emit(
                f"fig10/{regime.name}/{name}", r.iteration_time * 1e6,
                f"iter={r.iteration_time*1e3:.1f}ms "
                f"bubble={r.bubble_fraction:.2f} tts={tts[name]:.0f}s "
                f"speedup_vs_ddp={base/max(tts[name],1e-9):.2f}x",
            )


if __name__ == "__main__":
    run()
