"""Elastic control-plane benchmark: fault -> detection -> Preserver-gated
scale-down -> cycle-boundary repack, plus the symmetric scale-up.

Two deterministic fault scenarios replay through the SAME
:class:`repro.elastic.FaultScenario` / :class:`HealthMonitor` /
:class:`ElasticController` objects the chaos tests drive:

* a **device drop** (2 of 4 data shards vanish) — measures the
  heartbeat-timeout detection latency and the re-priced 2-shard plan;
* a **straggler** (one shard runs ``STRAGGLER_FACTOR`` x slow) — measures
  the EWMA-ratio detection latency and the throughput recovered by
  planning the slow shard out of the mesh.

Per-step wall times come from the same steady-state timeline model the
adapt bench uses (this container has no device that can actually die),
so detection latencies and steps/s are bit-for-bit reproducible.  The
migration cost is NOT modeled: a miniature smoke-config runtime pair
runs a real ``migrate_state`` (accumulator fold -> device transfer ->
``repack_state``) on the local device set and reports measured
milliseconds.  Emits ``BENCH_elastic.json`` (schema: bench_schema.py).
"""
from __future__ import annotations

import json
import os
import time

_OUT = os.environ.get("BENCH_ELASTIC_OUT", "BENCH_elastic.json")
_STEPS = int(os.environ.get("BENCH_ELASTIC_STEPS", "64"))
N_SHARDS = 4
DROP_STEP = 12
DROP_SHARDS = (2, 3)
STRAGGLER_SHARD = 1
STRAGGLER_FACTOR = 3.0
CR = 1.8
GLOBAL_BATCH = 16
SEQ = 512
PARTITION_ELEMS = 6_500_000


def _measure_migrate() -> dict:
    """Real measured migration between two smoke-config runtimes on the
    local device set: fold (no-op at equal width) + device_put +
    ``repack_state`` across a partition change, both directions."""
    import jax

    from repro.configs import get_config, reduce_for_smoke
    from repro.core.deft import feedback_solve
    from repro.core.preserver import WalkParams
    from repro.core.profiler import HardwareModel
    from repro.elastic import migrate_state
    from repro.launch.mesh import make_debug_mesh
    from repro.models.model import init_params
    from repro.optim.optimizers import adamw
    from repro.train.bucketing import (
        assign_buckets,
        build_bucket_layout,
        leaf_bucket_times,
    )
    from repro.train.runtime import DeftRuntime

    cfg = reduce_for_smoke(get_config("gemma2-2b"))
    mesh = make_debug_mesh(data=1, model=1)
    params_abs = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0)
    )
    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)

    def plan(partition_elems):
        bo, nb = assign_buckets(params_abs, cfg, partition_elems)
        times = leaf_bucket_times(
            params_abs, cfg, bo, nb, HardwareModel(dp_degree=1), 64, 8
        )
        schedule, _, _, _ = feedback_solve(times, walk)
        return bo, nb, schedule

    bo_a, nb_a, sched_a = plan(200_000)
    bo_b, nb_b, sched_b = plan(420_000)
    with jax.set_mesh(mesh):
        layout_a = build_bucket_layout(params_abs, bo_a, nb_a, shard_count=1)
        layout_b = build_bucket_layout(params_abs, bo_b, nb_b, shard_count=1)
        rt_a = DeftRuntime(cfg, adamw(1e-3), sched_a, layout_a, mesh)
        rt_b = rt_a.spawn(schedule=sched_b, layout=layout_b)
        state = rt_a.init_state(jax.random.PRNGKey(0))

        def timed_roundtrip():
            nonlocal state
            t0 = time.perf_counter()
            state = migrate_state(rt_a, rt_b, state)
            jax.block_until_ready(jax.tree_util.tree_leaves(state))
            ab = time.perf_counter() - t0
            t0 = time.perf_counter()
            state = migrate_state(rt_b, rt_a, state)
            jax.block_until_ready(jax.tree_util.tree_leaves(state))
            return ab, time.perf_counter() - t0

        # rep 1 pays the repack jit; rep 2 is the steady cost a live
        # migration would re-pay only on a never-seen transition
        first_ab, first_ba = timed_roundtrip()
        warm_ab, warm_ba = timed_roundtrip()
    return {
        "n_buckets_a": nb_a,
        "n_buckets_b": nb_b,
        "total_elems": layout_a.total_elems,
        "migrate_ms_a_to_b": warm_ab * 1e3,
        "migrate_ms_b_to_a": warm_ba * 1e3,
        "migrate_ms_first_call": first_ab * 1e3,
        "first_ba_ms": first_ba * 1e3,
    }


def run() -> None:
    """Benchmark section entry point (benchmarks/run.py)."""
    import jax

    from repro.adapt.calibrate import schedule_plans, steady_phase_durations
    from repro.configs import get_config
    from repro.core.preserver import WalkParams
    from repro.core.profiler import HardwareModel
    from repro.elastic import (
        DeviceDrop,
        ElasticController,
        FaultScenario,
        HealthMonitor,
        StragglerSlowdown,
    )
    from repro.models.model import init_params
    from repro.train.bucketing import assign_buckets, build_leaf_time_model

    t0 = time.time()
    cfg = get_config("gemma2-2b")
    params_abs = jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0)
    )
    bucket_of, nb = assign_buckets(params_abs, cfg, PARTITION_ELEMS)

    def model_for(width):
        m = build_leaf_time_model(
            params_abs, cfg, HardwareModel(dp_degree=width), SEQ,
            max(GLOBAL_BATCH // width, 1),
        )
        return m.with_coverage_rate(bucket_of, nb, CR)

    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    controller = ElasticController(model_for, bucket_of, nb, walk=walk)

    def steps_per_s(plan, wall_factor=1.0):
        sc = plan.scheduler_cfg
        durs = steady_phase_durations(
            schedule_plans(plan.times, sc), plan.times, plan.schedule.period,
            mu=sc.mu, heterogeneous=sc.heterogeneous,
        )
        return plan.schedule.period / max(sum(durs) * wall_factor, 1e-12)

    # the healthy 4-shard plan every scenario starts from
    plan4 = controller.propose(0, N_SHARDS, "initial")
    base_wall = 1.0 / steps_per_s(plan4)

    def detect(scenario, kind):
        mon = HealthMonitor(N_SHARDS)
        for step in range(_STEPS):
            obs = scenario.observe(step, base_wall)
            for ev in mon.observe(step, list(obs.walls)):
                if ev.kind == kind:
                    return step
        return None

    drop = FaultScenario(N_SHARDS, (DeviceDrop(DROP_STEP, DROP_SHARDS),))
    straggle = FaultScenario(
        N_SHARDS,
        (StragglerSlowdown(DROP_STEP, STRAGGLER_SHARD, STRAGGLER_FACTOR),),
    )
    drop_detected = detect(drop, "dead")
    straggler_detected = detect(straggle, "straggler")

    # the Preserver-gated survival plans (what the coordinator executes)
    plan_down = controller.propose(drop_detected or DROP_STEP, 2, "dead")
    controller.adopt(plan_down)
    plan_up = controller.propose(_STEPS, N_SHARDS, "scale-up")

    sps_before = steps_per_s(plan4)
    # the fault window: the straggler gates every step's critical path
    # until its removal executes at the cycle boundary
    sps_during = steps_per_s(plan4, wall_factor=STRAGGLER_FACTOR)
    sps_after = steps_per_s(plan_down)
    migrate = _measure_migrate()

    def plan_dict(p):
        return {
            "n_shards": p.n_shards,
            "action": p.action,
            "period": p.schedule.period,
            "updates_per_period": p.schedule.updates_per_period,
            "preserver_ratio": p.verdict.ratio,
            "preserver_ok": p.verdict.ok,
            "plan_s": p.plan_s,
        }

    result = {
        "scenario": {
            "n_shards": N_SHARDS,
            "drop_step": DROP_STEP,
            "drop_shards": list(DROP_SHARDS),
            "straggler_shard": STRAGGLER_SHARD,
            "straggler_factor": STRAGGLER_FACTOR,
            "coverage_rate": CR,
            "steps": _STEPS,
        },
        "initial_plan": plan_dict(plan4),
        "detection": {
            "device_drop_step": drop_detected,
            "device_drop_latency_steps":
                None if drop_detected is None else drop_detected - DROP_STEP,
            "straggler_step": straggler_detected,
            "straggler_latency_steps":
                None if straggler_detected is None
                else straggler_detected - DROP_STEP,
        },
        "steps_per_s_before_fault": sps_before,
        "steps_per_s_during_fault": sps_during,
        "steps_per_s_after_repack": sps_after,
        "after_over_during_fault": sps_after / max(sps_during, 1e-12),
        "scale_down_plan": plan_dict(plan_down),
        "scale_up_plan": plan_dict(plan_up),
        "repack": migrate,
    }
    tmp = _OUT + ".tmp"
    json.dump(result, open(tmp, "w"), indent=1)
    os.replace(tmp, _OUT)

    print(f"elastic_detect_drop_steps,{(drop_detected or 0) - DROP_STEP},"
          f"heartbeat-timeout latency (4 shards, 2 dead)")
    print(f"elastic_detect_straggler_steps,"
          f"{(straggler_detected or 0) - DROP_STEP},"
          f"EWMA-ratio latency ({STRAGGLER_FACTOR}x slow shard)")
    print(f"elastic_steps_per_s_before,{1e6 / max(sps_before, 1e-12):.0f},"
          f"{sps_before:.3f} steps/s (healthy 4-shard plan)")
    print(f"elastic_steps_per_s_during,{1e6 / max(sps_during, 1e-12):.0f},"
          f"{sps_during:.3f} steps/s (straggler-gated)")
    print(f"elastic_steps_per_s_after,{1e6 / max(sps_after, 1e-12):.0f},"
          f"{sps_after:.3f} steps/s (repacked 2-shard plan, "
          f"preserver ratio {plan_down.verdict.ratio:.4f})")
    print(f"elastic_migrate_us,{migrate['migrate_ms_a_to_b'] * 1e3:.0f},"
          f"measured fold+transfer+repack "
          f"{migrate['migrate_ms_a_to_b']:.1f}ms "
          f"({migrate['n_buckets_a']}->{migrate['n_buckets_b']} buckets, "
          f"{migrate['total_elems']:,} elems)")
    print(f"# BENCH_elastic.json written in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    run()
