"""Paper Fig. 15 analog: throughput under shrinking interconnect
bandwidth (the paper throttled 40 Gbps Ethernet to 10/20/30/40)."""
from __future__ import annotations

from benchmarks.common import emit, run_all_schedulers
from repro.configs import get_config
from repro.core.profiler import HardwareModel, profile_arch

# bandwidths chosen so the profiled CR sweeps the paper's regimes
# (CR ~ 8 / 4 / 2 / 1 -- the paper's 10..40 Gbps sweep on VGG-19)
BWS = (1.5e9, 3.0e9, 6.0e9, 1.2e10)


def run() -> None:
    cfg = get_config("gemma2-2b")
    for bw in BWS:
        hw = HardwareModel(dp_degree=16, ici_bw=bw)
        prof = profile_arch(cfg, hw=hw, seq_len=4096, per_device_batch=1)
        results = run_all_schedulers(prof.times)
        base = results["pytorch-ddp"].iteration_time
        for name, r in results.items():
            emit(
                f"fig15/bw{bw/1e9:.1f}GBps/{name}",
                r.iteration_time * 1e6,
                f"CR={prof.times.coverage_rate:.2f} "
                f"iter={r.iteration_time*1e3:.1f}ms "
                f"speedup_vs_ddp={base/r.iteration_time:.2f}x "
                f"upd/iter={r.updates_per_iteration:.2f}",
            )


if __name__ == "__main__":
    run()
