"""Paper Table IV analog: all-reduce time on the primary vs secondary
link, multi-link vs single-link (contention) across tensor sizes.

The paper measured NCCL vs gloo over one or two NICs; the TPU adaptation
models the secondary path at 1/mu of ICI speed and single-link contention
as serialized transfers (paper: gloo slows ~20% when sharing the NIC —
here the two transfers share one link's bandwidth exactly)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.profiler import HardwareModel

SIZES = (4_194_304, 8_388_608, 16_777_216, 33_554_432, 67_108_864)


def run() -> None:
    hw = HardwareModel(dp_degree=16)
    for n in SIZES:
        t_p = hw.allreduce_time(n)
        t_s = hw.allreduce_time(n, link_bw=hw.secondary_bw)
        # multi-link: both proceed concurrently -> max; single-link: share
        multi = max(t_p, t_s)
        single = t_p + t_s
        emit(
            f"table4/size{n}", t_p * 1e6,
            f"primary={t_p*1e3:.2f}ms secondary={t_s*1e3:.2f}ms "
            f"ratio={t_s/t_p:.2f} multi_link={multi*1e3:.2f}ms "
            f"single_link={single*1e3:.2f}ms "
            f"contention_penalty={single/multi:.2f}x",
        )


if __name__ == "__main__":
    run()
