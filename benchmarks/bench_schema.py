"""Machine-readable BENCH_*.json key schemas.

The perf trajectory across PRs is tracked by the benchmark emitters
(runtime_bench, adapt_bench); this module pins the key sets those files
must contain so an emitter refactor cannot silently drop or rename a
metric.  ``scripts/check_bench_schema.py`` runs the validation from CI
after the smoke benchmark job; tests/test_bench_schema.py validates the
checked-in files at the repo root.

A schema is a nested dict: leaf ``None`` means "key must exist" (any
value), a dict means "key must exist and hold a mapping with at least
these keys".  Extra keys are allowed — the schema is a floor, not a
straitjacket, so emitters can grow without breaking older checkers.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

_RUNTIME_SCENARIO = {
    "host_devices": None,
    "model": {"name": None, "params": None, "n_leaves": None,
              "n_buckets": None},
    "schedule": {"period": None, "updates_per_period": None},
    "engine": {"flat_state": None, "update_impl": None},
    "steps_timed": None,
    "steps_per_s_fused": None,
    "steps_per_s_fused_tree": None,
    "steps_per_s_legacy": None,
    "speedup_fused_vs_legacy": None,
    "compile_s_fused_aot": None,
    "update_phase_ms_flat": None,
    "update_phase_ms_tree": None,
    "update_phase_ms_legacy_per_leaf": None,
    "update_phase_speedup_flat_vs_per_leaf": None,
    "update_phase_speedup_flat_vs_tree": None,
    "collectives_per_phase_fused": None,
    "collectives_per_phase_legacy_per_leaf": None,
}

_UPDATE_PATH_GRANULARITY = {
    "n_leaves": None,
    "n_buckets": None,
    "total_elems": None,
    "apply_ms_flat": None,
    "apply_ms_per_leaf": None,
    "speedup_flat_vs_per_leaf": None,
}

_FSDP_FLAT_SCENARIO = {
    "host_devices": None,
    "mesh": {"pod": None, "data": None, "model": None},
    "model": {"name": None, "params": None, "n_leaves": None,
              "n_buckets": None},
    "schedule": {"period": None, "updates_per_period": None},
    "engine": {"flat_state": None, "sharded_state": None, "shards": None,
               "update_impl": None},
    "steps_timed": None,
    "compile_s_fused_aot": None,
    "steps_per_s_sharded": None,
    "steps_per_s_replicated_flat": None,
    "update_phase_ms_sharded": None,
    "update_phase_ms_replicated_flat": None,
    "update_path_sharded": {
        "n_leaves": None,
        "n_buckets": None,
        "shard_count": None,
        "total_elems": None,
        "apply_ms_flat_shard": None,
        "apply_ms_per_leaf_shard": None,
        "speedup_flat_vs_per_leaf": None,
    },
}

_DECOUPLED_SCENARIO = {
    "host_devices": None,
    "mesh": {"pod": None, "data": None, "model": None},
    "model": {"name": None, "params": None, "n_leaves": None,
              "n_buckets": None},
    "schedule": {"period": None, "updates_per_period": None},
    "engine": {"flat_state": None, "sharded_state": None, "shards": None,
               "decoupled": None},
    "steps_timed": None,
    "compile_s_decoupled_aot": None,
    "steps_per_s_fused": None,
    "steps_per_s_decoupled": None,
    "steps_per_s_ratio_decoupled_vs_fused": None,
    "sim": {
        "iteration_time_fused_burst": None,
        "iteration_time_decoupled_streamed": None,
        "coverage_fused": None,
        "coverage_decoupled": None,
        "ag_stall_s_streamed": None,
        "ag_plan_coverage": None,
        "ag_plan_items": None,
    },
    "ag_burst_bytes_fused": None,
    "ag_burst_bytes_decoupled_peak": None,
    "ag_burst_bytes_delta": None,
}

_PRECISION_SCENARIO = {
    "host_devices": None,
    "model": {"name": None, "params": None, "n_leaves": None,
              "n_buckets": None},
    "schedule": {"period": None, "updates_per_period": None},
    "engine": {"flat_state": None, "wire_precision": None,
               "master_dtype": None},
    "steps_timed": None,
    "compile_s_mixed_aot": None,
    "steps_per_s_f32": None,
    "steps_per_s_mixed": None,
    "steps_per_s_ratio_mixed_vs_f32": None,
    "sim": {
        "iteration_time_f32": None,
        "iteration_time_mixed": None,
        "coverage_f32": None,
        "coverage_mixed": None,
        "wire_bytes_scale_mixed": None,
        "gate_ok_mixed": None,
        "ladder_candidates": None,
    },
    "wire_bytes_per_cycle_f32": None,
    "wire_bytes_per_cycle_mixed": None,
}

_TWO_LINK_SCENARIO = {
    "host_devices": None,
    "mesh": {"data": None, "model": None},
    "model": {"name": None, "params": None, "n_leaves": None,
              "n_buckets": None},
    "schedule": {"period": None, "updates_per_period": None,
                 "secondary_slots_planned": None,
                 "secondary_slots_forced": None,
                 "ag_items": None, "ag_items_link1_planned": None},
    "engine": {"flat_state": None, "sharded_state": None, "shards": None,
               "decoupled": None, "secondary_chain": None},
    "steps_timed": None,
    "compile_s_chain_aot": None,
    "steps_per_s_single_axis": None,
    "steps_per_s_chain": None,
    "steps_per_s_ratio_chain_vs_single_axis": None,
    "sim": {
        "mu": None,
        "iteration_time_single_link": None,
        "iteration_time_two_link": None,
        "coverage_single_link": None,
        "coverage_two_link": None,
    },
    "wire_bytes_primary_per_cycle": None,
    "wire_bytes_secondary_per_cycle": None,
    "wire_split_max_abs_error": None,
    "wire_split_ok": None,
}

_REPACK = {
    "n_buckets_a": None,
    "n_buckets_b": None,
    "total_elems": None,
    "moved_elems_a_to_b": None,
    "repack_ms_a_to_b": None,
    "repack_ms_b_to_a": None,
    "update_phase_apply_ms": None,
    "repack_over_update_apply": None,
    "step_ms_smoke": None,
    "amortized_overhead_at_replan_every_100_steps": None,
}

_ELASTIC_PLAN = {
    "n_shards": None,
    "action": None,
    "period": None,
    "updates_per_period": None,
    "preserver_ratio": None,
    "preserver_ok": None,
    "plan_s": None,
}

SCHEMAS: Dict[str, Dict[str, Any]] = {
    "BENCH_runtime.json": {
        "solver": {
            "n_buckets": None,
            "plan_s_unmemoized": None,
            "plan_s_memoized": None,
            "speedup": None,
            "cache_hits": None,
            "cache_misses": None,
        },
        "repack": _REPACK,
        "update_path": {
            "smoke_config": _UPDATE_PATH_GRANULARITY,
            "paper_leafcount": _UPDATE_PATH_GRANULARITY,
        },
        "smoke": _RUNTIME_SCENARIO,
        "dp4": _RUNTIME_SCENARIO,
        "fsdp_flat": _FSDP_FLAT_SCENARIO,
        "decoupled": _DECOUPLED_SCENARIO,
        "precision": _PRECISION_SCENARIO,
        "two_link": _TWO_LINK_SCENARIO,
    },
    "BENCH_adapt.json": {
        "scenario": {"drop_step": None, "drop_scale": None,
                     "coverage_rate": None, "steps": None},
        "initial_plan": {"period": None, "updates_per_period": None,
                         "batch_seq": None, "preserver_ratio": None},
        "steps_per_s_before_drop": None,
        "steps_per_s_static_after_drop": None,
        "steps_per_s_adaptive_after_drop": None,
        "adaptive_over_static_after_drop": None,
        "detection_latency_steps": None,
        "replan_events": None,
        "knapsack_cache_trail": None,
    },
    "BENCH_obs.json": {
        "scenario": {"drop_step": None, "drop_scale": None,
                     "coverage_rate": None, "steps": None},
        "closure": {"sim_iteration_time": None, "span_iteration_time": None,
                    "iteration_time_exact": None, "sim_bubble_fraction": None,
                    "span_bubble_fraction": None, "bubble_abs_error": None,
                    "planned_cr": None, "measured_cr": None,
                    "cr_error": None, "n_spans": None},
        "attribution": {"comp_scale": None, "comm_scale": None,
                        "max_divergence": None, "cr_error": None,
                        "bubble_fraction": None,
                        "capacity_utilization": None},
        "divergence_lead": {"ema_replan_step": None,
                            "divergence_replan_step": None,
                            "lead_steps": None},
        "tracing": {"steps_timed": None, "steps_per_s_plain": None,
                    "steps_per_s_traced": None, "overhead_pct": None,
                    "spans_recorded": None, "span_kinds": None},
    },
    "BENCH_elastic.json": {
        "scenario": {"n_shards": None, "drop_step": None,
                     "drop_shards": None, "straggler_shard": None,
                     "straggler_factor": None, "coverage_rate": None,
                     "steps": None},
        "initial_plan": _ELASTIC_PLAN,
        "detection": {"device_drop_step": None,
                      "device_drop_latency_steps": None,
                      "straggler_step": None,
                      "straggler_latency_steps": None},
        "steps_per_s_before_fault": None,
        "steps_per_s_during_fault": None,
        "steps_per_s_after_repack": None,
        "after_over_during_fault": None,
        "scale_down_plan": _ELASTIC_PLAN,
        "scale_up_plan": _ELASTIC_PLAN,
        "repack": {"n_buckets_a": None, "n_buckets_b": None,
                   "total_elems": None, "migrate_ms_a_to_b": None,
                   "migrate_ms_b_to_a": None},
    },
}


def _walk(schema: Dict[str, Any], data: Any, prefix: str,
          errors: List[str]) -> None:
    if not isinstance(data, dict):
        errors.append(f"{prefix or '<root>'}: expected a mapping, "
                      f"got {type(data).__name__}")
        return
    for key, sub in schema.items():
        path = f"{prefix}.{key}" if prefix else key
        if key not in data:
            errors.append(f"missing key: {path}")
            continue
        if isinstance(sub, dict):
            _walk(sub, data[key], path, errors)


def validate_data(name: str, data: Any) -> List[str]:
    """Validate a parsed BENCH payload against its schema by file name.
    Returns a list of human-readable problems (empty = valid)."""
    if name not in SCHEMAS:
        return [f"no schema registered for {name!r} "
                f"(known: {sorted(SCHEMAS)})"]
    errors: List[str] = []
    _walk(SCHEMAS[name], data, "", errors)
    return errors


def validate_file(path: str) -> List[str]:
    """Validate a BENCH_*.json file on disk (schema chosen by basename)."""
    import os

    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return [f"{path}: file not found"]
    except json.JSONDecodeError as e:
        return [f"{path}: invalid json ({e})"]
    return [f"{path}: {e}"
            for e in validate_data(os.path.basename(path), data)]
