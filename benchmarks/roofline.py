"""Roofline table assembly: reads experiments/dryrun/*.json (written by
repro.launch.dryrun) and emits the per-(arch x shape x mode) roofline
terms.  Run the dry-run sweep first; missing combos are reported."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit

DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def rows():
    if not DRYRUN_DIR.exists():
        return []
    out = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(p.read_text())
        d["_file"] = p.name
        out.append(d)
    return out


def run() -> None:
    rs = rows()
    if not rs:
        emit("roofline/missing", 0,
             "run PYTHONPATH=src python -m repro.launch.dryrun --all first")
        return
    for d in rs:
        if "skip" in d:
            emit(f"roofline/{d['arch']}/{d['shape']}", 0, f"SKIP {d['skip']}")
            continue
        emit(
            f"roofline/{d['arch']}/{d['shape']}/{d['mode']}/{d['mesh']}",
            d.get("wall_seconds", 0) * 1e6,
            f"compute={d['t_compute']*1e3:.2f}ms "
            f"memory={d['t_memory']*1e3:.2f}ms "
            f"collective={d['t_collective']*1e3:.2f}ms "
            f"dominant={d['dominant']} useful={d['useful_flops_ratio']:.2f} "
            f"mem/dev={d['bytes_per_device']/2**30:.2f}GiB",
        )


if __name__ == "__main__":
    run()
