"""Shared benchmark plumbing.

The paper's testbed is 16xA100 over 40 Gbps Ethernet.  Its regimes are
reproduced on the assignment's TPU-v5e hardware model by scaling the
interconnect bandwidth so the coverage rate (CR = T_comm / T_compute)
lands where the paper's benchmarks landed:

    VGG-19-like    CR ~ 2.0   (param-heavy, cheap compute)
    ResNet-101-like CR ~ 1.4
    GPT-2-like     CR ~ 1.0

Each regime is an (assigned arch, bandwidth) pair so every number still
flows through the real Profiler -> Solver -> Simulator pipeline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs import get_config
from repro.core.bucket import BucketTimes
from repro.core.deft import plan_deft
from repro.core.policies import ALL_BASELINES
from repro.core.profiler import HardwareModel, profile_arch
from repro.core.scheduler import DeftScheduler, SchedulerConfig
from repro.core.simulator import SimResult, simulate_baseline, simulate_deft


@dataclasses.dataclass(frozen=True)
class Regime:
    name: str          # paper benchmark this regime mirrors
    arch: str          # assigned architecture that carries it
    ici_bw: float      # interconnect bytes/s that lands the target CR
    seq_len: int = 4096


REGIMES = (
    Regime("vgg19-like(CR~2)", "gemma2-2b", 1.55e9),
    Regime("resnet101-like(CR~1.4)", "gemma2-2b", 2.2e9),
    Regime("gpt2-like(CR~1)", "qwen3-4b", 4.5e9),
)


def hw_for(regime: Regime, dp: int = 16, mu: float = 1.65) -> HardwareModel:
    return HardwareModel(dp_degree=dp, ici_bw=regime.ici_bw, mu=mu)


def profile_regime(
    regime: Regime,
    dp: int = 16,
    partition_elems: int = 6_500_000,
    strategy: str = "deft",
):
    cfg = get_config(regime.arch)
    hw = hw_for(regime, dp)
    return profile_arch(
        cfg, hw=hw, seq_len=regime.seq_len, per_device_batch=1,
        partition_strategy=strategy, partition_elems=partition_elems,
    )


def deft_with_preserver(
    times: BucketTimes,
    mu: float = 1.65,
    heterogeneous: bool = True,
    eps: float = 0.01,
    max_retries: int = 10,
) -> Tuple[list, SchedulerConfig]:
    """Solver + Preserver feedback (paper Fig. 7): the schedule the
    benchmarks simulate is the accuracy-checked one, not the raw solver
    output — update frequency cannot collapse just to win throughput."""
    from repro.core.deft import solve_schedule
    from repro.core.preserver import WalkParams, check_schedule

    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    factor = 1.0
    for _ in range(max_retries + 1):
        scfg = SchedulerConfig(heterogeneous=heterogeneous, mu=mu,
                               capacity_factor=factor)
        sched = solve_schedule(times, scfg)
        if check_schedule(sched.batch_size_sequence, sched.period, walk,
                          eps=eps).ok:
            break
        factor *= 1.2
    plans = DeftScheduler(times, scfg).run(48)
    return plans, scfg


def run_all_schedulers(
    times: BucketTimes,
    mu: float = 1.65,
    heterogeneous: bool = True,
) -> Dict[str, SimResult]:
    out: Dict[str, SimResult] = {}
    for name, mk in ALL_BASELINES.items():
        out[name] = simulate_baseline(times, mk(times))
    plans, scfg = deft_with_preserver(times, mu=mu,
                                      heterogeneous=heterogeneous)
    out["deft"] = simulate_deft(times, plans, mu=mu,
                                heterogeneous=heterogeneous)
    return out


def timed(fn: Callable, *args, **kw) -> Tuple[object, float]:
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.0f},{derived}")
