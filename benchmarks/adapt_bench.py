"""Adaptive control plane benchmark: static plan vs online replanning
under an injected mid-run bandwidth degradation.

The scenario: a job planned at the nominal interconnect bandwidth loses
``DROP_SCALE``x of it at step ``DROP_STEP`` (congestion, a flaky link, a
mis-modeled HardwareModel).  The *static* run keeps executing the stale
schedule; the *adaptive* run feeds per-phase telemetry to the
``AdaptiveController``, which detects the drift, re-calibrates, replans
under the Preserver gate and hot-swaps the schedule.

Wall-clock per iteration comes from the same discrete-event timeline
model the paper-figure benchmarks use (this container has no degradable
link), so the whole benchmark is deterministic.  Emits
``BENCH_adapt.json`` with steps/s before/after the drop for both runs,
the replan-event trail, and the knapsack memo-cache hit counters across
consecutive replans.
"""
from __future__ import annotations

import json
import os
import time

_OUT = os.environ.get("BENCH_ADAPT_OUT", "BENCH_adapt.json")
_STEPS = int(os.environ.get("BENCH_ADAPT_STEPS", "160"))
DROP_STEP = 60
DROP_SCALE = 3.0
CR = 1.8


def _profile():
    """Paper-scale bucket profile (gemma2-2b leaf-free analytic)."""
    from repro.configs import get_config
    from repro.core.bucket import BucketTimes
    from repro.core.profiler import HardwareModel, profile_arch

    hw = HardwareModel(dp_degree=16)
    prof = profile_arch(get_config("gemma2-2b"), hw=hw, seq_len=4096)
    t = prof.times
    scale = CR * (t.fwd_total + t.bwd_total) / max(t.comm_total, 1e-12)
    return BucketTimes(t.fwd, t.bwd, tuple(c * scale for c in t.comm))


def run() -> None:
    """Benchmark section entry point (benchmarks/run.py)."""
    from repro.adapt import (
        AdaptiveController,
        BandwidthDrop,
        SyntheticTelemetrySource,
        run_control_loop,
        scale_times,
        schedule_plans,
        steady_phase_durations,
    )
    from repro.core.deft import feedback_solve
    from repro.core.knapsack import (
        clear_knapsack_caches,
        knapsack_cache_info,
    )
    from repro.core.preserver import WalkParams

    t0 = time.time()
    times = _profile()
    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    schedule, verdict, scfg, _ = feedback_solve(times, walk)
    degraded = scale_times(times, 1.0, DROP_SCALE)

    def steps_per_s(solve_times, sc, period, run_times):
        durs = steady_phase_durations(
            schedule_plans(solve_times, sc), run_times, period,
            mu=sc.mu, heterogeneous=sc.heterogeneous,
        )
        return period / max(sum(durs), 1e-12)

    # ---- static run: the stale schedule rides out the degradation ----
    sps_before = steps_per_s(times, scfg, schedule.period, times)
    sps_static_after = steps_per_s(times, scfg, schedule.period, degraded)

    # ---- adaptive run: telemetry -> drift -> replan -> hot-swap ------
    clear_knapsack_caches()
    src = SyntheticTelemetrySource(
        times, BandwidthDrop(step=DROP_STEP, comm_scale=DROP_SCALE)
    )
    ctrl = AdaptiveController(times, schedule, scfg, walk=walk)
    events = []
    cache_trail = []

    def on_event(event):
        info = knapsack_cache_info()
        cache_trail.append(
            {"step": event.step, "hits": info.hits, "misses": info.misses}
        )
        events.append(
            {"step": event.step, "trigger": event.trigger,
             "comp_scale": event.profile.comp_scale,
             "comm_scale": event.profile.comm_scale,
             "coverage_delta": event.coverage_delta,
             "period": [event.old_period, event.new_period],
             "batch_seq": [list(event.old_batch_seq),
                           list(event.new_batch_seq)],
             "preserver_ratio": event.verdict.ratio,
             "preserver_ok": event.verdict.ok,
             "changed": event.changed,
             "replan_s": event.replan_s}
        )

    run_control_loop(ctrl, src, _STEPS, on_event=on_event)
    replan_wall = sum(e["replan_s"] for e in events)
    sps_adaptive_after = steps_per_s(
        ctrl.times, ctrl.scheduler_cfg, ctrl.schedule.period, degraded
    )

    detection = next(
        (e["step"] - DROP_STEP for e in events
         if e["step"] >= DROP_STEP and e["trigger"] == "timing-drift"),
        None,
    )
    result = {
        "scenario": {"drop_step": DROP_STEP, "drop_scale": DROP_SCALE,
                     "coverage_rate": CR, "steps": _STEPS},
        "initial_plan": {
            "period": schedule.period,
            "updates_per_period": schedule.updates_per_period,
            "batch_seq": list(schedule.batch_size_sequence),
            "preserver_ratio": verdict.ratio,
        },
        "steps_per_s_before_drop": sps_before,
        "steps_per_s_static_after_drop": sps_static_after,
        "steps_per_s_adaptive_after_drop": sps_adaptive_after,
        "adaptive_over_static_after_drop":
            sps_adaptive_after / max(sps_static_after, 1e-12),
        "detection_latency_steps": detection,
        "replan_wall_s_total": replan_wall,
        "replan_events": events,
        "knapsack_cache_trail": cache_trail,
    }
    tmp = _OUT + ".tmp"
    json.dump(result, open(tmp, "w"), indent=1)
    os.replace(tmp, _OUT)

    print(f"adapt_steps_per_s_before,{1e6 / max(sps_before, 1e-12):.0f},"
          f"{sps_before:.3f} steps/s (planned bandwidth)")
    print(f"adapt_steps_per_s_static_after,"
          f"{1e6 / max(sps_static_after, 1e-12):.0f},"
          f"{sps_static_after:.3f} steps/s (stale plan, degraded link)")
    print(f"adapt_steps_per_s_adaptive_after,"
          f"{1e6 / max(sps_adaptive_after, 1e-12):.0f},"
          f"{sps_adaptive_after:.3f} steps/s (replanned, degraded link)")
    print(f"adapt_speedup_after_drop,"
          f"{result['adaptive_over_static_after_drop']:.2f},"
          f"adaptive vs static with {len(events)} replan event(s), "
          f"detection latency "
          f"{detection if detection is not None else 'n/a'} steps")
    print(f"# BENCH_adapt.json written in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    run()
