"""Paper Table I analog: forward/backward/communication time + coverage
rate, for the paper's three regimes AND every assigned architecture under
the production hardware model.

``--measured`` additionally runs each regime's accuracy-checked DeFT
schedule through the discrete-event simulator and reads the coverage
rate back from the resulting spans via the observability layer — the
profile column says what the plan assumed, the measured column says what
the executed timeline actually transmitted and overlapped."""
from __future__ import annotations

from benchmarks.common import (
    REGIMES,
    deft_with_preserver,
    emit,
    profile_regime,
    timed,
)
from repro.configs import ARCH_NAMES, get_config
from repro.core.profiler import HardwareModel, profile_arch


def _measured_row(regime) -> None:
    from repro.core.simulator import simulate_deft
    from repro.obs import sim_metrics_from_spans, spans_from_sim

    def measure():
        prof = profile_regime(regime)
        t = prof.times
        plans, scfg = deft_with_preserver(t)
        sim = simulate_deft(t, plans, mu=scfg.mu,
                            heterogeneous=scfg.heterogeneous,
                            keep_timeline=True)
        return t, sim_metrics_from_spans(spans_from_sim(sim), mu=scfg.mu)

    (t, m), us = timed(measure)
    emit(
        f"table1/measured/{regime.name}", us,
        f"planned_CR={t.coverage_rate:.2f} measured_CR="
        f"{m.coverage_rate:.2f} err="
        f"{abs(m.coverage_rate - t.coverage_rate) / t.coverage_rate:.1%} "
        f"bubble={m.bubble_fraction:.1%}",
    )


def run(measured: bool = False) -> None:
    for regime in REGIMES:
        prof, us = timed(profile_regime, regime)
        t = prof.times
        emit(
            f"table1/{regime.name}", us,
            f"arch={regime.arch} Tf={t.fwd_total*1e3:.1f}ms "
            f"Tb={t.bwd_total*1e3:.1f}ms Tc={t.comm_total*1e3:.1f}ms "
            f"CR={t.coverage_rate:.2f}",
        )
        if measured:
            _measured_row(regime)
    hw = HardwareModel(dp_degree=16)
    for arch in ARCH_NAMES:
        prof, us = timed(
            profile_arch, get_config(arch), hw=hw, seq_len=4096,
            per_device_batch=1,
        )
        t = prof.times
        emit(
            f"table1/assigned/{arch}", us,
            f"Tf={t.fwd_total*1e3:.1f}ms Tb={t.bwd_total*1e3:.1f}ms "
            f"Tc={t.comm_total*1e3:.1f}ms CR={t.coverage_rate:.2f} "
            f"buckets={t.n}",
        )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--measured", action="store_true",
                    help="also read the coverage rate back from the "
                         "simulated timeline via the obs layer")
    run(measured=ap.parse_args().measured)
