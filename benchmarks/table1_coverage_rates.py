"""Paper Table I analog: forward/backward/communication time + coverage
rate, for the paper's three regimes AND every assigned architecture under
the production hardware model."""
from __future__ import annotations

from benchmarks.common import REGIMES, emit, profile_regime, timed
from repro.configs import ARCH_NAMES, get_config
from repro.core.profiler import HardwareModel, profile_arch


def run() -> None:
    for regime in REGIMES:
        prof, us = timed(profile_regime, regime)
        t = prof.times
        emit(
            f"table1/{regime.name}", us,
            f"arch={regime.arch} Tf={t.fwd_total*1e3:.1f}ms "
            f"Tb={t.bwd_total*1e3:.1f}ms Tc={t.comm_total*1e3:.1f}ms "
            f"CR={t.coverage_rate:.2f}",
        )
    hw = HardwareModel(dp_degree=16)
    for arch in ARCH_NAMES:
        prof, us = timed(
            profile_arch, get_config(arch), hw=hw, seq_len=4096,
            per_device_batch=1,
        )
        t = prof.times
        emit(
            f"table1/assigned/{arch}", us,
            f"Tf={t.fwd_total*1e3:.1f}ms Tb={t.bwd_total*1e3:.1f}ms "
            f"Tc={t.comm_total*1e3:.1f}ms CR={t.coverage_rate:.2f} "
            f"buckets={t.n}",
        )


if __name__ == "__main__":
    run()
