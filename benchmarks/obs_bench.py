"""Observability-layer benchmark: attribution fidelity and tracing cost.

Three questions, all deterministic except the overhead timing:

* **closure** — do spans reconstructed from the discrete-event timeline
  reproduce the simulator's own iteration time / bubble fraction /
  coverage rate (the §11 alignment rules, end to end)?
* **divergence lead** — on the BENCH_adapt bandwidth-drop scenario, how
  many steps earlier does the per-phase divergence drift source replan
  than the legacy EMA screen?
* **tracing overhead** — what does attaching per-step span recording to
  the fused smoke dispatch cost, paired traced-vs-plain min-of-reps?
  The acceptance bound is <2%; tests/test_bench_schema.py floors it.

Emits ``BENCH_obs.json``.
"""
from __future__ import annotations

import json
import os
import time

_OUT = os.environ.get("BENCH_OBS_OUT", "BENCH_obs.json")
_STEPS = int(os.environ.get("BENCH_OBS_STEPS", "40"))
DROP_STEP = 60
DROP_SCALE = 3.0
CR = 1.8


def _profile():
    """Paper-scale bucket profile (gemma2-2b leaf-free analytic) — the
    same scenario BENCH_adapt tracks, so the lead metric is comparable."""
    from repro.configs import get_config
    from repro.core.bucket import BucketTimes
    from repro.core.profiler import HardwareModel, profile_arch

    hw = HardwareModel(dp_degree=16)
    prof = profile_arch(get_config("gemma2-2b"), hw=hw, seq_len=4096)
    t = prof.times
    scale = CR * (t.fwd_total + t.bwd_total) / max(t.comm_total, 1e-12)
    return BucketTimes(t.fwd, t.bwd, tuple(c * scale for c in t.comm))


def _closure(times, schedule, scfg):
    """Timeline -> spans -> the simulator's own numbers."""
    from repro.core.scheduler import DeftScheduler
    from repro.core.simulator import simulate_deft
    from repro.obs import sim_metrics_from_spans, spans_from_sim

    plans = DeftScheduler(times, scfg).run(24)
    sim = simulate_deft(times, plans, mu=scfg.mu,
                        heterogeneous=scfg.heterogeneous,
                        keep_timeline=True)
    m = sim_metrics_from_spans(spans_from_sim(sim), mu=scfg.mu)
    return {
        "sim_iteration_time": sim.iteration_time,
        "span_iteration_time": m.iteration_time,
        "iteration_time_exact": m.iteration_time == sim.iteration_time,
        "sim_bubble_fraction": sim.bubble_fraction,
        "span_bubble_fraction": m.bubble_fraction,
        "bubble_abs_error": abs(m.bubble_fraction - sim.bubble_fraction),
        "planned_cr": times.coverage_rate,
        "measured_cr": m.coverage_rate,
        "cr_error": abs(m.coverage_rate - times.coverage_rate)
        / max(times.coverage_rate, 1e-12),
        "n_spans": len(spans_from_sim(sim)),
    }


def _attribution(times, schedule, scfg):
    """Undisturbed run: measured == plan must read back identity."""
    from repro.adapt.calibrate import planned_phase_durations
    from repro.obs import attribute

    planned = planned_phase_durations(times, scfg, schedule.period)
    att = attribute(planned, times, scfg, schedule)
    return {
        "comp_scale": att.comp_scale,
        "comm_scale": att.comm_scale,
        "max_divergence": att.max_divergence,
        "cr_error": att.cr_error,
        "bubble_fraction": att.bubble_fraction,
        "capacity_utilization": dict(att.capacity_utilization),
    }


def _divergence_lead(times, schedule, scfg, walk):
    """First replan step, EMA drift source vs per-phase divergence.

    Per-check detection (``check_every=1`` — a coarser cadence would
    quantize both sources onto the same check step) on a drop sized in
    the (threshold, EMA-instant) band: the latest-sample divergence
    crosses the threshold on the first degraded sample, the EMA needs
    ``(1-(1-alpha)^k) * delta`` to accumulate across k of them."""
    from repro.adapt import (
        AdaptConfig,
        AdaptiveController,
        BandwidthDrop,
        SyntheticTelemetrySource,
        run_control_loop,
    )

    lead_drop = 1.9

    def first_replan(drift_source):
        src = SyntheticTelemetrySource(
            times, BandwidthDrop(step=DROP_STEP, comm_scale=lead_drop)
        )
        ctrl = AdaptiveController(
            times, schedule, scfg, walk=walk,
            cfg=AdaptConfig(drift_source=drift_source, check_every=1),
        )
        run_control_loop(ctrl, src, 3 * DROP_STEP)
        return ctrl.events[0].step if ctrl.events else None

    ema = first_replan("ema")
    div = first_replan("divergence")
    lead = (ema - div) if (ema is not None and div is not None) else None
    return {
        "drop_scale": lead_drop,
        "ema_replan_step": ema,
        "divergence_replan_step": div,
        "lead_steps": lead,
    }


def _tracing_overhead():
    """Paired traced-vs-plain fused smoke dispatch (single device)."""
    import dataclasses

    import jax

    import repro  # noqa: F401  (jax compat shims)
    from repro.configs import get_config
    from repro.core.bucket import BucketTimes
    from repro.core.deft import feedback_solve
    from repro.core.preserver import WalkParams
    from repro.core.profiler import HardwareModel
    from repro.data.pipeline import make_batch
    from repro.models.model import init_params
    from repro.obs import Tracer
    from repro.optim.optimizers import adamw
    from repro.train import (
        DeftRuntime,
        assign_buckets,
        build_bucket_layout,
        leaf_bucket_times,
    )

    b, s = 4, 32
    cfg = dataclasses.replace(
        get_config("qwen3-4b"), name="qwen3-tiny", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
    )
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    bucket_of, nb = assign_buckets(params, cfg, partition_elems=20_000)
    hw = HardwareModel(dp_degree=2)
    times = leaf_bucket_times(params, cfg, bucket_of, nb, hw, s, b)
    scale = CR * (times.fwd_total + times.bwd_total) / times.comm_total
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    schedule, _, _, _ = feedback_solve(times, walk)
    layout = build_bucket_layout(params, bucket_of, nb)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    opt = adamw(1e-3)
    tracer = Tracer(capacity=1 << 16)
    rt_plain = DeftRuntime(cfg, opt, schedule, layout, mesh)
    rt_traced = DeftRuntime(cfg, opt, schedule, layout, mesh, tracer=tracer)
    batch = make_batch(cfg, 0, 0, b, s)
    with jax.set_mesh(mesh):
        s_plain = rt_plain.init_state(key)
        s_traced = rt_traced.init_state(key)
        rt_plain.compile(s_plain, batch)
        rt_traced.compile(s_traced, batch)

        def timed(rt, state, n):
            t0 = time.perf_counter()
            for i in range(n):
                state, m = rt.step(i, state, batch)
            jax.block_until_ready(m["loss"])
            return (time.perf_counter() - t0) / n, state

        # chunks align to the period so every rep times the same phase
        # mix; paired order + min-of-reps absorbs ambient load spikes
        chunk = max(1, round(_STEPS / schedule.period)) * schedule.period
        _, s_plain = timed(rt_plain, s_plain, 10)       # warm past compiles
        _, s_traced = timed(rt_traced, s_traced, 10)
        best_plain = best_traced = float("inf")
        for _ in range(9):
            dt, s_plain = timed(rt_plain, s_plain, chunk)
            best_plain = min(best_plain, dt)
            dt, s_traced = timed(rt_traced, s_traced, chunk)
            best_traced = min(best_traced, dt)

    by_kind = tracer.stats()["by_kind"]
    return {
        "steps_timed": chunk,
        "steps_per_s_plain": 1.0 / best_plain,
        "steps_per_s_traced": 1.0 / best_traced,
        "overhead_pct": (best_traced / best_plain - 1.0) * 100.0,
        "spans_recorded": tracer.n_recorded,
        "span_kinds": by_kind,
    }


def run() -> None:
    """Benchmark section entry point (benchmarks/run.py)."""
    from repro.core.deft import feedback_solve
    from repro.core.preserver import WalkParams

    t0 = time.time()
    times = _profile()
    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    schedule, _, scfg, _ = feedback_solve(times, walk)

    result = {
        "scenario": {"drop_step": DROP_STEP, "drop_scale": DROP_SCALE,
                     "coverage_rate": CR, "steps": _STEPS},
        "closure": _closure(times, schedule, scfg),
        "attribution": _attribution(times, schedule, scfg),
        "divergence_lead": _divergence_lead(times, schedule, scfg, walk),
        "tracing": _tracing_overhead(),
    }
    tmp = _OUT + ".tmp"
    json.dump(result, open(tmp, "w"), indent=1)
    os.replace(tmp, _OUT)

    c, a, d, tr = (result["closure"], result["attribution"],
                   result["divergence_lead"], result["tracing"])
    print(f"obs_closure_cr_error,{c['cr_error'] * 1e6:.0f},"
          f"measured CR {c['measured_cr']:.3f} vs planned "
          f"{c['planned_cr']:.3f} (iteration_time_exact="
          f"{c['iteration_time_exact']})")
    print(f"obs_attribution_max_divergence,{a['max_divergence'] * 1e6:.0f},"
          f"undisturbed run: comp x{a['comp_scale']:.2f} "
          f"comm x{a['comm_scale']:.2f}")
    print(f"obs_divergence_lead_steps,{d['lead_steps'] or 0},"
          f"divergence replans step {d['divergence_replan_step']} vs "
          f"EMA step {d['ema_replan_step']}")
    print(f"obs_tracing_overhead_pct,{tr['overhead_pct'] * 100:.0f},"
          f"{tr['overhead_pct']:.2f}% ({tr['steps_per_s_traced']:.1f} vs "
          f"{tr['steps_per_s_plain']:.1f} steps/s, "
          f"{tr['spans_recorded']} spans)")
    print(f"# BENCH_obs.json written in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    run()
