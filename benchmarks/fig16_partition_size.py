"""Paper Fig. 16 analog: the influence of tensor partition size on each
scheduler's iteration time (3M / 4M / 6.5M / 8M / 10M elements)."""
from __future__ import annotations

from benchmarks.common import REGIMES, emit, profile_regime, run_all_schedulers

SIZES = (3_000_000, 4_000_000, 6_500_000, 8_000_000, 10_000_000)


def run() -> None:
    # each scheme keeps its own partition strategy at every size (paper:
    # DDP uses uniform 10..40MB buckets; US-Byte/DeFT re-partition)
    from benchmarks.common import deft_with_preserver
    from repro.core.policies import ALL_BASELINES
    from repro.core.simulator import simulate_baseline, simulate_deft

    regime = REGIMES[0]  # VGG-like, the paper's choice for this figure
    strategies = {"pytorch-ddp": "uniform", "bytescheduler": "bytescheduler",
                  "us-byte": "usbyte", "deft": "deft"}
    for size in SIZES:
        profs = {
            strat: profile_regime(regime, partition_elems=size,
                                  strategy=strat)
            for strat in set(strategies.values())
        }
        for name, mk in ALL_BASELINES.items():
            t = profs[strategies[name]].times
            r = simulate_baseline(t, mk(t))
            emit(
                f"fig16/part{size//1_000_000}M/{name}",
                r.iteration_time * 1e6,
                f"buckets={t.n} iter={r.iteration_time*1e3:.1f}ms "
                f"bubble={r.bubble_fraction:.2f}",
            )
        t = profs["deft"].times
        plans, scfg = deft_with_preserver(t)
        r = simulate_deft(t, plans)
        emit(
            f"fig16/part{size//1_000_000}M/deft", r.iteration_time * 1e6,
            f"buckets={t.n} iter={r.iteration_time*1e3:.1f}ms "
            f"bubble={r.bubble_fraction:.2f}",
        )


if __name__ == "__main__":
    run()
