"""Paper Table II analog: per-bucket fwd/bwd/comm imbalance for the
VGG-like regime (the motivation for merging computation into one knapsack
capacity)."""
from __future__ import annotations

from benchmarks.common import REGIMES, emit, profile_regime, timed


def run() -> None:
    regime = REGIMES[0]  # VGG-like
    prof, us = timed(profile_regime, regime)
    t = prof.times
    for i in range(t.n):
        emit(
            f"table2/bucket{i + 1}", us / t.n,
            f"fwd={t.fwd[i]*1e6:.0f}us bwd={t.bwd[i]*1e6:.0f}us "
            f"comm={t.comm[i]*1e6:.0f}us",
        )
    imb = max(t.comm) / max(min(c for c in t.comm if c > 0), 1e-9)
    emit(
        "table2/total", us,
        f"fwd={t.fwd_total*1e3:.1f}ms bwd={t.bwd_total*1e3:.1f}ms "
        f"comm={t.comm_total*1e3:.1f}ms comm_imbalance={imb:.1f}x",
    )


if __name__ == "__main__":
    run()
