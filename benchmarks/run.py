"""Benchmark entry point: one section per paper table/figure plus the
dry-run roofline table.  Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        adapt_bench,
        fig10_time_to_solution,
        fig14_scalability,
        fig15_bandwidth,
        fig16_partition_size,
        roofline,
        runtime_bench,
        table1_coverage_rates,
        table2_bucket_times,
        table4_multilink,
    )

    sections = [
        ("table1 (coverage rates)", table1_coverage_rates.run),
        ("table2 (bucket times)", table2_bucket_times.run),
        ("table4 (multi-link)", table4_multilink.run),
        ("fig10 (time-to-solution)", fig10_time_to_solution.run),
        ("fig14 (scalability)", fig14_scalability.run),
        ("fig15 (bandwidth)", fig15_bandwidth.run),
        ("fig16 (partition size)", fig16_partition_size.run),
        ("roofline (dry-run)", roofline.run),
        ("runtime (fused DeftRuntime + solver, BENCH_runtime.json)",
         runtime_bench.run),
        ("adapt (static vs adaptive replan, BENCH_adapt.json)",
         adapt_bench.run),
    ]
    t0 = time.time()
    failures = 0
    for name, fn in sections:
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception as e:  # keep the harness going; fail at the end
            failures += 1
            print(f"{name},0,ERROR {type(e).__name__}: {e}")
    print(f"# benchmarks done in {time.time() - t0:.1f}s, "
          f"{failures} section failures")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
