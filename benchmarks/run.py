"""Benchmark entry point: one section per paper table/figure plus the
dry-run roofline table.  Prints ``name,us_per_call,derived`` CSV.

``--smoke`` runs only the machine-readable sections (runtime + adapt,
reduced step counts) — the mode the CI benchmark job uses; the emitted
BENCH_*.json are then validated by scripts/check_bench_schema.py
(verify.sh --smoke chains the two).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

# runnable both as `python -m benchmarks.run` and `python benchmarks/run.py`,
# with or without PYTHONPATH=src exported
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)


def _sections(smoke: bool):
    from benchmarks import adapt_bench, elastic_bench, obs_bench, runtime_bench

    runtime = (
        "runtime (fused DeftRuntime + solver, BENCH_runtime.json)",
        runtime_bench.run,
    )
    adapt = (
        "adapt (static vs adaptive replan, BENCH_adapt.json)",
        adapt_bench.run,
    )
    elastic = (
        "elastic (fault detection + scale-down repack, BENCH_elastic.json)",
        elastic_bench.run,
    )
    obs = (
        "obs (attribution closure + tracing overhead, BENCH_obs.json)",
        obs_bench.run,
    )
    if smoke:
        return [runtime, adapt, elastic, obs]

    from benchmarks import (
        fig10_time_to_solution,
        fig14_scalability,
        fig15_bandwidth,
        fig16_partition_size,
        roofline,
        table1_coverage_rates,
        table2_bucket_times,
        table4_multilink,
    )

    return [
        ("table1 (coverage rates)", table1_coverage_rates.run),
        ("table2 (bucket times)", table2_bucket_times.run),
        ("table4 (multi-link)", table4_multilink.run),
        ("fig10 (time-to-solution)", fig10_time_to_solution.run),
        ("fig14 (scalability)", fig14_scalability.run),
        ("fig15 (bandwidth)", fig15_bandwidth.run),
        ("fig16 (partition size)", fig16_partition_size.run),
        ("roofline (dry-run)", roofline.run),
        runtime,
        adapt,
        elastic,
        obs,
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="BENCH-emitting sections only, reduced steps "
                         "(the CI benchmark job; verify.sh --smoke "
                         "schema-checks the output)")
    args = ap.parse_args(argv)
    if args.smoke:
        os.environ.setdefault("BENCH_RUNTIME_STEPS", "6")
        os.environ.setdefault("BENCH_ADAPT_STEPS", "120")
        os.environ.setdefault("BENCH_OBS_STEPS", "20")

    t0 = time.time()
    failures = 0
    for name, fn in _sections(args.smoke):
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception as e:  # keep the harness going; fail at the end
            failures += 1
            print(f"{name},0,ERROR {type(e).__name__}: {e}")

    print(f"# benchmarks done in {time.time() - t0:.1f}s, "
          f"{failures} section failures")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
