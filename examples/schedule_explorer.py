"""Schedule explorer: ASCII timeline of what each scheduler does with one
iteration's buckets — the paper's Fig. 11-13 rendered in a terminal —
plus a replay of the online control plane acting on a mid-run bandwidth
drop (replan events: step, trigger, coverage-rate delta, Preserver
verdict).

    PYTHONPATH=src python examples/schedule_explorer.py --cr 2.0
    PYTHONPATH=src python examples/schedule_explorer.py --adapt \
        --drop-step 40 --drop-scale 3.0
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.adapt import (
    AdaptiveController,
    BandwidthDrop,
    SyntheticTelemetrySource,
    run_control_loop,
)
from repro.configs import get_config
from repro.core.bucket import BucketTimes
from repro.core.deft import plan_deft
from repro.core.policies import ALL_BASELINES
from repro.core.profiler import HardwareModel, profile_arch
from repro.core.scheduler import DeftScheduler
from repro.core.simulator import simulate_baseline, simulate_deft
from repro.obs import ManualClock, Tracer, format_event, spans_from_sim

WIDTH = 100


def render(timeline, t_end, label):
    streams = {}
    for stream, s, e, tag in timeline:
        streams.setdefault(stream, []).append((s, e, tag))
    print(f"\n== {label} ==")
    for stream in sorted(streams):
        row = [" "] * WIDTH
        for s, e, tag in streams[stream]:
            a = int(s / t_end * (WIDTH - 1))
            b = max(int(e / t_end * (WIDTH - 1)), a + 1)
            ch = tag[0] if tag else "#"
            for i in range(a, min(b, WIDTH)):
                row[i] = ch
        print(f"{stream:8s} |{''.join(row)}|")


def _per_bucket_precision(event) -> str:
    """``[b0=int8 b1=bf16 ...]`` for a precision-changing replan, plus
    the wire-byte delta the downgrade buys."""
    wire = (event.new_precision.wire if event.new_precision
            else ("f32",) * event.new_n_buckets)
    cells = " ".join(f"b{i}={w}" for i, w in enumerate(wire))
    return (f"    precision: [{cells}]  wire bytes "
            f"x{event.wire_bytes_scale:.2f}")


def explore_precision(times: BucketTimes, wire_precision: str) -> None:
    """Print the §13 precision ladder the planner scores: one row per
    candidate policy (iteration time, simulated coverage, wire-byte
    scale, Preserver verdict), then the adopted per-bucket wire."""
    from repro.core.deft import Planner, PlanRequest
    from repro.core.preserver import WalkParams

    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    res = Planner().plan(PlanRequest(
        times=times, walk=walk, wire_precision=wire_precision,
    ))
    print(f"\n== precision ladder (wire_precision={wire_precision}) ==")
    print(f"{'policy':<24s} {'iter ms':>9s} {'coverage':>9s} "
          f"{'bytes':>7s} {'gate':>6s}")
    for s in res.precision_candidates:
        mark = " <- adopted" if s.policy == res.precision else ""
        print(f"{s.policy.describe():<24s} "
              f"{s.iteration_time * 1e3:9.2f} {s.coverage:9.3f} "
              f"x{s.wire_bytes_scale:5.2f} "
              f"{'ok' if s.verdict.ok else 'FAIL':>6s}{mark}")
    if res.precision is not None:
        cells = " ".join(
            f"b{i}={w}" for i, w in enumerate(res.precision.wire)
        )
        print(f"adopted per-bucket wire: [{cells}]")


def explore_adapt(times: BucketTimes, drop_step: int, drop_scale: float,
                  steps: int, tracer=None,
                  wire_precision: str = "f32") -> None:
    """Replay the control plane on a synthetic bandwidth drop and print
    every replan event — the terminal view of the Fig. 7 loop acting.
    Precision-changing replans (wire_precision='auto', or any replan
    whose calibrated comm_scale crosses the collapse bar) additionally
    print the per-bucket wire choice and the bytes delta."""
    from repro.adapt import AdaptConfig
    from repro.core.deft import feedback_solve
    from repro.core.preserver import WalkParams

    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    schedule, verdict, scfg, _ = feedback_solve(times, walk)
    print(f"\n== adaptive control plane: bandwidth x1/{drop_scale:.1f} "
          f"at step {drop_step} ==")
    print(f"initial plan: period={schedule.period} "
          f"k-seq={schedule.batch_size_sequence} "
          f"CR={times.coverage_rate:.2f} "
          f"preserver ratio={verdict.ratio:.4f}")
    src = SyntheticTelemetrySource(
        times, BandwidthDrop(step=drop_step, comm_scale=drop_scale)
    )
    ctrl = AdaptiveController(
        times, schedule, scfg, walk=walk, tracer=tracer,
        cfg=AdaptConfig(wire_precision=wire_precision),
    )

    def on_event(e):
        print(format_event(e))
        if e.precision_changed:
            print(_per_bucket_precision(e))

    run_control_loop(ctrl, src, steps, on_event=on_event)
    if not ctrl.events:
        print("(no drift detected — no replan events)")
    else:
        print(f"{len(ctrl.events)} replan event(s), "
              f"{sum(1 for e in ctrl.events if e.changed)} hot-swap(s)")


def explore_elastic(steps: int, tracer=None) -> None:
    """Replay the health monitor on a synthetic fault sequence — one
    straggler excursion and one silent (dead) shard — printing every
    detection through the same formatter as the replan/repack surfaces."""
    from repro.elastic import HealthConfig, HealthMonitor

    print("\n== elastic health replay: straggler @ 1/3, "
          "silent shard @ 2/3 ==")
    mon = HealthMonitor(
        4,
        HealthConfig(warmup_steps=1, straggler_patience=2,
                     recovered_patience=2, timeout_factor=4.0),
        tracer=tracer,
    )
    t_strag, t_dead = steps // 3, 2 * steps // 3
    base = 0.1
    n_events = 0
    for i in range(steps):
        walls = [base] * 4
        if i >= t_strag:
            walls[1] = base * (3.0 if i < t_dead else 1.0)
        if i >= t_dead:
            walls[3] = None
        for ev in mon.observe(i, walls):
            print(format_event(ev))
            n_events += 1
    print(f"{n_events} fault event(s), status={mon.status}")


def explore_repartition(arch: str, drop_step: int, drop_scale: float,
                        steps: int, tracer=None) -> None:
    """Replay the control plane WITH the candidate-partition path on the
    smoke-reduced config: partition-changing replans print old/new
    n_buckets + shard count + the Preserver verdict of the winner, and
    each adopted repartition is followed by a REAL timed re-pack of a
    smoke-scale flat state between the two layouts (the cycle-boundary
    cost the runtime would pay — DESIGN.md §9)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.adapt import (
        RepartitionConfig,
        Repartitioner,
        candidate_solve_table,
    )
    from repro.configs import reduce_for_smoke
    from repro.core.deft import feedback_solve
    from repro.core.preserver import WalkParams
    from repro.core.profiler import HardwareModel
    from repro.models.model import init_params
    from repro.train import (
        build_bucket_layout,
        build_layout_transition,
        build_leaf_time_model,
        repack_buffers,
    )

    cfg = reduce_for_smoke(get_config(arch))
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    model = build_leaf_time_model(params, cfg, HardwareModel(dp_degree=16),
                                  64, 1)
    pe = 100_000
    bucket_of, nb = model.partition(pe)
    model = model.with_coverage_rate(bucket_of, nb, 1.8)
    times = model.bucket_times(bucket_of, nb)
    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    schedule, verdict, scfg, _ = feedback_solve(times, walk)
    rp = Repartitioner(model, RepartitionConfig(base_partition_elems=pe))
    print(f"\n== adaptive repartitioning ({cfg.name}, smoke scale): "
          f"bandwidth x1/{drop_scale:.1f} at step {drop_step} ==")
    print(f"initial partition: {nb} buckets "
          f"(partition_elems={pe}), period={schedule.period}, "
          f"CR={times.coverage_rate:.2f}")

    def time_repack(event) -> None:
        from repro.obs import Span

        lay_a = build_bucket_layout(params, tuple(ctrl_prev["bucket_of"]),
                                    ctrl_prev["n_buckets"])
        lay_b = build_bucket_layout(params, event.partition.bucket_of,
                                    event.partition.n_buckets)
        tr = build_layout_transition(lay_a, lay_b)
        # a full flat-state repack at smoke scale: pbuf/m/v (1-D) and
        # cur/fut (leading accum axis) in one jitted pass, like the
        # runtime's staged swap
        bufs1 = [jnp.zeros((n,), jnp.float32) for n in lay_a.buf_sizes]
        bufs2 = [jnp.zeros((1, n), jnp.float32) for n in lay_a.buf_sizes]
        f = jax.jit(lambda p, m, v, c, fz: (
            repack_buffers(tr, p), repack_buffers(tr, m),
            repack_buffers(tr, v), repack_buffers(tr, c),
            repack_buffers(tr, fz),
        ))
        out = f(bufs1, bufs1, bufs1, bufs2, bufs2)
        jax.block_until_ready(out)          # compile outside the timing
        t0 = time.perf_counter()
        out = f(bufs1, bufs1, bufs1, bufs2, bufs2)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        sp = Span(
            "repack",
            f"repack {lay_a.n_buckets}->{lay_b.n_buckets} buckets",
            0.0, dt, step=event.step,
            attrs=(("moved_elems", tr.moved_elems),
                   ("shards", f"{lay_a.shards}->{lay_b.shards}")),
        )
        print("    " + format_event(sp))
        if tracer is not None:
            tr0 = tracer.now()
            tracer.add("repack", sp.name, tr0, tr0 + dt,
                       step=event.step, **sp.args)

    ctrl_prev = {"bucket_of": bucket_of, "n_buckets": nb}

    def on_event(e):
        print(format_event(e))
        if e.candidate_solves:
            print(candidate_solve_table(e.candidate_solves))
        if e.partition_changed:
            time_repack(e)
            ctrl_prev["bucket_of"] = e.partition.bucket_of
            ctrl_prev["n_buckets"] = e.partition.n_buckets

    src = SyntheticTelemetrySource(
        times, BandwidthDrop(step=drop_step, comm_scale=drop_scale)
    )
    ctrl = AdaptiveController(times, schedule, scfg, walk=walk,
                              repartitioner=rp, bucket_of=bucket_of,
                              tracer=tracer)
    run_control_loop(ctrl, src, steps, on_event=on_event,
                     run_base_fn=lambda e: rp.base_times_for(e.partition))
    reparts = ctrl.stats()["repartitions"]
    print(f"{len(ctrl.events)} replan event(s), {reparts} "
          f"partition-changing")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--cr", type=float, default=2.0)
    ap.add_argument("--iters", type=int, default=4)
    ap.add_argument("--adapt", action="store_true",
                    help="also replay the online control plane on a "
                         "synthetic mid-run bandwidth drop")
    ap.add_argument("--adapt-repartition", action="store_true",
                    help="with --adapt: the replay also considers "
                         "candidate bucket partitions and times a real "
                         "smoke-scale re-pack per adopted change")
    ap.add_argument("--wire-precision", default="f32",
                    choices=["auto", "f32", "bf16", "int8"],
                    help="per-bucket wire precision for the planner "
                         "ladder table and the --adapt replay "
                         "('auto' lets the knapsack pick per bucket)")
    ap.add_argument("--drop-step", type=int, default=40)
    ap.add_argument("--drop-scale", type=float, default=3.0)
    ap.add_argument("--adapt-steps", type=int, default=120)
    ap.add_argument("--elastic", action="store_true",
                    help="also replay the health monitor on a synthetic "
                         "fault sequence (straggler + dead shard)")
    ap.add_argument("--trace", default="", metavar="OUT.json",
                    help="export the DeFT simulator timeline plus every "
                         "replayed control-plane event as a Chrome-trace "
                         "(Perfetto-loadable) JSON")
    args = ap.parse_args()
    # ManualClock: the explorer is pure replay, so the exported trace is
    # bit-reproducible; sim spans carry their own sim-time bounds and
    # control events land after the simulated window
    tracer = Tracer(clock=ManualClock()) if args.trace else None

    cfg = get_config(args.arch)
    hw = HardwareModel(dp_degree=16)
    prof = profile_arch(cfg, hw=hw, seq_len=4096, per_device_batch=1)
    t = prof.times
    scale = args.cr * (t.fwd_total + t.bwd_total) / max(t.comm_total, 1e-12)
    t = BucketTimes(t.fwd, t.bwd, tuple(c * scale for c in t.comm))
    print(f"arch={cfg.name} buckets={t.n} CR={t.coverage_rate:.2f}")
    print("legend: F=forward  B=backward  C=communication")

    for name, mk in ALL_BASELINES.items():
        r = simulate_baseline(t, mk(t), n_iterations=args.iters + 2,
                              keep_timeline=True)
        t_end = max(e for _, _, e, _ in r.timeline)
        render(r.timeline, t_end,
               f"{name}: iter={r.iteration_time*1e3:.1f}ms "
               f"bubble={r.bubble_fraction:.2f}")

    plan = plan_deft(cfg, hw=hw, seq_len=4096)
    sched = DeftScheduler(t, plan.scheduler_cfg)
    plans = sched.run(args.iters + 4)
    r = simulate_deft(t, plans, keep_timeline=True)
    t_end = max(e for _, _, e, _ in r.timeline)
    render(r.timeline, t_end,
           f"deft: iter={r.iteration_time*1e3:.1f}ms "
           f"bubble={r.bubble_fraction:.2f} "
           f"upd/iter={r.updates_per_iteration:.2f}")
    if tracer is not None:
        for sp in spans_from_sim(r):
            tracer.add(sp.kind, sp.name, sp.t0, sp.t1,
                       step=sp.step, track=sp.track, **sp.args)
        tracer.clock.advance(t_end)     # control events after the window

    if args.wire_precision != "f32":
        explore_precision(t, args.wire_precision)

    if args.adapt:
        explore_adapt(t, args.drop_step, args.drop_scale, args.adapt_steps,
                      tracer=tracer, wire_precision=args.wire_precision)
        if args.adapt_repartition:
            explore_repartition(args.arch, args.drop_step,
                                args.drop_scale, args.adapt_steps,
                                tracer=tracer)
    if args.elastic:
        explore_elastic(args.adapt_steps, tracer=tracer)

    if tracer is not None:
        tracer.export_chrome_trace(args.trace)
        print(f"\ntrace -> {args.trace} ({len(tracer)} spans)")


if __name__ == "__main__":
    main()
