"""End-to-end convergence experiment: train the same model with plain DDP
and with a DeFT schedule (delayed updates, merged generations) on the
deterministic synthetic stream, and compare loss curves — the CPU-scale
version of the paper's Fig. 10 time-to-solution study.

Throughput cannot be measured honestly on one CPU, so the wall-clock axis
uses the timeline simulator's iteration times (the same machinery as
benchmarks/fig10) while the LOSS axis is real training.

Default is a ~20M-parameter model sized for a single CPU core; pass
``--dmodel 768 --layers 12 --vocab 32768`` for the ~100M configuration on
faster hardware.

    PYTHONPATH=src python examples/train_deft_vs_ddp.py --steps 150
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.adapt import AdaptConfig, AdaptiveController
from repro.configs import get_config
from repro.core.bucket import BucketTimes
from repro.core.deft import feedback_solve
from repro.core.scheduler import DeftScheduler
from repro.core.profiler import HardwareModel
from repro.core.simulator import simulate_baseline, simulate_deft
from repro.core.policies import pytorch_ddp
from repro.data.pipeline import SyntheticDataset
from repro.optim.optimizers import adamw, init_opt_state
from repro.train import (
    DeftRuntime,
    assign_buckets,
    build_bucket_layout,
    init_train_state,
    leaf_bucket_times,
    make_ddp_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--dmodel", type=int, default=448)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--coverage-rate", type=float, default=1.8,
                    help="simulated CR (sets how aggressively DeFT merges)")
    ap.add_argument("--adapt", action="store_true",
                    help="attach the online control plane to the DeFT run "
                         "(real measured wall times feed drift detection)")
    ap.add_argument("--fsdp", action="store_true",
                    help="drive the SHARDED flat engine end-to-end: params "
                         "and optimizer moments resident 1/N over the data "
                         "axis (ZeRO), DDP baseline sharded to match")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    base = get_config("qwen3-4b")
    cfg = dataclasses.replace(
        base, name="qwen3-midi", n_layers=args.layers, d_model=args.dmodel,
        n_heads=8, n_kv_heads=4, head_dim=args.dmodel // 8,
        d_ff=args.dmodel * 3, vocab_size=args.vocab,
    )
    print(f"model: {cfg.total_params():,} params "
          f"({args.layers}L d={args.dmodel} vocab={args.vocab})")
    mesh = jax.make_mesh(
        (jax.device_count(), 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
    dp = jax.device_count()
    opt = adamw(3e-4)
    key = jax.random.PRNGKey(args.seed)

    # ---- DeFT schedule at the requested coverage rate ----
    state_d = init_train_state(key, cfg, opt)
    bucket_of, nb = assign_buckets(state_d["params"], cfg,
                                   partition_elems=1_000_000)
    hw = HardwareModel(dp_degree=max(dp, 2))
    times = leaf_bucket_times(state_d["params"], cfg, bucket_of, nb, hw,
                              args.seq, max(args.batch // dp, 1))
    scale = args.coverage_rate * (times.fwd_total + times.bwd_total) / max(
        times.comm_total, 1e-12)
    times = BucketTimes(times.fwd, times.bwd,
                        tuple(c * scale for c in times.comm))
    # Solver + Preserver feedback (paper Fig. 7): reject schedules whose
    # variable-batch-size sequence would hurt convergence
    from repro.core.preserver import WalkParams
    walk = WalkParams(s0=4.0, eta=0.01, mu=1.0, sigma=40.0, batch=256)
    schedule, _verdict, scfg, _ = feedback_solve(times, walk)
    print(f"deft schedule: {nb} buckets CR={times.coverage_rate:.2f} "
          f"period={schedule.period} updates/period="
          f"{schedule.updates_per_period} k-seq={schedule.batch_size_sequence}")

    # simulated per-iteration wall times (the throughput axis)
    r_ddp = simulate_baseline(times, pytorch_ddp(times))
    plans = DeftScheduler(times, scfg).run(32)
    r_deft = simulate_deft(times, plans)
    print(f"simulated iteration: ddp={r_ddp.iteration_time*1e3:.1f}ms "
          f"deft={r_deft.iteration_time*1e3:.1f}ms "
          f"(speedup {r_ddp.iteration_time/r_deft.iteration_time:.2f}x)")

    # ---- real training, same data order ----
    # Both paths run through the donated production executables (runtime
    # fused phases / donated DDP step), so params and optimizer state
    # update in place; the two states must NOT share arrays (a donated
    # buffer is consumed), hence separate init_state/init_opt_state calls.
    # --fsdp swaps in the SHARDED flat engine (ROADMAP satellite): the
    # layout pads each bucket into dp equal lane-aligned spans and the
    # runtime keeps params/moments 1/dp-resident, gather-skip on.
    layout = build_bucket_layout(state_d["params"], bucket_of, nb,
                                 shard_count=dp if args.fsdp else 1)
    runtime = DeftRuntime(cfg, opt, schedule, layout, mesh,
                          fsdp=args.fsdp)
    if args.fsdp:
        st = runtime.stats()
        print(f"fsdp: sharded flat engine, params/moments 1/{st['shards']} "
              f"resident over 'data', gather_skip={st['gather_skip']}")
    state_r = {"params": state_d["params"],
               "opt": init_opt_state(opt, state_d["params"])}
    state_d = runtime.init_state(key)
    ddp_fn = make_ddp_step(cfg, opt, fsdp=args.fsdp)
    controller = (
        AdaptiveController(times, schedule, scfg, walk=walk,
                           cfg=AdaptConfig(eta=3e-4))
        if args.adapt else None
    )
    with jax.set_mesh(mesh):
        ds_d = SyntheticDataset(cfg, args.seed, args.batch, args.seq)
        ds_r = SyntheticDataset(cfg, args.seed, args.batch, args.seq)
        log_every = max(args.steps // 15, 1)
        print(f"{'step':>5} {'ddp-loss':>9} {'deft-loss':>9} "
              f"{'ddp-t(sim ms)':>13} {'deft-t(sim ms)':>14}")
        t0 = time.time()
        ddp_hist, deft_hist = [], []
        for step in range(args.steps):
            bd = next(ds_d)
            br = next(ds_r)
            t_s = time.time()
            state_d, md = runtime.step(step, state_d, bd)
            if controller is not None:
                jax.block_until_ready(md["loss"])
                event = controller.observe(
                    step, runtime.last_phase, time.time() - t_s,
                    loss=float(md["loss"]),
                )
                if event is not None and event.changed:
                    runtime.prepare_swap(event.schedule, state_d, bd,
                                         background=True)
            state_r, mr = ddp_fn(state_r, br)
            ddp_hist.append(float(mr["loss"]))
            deft_hist.append(float(md["loss"]))
            if step % log_every == 0 or step == args.steps - 1:
                print(f"{step:5d} {ddp_hist[-1]:9.4f} {deft_hist[-1]:9.4f} "
                      f"{step * r_ddp.iteration_time * 1e3:13.1f} "
                      f"{step * r_deft.iteration_time * 1e3:14.1f}")
        print(f"(wall {time.time()-t0:.1f}s on this CPU)")

    # The fair accuracy comparison is at MATCHED SIMULATED WALL-CLOCK:
    # DeFT runs more iterations in the time DDP runs fewer (speedup x),
    # so compare DeFT's final loss with DDP's loss at the step DDP would
    # have reached in the same simulated time.
    t_final = (args.steps - 1) * r_deft.iteration_time
    ddp_step_at_t = min(int(t_final / max(r_ddp.iteration_time, 1e-12)),
                        args.steps - 1)
    tail = max(args.steps // 10, 1)
    avg = lambda xs: sum(xs) / len(xs)
    print(f"\nat matched simulated wall-clock ({t_final*1e3:.0f} ms): "
          f"deft loss={avg(deft_hist[-tail:]):.4f} (step {args.steps-1}) vs "
          f"ddp loss={avg(ddp_hist[max(ddp_step_at_t-tail,0):ddp_step_at_t+1]):.4f} "
          f"(step {ddp_step_at_t})")
    print(f"equal-step gap |deft - ddp| = "
          f"{abs(deft_hist[-1] - ddp_hist[-1]):.4f} "
          f"(DeFT applies ~{schedule.updates_per_period}/{schedule.period} "
          f"updates per iteration by design; the paper's 'no accuracy loss' "
          f"claim is per unit wall-clock, where DeFT is "
          f"{r_ddp.iteration_time/r_deft.iteration_time:.2f}x faster)")


if __name__ == "__main__":
    main()
