"""Batched serving example: prefill a batch of prompts, decode with a
shared compiled step, report per-token latency — exercising the same
``serve_step`` the decode dry-run shapes lower (one new token against a
KV cache / recurrent state).

Works for any assigned arch family, including the attention-free and
sliding-window ones whose O(1)/O(window) state makes long contexts cheap:

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-1.6b
    PYTHONPATH=src python examples/serve_decode.py --arch gemma2-2b
"""
import argparse
import functools
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, reduce_for_smoke
from repro.models.model import init_params
from repro.serve.steps import (
    decode_serve_step,
    make_serve_cache,
    prefill_serve_step,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="rwkv6-1.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    b = args.requests
    max_len = args.prompt_len + args.gen

    params = init_params(key, cfg)
    cache = make_serve_cache(cfg, b, max_len, dtype=jnp.float32,
                             prefill_chunk=args.prompt_len)
    prompts = jax.random.randint(key, (b, args.prompt_len), 0, cfg.vocab_size)
    memory = None
    if cfg.modality != "text":
        memory = jax.random.normal(
            key, (b, max(cfg.n_modal_tokens, 1), cfg.d_model)
        )

    prefill_fn = jax.jit(functools.partial(prefill_serve_step, cfg=cfg))
    decode_fn = jax.jit(functools.partial(decode_serve_step, cfg=cfg),
                        donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill_fn(params, prompts, cache, memory=memory)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    generated = [token]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.asarray(args.prompt_len + i, jnp.int32)
        logits, cache = decode_fn(params, token, cache, pos)
        key, sub = jax.random.split(key)
        if args.temperature > 0:
            token = jax.random.categorical(
                sub, logits / args.temperature, axis=-1
            ).astype(jnp.int32)
        else:
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(token)
    jax.block_until_ready(token)
    t_decode = time.time() - t0

    out = jnp.stack(generated, axis=1)
    per_tok = t_decode / max(args.gen - 1, 1)
    print(f"arch={cfg.name} family={cfg.family} requests={b}")
    print(f"prefill({args.prompt_len} tok): {t_prefill*1e3:.1f}ms")
    print(f"decode: {per_tok*1e3:.2f}ms/token/batch "
          f"-> {b / per_tok:.0f} tok/s aggregate")
    for r in range(min(b, 3)):
        print(f"request {r}: {out[r, :16].tolist()} ...")


if __name__ == "__main__":
    main()
