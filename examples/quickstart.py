"""Quickstart: plan a DeFT communication schedule for an assigned
architecture and compare it against the baselines in the timeline
simulator — the whole paper pipeline in one page.

    PYTHONPATH=src python examples/quickstart.py [--arch gemma2-2b]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs import ARCH_NAMES, get_config
from repro.core.deft import plan_deft
from repro.core.policies import ALL_BASELINES
from repro.core.profiler import HardwareModel
from repro.core.scheduler import DeftScheduler
from repro.core.simulator import simulate_baseline, simulate_deft


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="gemma2-2b")
    ap.add_argument("--bandwidth", type=float, default=1.2e10,
                    help="interconnect bytes/s (small => high CR)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    hw = HardwareModel(dp_degree=16, ici_bw=args.bandwidth)

    # 1. Profiler + Solver + Preserver (paper Fig. 7)
    plan = plan_deft(cfg, hw=hw, seq_len=4096, per_device_batch=1)
    t = plan.profile.times
    print(f"arch={cfg.name}  params={cfg.total_params():,}")
    print(f"buckets={t.n}  T_fwd={t.fwd_total*1e3:.1f}ms  "
          f"T_bwd={t.bwd_total*1e3:.1f}ms  T_comm={t.comm_total*1e3:.1f}ms  "
          f"CR={t.coverage_rate:.2f}")
    s = plan.schedule
    print(f"schedule: period={s.period}  updates/period={s.updates_per_period}"
          f"  batch-size sequence={s.batch_size_sequence}")
    print(f"preserver: ratio={plan.verdict.ratio:.4f} ok={plan.verdict.ok} "
          f"(capacity x{plan.capacity_factor:.2f}, {plan.retries} retries)")

    # 2. Timeline comparison (paper Fig. 10/11 style)
    print("\nscheduler        iter(ms)  bubbles  upd/iter  speedup")
    plans = DeftScheduler(t, plan.scheduler_cfg).run(48)
    r_deft = simulate_deft(t, plans)
    rows = [("deft", r_deft)]
    for name, mk in ALL_BASELINES.items():
        rows.append((name, simulate_baseline(t, mk(t))))
    base = dict(rows)["pytorch-ddp"].iteration_time
    for name, r in rows:
        print(f"{name:16s} {r.iteration_time*1e3:8.1f}  "
              f"{r.bubble_fraction:7.2f}  {r.updates_per_iteration:8.2f}  "
              f"{base/r.iteration_time:6.2f}x")


if __name__ == "__main__":
    main()
