#!/usr/bin/env python
"""Lint: no module under src/repro may call the legacy planner entry
points.  All scheduling flows through the unified ``Planner`` facade
(``repro.core.deft.Planner`` / ``PlanRequest``); the legacy functions
(``feedback_solve``, ``feedback_solve_candidates``, ``solve_schedule``,
``plan_deft``) survive only as deprecated shims for out-of-tree callers
and the tests that pin shim equivalence.

Also linted: hard-coded f32 wire-byte math.  ``Bucket.bytes_fp32`` is a
deprecated shim for ``Bucket.wire_bytes(policy)``, and any literal
``4 * n_elements`` (either operand order) outside ``core/bucket.py``
bypasses the per-bucket PrecisionPolicy — bytes on the wire are a
function of the layout's precision, not of the element count alone.

AST-based so prose (docstrings, comments) never trips it: only actual
``import``s of the legacy names and ``Name``/``Attribute`` references in
code are flagged.  ``core/deft.py`` (defines the shims) and
``core/__init__.py`` (re-exports them) are exempt.
"""
from __future__ import annotations

import ast
import pathlib
import sys

LEGACY = {
    "feedback_solve",
    "feedback_solve_candidates",
    "solve_schedule",
    "plan_deft",
}
LEGACY_BYTES = {"bytes_fp32"}
EXEMPT = {"core/deft.py", "core/__init__.py"}
BYTES_EXEMPT = {"core/bucket.py"}


def _is_n_elements(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Attribute) and node.attr == "n_elements"
    ) or (isinstance(node, ast.Name) and node.id == "n_elements")


def _is_four(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and node.value == 4


def violations(path: pathlib.Path, rel: str):
    tree = ast.parse(path.read_text(), filename=rel)
    bytes_ok = rel in BYTES_EXEMPT
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name in LEGACY:
                    yield node.lineno, f"imports {alias.name}"
        elif isinstance(node, ast.Name) and node.id in LEGACY:
            yield node.lineno, f"references {node.id}"
        elif isinstance(node, ast.Attribute) and node.attr in LEGACY:
            yield node.lineno, f"references .{node.attr}"
        elif (
            isinstance(node, ast.Attribute)
            and node.attr in LEGACY_BYTES
            and not bytes_ok
        ):
            yield node.lineno, (
                f"references .{node.attr} (use wire_bytes(policy))"
            )
        elif (
            isinstance(node, ast.BinOp)
            and isinstance(node.op, ast.Mult)
            and not bytes_ok
            and (
                (_is_four(node.left) and _is_n_elements(node.right))
                or (_is_four(node.right) and _is_n_elements(node.left))
            )
        ):
            yield node.lineno, (
                "hard-codes 4 * n_elements (use wire_bytes(policy))"
            )


def main() -> int:
    root = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"
    bad = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        if rel in EXEMPT:
            continue
        for lineno, what in violations(path, rel):
            bad.append(f"src/repro/{rel}:{lineno}: {what}")
    if bad:
        print("legacy planner entry points are shim-only; use "
              "Planner/PlanRequest (core/deft.py):", file=sys.stderr)
        for b in bad:
            print(f"  {b}", file=sys.stderr)
        return 1
    print(f"check_no_legacy_planner: OK ({len(LEGACY)} names, "
          f"exempt: {', '.join(sorted(EXEMPT))})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
