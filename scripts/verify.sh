#!/usr/bin/env bash
# Tier-1 verification: the fast deterministic suite + a dry-run smoke.
#
# The default pytest run excludes the `slow` / `multidevice` markers
# (full multi-device subprocess equivalence runs, ~10 min) so that the
# everyday gate stays fast; run `pytest -m slow` explicitly before
# touching shard_map/collective code.
#
#   scripts/verify.sh          # tests + dry-run smoke
#   scripts/verify.sh --fast   # tests only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests (excluding slow/multidevice) =="
python -m pytest -q -m "not slow and not multidevice"

if [[ "${1:-}" != "--fast" ]]; then
  echo "== dry-run smoke (compile-only, no model memory) =="
  # default (ddp) mode: --mode deft needs jax >= 0.5 on the production
  # mesh (partial-manual SPMD CHECK on old jaxlib — DESIGN.md §6)
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
fi

echo "verify.sh: OK"
