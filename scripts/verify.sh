#!/usr/bin/env bash
# Tier-1 verification: the fast deterministic suite + a dry-run smoke.
#
# The default pytest run excludes the `slow` / `multidevice` markers
# (full multi-device subprocess equivalence runs, ~10 min) so that the
# everyday gate stays fast; run `pytest -m slow` explicitly before
# touching shard_map/collective code.
#
#   scripts/verify.sh               # tests + dry-run smoke
#   scripts/verify.sh --fast        # tests only
#   scripts/verify.sh --smoke       # smoke benchmarks + BENCH schema check
#                                   # (the CI benchmark job; no test run)
#   scripts/verify.sh --multidevice # the multidevice-marked subprocess
#                                   # suite on forced host devices (the
#                                   # CI multidevice job)
#   scripts/verify.sh --chaos       # fault-injection recovery suite:
#                                   # elastic scale-down/up on forced
#                                   # devices (the CI chaos-smoke job)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

mode="${1:-}"

if [[ "$mode" == "--smoke" ]]; then
  echo "== smoke benchmarks (BENCH_*.json + schema check) =="
  python benchmarks/run.py --smoke
  python scripts/check_bench_schema.py
  echo "== traced smoke loop (trace_smoke.json artifact) =="
  # exercises the live trace path end to end: adaptive drop -> replan ->
  # hot-swap, exported as a Perfetto-loadable Chrome trace (§11)
  python -m repro.launch.train --smoke --scheduler deft --steps 56 \
    --adapt --adapt-repartition --adapt-drop-step 12 \
    --adapt-drop-scale 6.0 --trace trace_smoke.json
  python - <<'PY'
import json
kinds = {e.get("cat") for e in json.load(open("trace_smoke.json"))["traceEvents"]}
need = {"step", "phase", "collective-group", "swap-compile",
        "swap-install", "replan", "repack"}
missing = need - kinds
assert not missing, f"trace_smoke.json missing span kinds: {missing}"
print(f"trace_smoke.json OK ({sorted(k for k in kinds if k)})")
PY
  echo "== precision smoke loop (wire quantization end to end, §13) =="
  # forced int8 wire + bf16sr master -> quantized layout -> traced
  # quantized collectives ('auto' would keep f32 here: the smoke
  # model's us-scale comm sits under the collective latency floor, so
  # the ladder rightly finds no gain).  The trace must carry per-group
  # wire_bytes/precision attrs so the wire-bytes attribution
  # (obs.wire_bytes_report) can close the loop
  python -m repro.launch.train --smoke --scheduler deft --steps 12 \
    --wire-precision int8 --master-dtype bf16sr \
    --trace trace_precision.json
  python - <<'PY'
import json
evs = json.load(open("trace_precision.json"))["traceEvents"]
coll = [e for e in evs if e.get("cat") == "collective-group"]
assert coll, "trace_precision.json has no collective-group spans"
tagged = [e for e in coll if "wire_bytes" in e.get("args", {})]
assert tagged, "collective-group spans carry no wire_bytes attrs"
prec = {e["args"].get("precision") for e in tagged}
print(f"trace_precision.json OK ({len(tagged)} quantized collective "
      f"spans, precisions={sorted(p for p in prec if p)})")
PY
  echo "verify.sh --smoke: OK"
  exit 0
fi

if [[ "$mode" == "--multidevice" ]]; then
  echo "== multi-device suite (forced host devices) =="
  # the tests spawn subprocesses that force their own device counts;
  # the outer XLA_FLAGS only covers any future in-process cases
  rc=0
  XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m pytest -q -m multidevice || rc=$?
  if [[ "$rc" -ne 0 ]]; then
    echo "verify.sh: multidevice tests FAILED (exit $rc)" >&2
    exit "$rc"
  fi
  echo "verify.sh --multidevice: OK"
  exit 0
fi

if [[ "$mode" == "--chaos" ]]; then
  echo "== chaos suite (fault injection -> elastic recovery) =="
  # the chaos tests spawn subprocesses that force their own device
  # counts, same pattern as --multidevice
  rc=0
  python -m pytest -q -m chaos || rc=$?
  if [[ "$rc" -ne 0 ]]; then
    echo "verify.sh: chaos tests FAILED (exit $rc)" >&2
    exit "$rc"
  fi
  echo "verify.sh --chaos: OK"
  exit 0
fi

echo "== lint: no legacy planner entry points outside core =="
python scripts/check_no_legacy_planner.py

echo "== tier-1 tests (excluding slow/multidevice) =="
# run under an if so `set -e` cannot short-circuit before we report,
# then propagate pytest's exit code verbatim (CI must see the status)
rc=0
python -m pytest -q -m "not slow and not multidevice" || rc=$?
if [[ "$rc" -ne 0 ]]; then
  echo "verify.sh: tier-1 tests FAILED (exit $rc)" >&2
  exit "$rc"
fi

if [[ "$mode" != "--fast" ]]; then
  echo "== dry-run smoke (compile-only, no model memory) =="
  # default (ddp) mode: --mode deft needs jax >= 0.5 on the production
  # mesh (partial-manual SPMD CHECK on old jaxlib — DESIGN.md §6).
  # Output goes to a scratch dir: the checked-in experiments/dryrun
  # artifacts are updated deliberately, not by every verify run (CI
  # asserts the tree is clean afterwards).
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k \
    --out "$(mktemp -d)"
fi

echo "verify.sh: OK"
