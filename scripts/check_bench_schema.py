#!/usr/bin/env python
"""Validate BENCH_*.json files against benchmarks/bench_schema.py.

    python scripts/check_bench_schema.py [FILE ...]

With no arguments checks every schema-registered BENCH file in the repo
root (the checked-in perf trajectory).  Exits 1 listing every missing /
malformed key, so CI fails loudly when a benchmark emitter drifts.
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)

from benchmarks.bench_schema import SCHEMAS, validate_file  # noqa: E402


def main(argv) -> int:
    paths = argv or [os.path.join(_ROOT, name) for name in sorted(SCHEMAS)]
    failures = 0
    for path in paths:
        errors = validate_file(path)
        if errors:
            failures += 1
            for e in errors:
                print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        else:
            print(f"{path}: OK")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
