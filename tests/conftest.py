"""Shared fixtures.  NOTE: no XLA_FLAGS device-count forcing here — tests
run with the real (single) CPU device; multi-device behaviour is covered
by the subprocess test in test_multidevice.py and by the dry-run."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

import repro  # noqa: F401  (activates the jax version-compat shims)

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def single_mesh():
    """1x1 (data, model) mesh — exercises the full pjit/shard_map machinery
    on one device (psum over a size-1 axis is an identity with the same
    graph structure)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
